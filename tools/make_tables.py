"""Generate EXPERIMENTS.md tables from dry-run artifacts."""
import json, os, sys
import numpy as np
sys.path.insert(0, 'src')
import warnings; warnings.filterwarnings('ignore')
from repro.configs import get_config
from repro.configs.shapes import SHAPES
from repro.hw import roofline as RL

def fmt(x):
    return f"{x:.2e}"

def main():
    art_dir = sys.argv[1] if len(sys.argv) > 1 else 'artifacts/dryrun'
    arts = {}
    for f in sorted(os.listdir(art_dir)):
        d = json.load(open(os.path.join(art_dir, f)))
        arts[(d['arch'], d['shape'], d['mesh'])] = d

    # --- dry-run table (both meshes) ---
    print('## table:dryrun')
    print('| arch | shape | mesh | status | params/dev | temp/dev | HLO dotF/dev | coll B/dev | compile |')
    print('|---|---|---|---|---|---|---|---|---|')
    for (a, s, m), d in sorted(arts.items()):
        if d['status'] == 'skipped':
            print(f"| {a} | {s} | {m} | skipped (full attention) | | | | | |")
            continue
        nd = 512 if 'multipod' in m else 256
        pdev = d['param_bytes_global'] / nd
        w = d['weighted']
        print(f"| {a} | {s} | {m} | ok | {pdev/2**30:.2f} GiB | "
              f"{d['temp_size_in_bytes']/2**30:.1f} GiB* | {fmt(w['dot_flops_per_device'])} | "
              f"{fmt(w['wire_bytes_per_device'])} | {d['compile_s']:.0f}s |")

    # --- roofline table (single pod) ---
    print()
    print('## table:roofline')
    print('| arch | shape | compute s | memory s | collective s | dominant | MODEL_FLOPS | MODEL/HLO | roofline frac |')
    print('|---|---|---|---|---|---|---|---|---|')
    rows = []
    for (a, s, m), d in sorted(arts.items()):
        if d['status'] != 'ok' or m != 'pod_16x16':
            continue
        cfg = get_config(a); cell = SHAPES[s]
        mesh = {p.split('=')[0].strip(): int(p.split('=')[1]) for p in d['mesh_desc'].split(' x ')}
        r = RL.analyze_cell(cfg, cell.kind, cell.seq, cell.global_batch, mesh, d)
        nd = int(np.prod(list(mesh.values())))
        frac = RL.roofline_fraction(r, n_dev=nd)
        rows.append((a, s, r, frac))
        print(f"| {a} | {s} | {fmt(r.compute_s)} | {fmt(r.memory_s)} | {fmt(r.collective_s)} "
              f"| **{r.dominant}** | {fmt(r.model_flops)} | {r.usefulness:.2f} | {frac:.3f} |")
    # summary
    doms = {}
    for a, s, r, frac in rows:
        doms.setdefault(r.dominant, []).append((a, s, frac))
    print()
    print('## summary')
    for d, cells in doms.items():
        print(f"- {d}-bound: {len(cells)} cells")
    worst = sorted(rows, key=lambda x: x[-1])[:5]
    print('- worst roofline fractions:', [(a, s, round(f, 4)) for a, s, _, f in worst])
    best = sorted(rows, key=lambda x: -x[-1])[:5]
    print('- best roofline fractions:', [(a, s, round(f, 4)) for a, s, _, f in best])

main()
