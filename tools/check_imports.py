#!/usr/bin/env python
"""Import every ``repro.*`` module; exit nonzero on any failure.

The dependency-light contract: the whole package must import with only
jax + numpy + msgpack installed (hypothesis and zstandard are optional,
guarded at their use sites).  Run from anywhere:

    python tools/check_imports.py
"""
import importlib
import os
import sys
import traceback

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)

# repro.launch.dryrun/autotune pin the placeholder device count via
# XLA_FLAGS at import time; keep it tiny for the import check.
os.environ.setdefault("REPRO_DRYRUN_DEVICES", "2")

# Modules that must exist (guards against packages being dropped or renamed
# without this check noticing — the walk below only sees what's on disk).
REQUIRED = (
    "repro.compiler",
    "repro.compiler.cli",
    "repro.compiler.executor",
    "repro.compiler.executor.base",
    "repro.compiler.executor.pool",
    "repro.compiler.executor.remote",
    "repro.compiler.executor.stub",
    "repro.compiler.executor.wire",
    "repro.compiler.executor.worker",
    "repro.compiler.netopt",
    "repro.compiler.netopt.genetic",
    "repro.compiler.netopt.hwspace",
    "repro.compiler.netopt.loop",
    "repro.compiler.netopt.partition",
    "repro.compiler.netopt.report",
    "repro.compiler.oracle",
    "repro.compiler.records",
    "repro.compiler.report",
    "repro.compiler.serve_tune",
    "repro.compiler.session",
    "repro.compiler.surrogate_store",
    "repro.compiler.task",
    "repro.compiler.zoo",
    "repro.core.tuner",
    "repro.core.baselines",
    "repro.launch.autotune",
    "repro.obs",
    "repro.obs.export",
    "repro.obs.log",
    "repro.obs.metrics",
    "repro.obs.serve",
    "repro.obs.trace",
)


def iter_modules():
    pkg_root = os.path.join(SRC, "repro")
    for dirpath, _dirnames, filenames in os.walk(pkg_root):
        for fname in sorted(filenames):
            if not fname.endswith(".py"):
                continue
            rel = os.path.relpath(os.path.join(dirpath, fname), SRC)
            mod = rel[:-3].replace(os.sep, ".")
            if mod.endswith(".__init__"):
                mod = mod[: -len(".__init__")]
            yield mod


def main() -> int:
    failures = []
    modules = sorted(set(iter_modules()))
    missing = [m for m in REQUIRED if m not in modules]
    if missing:
        print(f"MISSING required modules: {missing}", file=sys.stderr)
        return 1
    for mod in modules:
        try:
            importlib.import_module(mod)
        except Exception:
            failures.append((mod, traceback.format_exc()))
            print(f"FAIL  {mod}")
        else:
            print(f"ok    {mod}")
    print(f"\n{len(modules) - len(failures)}/{len(modules)} modules import "
          "cleanly")
    for mod, tb in failures:
        print(f"\n--- {mod} ---\n{tb}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
