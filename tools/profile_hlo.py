"""Dev tool: list the largest per-partition tensors in a dry-run cell's HLO.

    PYTHONPATH=src python tools/profile_hlo.py --arch jamba-1.5-large-398b \
        --shape train_4k --multipod --min-gb 0.3
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                           + os.environ.get("REPRO_DRYRUN_DEVICES", "512"))
import argparse
import re

import jax

from repro.configs import get_config
from repro.configs.shapes import SHAPES, input_specs
from repro.launch.mesh import make_dryrun_mesh
from repro.models import transformer as T
from repro.train import steps as ST

DT = {"f32": 4, "bf16": 2, "pred": 1, "s32": 4, "u32": 4, "s8": 1, "f16": 2}


def lower_cell(arch, shape_name, multipod, grad_accum=1):
    cfg = get_config(arch)
    mesh = make_dryrun_mesh(multi_pod=multipod)
    abstract = T.abstract_params(jax.random.PRNGKey(0), cfg)
    shape = SHAPES[shape_name]
    spec = input_specs(cfg, shape)
    with mesh:
        if shape.kind == "train":
            tc = ST.TrainConfig(grad_accum=grad_accum)
            jitted, _ = ST.build_sharded_train_step(
                cfg, tc, mesh, abstract_params=abstract)
            opt = ST.make_optimizer(tc)
            lowered = jitted(spec).lower(
                abstract, jax.eval_shape(opt.init, abstract), spec)
        elif shape.kind == "prefill":
            jitted, _ = ST.build_sharded_prefill(
                cfg, mesh, max_len=shape.seq, abstract_params=abstract)
            lowered = jitted(spec).lower(abstract, spec)
        else:
            jitted, _ = ST.build_sharded_serve_step(
                cfg, mesh, abstract_params=abstract,
                abstract_cache=spec["cache"], batch=shape.global_batch,
                max_len=shape.seq)
            lowered = jitted.lower(abstract, spec["cache"], spec["tokens"])
        return lowered.compile()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--min-gb", type=float, default=0.3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--dump", default=None)
    args = ap.parse_args()

    compiled = lower_cell(args.arch, args.shape, args.multipod,
                          args.grad_accum)
    hlo = compiled.as_text()
    if args.dump:
        open(args.dump, "w").write(hlo)
    sizes = {}
    for m in re.finditer(
            r"%([\w\.\-]+) = ([a-z0-9]+)\[([0-9,]+)\]\{[^}]*\} "
            r"([\w\-\.]+)\(", hlo):
        name, dt, dims, op = m.groups()
        if dt not in DT:
            continue
        n = 1
        for d in dims.split(","):
            n *= int(d)
        b = n * DT[dt]
        if b < args.min_gb * 1e9:
            continue
        key = (op, dt, dims)
        s = sizes.get(key, [0, 0])
        s[0] += b
        s[1] += 1
        sizes[key] = s
    for (op, dt, dims), (b, c) in sorted(sizes.items(),
                                         key=lambda kv: -kv[1][0])[:20]:
        print(f"{b/1e9:9.2f} GB  x{c:4d}  {op:24s} {dt}[{dims}]")
    mem = compiled.memory_analysis()
    print("temp GB:", mem.temp_size_in_bytes / 1e9,
          " args GB:", mem.argument_size_in_bytes / 1e9)


if __name__ == "__main__":
    main()
