#!/usr/bin/env python
"""Diff two saved traces of the same bench: where did the time go?

Compares per-phase (``cat == "phase"``) and per-category wall-time
totals plus the overall wall extent between an *old* and a *new* trace —
either format ``repro.obs`` writes (Chrome-trace JSON or raw JSONL).
Sampled traces stay honest: dropped spans' exact summed seconds (from
the trace's sampling metadata) are folded back into category totals
before diffing.

CI regression gate::

    python tools/trace_diff.py old.json new.json --fail-on-regression 25

exits non-zero when any compared total regressed (grew) by more than
25% — rows below the ``--min-s`` noise floor (default 0.05 s) are
reported but never fail the gate, so micro-jitter on near-zero phases
cannot flap CI.

Stdlib only (like everything under ``repro.obs`` and its tools).
"""
from __future__ import annotations

import argparse
import importlib.util
import os
import sys
from typing import Dict, List, Tuple

_HERE = os.path.dirname(os.path.abspath(__file__))


def _load_trace_summary():
    spec = importlib.util.spec_from_file_location(
        "trace_summary", os.path.join(_HERE, "trace_summary.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def load_totals(path: str) -> Dict[str, Dict[str, float]]:
    """``{"phase": {...}, "category": {...}, "wall": {"extent_s": s}}``
    for one trace file (sampling-corrected)."""
    ts = _load_trace_summary()
    events = ts.load_events(path)
    sampling = ts.sampling_info(events)
    return {
        "phase": ts.phase_totals(events),
        "category": ts.category_totals(events, sampling),
        "wall": {"extent_s": ts.wall_extent_s(events)},
    }


def diff_rows(old: Dict[str, float], new: Dict[str, float]
              ) -> List[Tuple[str, float, float, float]]:
    """``(name, old_s, new_s, delta_pct)`` over the union of keys;
    delta_pct is +inf for a new row with no old baseline."""
    rows = []
    for name in sorted(set(old) | set(new)):
        a, b = float(old.get(name, 0.0)), float(new.get(name, 0.0))
        pct = ((b - a) / a * 100.0) if a > 0 else (
            float("inf") if b > 0 else 0.0)
        rows.append((name, a, b, pct))
    return rows


def render(title: str, rows: List[Tuple[str, float, float, float]]) -> str:
    lines = [title, f"  {'name':<28s} {'old_s':>10s} {'new_s':>10s} "
                    f"{'delta':>8s}"]
    for name, a, b, pct in rows:
        d = "   new" if pct == float("inf") else f"{pct:+7.1f}%"
        lines.append(f"  {name:<28s} {a:10.3f} {b:10.3f} {d:>8s}")
    return "\n".join(lines)


def regressions(rows: List[Tuple[str, float, float, float]],
                threshold_pct: float, min_s: float
                ) -> List[Tuple[str, float, float, float]]:
    """Rows that *grew* past the threshold — only rows whose old total
    clears the noise floor can fail the gate."""
    return [r for r in rows
            if r[1] >= min_s and r[3] != float("inf")
            and r[3] > threshold_pct]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("old", help="baseline trace (.json or .jsonl)")
    ap.add_argument("new", help="candidate trace (.json or .jsonl)")
    ap.add_argument("--fail-on-regression", type=float, default=None,
                    metavar="PCT",
                    help="exit 1 if any phase/category/wall total grew "
                         "by more than PCT percent")
    ap.add_argument("--min-s", type=float, default=0.05,
                    help="noise floor: rows whose old total is below this "
                         "many seconds never fail the gate (default 0.05)")
    args = ap.parse_args(argv)
    old, new = load_totals(args.old), load_totals(args.new)
    bad: List[Tuple[str, str, float, float, float]] = []
    for section, title in (("phase", "phases (cat=phase):"),
                           ("category", "categories:"),
                           ("wall", "wall extent:")):
        rows = diff_rows(old[section], new[section])
        if not rows:
            continue
        print(render(title, rows))
        if args.fail_on_regression is not None:
            bad += [(section, *r) for r in regressions(
                rows, args.fail_on_regression, args.min_s)]
    if bad:
        print(f"\nREGRESSION: {len(bad)} total(s) grew more than "
              f"{args.fail_on_regression:g}% (noise floor {args.min_s:g}s):")
        for section, name, a, b, pct in bad:
            print(f"  [{section}] {name}: {a:.3f}s -> {b:.3f}s "
                  f"({pct:+.1f}%)")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
