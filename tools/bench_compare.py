#!/usr/bin/env python
"""Validate and compare two ``BENCH_*.json`` artifacts; CI regression gate.

Both inputs must be well-formed ``repro-bench/1`` or ``/2`` documents
(the validation rules here deliberately mirror
``benchmarks/tuning_runs.py::validate_bench_doc`` — this tool stays
stdlib-only and importable without the benchmarks' jax dependencies, so
it re-states the contract instead of importing it; keep the two in
sync).  It reports entry-wise metric deltas, including the ``/2``
``phase_times`` nested block (flattened as ``phase_times.<name>``), and
can gate CI::

    python tools/bench_compare.py BENCH_old.json BENCH_new.json \
        --fail-on-regression 20

A metric *regresses* directionally: lower is better for latency-like
names (``*_s``, ``*latency*``, ``*time*``), higher is better for
``*speedup*``/``*x``/``*gflops*``/``*per_sec*`` names; metrics with no
recognized direction (counts, budgets) are reported but never gated.
``--keys`` restricts the comparison to named metrics.
"""
from __future__ import annotations

import argparse
import json
import math
import numbers
import sys
from typing import Dict, List, Optional, Tuple

BENCH_SCHEMAS = ("repro-bench/1", "repro-bench/2")

LOWER_BETTER = ("latency", "time", "_s")
HIGHER_BETTER = ("speedup", "gflops", "per_sec", "throughput", "_x")


def _check_metric(k, v, where: str) -> None:
    if not isinstance(k, str):
        raise ValueError(f"{where} name {k!r} is not a str")
    if isinstance(v, bool) or not isinstance(v, numbers.Real) \
            or not math.isfinite(float(v)):
        raise ValueError(f"{where} {k!r} must be a finite float, got {v!r}")


def validate(doc: Dict) -> Dict:
    """Standalone mirror of ``validate_bench_doc``: schema in
    ``repro-bench/1|2``, nonempty str ``bench``/``git_rev``, numeric
    ``created_unix``, dict ``config``, nonempty flat finite-float
    ``metrics`` — with ``metrics["phase_times"]`` the one sanctioned
    nested (flat name -> finite seconds) block, ``/2`` only."""
    if not isinstance(doc, dict):
        raise ValueError(f"bench doc must be a dict, got {type(doc)}")
    if doc.get("schema") not in BENCH_SCHEMAS:
        raise ValueError(f"bench schema {doc.get('schema')!r} not in "
                         f"{BENCH_SCHEMAS!r}")
    if not doc.get("bench") or not isinstance(doc["bench"], str):
        raise ValueError("bench doc needs a nonempty str 'bench' name")
    if not isinstance(doc.get("created_unix"), numbers.Real):
        raise ValueError("bench doc needs a numeric 'created_unix'")
    if not doc.get("git_rev") or not isinstance(doc["git_rev"], str):
        raise ValueError("bench doc needs a nonempty str 'git_rev'")
    if not isinstance(doc.get("config"), dict):
        raise ValueError("bench doc needs a dict 'config'")
    metrics = doc.get("metrics")
    if not isinstance(metrics, dict) or not metrics:
        raise ValueError("bench doc needs a nonempty 'metrics' dict")
    for k, v in metrics.items():
        if (k == "phase_times" and doc["schema"] == "repro-bench/2"
                and isinstance(v, dict)):
            for pk, pv in v.items():
                _check_metric(pk, pv, "phase_times entry")
            continue
        _check_metric(k, v, "metric")
    return doc


def load(path: str) -> Dict:
    with open(path) as f:
        return validate(json.load(f))


def flat_metrics(doc: Dict) -> Dict[str, float]:
    """Metrics with the ``phase_times`` block flattened to dotted keys."""
    out: Dict[str, float] = {}
    for k, v in doc["metrics"].items():
        if isinstance(v, dict):
            for pk, pv in v.items():
                out[f"{k}.{pk}"] = float(pv)
        else:
            out[k] = float(v)
    return out


def direction(name: str) -> Optional[int]:
    """-1 = lower is better, +1 = higher is better, None = ungated.
    Higher-better suffixes win ties (``speedup_x`` ends in ``_x`` AND
    contains ``speedup`` — both agree; ``throughput_per_sec`` must not
    be dragged to lower-better by a ``_s``-ish match)."""
    low = name.lower()
    base = low.split(".")[-1]
    if any(t in low for t in HIGHER_BETTER):
        return +1
    if any(t in low for t in LOWER_BETTER[:-1]) or base.endswith("_s"):
        return -1
    return None


def compare(old: Dict, new: Dict, keys: Optional[List[str]] = None
            ) -> List[Tuple[str, Optional[float], Optional[float],
                            Optional[float], Optional[int]]]:
    """``(name, old_v, new_v, delta_pct, direction)`` over the union of
    flattened metric names (restricted to ``keys`` when given)."""
    a, b = flat_metrics(old), flat_metrics(new)
    names = sorted(set(a) | set(b))
    if keys:
        missing = [k for k in keys if k not in set(a) | set(b)]
        if missing:
            raise KeyError(f"--keys not in either artifact: {missing}")
        names = [n for n in names if n in set(keys)]
    rows = []
    for n in names:
        va, vb = a.get(n), b.get(n)
        pct = None
        if va is not None and vb is not None and va != 0:
            pct = (vb - va) / abs(va) * 100.0
        rows.append((n, va, vb, pct, direction(n)))
    return rows


def regressions(rows, threshold_pct: float):
    """Directional gate: a row fails when its metric moved in the *bad*
    direction by more than the threshold."""
    bad = []
    for name, va, vb, pct, sign in rows:
        if pct is None or sign is None:
            continue
        worsened = pct if sign < 0 else -pct
        if worsened > threshold_pct:
            bad.append((name, va, vb, pct))
    return bad


def _fmt(v: Optional[float]) -> str:
    return "-" if v is None else f"{v:.6g}"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("old", help="baseline BENCH_*.json")
    ap.add_argument("new", help="candidate BENCH_*.json")
    ap.add_argument("--keys", nargs="+", default=None,
                    help="restrict the comparison to these metric names "
                         "(phase_times entries as phase_times.<name>)")
    ap.add_argument("--fail-on-regression", type=float, default=None,
                    metavar="PCT",
                    help="exit 1 if any direction-aware metric worsened "
                         "by more than PCT percent")
    args = ap.parse_args(argv)
    old, new = load(args.old), load(args.new)
    if old["bench"] != new["bench"]:
        print(f"note: comparing different benches "
              f"{old['bench']!r} -> {new['bench']!r}")
    rows = compare(old, new, args.keys)
    print(f"{'metric':<36s} {'old':>12s} {'new':>12s} {'delta':>9s}  dir")
    for name, va, vb, pct, sign in rows:
        d = "-" if pct is None else f"{pct:+.1f}%"
        arrow = {None: " ", -1: "v", +1: "^"}[sign]
        print(f"{name:<36s} {_fmt(va):>12s} {_fmt(vb):>12s} {d:>9s}  "
              f"{arrow}")
    if args.fail_on_regression is not None:
        bad = regressions(rows, args.fail_on_regression)
        if bad:
            print(f"\nREGRESSION: {len(bad)} metric(s) worsened more than "
                  f"{args.fail_on_regression:g}%:")
            for name, va, vb, pct in bad:
                print(f"  {name}: {_fmt(va)} -> {_fmt(vb)} ({pct:+.1f}%)")
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
