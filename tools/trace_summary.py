#!/usr/bin/env python
"""Break a saved trace's wall clock into named phases and categories.

Reads either form ``repro.obs`` writes — Chrome-trace JSON
(``--trace run.json``) or raw JSONL (``run.jsonl``) — and reports where
the run's time went:

* per-**phase** totals (netopt ``phase:seed`` / ``phase:cs`` /
  ``phase:refine`` / ``phase:hw-refit`` ... spans), plus their
  union-of-intervals coverage of the trace's wall extent;
* per-**category** totals (measure vs surrogate-refit vs mappo-update vs
  executor-wait vs executor dispatch overhead);
* per-**tid** measure totals — for remote runs, one row per worker
  daemon endpoint.

Usage::

    python tools/trace_summary.py artifacts/run.trace.json

Stdlib only (like everything under ``repro.obs``).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, Iterable, List, Tuple


def load_events(path: str) -> List[Dict[str, object]]:
    """Normalize either trace format to rows with seconds-valued
    ``start_s``/``dur_s`` (duration spans only carry ``dur_s > 0``)."""
    with open(path) as f:
        text = f.read()
    # Both forms start with "{": a Chrome trace is ONE JSON object with
    # "traceEvents"; anything else (including a whole-file parse failure)
    # is one raw event object per line.
    try:
        doc = json.loads(text)
    except ValueError:
        doc = None
    if isinstance(doc, dict) and "traceEvents" in doc:
        rows = [{
            "name": ev.get("name", "?"),
            "cat": ev.get("cat", ""),
            "ph": ev.get("ph", "X"),
            "tid": ev.get("tid", ""),
            "start_s": float(ev.get("ts", 0.0)) / 1e6,
            "dur_s": float(ev.get("dur", 0.0)) / 1e6,
            "args": ev.get("args", {}),
        } for ev in doc["traceEvents"]]
        # a sampled tracer's kept/dropped bookkeeping rides in otherData;
        # surface it as the same "M" metadata row the JSONL form carries
        sampling = (doc.get("otherData") or {}).get("sampling")
        if sampling:
            rows.append({"name": "sampling", "cat": "", "ph": "M",
                         "tid": "", "start_s": 0.0, "dur_s": 0.0,
                         "args": sampling})
        return rows
    rows = []
    for line in text.splitlines():
        line = line.strip()
        if not line:
            continue
        ev = json.loads(line)
        rows.append({
            "name": ev.get("name", "?"),
            "cat": ev.get("cat", ""),
            "ph": ev.get("ph", "X"),
            "tid": ev.get("tid", ""),
            "start_s": float(ev.get("wall_s", 0.0)),
            "dur_s": float(ev.get("dur", 0.0)),
            "args": ev.get("args", {}),
        })
    return rows


def sampling_info(events: Iterable[Dict[str, object]]) -> Dict[str, object]:
    """The trace's span-sampling bookkeeping (``{}`` for unsampled
    traces): ``{"sample_rate": r, "cats": {cat: {kept, dropped,
    dropped_dur_s}}}``."""
    for e in events:
        if e.get("ph") == "M" and e.get("name") == "sampling":
            return dict(e.get("args") or {})
    return {}


def union_seconds(spans: Iterable[Dict[str, object]]) -> float:
    """Total seconds covered by the union of span intervals (overlap
    counted once) — the honest coverage number for nested/parallel
    spans."""
    ivals: List[Tuple[float, float]] = sorted(
        (s["start_s"], s["start_s"] + s["dur_s"]) for s in spans
        if s["dur_s"] > 0)
    total = 0.0
    cur_a = cur_b = None
    for a, b in ivals:
        if cur_b is None or a > cur_b:
            if cur_b is not None:
                total += cur_b - cur_a
            cur_a, cur_b = a, b
        elif b > cur_b:
            cur_b = b
    if cur_b is not None:
        total += cur_b - cur_a
    return total


def _spans(events: Iterable[Dict[str, object]]) -> List[Dict[str, object]]:
    return [e for e in events if e["ph"] == "X" and e["dur_s"] > 0]


def phase_totals(events: Iterable[Dict[str, object]]) -> Dict[str, float]:
    out: Dict[str, float] = {}
    for s in _spans(events):
        if s["cat"] == "phase":
            out[s["name"]] = out.get(s["name"], 0.0) + s["dur_s"]
    return out


def category_totals(events: Iterable[Dict[str, object]],
                    sampling: Dict[str, object] = None
                    ) -> Dict[str, float]:
    """Summed seconds per category.  With a sampled trace's bookkeeping
    passed in, the dropped spans' exact summed duration is added back so
    totals stay honest (the *count* of spans is reduced; their seconds
    are not)."""
    out: Dict[str, float] = {}
    for s in _spans(events):
        cat = s["cat"] or "default"
        out[cat] = out.get(cat, 0.0) + s["dur_s"]
    for cat, info in ((sampling or {}).get("cats") or {}).items():
        dropped = float(info.get("dropped_dur_s", 0.0))
        if dropped:
            out[cat] = out.get(cat, 0.0) + dropped
    return out


def tid_totals(events: Iterable[Dict[str, object]],
               cat: str = "measure") -> Dict[str, float]:
    out: Dict[str, float] = {}
    for s in _spans(events):
        if s["cat"] == cat:
            out[str(s["tid"])] = out.get(str(s["tid"]), 0.0) + s["dur_s"]
    return out


def wall_extent_s(events: Iterable[Dict[str, object]]) -> float:
    spans = _spans(events)
    if not spans:
        return 0.0
    t0 = min(s["start_s"] for s in spans)
    t1 = max(s["start_s"] + s["dur_s"] for s in spans)
    return t1 - t0


def _table(title: str, rows: Dict[str, float], wall: float) -> str:
    lines = [title]
    for name, sec in sorted(rows.items(), key=lambda kv: -kv[1]):
        pct = 100.0 * sec / wall if wall else 0.0
        lines.append(f"  {name:<28s} {sec:10.3f} s  {pct:5.1f}%")
    return "\n".join(lines)


def summarize(path: str) -> str:
    events = load_events(path)
    spans = _spans(events)
    wall = wall_extent_s(events)
    phases = phase_totals(events)
    sampling = sampling_info(events)
    parts = [
        f"trace: {path}",
        f"spans: {len(spans)}   wall extent: {wall:.3f} s",
    ]
    if sampling:
        dropped = sum(int(c.get("dropped", 0))
                      for c in (sampling.get("cats") or {}).values())
        parts.append(f"sampled trace (rate={sampling.get('sample_rate')}):"
                     f" {dropped} spans dropped; their seconds are"
                     f" included in category totals")
    if phases:
        covered = union_seconds(
            [s for s in spans if s["cat"] == "phase"])
        pct = 100.0 * covered / wall if wall else 0.0
        parts.append(_table("phases (cat=phase):", phases, wall))
        parts.append(f"  phase union coverage: {covered:.3f} s"
                     f" ({pct:.1f}% of wall extent)")
    parts.append(_table("categories:", category_totals(events, sampling),
                        wall))
    meas = tid_totals(events, "measure")
    if len(meas) > 1:
        parts.append(_table("measure seconds by tid/endpoint:", meas, wall))
    return "\n".join(parts)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="trace file (.json Chrome trace or .jsonl)")
    args = ap.parse_args(argv)
    print(summarize(args.trace))
    return 0


if __name__ == "__main__":
    sys.exit(main())
