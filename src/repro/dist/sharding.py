"""Sharding rules: ArchConfig + Mesh + ShardingRules -> NamedSharding trees.

This module is the single place where parameter/optimizer/batch/cache
placement is decided.  Everything downstream (``repro.train.steps``, the
trainer, the dry-run estimator, the shard-space autotuner) consumes the
functional API here and never hand-writes a ``PartitionSpec``.

Layout policy (Megatron-style TP + optional ZeRO-3 + expert parallelism):

  * **Tensor parallel** (``rules.tp_axis``, default ``"model"``):
      - attention qkv projections are column-parallel (output features
        sharded), the output projection is row-parallel (contraction dim
        sharded) — the pair needs one all-reduce per block;
      - MLPs shard ``w_gate``/``w_up`` column-wise and ``w_down`` row-wise;
      - the embedding shards the *vocab* dim, the LM head its vocab output
        (the chunked-softmax loss reduces over the sharded vocab);
      - MoE FFNs prefer **expert parallelism** (experts split over the model
        axis); when ``n_experts`` does not divide the axis they fall back to
        per-expert tensor parallelism.
  * **Data parallel**: the batch dim of inputs/activations is sharded over
    every non-model mesh axis (``("pod", "data")`` on a multi-pod mesh).
  * **FSDP** (``rules.fsdp_weights``): each large parameter additionally
    shards one remaining unsharded dim over the data axes (ZeRO-3; weights
    are all-gathered per-layer by GSPMD, activations stay batch-sharded via
    ``transformer.constrain_batch``).
  * **Sequence parallel** (``rules.sequence_parallel``): the residual
    stream's *sequence* dim is sharded over the model axis between TP
    regions (Megatron-SP).  Applied by the step builders through
    ``transformer.set_batch_axes``; it changes activation placement only,
    never parameter placement.

Every rule is guarded by a divisibility check (``fit_axes``): a dim that
does not divide the mesh axis is simply left unsharded (e.g. smollm's 15
heads on a 16-way model axis) — the layout degrades, it never errors.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axes = Union[None, str, Tuple[str, ...]]

# Mesh axes considered data-parallel, in the order batch dims shard over
# them.  Mesh construction (repro.launch.mesh) only ever uses these names
# plus the model axis.
DATA_AXIS_ORDER: Tuple[str, ...] = ("pod", "data")

# Mixers whose state is recurrent (O(1) decode state): sequence parallelism
# interacts badly with their chunked scan (the per-chunk carry would cross
# shard boundaries every step), so the recommended rules disable SP.
_RECURRENT_MIXERS = frozenset({"mamba", "mlstm", "slstm"})
_ATTENTION_MIXERS = frozenset({"attn", "swa"})


# ---------------------------------------------------------------------------
# Rules
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Declarative knobs the autotuner searches over.

    ``ShardSpace`` (repro.core.shard_space) emits exactly these fields; the
    step builders translate them into concrete ``NamedSharding`` trees.
    """

    fsdp_weights: bool = False          # ZeRO-3: shard params over data axes
    sequence_parallel: bool = False     # Megatron-SP residual stream
    tp_axis: str = "model"              # mesh axis used for tensor parallel
    fsdp_min_size: int = 2 ** 16        # leave small params replicated

    @classmethod
    def recommended(cls, cfg) -> "ShardingRules":
        """Default production rules for an ``ArchConfig``.

        Sequence parallelism is ON only for pure-attention stacks: recurrent
        mixers scan over sequence chunks (the carry would cross shard
        boundaries) and MoE FFNs already pay an all-to-all on the token dim,
        so SP's gather/scatter pair costs more than the all-reduce it
        replaces (measured in the §Perf hillclimb).  FSDP is ON once the
        parameter body is large enough that replicated weights dominate HBM.
        """
        mixers = {m for m, _ in cfg.pattern}
        ffns = {f for _, f in cfg.pattern}
        pure_attention = mixers <= _ATTENTION_MIXERS
        has_moe = "moe" in ffns or cfg.n_experts > 0
        recurrent = bool(mixers & _RECURRENT_MIXERS)
        sp = pure_attention and not has_moe and not recurrent
        # ~ >1 GiB of bf16 block params: replication stops being free
        big = cfg.n_layers * cfg.d_model * max(
            cfg.d_ff, cfg.d_model) * max(cfg.n_experts, 1) >= 2 ** 29
        return cls(fsdp_weights=big, sequence_parallel=sp)

    def describe(self) -> str:
        return (f"tp={self.tp_axis} fsdp={'on' if self.fsdp_weights else 'off'}"
                f" sp={'on' if self.sequence_parallel else 'off'}")


# ---------------------------------------------------------------------------
# Axis arithmetic
# ---------------------------------------------------------------------------

def axis_size(mesh: Mesh, axes: Axes) -> int:
    """Product of the named mesh axes (missing axes count as 1)."""
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= int(mesh.shape.get(a, 1))
    return n


def data_axes(mesh: Mesh, tp_axis: str = "model") -> Tuple[str, ...]:
    """Mesh axes used for batch/data parallelism, in mesh order."""
    return tuple(a for a in mesh.axis_names
                 if a != tp_axis and a in DATA_AXIS_ORDER)


def fit_axes(n: int, axes: Axes, mesh: Mesh) -> Axes:
    """Largest dividing subset of ``axes``, kept in axis order — the
    universal divisibility fallback.  Axes absent from ``mesh`` are ignored,
    and an axis that does not divide the remaining factor of ``n`` is
    *skipped*, not a stopping point (n=6 over (pod=4, data=3) -> ("data",)).

    Returns axes in the same general shape they came in: a single name stays
    a name, a sequence comes back as a tuple; ``None`` when nothing fits.
    """
    if axes is None or n <= 0:
        return None
    single = isinstance(axes, str)
    candidates = (axes,) if single else tuple(axes)
    kept = []
    prod = 1
    for a in candidates:
        size = int(mesh.shape.get(a, 0))
        if size <= 0:
            continue                       # axis absent from this mesh
        if n % (prod * size) == 0:
            kept.append(a)
            prod *= size
    if not kept:
        return None
    if single:
        return kept[0]
    return tuple(kept)


def _path_names(path) -> Tuple[str, ...]:
    """Stringify a tree_util key path (DictKey / SequenceKey / attr)."""
    out = []
    for p in path:
        for attr in ("key", "name", "idx"):
            if hasattr(p, attr):
                out.append(str(getattr(p, attr)))
                break
        else:
            out.append(str(p))
    return tuple(out)


# ---------------------------------------------------------------------------
# Parameter shardings
# ---------------------------------------------------------------------------

# Column-parallel weights: shard the *output-feature* (last) dim.  The
# matching activations stay replicated on entry, sharded on exit.
_COLUMN = frozenset({
    "wq", "wk", "wv",            # attention qkv
    "w_gate", "w_up", "w_in",    # swiglu / gelu MLP up-projections
    "in_proj", "dt_proj",        # mamba expand + dt
    "wz", "wi", "wf",            # xLSTM input/gate projections
})
# Row-parallel weights: shard the *contraction* (first non-stack) dim; the
# product carries partial sums that GSPMD all-reduces once per block.
_ROW = frozenset({
    "wo",                        # attention output
    "w_down", "w_out",           # MLP down-projections
    "out_proj",                  # mamba output
    "wo_out",                    # sLSTM output
})
# Biases of column-parallel weights follow their output-feature sharding.
_COLUMN_BIAS = frozenset({"bq", "bk", "bv", "b_in"})
# Mamba per-channel (d_inner-indexed) vectors: keep them aligned with the
# in_proj output sharding so the selective scan runs fully sharded.
_CHANNEL_LAST = frozenset({"conv_w", "conv_b", "dt_bias", "D"})
_CHANNEL_FIRST = frozenset({"A_log"})
# MoE tensors carrying a leading expert dim (after the layer-stack dim).
_MOE_EXPERT = frozenset({"w_gate", "w_up", "w_down"})


def _param_spec(names: Tuple[str, ...], shape: Tuple[int, ...],
                mesh: Mesh, cfg, rules: ShardingRules) -> P:
    """PartitionSpec for one parameter leaf, identified by its tree path."""
    tp = rules.tp_axis
    ndim = len(shape)
    spec: list = [None] * ndim
    # Layer stacks carry a leading repeats dim (lax.scan axis) — never
    # sharded: every device owns every layer's slice of each weight.
    stacked = bool(names) and names[0] in ("layers", "enc_layers")
    off = 1 if stacked else 0
    name = names[-1] if names else ""

    if name == "embed" and ndim == 2:
        spec[0] = fit_axes(shape[0], tp, mesh)           # vocab rows
    elif name == "lm_head" and ndim == 2:
        spec[1] = fit_axes(shape[1], tp, mesh)           # vocab cols
    elif name in _MOE_EXPERT and ndim - off == 3:
        # MoE: (E, d_model, d_ff) / (E, d_ff, d_model) behind the stack dim.
        if fit_axes(shape[off], tp, mesh) is not None:
            spec[off] = tp                               # expert parallel
        elif name in ("w_gate", "w_up"):
            spec[ndim - 1] = fit_axes(shape[-1], tp, mesh)
        else:                                            # w_down
            spec[off + 1] = fit_axes(shape[off + 1], tp, mesh)
    elif name in _COLUMN and ndim - off == 2:
        spec[ndim - 1] = fit_axes(shape[-1], tp, mesh)
    elif name in _ROW and ndim - off == 2:
        spec[off] = fit_axes(shape[off], tp, mesh)
    elif name in _COLUMN_BIAS and ndim - off == 1:
        spec[ndim - 1] = fit_axes(shape[-1], tp, mesh)
    elif name in _CHANNEL_LAST and ndim - off >= 1:
        spec[ndim - 1] = fit_axes(shape[-1], tp, mesh)
    elif name in _CHANNEL_FIRST and ndim - off == 2:
        spec[off] = fit_axes(shape[off], tp, mesh)
    # everything else (norms, routers, recurrent r-mats): replicated

    if rules.fsdp_weights and int(np.prod(shape)) >= rules.fsdp_min_size:
        dp = data_axes(mesh, tp)
        for d in range(off, ndim):
            if spec[d] is None:
                ax = fit_axes(shape[d], dp, mesh)
                if ax:
                    spec[d] = ax
                    break
    return P(*spec)


def param_shardings(abstract_params: Any, mesh: Mesh, cfg,
                    rules: Optional[ShardingRules] = None) -> Any:
    """NamedSharding tree matching an (abstract) parameter tree.

    ``abstract_params`` is the output of ``transformer.abstract_params``
    (or a real parameter tree — only shapes are read).  Also the right
    sharding for gradients and Adam moments, which mirror the params.
    """
    rules = rules or ShardingRules()

    def one(path, leaf):
        spec = _param_spec(_path_names(path), tuple(leaf.shape), mesh, cfg,
                           rules)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, abstract_params)


# ---------------------------------------------------------------------------
# Batch / input shardings
# ---------------------------------------------------------------------------

def batch_sharding(mesh: Mesh, batch: int, seq: int,
                   tp_axis: str = "model") -> NamedSharding:
    """Sharding for a single (batch, seq) int token array."""
    del seq  # decode tokens are seq-len 1; seq stays unsharded here
    return NamedSharding(
        mesh, P(fit_axes(batch, data_axes(mesh, tp_axis), mesh)))


def batch_specs(batch_tree: Any, mesh: Mesh,
                tp_axis: str = "model") -> Any:
    """NamedSharding tree for a host batch: dim 0 over the data axes.

    Works on any pytree of arrays/ShapeDtypeStructs whose leaves all carry
    a leading global-batch dim (tokens, labels, patches, frames...).  Leaves
    whose batch does not divide the data axes stay replicated.
    """
    dp = data_axes(mesh, tp_axis)

    def one(leaf):
        spec = [None] * len(leaf.shape)
        if leaf.shape:
            spec[0] = fit_axes(leaf.shape[0], dp, mesh)
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, batch_tree)


def cache_shardings(abstract_cache: Any, mesh: Mesh, cfg,
                    rules: Optional[ShardingRules] = None) -> Any:
    """NamedSharding tree for a decode cache (``transformer.init_cache``).

    Layout: the per-sequence batch dim shards over the data axes; attention
    KV caches additionally shard the kv-head dim over the model axis (the
    serve-step attention then reduces over a sharded cache — flash-decoding
    semantics via GSPMD).  The cache *sequence* dim is never sharded: SWA
    ring-buffer writes are dynamic-slice updates at arbitrary offsets.
    """
    rules = rules or ShardingRules()
    dp = data_axes(mesh, rules.tp_axis)

    def one(path, leaf):
        names = _path_names(path)
        shape = tuple(leaf.shape)
        spec: list = [None] * len(shape)
        if names and names[0] == "pos":
            spec[0] = fit_axes(shape[0], dp, mesh)
        elif len(shape) >= 2:
            # layer entries are stacked (repeats, batch, ...)
            spec[1] = fit_axes(shape[1], dp, mesh)
            if len(shape) == 5 and names[-1] in ("k", "v", "xk", "xv"):
                spec[3] = fit_axes(shape[3], rules.tp_axis, mesh)
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, abstract_cache)


# ---------------------------------------------------------------------------
# Introspection / validation helpers
# ---------------------------------------------------------------------------

def validate_shardings(abstract: Any, shardings: Any) -> None:
    """Assert every spec'd dim divides evenly on its mesh axes.

    ``param_shardings``/``cache_shardings`` guarantee this by construction;
    this guards hand-built or deserialized sharding trees before they reach
    ``jax.jit`` (whose own error points at an HLO op, not a parameter).
    """
    flat_a = jax.tree_util.tree_flatten_with_path(abstract)[0]
    flat_s = jax.tree.leaves(
        shardings, is_leaf=lambda x: isinstance(x, NamedSharding))
    if len(flat_a) != len(flat_s):
        raise ValueError(
            f"tree mismatch: {len(flat_a)} leaves vs {len(flat_s)} shardings")
    for (path, leaf), sh in zip(flat_a, flat_s):
        if not isinstance(sh, NamedSharding):
            raise TypeError(f"{_path_names(path)}: {type(sh).__name__} "
                            "is not a NamedSharding")
        for d, axes in enumerate(sh.spec):
            if axes is None:
                continue
            size = axis_size(sh.mesh, axes)
            if leaf.shape[d] % size:
                raise ValueError(
                    f"{'/'.join(_path_names(path))}: dim {d} of shape "
                    f"{tuple(leaf.shape)} not divisible by {axes}={size}")


def describe_shardings(abstract: Any, shardings: Any,
                       max_rows: int = 0) -> str:
    """Human-readable placement table (dry-run debugging aid)."""
    flat_a = jax.tree_util.tree_flatten_with_path(abstract)[0]
    flat_s = jax.tree.leaves(
        shardings, is_leaf=lambda x: isinstance(x, NamedSharding))
    rows = []
    for (path, leaf), sh in zip(flat_a, flat_s):
        key = "/".join(_path_names(path))
        spec = tuple(sh.spec) + (None,) * (len(leaf.shape) - len(sh.spec))
        rows.append(f"{key:<48} {str(tuple(leaf.shape)):<28} {spec}")
    if max_rows and len(rows) > max_rows:
        rows = rows[:max_rows] + [f"... ({len(flat_a) - max_rows} more)"]
    return "\n".join(rows)


def param_bytes_per_device(abstract: Any, shardings: Any) -> int:
    """Per-device resident parameter bytes under a sharding tree — the
    number the roofline HBM-residency model cross-checks."""
    flat_a = jax.tree.leaves(abstract)
    flat_s = jax.tree.leaves(
        shardings, is_leaf=lambda x: isinstance(x, NamedSharding))
    total = 0
    for leaf, sh in zip(flat_a, flat_s):
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        shards = 1
        for axes in sh.spec:
            shards *= axis_size(sh.mesh, axes)
        total += (n // max(shards, 1)) * jax.dtypes.canonicalize_dtype(
            leaf.dtype).itemsize
    return total
