"""Distributed execution layer: sharding rules over GSPMD meshes.

``repro.dist.sharding`` is the runtime consumer of the co-optimization
search: the ARCO shard-space tuner (``repro.launch.autotune``) emits a
``ShardingRules``, and the step builders in ``repro.train.steps`` turn it
into explicit in/out shardings for every jitted entry point.
"""
from repro.dist.sharding import (  # noqa: F401
    ShardingRules,
    axis_size,
    batch_sharding,
    batch_specs,
    cache_shardings,
    data_axes,
    fit_axes,
    param_shardings,
)
