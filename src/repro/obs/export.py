"""Trace persistence: Chrome-trace/Perfetto JSON and raw JSONL.

The Chrome JSON object format (``{"traceEvents": [...]}``) loads
directly into ``chrome://tracing`` and https://ui.perfetto.dev: complete
spans are ``ph: "X"`` with microsecond ``ts``/``dur``, instant events
``ph: "i"``.  Timestamps are wall-clock microseconds (tracer epoch +
monotonic offset) so traces merged from several hosts line up.  The
metrics registry snapshot rides along under ``otherData`` — extra
top-level keys are explicitly allowed by the format.

``save_trace(tracer, "run.jsonl")`` instead writes one raw event dict
per line (with a ``wall_s`` absolute-start field), the
append-friendly form ``tools/trace_summary.py`` also reads.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List


def chrome_trace(tracer) -> Dict[str, object]:
    """Render a :class:`~repro.obs.trace.Tracer` to the Chrome trace
    object format."""
    pid = os.getpid()
    out: List[Dict[str, object]] = []
    for ev in tracer.events():
        row: Dict[str, object] = {
            "name": ev["name"],
            "cat": ev["cat"] or "default",
            "ph": ev["ph"],
            "ts": (tracer.epoch + ev["t"]) * 1e6,
            "pid": pid,
            "tid": ev["tid"],
        }
        if ev["ph"] == "X":
            row["dur"] = ev["dur"] * 1e6
        if ev["ph"] == "i":
            row["s"] = "t"  # instant scope: thread
        if "args" in ev:
            row["args"] = ev["args"]
        out.append(row)
    other: Dict[str, object] = {
        "tracer": tracer.name,
        "metrics": tracer.metrics.snapshot(),
    }
    sampling = getattr(tracer, "sampling_stats", lambda: {})()
    if sampling:
        other["sampling"] = sampling
    return {
        "traceEvents": out,
        "displayTimeUnit": "ms",
        "otherData": other,
    }


def save_trace(tracer, path: str) -> None:
    """Write ``tracer`` to ``path``: raw JSONL when the suffix is
    ``.jsonl``, Chrome-trace JSON otherwise."""
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    # default=str: a stray non-JSON span arg must never lose the whole
    # trace at the end of a long run
    if str(path).endswith(".jsonl"):
        with open(path, "w") as f:
            for ev in tracer.events():
                row = dict(ev)
                row["wall_s"] = tracer.epoch + row.pop("t")
                f.write(json.dumps(row, sort_keys=True, default=str) + "\n")
            # sampled tracer: a trailing metadata row carries the exact
            # kept/dropped bookkeeping (ph "M" — readers that only look
            # at "X"/"i" rows skip it harmlessly)
            sampling = getattr(tracer, "sampling_stats", lambda: {})()
            if sampling:
                f.write(json.dumps(
                    {"ph": "M", "name": "sampling", "args": sampling,
                     "wall_s": 0.0}, sort_keys=True, default=str) + "\n")
        return
    with open(path, "w") as f:
        json.dump(chrome_trace(tracer), f, indent=1, default=str)
        f.write("\n")
