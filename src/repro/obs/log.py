"""Leveled structured logging for the tuning stack's diagnostics.

``REPRO_LOG=debug|info|warn`` selects the threshold (default ``warn``);
the env var is read at call time so tests and long-lived daemons can
flip verbosity without re-imports.  Output is plain flushed stdout lines
— byte-identical to the ad-hoc ``print(...)`` calls this replaces when
no structured fields are attached, so default output is unchanged.
Structured fields render as a trailing ``[k=v ...]`` block.

The mapping from the old prints: diagnostics that always showed
(corrupt-record drops) are ``warn``; diagnostics gated on a ``verbose``
flag stay gated (the caller picks ``warn`` vs ``info``/``debug`` by its
flag), with ``REPRO_LOG=debug`` additionally surfacing the quiet path.
"""
from __future__ import annotations

import os

_LEVELS = {"debug": 10, "info": 20, "warn": 30}
_DEFAULT = "warn"


def threshold() -> int:
    """Current numeric threshold from ``REPRO_LOG`` (default warn)."""
    name = os.environ.get("REPRO_LOG", _DEFAULT).strip().lower()
    return _LEVELS.get(name, _LEVELS[_DEFAULT])


def enabled(level: str) -> bool:
    return _LEVELS[level] >= threshold()


def log(level: str, msg: str, **fields) -> None:
    if _LEVELS[level] < threshold():
        return
    if fields:
        tail = " ".join(f"{k}={v}" for k, v in sorted(fields.items()))
        msg = f"{msg} [{tail}]"
    print(msg, flush=True)


def debug(msg: str, **fields) -> None:
    log("debug", msg, **fields)


def info(msg: str, **fields) -> None:
    log("info", msg, **fields)


def warn(msg: str, **fields) -> None:
    log("warn", msg, **fields)
