"""Nested-span tracer with an ambient (process-global) current tracer.

Spans are timed with ``time.monotonic`` and anchored to wall clock via a
single ``epoch`` offset captured at tracer creation, so traces from
different processes/hosts merge onto one timeline: a remote daemon ships
``(wall_start_s, dur_s)`` pairs and :meth:`Tracer.add_span` re-anchors
them against the local epoch.

The ambient tracer (:func:`current` / :func:`use`) is how instrumented
library code finds the active tracer without threading it through every
call signature: ``Session.run`` / ``NetworkCoOptimizer.run`` activate
their tracer around the whole run, and everything underneath — the ARCO
loop, oracles, executors — emits into ``current()``.  The default is the
shared :data:`NOOP` singleton whose ``span()`` hands back one reusable
no-op context manager, so uninstrumented runs pay a dict-free attribute
lookup per span site and nothing else (guarded by a tier-1 overhead
test).  ``use()`` is re-entrant; a ``Session`` run *inside* an active
netopt trace inherits the outer tracer because a session without its own
``trace=``/``obs=`` never overrides the ambient one.
"""
from __future__ import annotations

import random
import threading
import time
from typing import Dict, List, Optional

from repro.obs.metrics import Metrics, NoopMetrics

# Categories eligible for probabilistic sampling: the per-measurement
# firehose.  Structural spans (phases, session/mappo/gbt steps) are
# always kept — they are few and carry the wall-clock attribution.
SAMPLED_CATS = frozenset({"measure", "dispatch"})


class _SpanHandle:
    """Context manager for one open span; re-used per call, not pooled —
    span entry/exit only happens on instrumented (non-noop) runs."""

    __slots__ = ("_tracer", "_name", "_cat", "_tid", "_args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 tid: Optional[str], args: Optional[dict]) -> None:
        self._tracer = tracer
        self._name = name
        self._cat = cat
        self._tid = tid
        self._args = args

    def __enter__(self) -> "_SpanHandle":
        self._tracer._stack().append(self._name)
        self._t0 = time.monotonic()
        return self

    def __exit__(self, *exc) -> bool:
        dur = time.monotonic() - self._t0
        stack = self._tracer._stack()
        stack.pop()
        self._tracer._record(self._name, self._cat, self._t0, dur,
                             self._tid, self._args, depth=len(stack))
        return False


class Tracer:
    """Thread-safe collector of duration spans and instant events.

    Internal event rows are plain dicts with monotonic-seconds
    timestamps; :mod:`repro.obs.export` converts them to Chrome-trace
    microseconds.  ``metrics`` is a full :class:`Metrics` registry that
    rides along into the export's ``otherData``.
    """

    def __init__(self, name: str = "repro", sample_rate: float = 1.0,
                 sample_seed: int = 0) -> None:
        if not 0.0 <= sample_rate <= 1.0:
            raise ValueError(f"sample_rate must be in [0, 1], "
                             f"got {sample_rate}")
        self.name = name
        self.enabled = True
        # wall-clock seconds at monotonic zero: wall = epoch + monotonic
        self.epoch = time.time() - time.monotonic()
        self.metrics = Metrics()
        # Span sampling for million-measurement runs: spans in
        # SAMPLED_CATS are kept with probability ``sample_rate`` (own
        # RNG — the tuner's seeded RNG streams must not shift with the
        # sampling decision); dropped spans still accumulate exact
        # (count, total-duration) bookkeeping per category so
        # trace_summary coverage math stays honest.
        self.sample_rate = float(sample_rate)
        self._sample_rng = random.Random(sample_seed)
        self._kept: Dict[str, int] = {}
        self._dropped: Dict[str, List[float]] = {}  # cat -> [count, dur_s]
        self._lock = threading.Lock()
        self._events: List[Dict[str, object]] = []
        self._local = threading.local()

    # -- span / event emission ------------------------------------------

    def span(self, name: str, cat: str = "", tid: Optional[str] = None,
             **args) -> _SpanHandle:
        """``with tracer.span("measure", cat="measure", task=t): ...``"""
        return _SpanHandle(self, name, cat, tid, args or None)

    def event(self, name: str, cat: str = "", tid: Optional[str] = None,
              **args) -> None:
        """Zero-duration instant event (Chrome ``ph: "i"``)."""
        ev: Dict[str, object] = {
            "name": name, "cat": cat, "ph": "i", "t": time.monotonic(),
            "tid": tid or threading.current_thread().name,
        }
        if args:
            ev["args"] = args
        with self._lock:
            self._events.append(ev)

    def add_span(self, name: str, cat: str = "", *, wall_start_s: float,
                 dur_s: float, tid: str = "remote",
                 args: Optional[dict] = None) -> None:
        """Ingest an externally timed span (e.g. shipped from a remote
        daemon) by its wall-clock start, re-anchored to this tracer's
        timeline."""
        self._record(name, cat, wall_start_s - self.epoch, dur_s, tid,
                     args, depth=0)

    def add_span_mono(self, name: str, cat: str = "", *,
                      start_mono_s: float, dur_s: float, tid: str = "",
                      args: Optional[dict] = None) -> None:
        """Record an already-finished span timed locally with
        ``time.monotonic()`` (executor event loops learn a job's extent
        only when its result arrives)."""
        self._record(name, cat, start_mono_s, dur_s, tid or None, args,
                     depth=0)

    def _record(self, name: str, cat: str, t_mono: float, dur_s: float,
                tid: Optional[str], args: Optional[dict],
                depth: int) -> None:
        ev: Dict[str, object] = {
            "name": name, "cat": cat, "ph": "X", "t": t_mono,
            "dur": dur_s,
            "tid": tid or threading.current_thread().name,
            "depth": depth,
        }
        if args:
            ev["args"] = args
        with self._lock:
            if self.sample_rate < 1.0 and cat in SAMPLED_CATS:
                if self._sample_rng.random() >= self.sample_rate:
                    acc = self._dropped.get(cat)
                    if acc is None:
                        acc = self._dropped[cat] = [0, 0.0]
                    acc[0] += 1
                    acc[1] += dur_s
                    return
                self._kept[cat] = self._kept.get(cat, 0) + 1
            self._events.append(ev)

    def _stack(self) -> List[str]:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    # -- inspection / persistence ---------------------------------------

    def events(self) -> List[Dict[str, object]]:
        with self._lock:
            return list(self._events)

    def sampling_stats(self) -> Dict[str, object]:
        """Per-category kept/dropped bookkeeping — ``{}`` at rate 1.0 (no
        sampling, nothing to account for).  ``dropped_dur_s`` is the
        *exact* summed duration of dropped spans, so category totals can
        be reconstructed exactly rather than estimated from the rate."""
        if self.sample_rate >= 1.0:
            return {}
        with self._lock:
            cats: Dict[str, Dict[str, float]] = {}
            for cat in sorted(set(self._kept) | set(self._dropped)):
                d = self._dropped.get(cat, (0, 0.0))
                cats[cat] = {"kept": int(self._kept.get(cat, 0)),
                             "dropped": int(d[0]),
                             "dropped_dur_s": float(d[1])}
            return {"sample_rate": self.sample_rate, "cats": cats}

    def recent_spans(self, limit: int = 256) -> List[Dict[str, object]]:
        """Tail of the most recent complete spans, wall-clock anchored —
        the copy-on-read snapshot ``/trace`` serves.  The lock is held
        only for the tail slice; dict conversion happens outside it."""
        with self._lock:
            tail = self._events[-max(int(limit), 0) * 4:] if limit else []
        out: List[Dict[str, object]] = []
        for ev in tail:
            if ev["ph"] != "X":
                continue
            row: Dict[str, object] = {
                "name": ev["name"], "cat": ev["cat"],
                "tid": ev["tid"], "depth": ev["depth"],
                "wall_s": self.epoch + float(ev["t"]),
                "dur_s": float(ev["dur"]),
            }
            if "args" in ev:
                row["args"] = ev["args"]
            out.append(row)
        return out[-max(int(limit), 0):]

    def spans(self) -> List[Dict[str, object]]:
        return [e for e in self.events() if e["ph"] == "X"]

    def phase_times(self) -> Dict[str, float]:
        """Summed seconds per named top-level phase span (``cat ==
        "phase"``) — the ``phase_times`` block bench artifacts embed."""
        out: Dict[str, float] = {}
        for e in self.spans():
            if e.get("cat") == "phase":
                out[str(e["name"])] = (out.get(str(e["name"]), 0.0)
                                       + float(e["dur"]))
        return out

    def save(self, path: str) -> None:
        """Write the trace: Chrome-trace JSON (Perfetto-loadable), or
        raw JSONL when ``path`` ends in ``.jsonl``."""
        from repro.obs.export import save_trace
        save_trace(self, path)


class _NoopSpan:
    __slots__ = ()

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


_NOOP_SPAN = _NoopSpan()
_NOOP_METRICS = NoopMetrics()


class NoopTracer:
    """Disabled tracer: every call is a constant-return no-op."""

    __slots__ = ()
    enabled = False
    metrics = _NOOP_METRICS

    def span(self, name: str, cat: str = "", tid: Optional[str] = None,
             **args) -> _NoopSpan:
        return _NOOP_SPAN

    def event(self, name: str, cat: str = "", tid: Optional[str] = None,
              **args) -> None:
        pass

    def add_span(self, name: str, cat: str = "", *, wall_start_s: float,
                 dur_s: float, tid: str = "remote",
                 args: Optional[dict] = None) -> None:
        pass

    def add_span_mono(self, name: str, cat: str = "", *,
                      start_mono_s: float, dur_s: float, tid: str = "",
                      args: Optional[dict] = None) -> None:
        pass

    def phase_times(self) -> Dict[str, float]:
        return {}

    def sampling_stats(self) -> Dict[str, object]:
        return {}

    def recent_spans(self, limit: int = 256) -> List[Dict[str, object]]:
        return []

    def save(self, path: str) -> None:
        pass


NOOP = NoopTracer()

_current: "Tracer | NoopTracer" = NOOP


def current() -> "Tracer | NoopTracer":
    """The ambient tracer instrumented code emits into (default: NOOP)."""
    return _current


class _Use:
    __slots__ = ("_tracer", "_prev")

    def __init__(self, tracer) -> None:
        self._tracer = tracer if tracer is not None else NOOP

    def __enter__(self):
        global _current
        self._prev = _current
        _current = self._tracer
        return self._tracer

    def __exit__(self, *exc) -> bool:
        global _current
        _current = self._prev
        return False


def use(tracer) -> _Use:
    """``with obs.use(tracer): ...`` — install ``tracer`` as the ambient
    tracer for the dynamic extent of the block (re-entrant; restores the
    previous one on exit).  ``use(None)`` installs the no-op tracer."""
    return _Use(tracer)
