"""Live monitoring: ``/metrics`` + ``/status`` + ``/trace`` over stdlib HTTP.

A :class:`MonitorServer` is a tiny ``ThreadingHTTPServer`` that turns a
running tuning session — since PR 7 a distributed system of sessions,
netopt loops, and worker daemons — from post-hoc trace files into
something you can watch live:

* ``/metrics`` — Prometheus text exposition (version 0.0.4) of one
  :class:`~repro.obs.metrics.Metrics` registry.  Registered *collectors*
  run at scrape time (copy-on-read: they pull ``Executor.stats()`` /
  tracker state and write instruments), so the measurement hot path
  carries zero monitoring cost and Serial/Subprocess/Remote pools all
  export uniformly through ``record_executor_stats``.
* ``/status`` — JSON snapshot assembled from attached *status sources*
  (``attach(name, status_fn)``): live session progress (best-so-far,
  spent vs budget, per-task state, surrogate hit/miss), netopt phase,
  and fleet health (per-endpoint jobs/failures/reconnects/in-flight
  plus daemon heartbeat load).
* ``/trace`` — bounded tail of recent spans from an attached
  :class:`~repro.obs.trace.Tracer` (empty without one).

Lifecycle: owners (``Session``, netopt ``_Evaluator``, ``WorkerDaemon``)
either *own* a server (built from ``monitor=PORT``, stopped with the
run) or *borrow* one (``monitor=MonitorServer``) — mirroring the
borrowed-RemoteExecutor idiom — and must call :meth:`finalize` before
tearing down the structures their callbacks read: the last snapshot is
frozen, so a scrape after the run still answers with final values (the
acceptance path: the final ``/metrics`` scrape matches the report).

Stdlib only, like the rest of ``repro.obs`` — daemons import this.
"""
from __future__ import annotations

import json
import math
import threading
import time
import weakref
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable, Dict, List, Optional
from urllib.parse import urlparse

from repro.obs import log
from repro.obs.metrics import Metrics

_REGISTRY: "weakref.WeakSet[MonitorServer]" = weakref.WeakSet()


def active_servers() -> List["MonitorServer"]:
    """Every started, not-yet-stopped :class:`MonitorServer` in this
    process — how tests (and the CLI smoke test) discover the ephemeral
    port a ``--monitor 0`` run bound."""
    return [s for s in _REGISTRY if s.running]


def _fmt(v: float) -> str:
    """Prometheus sample value: exact round-trip formatting."""
    v = float(v)
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    if math.isnan(v):
        return "NaN"
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(v)


def _sanitize(name: str) -> str:
    """Metric-name charset: ``[a-zA-Z_:][a-zA-Z0-9_:]*``; dotted registry
    names become underscore-separated with a ``repro_`` prefix."""
    out = "".join(c if c.isalnum() or c in "_:" else "_" for c in name)
    if not out or not (out[0].isalpha() or out[0] in "_:"):
        out = "_" + out
    return "repro_" + out


def prometheus_text(snapshot: Dict[str, object]) -> str:
    """Render a ``Metrics.snapshot()`` dict to the Prometheus text
    exposition format.  Histograms are rendered as summaries (quantile
    labels + ``_count``/``_sum``) — the snapshot already reduced the
    stream, so the cumulative-bucket histogram type does not apply."""
    lines: List[str] = []
    for name, v in sorted(snapshot.get("counters", {}).items()):
        mn = _sanitize(name)
        lines.append(f"# TYPE {mn} counter")
        lines.append(f"{mn} {_fmt(v)}")
    for name, v in sorted(snapshot.get("gauges", {}).items()):
        mn = _sanitize(name)
        lines.append(f"# TYPE {mn} gauge")
        lines.append(f"{mn} {_fmt(v)}")
    for name, h in sorted(snapshot.get("histograms", {}).items()):
        mn = _sanitize(name)
        lines.append(f"# TYPE {mn} summary")
        for q, key in (("0.5", "p50"), ("0.9", "p90"), ("0.99", "p99")):
            if key in h:
                lines.append(f'{mn}{{quantile="{q}"}} {_fmt(h[key])}')
        lines.append(f"{mn}_count {_fmt(h.get('count', 0))}")
        lines.append(f"{mn}_sum {_fmt(h.get('sum', 0.0))}")
    return "\n".join(lines) + "\n" if lines else ""


class _Source:
    """One attached status source: a live callback, then (after
    ``finalize``) its frozen last snapshot."""

    __slots__ = ("status_fn", "collector", "frozen")

    def __init__(self, status_fn: Optional[Callable[[], dict]],
                 collector: Optional[Callable[[Metrics], None]]) -> None:
        self.status_fn = status_fn
        self.collector = collector
        self.frozen: Optional[dict] = None


class _Handler(BaseHTTPRequestHandler):
    server_version = "repro-monitor/1"
    protocol_version = "HTTP/1.1"

    def log_message(self, fmt, *args):  # keep scrapes off stderr
        pass

    def do_GET(self) -> None:  # noqa: N802 (http.server API)
        mon: "MonitorServer" = self.server.monitor  # type: ignore[attr-defined]
        path = urlparse(self.path).path
        try:
            if path == "/metrics":
                body = mon.metrics_text().encode()
                ctype = "text/plain; version=0.0.4; charset=utf-8"
            elif path == "/status":
                body = json.dumps(mon.status_snapshot(), sort_keys=True,
                                  default=str).encode()
                ctype = "application/json"
            elif path == "/trace":
                body = json.dumps({"spans": mon.trace_tail()},
                                  sort_keys=True, default=str).encode()
                ctype = "application/json"
            elif path == "/":
                body = json.dumps({"endpoints": ["/metrics", "/status",
                                                 "/trace"]}).encode()
                ctype = "application/json"
            else:
                self.send_error(404, "unknown endpoint")
                return
        except Exception as e:  # a broken callback must not kill scrapes
            body = json.dumps({"error": f"{type(e).__name__}: {e}"}).encode()
            self.send_response(500)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)
            return
        self.send_response(200)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)


class MonitorServer:
    """The live-monitoring HTTP server; see the module docstring.

    ``port=0`` binds an ephemeral port (read it back from ``.port``
    after :meth:`start`).  Handlers run on daemon threads and every
    snapshot is copy-on-read, so a slow or wedged scraper never blocks
    the tuning run.
    """

    def __init__(self, port: int = 0, host: str = "127.0.0.1",
                 trace_tail: int = 256) -> None:
        self.host = host
        self.requested_port = int(port)
        self.trace_tail_limit = int(trace_tail)
        self.metrics = Metrics()
        self.tracer = None  # a repro.obs.trace.Tracer, when one exists
        self._lock = threading.Lock()
        self._sources: Dict[str, _Source] = {}
        self._httpd: Optional[ThreadingHTTPServer] = None
        self._thread: Optional[threading.Thread] = None
        self._started_unix = 0.0

    # ------------------------------------------------------------ lifecycle

    @property
    def running(self) -> bool:
        return self._httpd is not None

    @property
    def port(self) -> int:
        if self._httpd is None:
            return self.requested_port
        return self._httpd.server_address[1]

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self) -> "MonitorServer":
        if self._httpd is not None:
            return self
        httpd = ThreadingHTTPServer((self.host, self.requested_port),
                                    _Handler)
        httpd.daemon_threads = True
        httpd.monitor = self  # type: ignore[attr-defined]
        self._httpd = httpd
        self._started_unix = time.time()
        self._thread = threading.Thread(target=httpd.serve_forever,
                                        name="repro-monitor", daemon=True)
        self._thread.start()
        _REGISTRY.add(self)
        log.info("monitor serving", url=self.url)
        return self

    def stop(self) -> None:
        httpd, self._httpd = self._httpd, None
        if httpd is None:
            return
        httpd.shutdown()
        httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None
        _REGISTRY.discard(self)

    def __enter__(self) -> "MonitorServer":
        return self.start()

    def __exit__(self, *exc) -> bool:
        self.stop()
        return False

    # ------------------------------------------------------------- sources

    def attach(self, name: str, status_fn: Optional[Callable[[], dict]],
               collector: Optional[Callable[[Metrics], None]] = None,
               tracer=None) -> str:
        """Register a status source (and optional scrape-time collector).
        Returns the actual source name — suffixed on collision, so a
        shared (borrowed) server can host several runs."""
        with self._lock:
            actual, i = name, 1
            while actual in self._sources:
                i += 1
                actual = f"{name}#{i}"
            self._sources[actual] = _Source(status_fn, collector)
        if tracer is not None and getattr(tracer, "enabled", False):
            self.tracer = tracer
        return actual

    def finalize(self, name: str) -> None:
        """Freeze ``name``'s status into its last live snapshot and run
        its collector one final time, then drop both callbacks — called
        by owners *before* tearing down what the callbacks read (e.g.
        executor close).  Idempotent; a post-run scrape then still
        serves final values."""
        with self._lock:
            src = self._sources.get(name)
        if src is None or (src.status_fn is None and src.collector is None):
            return
        status_fn, collector = src.status_fn, src.collector
        src.status_fn = src.collector = None
        if collector is not None:
            try:
                collector(self.metrics)
            except Exception as e:
                log.warn("monitor collector failed at finalize",
                         source=name, error=str(e))
        if status_fn is not None:
            try:
                src.frozen = status_fn()
            except Exception as e:
                src.frozen = {"error": f"{type(e).__name__}: {e}"}

    def detach(self, name: str) -> None:
        with self._lock:
            self._sources.pop(name, None)

    # ------------------------------------------------------------ snapshots

    def metrics_text(self) -> str:
        """Run live collectors, then render the registry — what
        ``/metrics`` serves."""
        with self._lock:
            collectors = [(n, s.collector) for n, s in self._sources.items()
                          if s.collector is not None]
        for name, collector in collectors:
            try:
                collector(self.metrics)
            except Exception as e:
                log.warn("monitor collector failed", source=name,
                         error=str(e))
        return prometheus_text(self.metrics.snapshot())

    def status_snapshot(self) -> Dict[str, object]:
        """Assemble ``/status``: one section per attached source (live
        callback or frozen final snapshot)."""
        with self._lock:
            items = list(self._sources.items())
        sources: Dict[str, object] = {}
        for name, src in items:
            if src.status_fn is not None:
                try:
                    sources[name] = src.status_fn()
                except Exception as e:
                    sources[name] = {"error": f"{type(e).__name__}: {e}"}
            elif src.frozen is not None:
                sources[name] = dict(src.frozen, final=True)
        return {"time_unix": time.time(),
                "uptime_s": (time.time() - self._started_unix
                             if self._started_unix else 0.0),
                "sources": sources}

    def trace_tail(self) -> List[Dict[str, object]]:
        tracer = self.tracer
        if tracer is None:
            return []
        return tracer.recent_spans(self.trace_tail_limit)


def coerce_monitor(monitor) -> "tuple[Optional[MonitorServer], bool]":
    """``monitor=`` coercion shared by Session / netopt / daemons:
    ``None`` -> no server; an ``int`` port -> a new *owned* server
    (started by the caller, stopped with the run); a
    :class:`MonitorServer` -> *borrowed* (caller attaches but never
    stops it).  Returns ``(server, owned)``."""
    if monitor is None:
        return None, False
    if isinstance(monitor, MonitorServer):
        return monitor, False
    return MonitorServer(port=int(monitor)), True
