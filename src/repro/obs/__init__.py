"""``repro.obs`` — dependency-light tracing + metrics for the tuning stack.

The paper's headline claim is as much about *optimization time* as about
the resulting throughput, so every layer of this repo's tuning stack
(ARCO loop halves, oracle measurement, all three executors, the remote
worker fabric, netopt phases) emits named spans into one
:class:`~repro.obs.trace.Tracer`.  A run's single ``wall_time_s`` then
decomposes into measure vs surrogate-refit vs mappo-update vs
executor-wait — per phase, per endpoint — instead of being one opaque
number.

Design constraints, in order:

* **Near-zero cost when off.**  The ambient tracer defaults to a shared
  :data:`NOOP` singleton whose ``span()`` returns one reusable no-op
  context manager; instrumented hot paths pay an attribute lookup and a
  method call, nothing else.  Guarded by a tier-1 overhead test.
* **Stdlib only.**  This package sits below
  ``repro.compiler.executor`` and is imported by spawned workers and
  remote daemons, which must never pay a jax import.
* **Cross-host mergeable.**  Spans carry a wall-clock anchor
  (``time.time`` at tracer creation) alongside monotonic timestamps, so
  span batches shipped back from remote daemons land on the same
  timeline as the parent's and one session yields one merged
  Chrome-trace/Perfetto file.

Entry points: ``Tracer`` / ``NOOP`` / the ambient ``current()``+``use()``
pair (:mod:`repro.obs.trace`), the counters/gauges/histograms registry
(:mod:`repro.obs.metrics`), the ``REPRO_LOG``-leveled structured logger
(:mod:`repro.obs.log`), Chrome-trace/JSONL export
(:mod:`repro.obs.export`), and the ``tools/trace_summary.py`` report
over saved traces.
"""
from repro.obs.metrics import Metrics, NoopMetrics
from repro.obs.serve import MonitorServer, active_servers, prometheus_text
from repro.obs.trace import NOOP, NoopTracer, Tracer, current, use

__all__ = [
    "Metrics",
    "MonitorServer",
    "NOOP",
    "NoopMetrics",
    "NoopTracer",
    "Tracer",
    "active_servers",
    "current",
    "prometheus_text",
    "use",
]
