"""Counters / gauges / histograms behind one thread-safe registry.

The registry unifies the per-executor ``stats()`` shapes: every executor
already answers the same eight keys (``kind``, ``workers_alive``,
``respawns``, ``queued``, ``running``, ``max_inflight``, ``jobs``,
``failures``), and :meth:`Metrics.record_executor_stats` maps them onto
typed instruments — monotone totals become counters, point-in-time
occupancy becomes gauges — so a saved trace carries the terminal
executor state next to its spans (``otherData.metrics`` in the Chrome
export).

Like the tracer, a :class:`NoopMetrics` singleton makes the disabled
path allocation-free: instrument lookups return shared do-nothing
objects.
"""
from __future__ import annotations

import threading
from typing import Dict, Mapping


class Counter:
    """Monotonically increasing total (jobs completed, failures, ...)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Gauge:
    """Last-written point-in-time value (queue depth, busy slots, ...)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Streaming count/sum/min/max — enough for mean latencies without
    holding every observation."""

    __slots__ = ("count", "sum", "min", "max")

    def __init__(self) -> None:
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def snapshot(self) -> Dict[str, float]:
        if not self.count:
            return {"count": 0, "sum": 0.0}
        return {"count": self.count, "sum": self.sum, "min": self.min,
                "max": self.max, "mean": self.sum / self.count}


class Metrics:
    """Thread-safe name -> instrument registry.

    Instruments are created on first use (``counter("jobs").inc()``);
    individual updates take the registry lock only on creation — the
    instruments themselves rely on the GIL for their single-field
    updates, matching how the executors' own counters already behave.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def _get(self, table: dict, name: str, cls):
        inst = table.get(name)
        if inst is None:
            with self._lock:
                inst = table.setdefault(name, cls())
        return inst

    def counter(self, name: str) -> Counter:
        return self._get(self._counters, name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(self._gauges, name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(self._histograms, name, Histogram)

    def record_executor_stats(self, stats: Mapping[str, object],
                              prefix: str = "executor") -> None:
        """Map the uniform ``Executor.stats()`` keys onto instruments.

        Totals (``jobs``, ``failures``, ``respawns``) land as counters
        *set to* the executor's own running total (executors already
        accumulate; re-recording overwrites rather than double-counts),
        occupancy (``workers_alive``, ``queued``, ``running``,
        ``max_inflight``) as gauges.
        """
        kind = stats.get("kind", "?")
        for key in ("jobs", "failures", "respawns"):
            if key in stats:
                c = self.counter(f"{prefix}.{kind}.{key}")
                c.value = float(stats[key])  # overwrite: source is a total
        for key in ("workers_alive", "queued", "running", "max_inflight"):
            if key in stats:
                self.gauge(f"{prefix}.{kind}.{key}").set(float(stats[key]))

    def snapshot(self) -> Dict[str, object]:
        with self._lock:
            return {
                "counters": {k: c.value for k, c in self._counters.items()},
                "gauges": {k: g.value for k, g in self._gauges.items()},
                "histograms": {k: h.snapshot()
                               for k, h in self._histograms.items()},
            }


class _NoopInstrument:
    __slots__ = ()
    value = 0.0
    count = 0
    sum = 0.0

    def inc(self, n: float = 1.0) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass

    def snapshot(self) -> Dict[str, float]:
        return {"count": 0, "sum": 0.0}


_NOOP_INSTRUMENT = _NoopInstrument()


class NoopMetrics:
    """Allocation-free stand-in used by the disabled tracer."""

    __slots__ = ()

    def counter(self, name: str) -> _NoopInstrument:
        return _NOOP_INSTRUMENT

    def gauge(self, name: str) -> _NoopInstrument:
        return _NOOP_INSTRUMENT

    def histogram(self, name: str) -> _NoopInstrument:
        return _NOOP_INSTRUMENT

    def record_executor_stats(self, stats: Mapping[str, object],
                              prefix: str = "executor") -> None:
        pass

    def snapshot(self) -> Dict[str, object]:
        return {}
