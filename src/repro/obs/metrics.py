"""Counters / gauges / histograms behind one thread-safe registry.

The registry unifies the per-executor ``stats()`` shapes: every executor
already answers the same eight keys (``kind``, ``workers_alive``,
``respawns``, ``queued``, ``running``, ``max_inflight``, ``jobs``,
``failures``), and :meth:`Metrics.record_executor_stats` maps them onto
typed instruments — monotone totals become counters, point-in-time
occupancy becomes gauges — so a saved trace carries the terminal
executor state next to its spans (``otherData.metrics`` in the Chrome
export).

Like the tracer, a :class:`NoopMetrics` singleton makes the disabled
path allocation-free: instrument lookups return shared do-nothing
objects.
"""
from __future__ import annotations

import math
import threading
from typing import Dict, Mapping


class Counter:
    """Monotonically increasing total (jobs completed, failures, ...).

    ``inc`` takes a per-instrument lock: ``x += n`` is not atomic at the
    bytecode level, and the monitor server scrapes counters that many
    executor threads increment concurrently."""

    __slots__ = ("value", "_lock")

    def __init__(self) -> None:
        self.value = 0.0
        self._lock = threading.Lock()

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self.value += n


class Gauge:
    """Last-written point-in-time value (queue depth, busy slots, ...).
    A single-field overwrite is atomic under the GIL — no lock needed."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Streaming count/sum/min/max plus power-of-two exponential buckets
    — enough for mean latencies *and* coarse quantiles without holding
    every observation.

    Bucket ``e`` counts values in ``(2**(e-1), 2**e]``; non-positive
    values land in a single underflow bucket.  Quantile estimates return
    the upper bound of the bucket holding the target rank, clamped to
    the observed ``[min, max]`` — deterministic, and exact whenever a
    bucket bound coincides with an observation."""

    __slots__ = ("count", "sum", "min", "max", "_buckets", "_lock")

    def __init__(self) -> None:
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self._buckets: Dict[int, int] = {}  # exponent -> count
        self._lock = threading.Lock()

    @staticmethod
    def _exponent(v: float) -> int:
        if v <= 0.0:
            return -(10 ** 9)  # underflow bucket, sorts before everything
        return max(math.ceil(math.log2(v)), -64)

    def observe(self, v: float) -> None:
        v = float(v)
        e = self._exponent(v)
        with self._lock:
            self.count += 1
            self.sum += v
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v
            self._buckets[e] = self._buckets.get(e, 0) + 1

    def quantile(self, q: float) -> float:
        """Bucket-resolution quantile estimate of the observed stream."""
        with self._lock:
            if not self.count:
                return float("nan")
            rank = max(math.ceil(q * self.count), 1)
            seen = 0
            for e in sorted(self._buckets):
                seen += self._buckets[e]
                if seen >= rank:
                    bound = 0.0 if e <= -64 else 2.0 ** e
                    return min(max(bound, self.min), self.max)
            return self.max

    def snapshot(self) -> Dict[str, float]:
        if not self.count:
            return {"count": 0, "sum": 0.0}
        return {"count": self.count, "sum": self.sum, "min": self.min,
                "max": self.max, "mean": self.sum / self.count,
                "p50": self.quantile(0.5), "p90": self.quantile(0.9),
                "p99": self.quantile(0.99)}


class Metrics:
    """Thread-safe name -> instrument registry.

    Instruments are created on first use (``counter("jobs").inc()``);
    updates take the registry lock only on creation — counters and
    histograms carry their own fine-grained locks (their updates are
    read-modify-write), gauges are single atomic stores.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def _get(self, table: dict, name: str, cls):
        inst = table.get(name)
        if inst is None:
            with self._lock:
                inst = table.setdefault(name, cls())
        return inst

    def counter(self, name: str) -> Counter:
        return self._get(self._counters, name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(self._gauges, name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(self._histograms, name, Histogram)

    def record_executor_stats(self, stats: Mapping[str, object],
                              prefix: str = "executor") -> None:
        """Map the uniform ``Executor.stats()`` keys onto instruments.

        Totals (``jobs``, ``failures``, ``respawns``) land as counters
        *set to* the executor's own running total (executors already
        accumulate; re-recording overwrites rather than double-counts),
        occupancy (``workers_alive``, ``queued``, ``running``,
        ``max_inflight``) as gauges.
        """
        kind = stats.get("kind", "?")
        for key in ("jobs", "failures", "respawns"):
            if key in stats:
                c = self.counter(f"{prefix}.{kind}.{key}")
                c.value = float(stats[key])  # overwrite: source is a total
        for key in ("workers_alive", "queued", "running", "max_inflight"):
            if key in stats:
                self.gauge(f"{prefix}.{kind}.{key}").set(float(stats[key]))

    def snapshot(self) -> Dict[str, object]:
        with self._lock:
            return {
                "counters": {k: c.value for k, c in self._counters.items()},
                "gauges": {k: g.value for k, g in self._gauges.items()},
                "histograms": {k: h.snapshot()
                               for k, h in self._histograms.items()},
            }


class _NoopInstrument:
    __slots__ = ()
    value = 0.0
    count = 0
    sum = 0.0

    def inc(self, n: float = 1.0) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass

    def snapshot(self) -> Dict[str, float]:
        return {"count": 0, "sum": 0.0}


_NOOP_INSTRUMENT = _NoopInstrument()


class NoopMetrics:
    """Allocation-free stand-in used by the disabled tracer."""

    __slots__ = ()

    def counter(self, name: str) -> _NoopInstrument:
        return _NOOP_INSTRUMENT

    def gauge(self, name: str) -> _NoopInstrument:
        return _NOOP_INSTRUMENT

    def histogram(self, name: str) -> _NoopInstrument:
        return _NOOP_INSTRUMENT

    def record_executor_stats(self, stats: Mapping[str, object],
                              prefix: str = "executor") -> None:
        pass

    def snapshot(self) -> Dict[str, object]:
        return {}
