"""qwen1.5-4b — 40L d_model=2560 20H (MHA kv=20) d_ff=6912 vocab=151936,
QKV bias.  [hf:Qwen/Qwen1.5 family; hf]
Pure full attention => long_500k cell is skipped.
"""
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    d_ff=6912,
    vocab=151936,
    qkv_bias=True,
)


def reduced() -> ArchConfig:
    return CONFIG.with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=512, attn_chunk=32, loss_chunk=32)
