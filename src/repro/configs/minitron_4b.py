"""minitron-4b — 32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000,
pruned nemotron.  [arXiv:2407.14679; hf]
Pure full attention => long_500k cell is skipped.
"""
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="minitron-4b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=9216,
    vocab=256000,
)


def reduced() -> ArchConfig:
    return CONFIG.with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=512, attn_chunk=32, loss_chunk=32)
