"""smollm-360m — 32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152,
llama-arch small.  [hf:HuggingFaceTB/SmolLM family; hf]

15 heads do NOT divide the 16-way model axis — this arch exercises the
sharding rule system's divisibility fallback (heads replicated, d_ff/vocab
sharded).  Pure full attention => long_500k cell is skipped.
"""
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    d_ff=2560,
    vocab=49152,
)


def reduced() -> ArchConfig:
    return CONFIG.with_(
        n_layers=2, d_model=60, n_heads=3, n_kv_heads=1, d_ff=128,
        vocab=512, attn_chunk=32, loss_chunk=32)
