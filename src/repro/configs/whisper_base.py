"""whisper-base — 6L d_model=512 8H d_ff=2048 vocab=51865, encoder-decoder,
conv frontend (stub).  [arXiv:2212.04356; unverified]

Audio: the conv frontend is a STUB; ``input_specs()`` supplies precomputed
frame embeddings (B, 1500, d_model) to the 6-layer bidirectional encoder.
The 6-layer decoder has causal self-attention + cross-attention.  GELU MLPs,
sinusoidal positions (no rope).  Pure full attention => long_500k skipped.
"""
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab=51865,
    pattern=(("attn", "gelu"),),
    use_rope=False,
    enc_dec=True,
    n_enc_layers=6,
    enc_seq=1500,
    mlp_variant="gelu",
)


def reduced() -> ArchConfig:
    return CONFIG.with_(
        n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=512, enc_seq=32, attn_chunk=32, loss_chunk=32)
