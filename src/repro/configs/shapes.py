"""Assigned input-shape cells + ShapeDtypeStruct input specs per cell.

Every architecture is paired with four shapes:

    train_4k     seq 4,096   global_batch 256   (training step)
    prefill_32k  seq 32,768  global_batch 32    (inference prefill)
    decode_32k   seq 32,768  global_batch 128   (one decode token, KV at 32k)
    long_500k    seq 524,288 global_batch 1     (long-context decode)

``decode_*``/``long_*`` lower ``serve_step`` (one token against a cache of
``seq`` tokens), not ``train_step``.  ``long_500k`` requires sub-quadratic
attention and is skipped (with reason) for pure full-attention archs.

``input_specs`` returns weak-type-correct ShapeDtypeStructs only — the
dry-run never allocates.  Modality frontends are stubs: the VLM entry takes
precomputed patch embeddings, the audio entry precomputed frames.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.transformer import ArchConfig, init_cache


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    global_batch: int


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}

SHAPE_NAMES = tuple(SHAPES)


def cell_supported(cfg: ArchConfig, shape: ShapeCell) -> Tuple[bool, str]:
    """(supported, reason-if-not). The long-context rule from the brief."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("pure full-attention arch: O(S^2) prefill / O(S) "
                       "per-token full KV at 512k — skipped per brief; run "
                       "for SSM/hybrid/SWA archs only")
    return True, ""


def _i32(shape) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def input_specs(cfg: ArchConfig, shape: ShapeCell,
                batch_override: Optional[int] = None) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b = batch_override or shape.global_batch
    s = shape.seq
    f32 = lambda sh: jax.ShapeDtypeStruct(sh, cfg.dtype)

    if shape.kind == "train":
        text = s - cfg.vision_prefix if cfg.vision_prefix else s
        spec: Dict[str, Any] = {"tokens": _i32((b, text)),
                                "labels": _i32((b, text))}
        if cfg.vision_prefix:
            spec["patches"] = f32((b, cfg.vision_prefix, cfg.d_model))
        if cfg.enc_dec:
            spec["frames"] = f32((b, cfg.enc_seq, cfg.d_model))
        return spec

    if shape.kind == "prefill":
        text = s - cfg.vision_prefix if cfg.vision_prefix else s
        spec = {"tokens": _i32((b, text))}
        if cfg.vision_prefix:
            spec["patches"] = f32((b, cfg.vision_prefix, cfg.d_model))
        if cfg.enc_dec:
            spec["frames"] = f32((b, cfg.enc_seq, cfg.d_model))
        return spec

    # decode: one new token against a cache of `s` tokens
    cache = jax.eval_shape(lambda: init_cache(cfg, b, s))
    return {"tokens": _i32((b, 1)), "cache": cache}
