"""moonshot-v1-16b-a3b — Moonlight-style MoE LM.

48L d_model=2048 16H (GQA kv=16) d_ff=1408/expert vocab=163840,
MoE 64 experts top-6.  [hf:moonshotai/Moonlight-16B-A3B; hf]
Pure full attention => long_500k cell is skipped (see DESIGN.md).
"""
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=163840,
    pattern=(("attn", "moe"),),
    n_experts=64,
    moe_top_k=6,
)


def reduced() -> ArchConfig:
    return CONFIG.with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=96,
        vocab=512, n_experts=4, moe_top_k=2, moe_impl="dense",
        attn_chunk=32, loss_chunk=32)
