"""internvl2-26b — 48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.
InternViT + InternLM2.  [arXiv:2404.16821; hf]

VLM: this config is the InternLM2 transformer BACKBONE only — the InternViT
frontend is a STUB; ``input_specs()`` supplies precomputed patch embeddings
(B, vision_prefix, d_model) which the model concatenates ahead of the text
tokens.  Pure full attention => long_500k cell is skipped.
"""
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=92553,
    vision_prefix=1024,
)


def reduced() -> ArchConfig:
    return CONFIG.with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=512, vision_prefix=8, attn_chunk=32, loss_chunk=32)
