"""mixtral-8x22b — 56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768,
MoE 8 experts top-2, sliding-window attention.  [arXiv:2401.04088; hf]

SWA (window 4096) makes decode state O(window) => long_500k cell runs with a
ring-buffer KV cache.
"""
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=32768,
    pattern=(("swa", "moe"),),
    swa_window=4096,
    n_experts=8,
    moe_top_k=2,
)


def reduced() -> ArchConfig:
    return CONFIG.with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=512, n_experts=4, moe_top_k=2, moe_impl="dense",
        swa_window=16, attn_chunk=32, loss_chunk=32)
