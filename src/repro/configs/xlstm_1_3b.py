"""xlstm-1.3b — 48L d_model=2048 4H vocab=50304, sLSTM + mLSTM blocks.
[arXiv:2405.04517; unverified]

Period-8 pattern: seven mLSTM (matrix-memory) blocks then one sLSTM
(scalar-memory, truly recurrent) block; d_ff=0 — the xLSTM blocks carry
their own internal projections.  Fully recurrent => O(1) decode state,
long_500k cell runs.
"""
from repro.models.transformer import ArchConfig

_PATTERN = tuple([("mlstm", "none")] * 7 + [("slstm", "none")])

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    pattern=_PATTERN,
    use_rope=False,
)


def reduced() -> ArchConfig:
    return CONFIG.with_(
        n_layers=8, d_model=64, n_heads=2, n_kv_heads=2, vocab=512,
        ssm_chunk=8, loss_chunk=32)
