"""jamba-1.5-large-398b — 72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16e top-2, Mamba+attention 7:1 interleave.
[arXiv:2403.19887; hf]

Period-8 pattern: one attention layer per 8, MoE on every other FFN.
Hybrid (mamba state is O(1)) => long_500k cell runs.
"""
from repro.models.transformer import ArchConfig

_PATTERN = (
    ("mamba", "mlp"), ("mamba", "moe"), ("mamba", "mlp"), ("mamba", "moe"),
    ("attn", "mlp"), ("mamba", "moe"), ("mamba", "mlp"), ("mamba", "moe"),
)

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab=65536,
    pattern=_PATTERN,
    n_experts=16,
    moe_top_k=2,
    use_rope=False,  # Jamba uses no positional encoding in attention
)


def reduced() -> ArchConfig:
    return CONFIG.with_(
        n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=512, n_experts=4, moe_top_k=2, moe_impl="dense",
        ssm_chunk=8, attn_chunk=32, loss_chunk=32)
