"""qwen2-1.5b — 28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936,
GQA + QKV bias.  [arXiv:2407.10671; hf]
Pure full attention => long_500k cell is skipped.
"""
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-1.5b",
    family="dense",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab=151936,
    qkv_bias=True,
)


def reduced() -> ArchConfig:
    return CONFIG.with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=512, attn_chunk=32, loss_chunk=32)
