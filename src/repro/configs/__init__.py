"""Architecture config registry — ``--arch <id>`` resolution.

Each module defines the exact published CONFIG plus a ``reduced()`` smoke
variant of the same family (same block pattern, tiny dims).
"""
from __future__ import annotations

from typing import Dict, List

from repro.models.transformer import ArchConfig

from repro.configs import (  # noqa: E402
    internvl2_26b,
    jamba_1_5_large_398b,
    minitron_4b,
    mixtral_8x22b,
    moonshot_v1_16b_a3b,
    qwen1_5_4b,
    qwen2_1_5b,
    smollm_360m,
    whisper_base,
    xlstm_1_3b,
)

_MODULES = {
    "moonshot-v1-16b-a3b": moonshot_v1_16b_a3b,
    "mixtral-8x22b": mixtral_8x22b,
    "xlstm-1.3b": xlstm_1_3b,
    "jamba-1.5-large-398b": jamba_1_5_large_398b,
    "qwen1.5-4b": qwen1_5_4b,
    "minitron-4b": minitron_4b,
    "smollm-360m": smollm_360m,
    "qwen2-1.5b": qwen2_1_5b,
    "internvl2-26b": internvl2_26b,
    "whisper-base": whisper_base,
}

ARCH_NAMES: List[str] = list(_MODULES)


def get_config(name: str, reduced: bool = False) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; one of {ARCH_NAMES}")
    mod = _MODULES[name]
    return mod.reduced() if reduced else mod.CONFIG


def all_configs(reduced: bool = False) -> Dict[str, ArchConfig]:
    return {n: get_config(n, reduced) for n in ARCH_NAMES}
