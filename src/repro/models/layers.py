"""Core transformer building blocks (pure-jnp, GSPMD-friendly).

All functions are shape-polymorphic over leading batch dims and written so
the 512-device dry-run lowers to small HLO:

  * attention is KV-chunked (online softmax) — memory O(S * chunk), never
    O(S^2), differentiable through ``lax.scan``;
  * decode attends against a KV cache with sequence sharding in mind: the
    softmax reductions over the (sharded) cache dimension lower to partial
    reductions + small all-reduces (flash-decoding semantics via GSPMD);
  * every projection is an einsum so GSPMD can propagate shardings.

Parameters are plain nested dicts; init helpers return matching pytrees and
are always invoked under ``jax.eval_shape`` by the dry-run (no allocation).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


# ---------------------------------------------------------------- basic ops

def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * w


def dense(x: jnp.ndarray, w: jnp.ndarray,
          b: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    out = jnp.einsum("...d,df->...f", x, w.astype(x.dtype))
    if b is not None:
        out = out + b.astype(x.dtype)
    return out


def rope(x: jnp.ndarray, positions: jnp.ndarray,
         theta: float = 10000.0) -> jnp.ndarray:
    """Rotary embedding. x: (..., S, H, D), positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :].astype(x.dtype)
    sin = jnp.sin(ang)[..., None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)


def sinusoidal_positions(s: int, d: int, dtype=jnp.float32) -> jnp.ndarray:
    pos = jnp.arange(s)[:, None].astype(jnp.float32)
    div = jnp.exp(jnp.arange(0, d, 2) * (-math.log(10000.0) / d))
    pe = jnp.zeros((s, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe.astype(dtype)


# ------------------------------------------------------------- init helpers

def _winit(rng, shape, fan_in, dtype):
    return (jax.random.normal(rng, shape, jnp.float32)
            * (1.0 / math.sqrt(fan_in))).astype(dtype)


def init_attention(rng, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, qkv_bias: bool, dtype) -> Params:
    rs = jax.random.split(rng, 5)
    p = {
        "ln": jnp.ones((d_model,), dtype),
        "wq": _winit(rs[0], (d_model, n_heads * head_dim), d_model, dtype),
        "wk": _winit(rs[1], (d_model, n_kv_heads * head_dim), d_model, dtype),
        "wv": _winit(rs[2], (d_model, n_kv_heads * head_dim), d_model, dtype),
        "wo": _winit(rs[3], (n_heads * head_dim, d_model),
                     n_heads * head_dim, dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
    return p


def init_mlp(rng, d_model: int, d_ff: int, variant: str, dtype) -> Params:
    rs = jax.random.split(rng, 3)
    if variant == "swiglu":
        return {"ln": jnp.ones((d_model,), dtype),
                "w_gate": _winit(rs[0], (d_model, d_ff), d_model, dtype),
                "w_up": _winit(rs[1], (d_model, d_ff), d_model, dtype),
                "w_down": _winit(rs[2], (d_ff, d_model), d_ff, dtype)}
    return {"ln": jnp.ones((d_model,), dtype),  # gelu (whisper-style)
            "w_in": _winit(rs[0], (d_model, d_ff), d_model, dtype),
            "b_in": jnp.zeros((d_ff,), dtype),
            "w_out": _winit(rs[1], (d_ff, d_model), d_ff, dtype),
            "b_out": jnp.zeros((d_model,), dtype)}


def mlp(x: jnp.ndarray, p: Params, variant: str = "swiglu") -> jnp.ndarray:
    h = rmsnorm(x, p["ln"])
    if variant == "swiglu":
        g = jax.nn.silu(dense(h, p["w_gate"]))
        u = dense(h, p["w_up"])
        return x + dense(g * u, p["w_down"])
    h = jax.nn.gelu(dense(h, p["w_in"], p["b_in"]))
    return x + dense(h, p["w_out"], p["b_out"])


# -------------------------------------------------------- chunked attention
#
# Flash-style attention with a *manual* backward (custom_vjp).  Naive scan
# autodiff would save the per-chunk probabilities -> O(S^2) residuals, which
# is exactly what flash attention exists to avoid.  Forward saves only
# (q, k, v, out, logsumexp) = O(S); backward re-scans over kv chunks
# recomputing probabilities from the saved logsumexp.

def _mask_for(ci, chunk, rows, sk, causal, window):
    cols = ci * chunk + jnp.arange(chunk)
    mask = cols[None, :] < sk
    if causal:
        mask &= cols[None, :] <= rows[:, None]
    if window is not None:
        mask &= cols[None, :] > rows[:, None] - window
    return mask  # (Sq, chunk)


def _chunked_attn_fwd_impl(q, k, v, causal, window, chunk, q_offset):
    """Returns (out (B,Sq,HQ,D), lse (B,KV,G,Sq))."""
    b, sq, hq, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    group = hq // hkv
    chunk = min(chunk, sk)
    n_chunks = -(-sk // chunk)
    pad = n_chunks * chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    scale = 1.0 / math.sqrt(d)
    qg = (q.astype(jnp.float32) * scale).reshape(b, sq, hkv, group, d)
    kc = k.reshape(b, n_chunks, chunk, hkv, d)
    vc = v.reshape(b, n_chunks, chunk, hkv, d)
    rows = q_offset + jnp.arange(sq)

    def step(carry, inp):
        m_prev, l_prev, acc = carry
        kci, vci, ci = inp
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kci.astype(jnp.float32),
                       preferred_element_type=jnp.float32)
        mask = _mask_for(ci, chunk, rows, sk, causal, window)
        s = jnp.where(mask, s, -1e30)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc = (acc * alpha[..., None]
               + jnp.einsum("bhgqk,bkhd->bhgqd", p,
                            vci.astype(jnp.float32),
                            preferred_element_type=jnp.float32))
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, hkv, group, sq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, hkv, group, sq), jnp.float32)
    acc0 = jnp.zeros((b, hkv, group, sq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, acc0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0),
         jnp.arange(n_chunks)))
    lsafe = jnp.where(l == 0, 1.0, l)
    out = acc / lsafe[..., None]
    lse = m + jnp.log(lsafe)
    out = jnp.moveaxis(out, 3, 1).reshape(b, sq, hq, d).astype(q.dtype)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      causal: bool = True, window: Optional[int] = None,
                      chunk: int = 1024,
                      q_offset: int = 0) -> jnp.ndarray:
    """Online-softmax attention, KV-chunked. q:(B,Sq,H,D) k,v:(B,Sk,KV,D).

    Memory O(Sq * chunk) in both passes. ``q_offset``: absolute position of
    q[0] (prefill continuation)."""
    out, _ = _chunked_attn_fwd_impl(q, k, v, causal, window, chunk, q_offset)
    return out


def _chunked_attn_fwd(q, k, v, causal, window, chunk, q_offset):
    out, lse = _chunked_attn_fwd_impl(q, k, v, causal, window, chunk,
                                      q_offset)
    return out, (q, k, v, out, lse)


def _chunked_attn_bwd(causal, window, chunk, q_offset, res, dout):
    q, k, v, out, lse = res
    b, sq, hq, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    group = hq // hkv
    chunk = min(chunk, sk)
    n_chunks = -(-sk // chunk)
    pad = n_chunks * chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    scale = 1.0 / math.sqrt(d)
    qg = (q.astype(jnp.float32) * scale).reshape(b, sq, hkv, group, d)
    dog = dout.astype(jnp.float32).reshape(b, sq, hkv, group, d)
    og = out.astype(jnp.float32).reshape(b, sq, hkv, group, d)
    dog = jnp.moveaxis(dog, 1, 3)   # (B,KV,G,Sq,D)
    og = jnp.moveaxis(og, 1, 3)
    delta = jnp.sum(dog * og, axis=-1)            # (B,KV,G,Sq)
    kc = jnp.moveaxis(k.reshape(b, n_chunks, chunk, hkv, d), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, n_chunks, chunk, hkv, d), 1, 0)
    rows = q_offset + jnp.arange(sq)

    def step(dq, inp):
        kci, vci, ci = inp
        kf = kci.astype(jnp.float32)
        vf = vci.astype(jnp.float32)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kf,
                       preferred_element_type=jnp.float32)
        mask = _mask_for(ci, chunk, rows, sk, causal, window)
        s = jnp.where(mask, s, -1e30)
        p = jnp.where(mask, jnp.exp(s - lse[..., None]), 0.0)
        dv_c = jnp.einsum("bhgqk,bhgqd->bkhd", p, dog)
        dp = jnp.einsum("bhgqd,bkhd->bhgqk", dog, vf)
        ds = p * (dp - delta[..., None])
        dq = dq + jnp.einsum("bhgqk,bkhd->bqhgd", ds, kf) * scale
        dk_c = jnp.einsum("bhgqk,bqhgd->bkhd", ds, qg)  # qg carries scale
        return dq, (dk_c, dv_c)

    dq0 = jnp.zeros((b, sq, hkv, group, d), jnp.float32)
    dq, (dk_c, dv_c) = jax.lax.scan(
        step, dq0, (kc, vc, jnp.arange(n_chunks)))
    dk = jnp.moveaxis(dk_c, 0, 1).reshape(b, n_chunks * chunk, hkv, d)
    dv = jnp.moveaxis(dv_c, 0, 1).reshape(b, n_chunks * chunk, hkv, d)
    dq = dq.reshape(b, sq, hq, d).astype(q.dtype)
    return dq, dk[:, :sk].astype(k.dtype), dv[:, :sk].astype(v.dtype)


chunked_attention.defvjp(_chunked_attn_fwd, _chunked_attn_bwd)


def attention_block(x: jnp.ndarray, p: Params, cfg, positions: jnp.ndarray,
                    causal: bool = True,
                    window: Optional[int] = None,
                    cross_kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
                    ) -> jnp.ndarray:
    """Full attention block (prefill/train path). x: (B, S, D_model)."""
    b, s, _ = x.shape
    h = rmsnorm(x, p["ln"])
    q = dense(h, p["wq"], p.get("bq")).reshape(b, s, cfg.n_heads, cfg.head_dim)
    if cross_kv is None:
        k = dense(h, p["wk"], p.get("bk")).reshape(b, s, cfg.n_kv_heads,
                                                   cfg.head_dim)
        v = dense(h, p["wv"], p.get("bv")).reshape(b, s, cfg.n_kv_heads,
                                                   cfg.head_dim)
        if cfg.use_rope:
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
    else:
        k, v = cross_kv
    out = chunked_attention(q, k, v, causal=causal, window=window,
                            chunk=cfg.attn_chunk)
    out = out.reshape(b, s, cfg.n_heads * cfg.head_dim)
    return x + dense(out, p["wo"])


# ------------------------------------------------------------ decode (KV$)

def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, cache_len: jnp.ndarray,
                     window: Optional[int] = None) -> jnp.ndarray:
    """One-token attention against a cache.

    q: (B, 1, HQ, D); caches: (B, S_max, HKV, D); cache_len: () or (B,).
    The reduction over S_max is GSPMD-shardable (sequence-parallel decode).
    """
    b, _, hq, d = q.shape
    smax, hkv = k_cache.shape[1], k_cache.shape[2]
    group = hq // hkv
    scale = 1.0 / math.sqrt(d)
    qg = (q * scale).reshape(b, hkv, group, d)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache,
                   preferred_element_type=jnp.float32)
    idx = jnp.arange(smax)
    length = jnp.broadcast_to(jnp.asarray(cache_len), (b,))
    mask = idx[None, :] < length[:, None]
    if window is not None:
        mask &= idx[None, :] >= jnp.maximum(length[:, None] - window, 0)
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhgk,bkhd->bhgd", (p / l).astype(v_cache.dtype),
                     v_cache, preferred_element_type=jnp.float32)
    return out.reshape(b, 1, hq, d).astype(q.dtype)


def update_kv_cache(k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                    k_new: jnp.ndarray, v_new: jnp.ndarray,
                    position: jnp.ndarray, ring: bool = False
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Write one token at ``position`` (scalar or per-sequence (B,)).

    ``ring``: modulo wraparound (sliding-window caches store only the last
    ``S_max`` tokens).  Per-sequence positions enable continuous batching —
    each slot in the batch can be at a different decode depth.
    """
    smax = k_cache.shape[1]
    pos = jnp.asarray(position)
    pos = pos % smax if ring else pos
    if pos.ndim == 0:
        k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new, pos, 1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new, pos, 1)
        return k_cache, v_cache
    upd = jax.vmap(lambda c, n, p:
                   jax.lax.dynamic_update_slice_in_dim(c, n, p, 0))
    return upd(k_cache, k_new, pos), upd(v_cache, v_new, pos)


def decode_attention_ring(q, k_cache, v_cache, position,
                          window: int) -> jnp.ndarray:
    """Decode against a ring-buffer window cache (mixtral SWA long-decode).

    The cache holds the last ``S_max`` = window tokens; all valid once full.
    """
    smax = k_cache.shape[1]
    filled = jnp.minimum(jnp.asarray(position) + 1, smax)
    return decode_attention(q, k_cache, v_cache, filled, window=None)
