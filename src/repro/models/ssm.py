"""Sequence-mixing blocks with recurrent state: Mamba, mLSTM, sLSTM.

All three follow the same execution discipline so the 512-device dry-run
stays small and memory-bounded:

  * training/prefill runs as an outer ``lax.scan`` over sequence *chunks*
    with the chunk body wrapped in ``jax.checkpoint`` — only chunk-boundary
    states are saved for backward, never O(S) copies of the matrix state;
  * within a Mamba chunk the linear recurrence is an ``associative_scan``
    (parallel); the LSTM variants are stepwise within the chunk (their gates
    are recurrent by construction);
  * decode is a single-step state update (O(1) per token — this is why these
    archs run the 500k-token cell).

State layouts keep the big axis (d_inner / head value dim) last so the
sharding rules can lay it on the ``model`` mesh axis.
"""
from __future__ import annotations

import math
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import Params, _winit, dense, rmsnorm


# ==========================================================================
# Mamba (selective SSM)
# ==========================================================================

def init_mamba(rng, d_model: int, d_state: int = 16, d_conv: int = 4,
               expand: int = 2, dtype=jnp.bfloat16) -> Params:
    di = expand * d_model
    dt_rank = -(-d_model // 16)
    rs = jax.random.split(rng, 6)
    return {
        "ln": jnp.ones((d_model,), dtype),
        "in_proj": _winit(rs[0], (d_model, 2 * di), d_model, dtype),
        "conv_w": _winit(rs[1], (d_conv, di), d_conv, dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": _winit(rs[2], (di, dt_rank + 2 * d_state), di, dtype),
        "dt_proj": _winit(rs[3], (dt_rank, di), dt_rank, dtype),
        "dt_bias": jnp.full((di,), -4.6, dtype),  # softplus^-1(0.01)
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, d_state + 1, dtype=jnp.float32), (di, d_state))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": _winit(rs[4], (di, d_model), di, dtype),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 hist: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Depthwise causal conv over seq. x: (B,S,di), w: (K,di).

    ``hist``: (B, K-1, di) trailing context from a previous segment (decode
    continuation); zeros when starting fresh.
    """
    k = w.shape[0]
    if hist is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([hist.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    return out + b


def _mamba_ssm_params(x: jnp.ndarray, p: Params, d_state: int):
    """delta (B,S,di), B/C (B,S,N) from the conv output."""
    dt_rank = p["dt_proj"].shape[0]
    dbl = dense(x, p["x_proj"])
    dt, bmat, cmat = jnp.split(dbl, [dt_rank, dt_rank + d_state], axis=-1)
    delta = jax.nn.softplus(dense(dt, p["dt_proj"])
                            + p["dt_bias"].astype(x.dtype))
    return delta, bmat, cmat


def _mamba_chunk(h0, delta, bmat, cmat, x, A):
    """One chunk of the selective scan (parallel via associative_scan).

    h0: (B, di, N); delta/x: (B, C, di); bmat/cmat: (B, C, N); A: (di, N).
    Returns (h_last, y (B, C, di)).
    """
    df = delta.astype(jnp.float32)
    dA = jnp.exp(df[..., None] * A)                              # (B,C,di,N)
    dBx = (df * x.astype(jnp.float32))[..., None] \
        * bmat.astype(jnp.float32)[..., None, :]                 # (B,C,di,N)

    def combine(a, b):
        (a1, b1), (a2, b2) = a, b
        return a2 * a1, a2 * b1 + b2

    a_cum, b_cum = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
    h_all = a_cum * h0[:, None] + b_cum                          # (B,C,di,N)
    y = jnp.einsum("bcdn,bcn->bcd", h_all, cmat.astype(jnp.float32))
    return h_all[:, -1], y


def mamba_mix(x: jnp.ndarray, p: Params, chunk: int = 64,
              state: Optional["MambaState"] = None
              ) -> Tuple[jnp.ndarray, "MambaState"]:
    """Full-sequence Mamba mixer. x: (B,S,D) -> (y, MambaState)."""
    b, s, d = x.shape
    di = p["in_proj"].shape[1] // 2
    n = p["A_log"].shape[1]
    kconv = p["conv_w"].shape[0]
    xz = dense(x, p["in_proj"])
    x_raw, z = jnp.split(xz, 2, axis=-1)
    hist = state.conv if state is not None else None
    xs = jax.nn.silu(_causal_conv(x_raw, p["conv_w"], p["conv_b"], hist))
    # trailing conv context for decode continuation
    if s >= kconv - 1:
        conv_tail = x_raw[:, s - (kconv - 1):]
    else:
        conv_tail = jnp.concatenate(
            [jnp.zeros((b, kconv - 1 - s, di), x_raw.dtype), x_raw], axis=1)
    delta, bmat, cmat = _mamba_ssm_params(xs, p, n)
    A = -jnp.exp(p["A_log"])

    chunk = min(chunk, s)
    n_chunks = -(-s // chunk)
    pad = n_chunks * chunk - s
    if pad:
        # padded timesteps must be state-identity: delta=0 -> dA=1, dBx=0
        delta = jnp.pad(delta, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
        xs_p = jnp.pad(xs, ((0, 0), (0, pad), (0, 0)))
    else:
        xs_p = xs

    def body(h, inp):
        dlt, bm, cm, xc = inp
        h_new, y = _mamba_chunk(h, dlt, bm, cm, xc, A)
        return h_new, y

    h0 = state.h if state is not None else jnp.zeros((b, di, n), jnp.float32)
    resh = lambda t: t.reshape(b, n_chunks, chunk, -1).swapaxes(0, 1)
    h_last, ys = jax.lax.scan(
        jax.checkpoint(body), h0,
        (resh(delta), resh(bmat), resh(cmat), resh(xs_p)))
    y = ys.swapaxes(0, 1).reshape(b, n_chunks * chunk, di)[:, :s]
    y = y.astype(x.dtype) + xs * p["D"].astype(x.dtype)
    return (dense(y * jax.nn.silu(z), p["out_proj"]),
            MambaState(h_last, conv_tail))


class MambaState(NamedTuple):
    h: jnp.ndarray        # (B, di, N) fp32
    conv: jnp.ndarray     # (B, K-1, di) — conv ring buffer


def init_mamba_state(batch: int, p: Params) -> MambaState:
    di = p["in_proj"].shape[1] // 2
    n = p["A_log"].shape[1]
    k = p["conv_w"].shape[0]
    return MambaState(jnp.zeros((batch, di, n), jnp.float32),
                      jnp.zeros((batch, k - 1, di), p["conv_w"].dtype))


def mamba_decode(x: jnp.ndarray, p: Params, st: MambaState
                 ) -> Tuple[jnp.ndarray, MambaState]:
    """Single-token step. x: (B, 1, D)."""
    n = p["A_log"].shape[1]
    xz = dense(x, p["in_proj"])
    xs, z = jnp.split(xz, 2, axis=-1)                 # (B,1,di)
    hist = jnp.concatenate([st.conv, xs], axis=1)     # (B,K,di)
    conv = jnp.einsum("bkd,kd->bd", hist, p["conv_w"]) + p["conv_b"]
    xs1 = jax.nn.silu(conv)[:, None, :]
    delta, bmat, cmat = _mamba_ssm_params(xs1, p, n)
    A = -jnp.exp(p["A_log"])
    df = delta[:, 0].astype(jnp.float32)              # (B,di)
    dA = jnp.exp(df[..., None] * A)
    dBx = (df * xs1[:, 0].astype(jnp.float32))[..., None] \
        * bmat[:, 0].astype(jnp.float32)[:, None, :]
    h = dA * st.h + dBx
    y = jnp.einsum("bdn,bn->bd", h, cmat[:, 0].astype(jnp.float32))
    y = y.astype(x.dtype) + xs1[:, 0] * p["D"].astype(x.dtype)
    out = dense((y * jax.nn.silu(z[:, 0]))[:, None], p["out_proj"])
    return out, MambaState(h, hist[:, 1:])


def mamba_block(x, p, cfg, state=None, decode=False):
    h = rmsnorm(x, p["ln"])
    if decode:
        y, new_state = mamba_decode(h, p, state)
        return x + y, new_state
    y, new_state = mamba_mix(h, p, cfg.ssm_chunk, state)
    return x + y, new_state


# ==========================================================================
# xLSTM — mLSTM (matrix memory) and sLSTM (scalar memory)
# ==========================================================================

def init_mlstm(rng, d_model: int, n_heads: int, dtype=jnp.bfloat16) -> Params:
    dh = d_model // n_heads
    rs = jax.random.split(rng, 7)
    return {
        "ln": jnp.ones((d_model,), dtype),
        "wq": _winit(rs[0], (d_model, d_model), d_model, dtype),
        "wk": _winit(rs[1], (d_model, d_model), d_model, dtype),
        "wv": _winit(rs[2], (d_model, d_model), d_model, dtype),
        "wi": _winit(rs[3], (d_model, n_heads), d_model, jnp.float32),
        "wf": _winit(rs[4], (d_model, n_heads), d_model, jnp.float32),
        "wz": _winit(rs[5], (d_model, d_model), d_model, dtype),
        "wo": _winit(rs[6], (d_model, d_model), d_model, dtype),
    }


class LstmState(NamedTuple):
    c: jnp.ndarray   # mLSTM: (B,H,dk,dv); sLSTM: (B,D)
    n: jnp.ndarray   # mLSTM: (B,H,dk);    sLSTM: (B,D)
    m: jnp.ndarray   # stabilizer: (B,H) / (B,D)


def init_mlstm_state(batch: int, n_heads: int, dh: int) -> LstmState:
    return LstmState(jnp.zeros((batch, n_heads, dh, dh), jnp.float32),
                     jnp.zeros((batch, n_heads, dh), jnp.float32),
                     jnp.full((batch, n_heads), -1e30, jnp.float32))


def _mlstm_step(st: LstmState, q, k, v, i_pre, f_pre):
    """One mLSTM cell step. q/k/v: (B,H,dh); i/f pre-activations: (B,H)."""
    dh = q.shape[-1]
    f_log = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(f_log + st.m, i_pre)
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(f_log + st.m - m_new)
    kf = k.astype(jnp.float32) / math.sqrt(dh)
    c = (f_g[..., None, None] * st.c
         + i_g[..., None, None] * (v.astype(jnp.float32)[..., None, :]
                                   * kf[..., :, None]))
    n = f_g[..., None] * st.n + i_g[..., None] * kf
    qf = q.astype(jnp.float32)
    num = jnp.einsum("bhkv,bhk->bhv", c, qf)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qf)), 1.0)
    h = num / den[..., None]
    return LstmState(c, n, m_new), h


def mlstm_mix(x: jnp.ndarray, p: Params, n_heads: int, chunk: int = 64,
              state: Optional[LstmState] = None
              ) -> Tuple[jnp.ndarray, LstmState]:
    b, s, d = x.shape
    dh = d // n_heads
    q = dense(x, p["wq"]).reshape(b, s, n_heads, dh)
    k = dense(x, p["wk"]).reshape(b, s, n_heads, dh)
    v = dense(x, p["wv"]).reshape(b, s, n_heads, dh)
    i_pre = jnp.einsum("bsd,dh->bsh", x.astype(jnp.float32), p["wi"])
    f_pre = jnp.einsum("bsd,dh->bsh", x.astype(jnp.float32), p["wf"])
    z = dense(x, p["wz"])

    chunk = min(chunk, s)
    n_chunks = -(-s // chunk)
    pad = n_chunks * chunk - s
    if pad:
        # state-identity padding: i-gate -> -inf (no write), f-gate -> keep
        i_pre = jnp.pad(i_pre, ((0, 0), (0, pad), (0, 0)),
                        constant_values=-1e30)
        f_pre = jnp.pad(f_pre, ((0, 0), (0, pad), (0, 0)),
                        constant_values=30.0)

    def padc(t):
        if pad and t.shape[1] != n_chunks * chunk:
            t = jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        return t.reshape(b, n_chunks, chunk, *t.shape[2:]).swapaxes(0, 1)

    def body(st, inp):
        qc, kc, vc, ic, fc = inp

        def inner(st, tup):
            qt, kt, vt, it, ft = tup
            st, h = _mlstm_step(st, qt, kt, vt, it, ft)
            return st, h

        st, hs = jax.lax.scan(
            inner, st, tuple(jnp.swapaxes(t, 0, 1)
                             for t in (qc, kc, vc, ic, fc)))
        return st, jnp.swapaxes(hs, 0, 1)

    st0 = state if state is not None else init_mlstm_state(b, n_heads, dh)
    st, hs = jax.lax.scan(jax.checkpoint(body), st0,
                          (padc(q), padc(k), padc(v), padc(i_pre),
                           padc(f_pre)))
    h = hs.swapaxes(0, 1).reshape(b, n_chunks * chunk, d)[:, :s]
    out = dense(h.astype(x.dtype) * jax.nn.silu(z), p["wo"])
    return out, st


def init_slstm(rng, d_model: int, n_heads: int, dtype=jnp.bfloat16) -> Params:
    dh = d_model // n_heads
    rs = jax.random.split(rng, 9)
    p = {"ln": jnp.ones((d_model,), dtype)}
    for i, g in enumerate("ifzo"):
        p[f"w{g}"] = _winit(rs[i], (d_model, d_model), d_model, dtype)
        p[f"r{g}"] = _winit(rs[4 + i], (n_heads, dh, dh), dh, dtype)
        p[f"b{g}"] = jnp.zeros((d_model,), jnp.float32)
    p["wo_out"] = _winit(rs[8], (d_model, d_model), d_model, dtype)
    return p


class SlstmState(NamedTuple):
    c: jnp.ndarray   # (B, D)
    n: jnp.ndarray   # (B, D)
    m: jnp.ndarray   # (B, D)
    h: jnp.ndarray   # (B, D) — recurrent hidden input to the gates


def init_slstm_state(batch: int, d_model: int) -> SlstmState:
    z = jnp.zeros((batch, d_model), jnp.float32)
    return SlstmState(z, z + 1e-6, z - 1e30, z)


def _slstm_step(p: Params, n_heads: int, st: SlstmState, x_t):
    """x_t: dict of (B,D) pre-projected gate inputs (+ optional 'v' valid
    flag (B,1) — invalid (padded) steps leave the state untouched)."""
    b, d = st.h.shape
    dh = d // n_heads
    hh = st.h.reshape(b, n_heads, dh)

    def gate(g):
        rec = jnp.einsum("bhk,hkv->bhv", hh.astype(jnp.float32),
                         p[f"r{g}"].astype(jnp.float32)).reshape(b, d)
        return x_t[g] + rec + p[f"b{g}"]

    i_pre, f_pre, z_pre, o_pre = (gate(g) for g in "ifzo")
    f_log = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(f_log + st.m, i_pre)
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(f_log + st.m - m_new)
    z_t = jnp.tanh(z_pre)
    c = f_g * st.c + i_g * z_t
    n = f_g * st.n + i_g
    h = jax.nn.sigmoid(o_pre) * c / jnp.maximum(n, 1e-6)
    new = SlstmState(c, n, m_new, h)
    if "v" in x_t:
        v = x_t["v"]
        new = SlstmState(*(v * a + (1.0 - v) * b_
                           for a, b_ in zip(new, st)))
    return new, h


def slstm_mix(x: jnp.ndarray, p: Params, n_heads: int, chunk: int = 64,
              state: Optional[LstmState] = None
              ) -> Tuple[jnp.ndarray, LstmState]:
    b, s, d = x.shape
    xg = {g: jnp.einsum("bsd,df->bsf", x, p[f"w{g}"]).astype(jnp.float32)
          for g in "ifzo"}
    xg["v"] = jnp.ones((b, s, 1), jnp.float32)  # valid-step flag
    chunk = min(chunk, s)
    n_chunks = -(-s // chunk)
    pad = n_chunks * chunk - s
    keys = "ifzov"

    def padc(t):
        if pad:
            t = jnp.pad(t, ((0, 0), (0, pad), (0, 0)))
        return t.reshape(b, n_chunks, chunk, -1).swapaxes(0, 1)

    def body(st, inp):
        def inner(st, x_t):
            st, h = _slstm_step(p, n_heads, st, dict(zip(keys, x_t)))
            return st, h

        st, hs = jax.lax.scan(
            inner, st, tuple(jnp.swapaxes(inp[g], 0, 1) for g in keys))
        return st, jnp.swapaxes(hs, 0, 1)

    st0 = state if state is not None else init_slstm_state(b, d)
    st, hs = jax.lax.scan(
        jax.checkpoint(body), st0, ({g: padc(xg[g]) for g in keys}))
    h = hs.swapaxes(0, 1).reshape(b, n_chunks * chunk, d)[:, :s]
    return dense(h.astype(x.dtype), p["wo_out"]), st


def mlstm_block(x, p, cfg, state=None, decode=False):
    h = rmsnorm(x, p["ln"])
    if decode:
        b = x.shape[0]
        dh = cfg.d_model // cfg.n_heads
        q = dense(h[:, 0], p["wq"]).reshape(b, cfg.n_heads, dh)
        k = dense(h[:, 0], p["wk"]).reshape(b, cfg.n_heads, dh)
        v = dense(h[:, 0], p["wv"]).reshape(b, cfg.n_heads, dh)
        i_pre = h[:, 0].astype(jnp.float32) @ p["wi"]
        f_pre = h[:, 0].astype(jnp.float32) @ p["wf"]
        z = dense(h[:, 0], p["wz"])
        st, hh = _mlstm_step(state, q, k, v, i_pre, f_pre)
        hh = hh.reshape(b, cfg.d_model)
        out = dense((hh.astype(x.dtype) * jax.nn.silu(z))[:, None], p["wo"])
        return x + out, st
    y, st = mlstm_mix(h, p, cfg.n_heads, cfg.ssm_chunk, state)
    return x + y, st


def slstm_block(x, p, cfg, state=None, decode=False):
    h = rmsnorm(x, p["ln"])
    if decode:
        xt = {g: (h[:, 0] @ p[f"w{g}"]).astype(jnp.float32) for g in "ifzo"}
        st, hh = _slstm_step(p, cfg.n_heads, state, xt)
        out = dense(hh.astype(x.dtype)[:, None], p["wo_out"])
        return x + out, st
    y, st = slstm_mix(h, p, cfg.n_heads, cfg.ssm_chunk, state)
    return x + y, st
