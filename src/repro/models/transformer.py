"""Unified block-stack LM covering all 10 assigned architectures.

An architecture is a *period pattern* of (mixer, ffn) pairs — e.g. jamba is
period 8: one attention layer, seven mamba layers, MoE on every other FFN.
The layer stack is ``lax.scan`` over period repeats with weights stacked on a
leading repeat axis, so HLO size is O(period), not O(n_layers) — essential
for compiling 72-layer models against a 512-device mesh.

Mixers:  attn | swa | mamba | mlstm | slstm | none
FFNs:    mlp  | moe | gelu  | none

Three entry points (built by ``repro.train.steps``):
  train:   tokens -> chunked-softmax xent loss (never materializes B,S,V)
  prefill: tokens -> logits for the last position + a decode cache
  decode:  one token + cache -> next-token logits + updated cache
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM

Params = Dict[str, Any]

# ---------------------------------------------------------------------------
# Activation sharding constraints.  GSPMD left alone resolves the
# FSDP-weight vs batch-sharded-activation einsum conflict by all-gathering
# the *batch* (catastrophic).  Step builders register the batch mesh axes
# here; the stack re-constrains x at every block boundary so the batch stays
# sharded and XLA all-gathers the (much smaller) per-layer weights instead.

_BATCH_AXES: Optional[Tuple[str, ...]] = None
_SEQ_AXIS: Optional[str] = None
_SEQ_DIVISOR: int = 1


def set_batch_axes(axes, seq_axis: Optional[str] = None,
                   seq_divisor: int = 1) -> None:
    """``seq_axis``: sequence-parallel residual stream (Megatron-SP style) —
    norms/elementwise run seq-sharded and the per-layer TP all-reduce of the
    (B,S,D) stream becomes a cheaper gather/scatter pair."""
    global _BATCH_AXES, _SEQ_AXIS, _SEQ_DIVISOR
    _BATCH_AXES = axes
    _SEQ_AXIS = seq_axis
    _SEQ_DIVISOR = max(seq_divisor, 1)


def constrain_batch(x: jnp.ndarray) -> jnp.ndarray:
    if _BATCH_AXES is None and _SEQ_AXIS is None:
        return x
    spec = [None] * x.ndim
    spec[0] = _BATCH_AXES
    if (_SEQ_AXIS is not None and x.ndim == 3
            and x.shape[1] % _SEQ_DIVISOR == 0 and x.shape[1] > 1):
        spec[1] = _SEQ_AXIS
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except (ValueError, RuntimeError):  # no mesh context (plain CPU tests)
        return x


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense|moe|ssm|hybrid|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    pattern: Tuple[Tuple[str, str], ...] = (("attn", "mlp"),)
    # attention
    qkv_bias: bool = False
    swa_window: Optional[int] = None
    use_rope: bool = True
    rope_theta: float = 10000.0
    attn_chunk: int = 1024
    # moe
    n_experts: int = 0
    moe_top_k: int = 0
    moe_capacity_factor: float = 1.25
    moe_group_size: int = 2048
    moe_impl: str = "dropping"
    aux_loss_weight: float = 0.01
    # ssm
    ssm_chunk: int = 64
    d_state: int = 16
    # structure
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 0            # audio frames fed by the frontend stub
    vision_prefix: int = 0      # VLM patch embeddings fed by the stub
    mlp_variant: str = "swiglu"
    # numerics / memory
    dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.bfloat16
    remat: bool = True
    loss_chunk: int = 512
    # long-context support marker (sub-quadratic mixers or SWA)
    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def period(self) -> int:
        return len(self.pattern)

    @property
    def repeats(self) -> int:
        assert self.n_layers % self.period == 0, (self.n_layers, self.period)
        return self.n_layers // self.period

    @property
    def sub_quadratic(self) -> bool:
        mixers = {m for m, _ in self.pattern}
        return bool(mixers & {"mamba", "mlstm", "slstm"}) or (
            "attn" not in mixers and "swa" in mixers
            and self.swa_window is not None)

    def with_(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


# --------------------------------------------------------------------- init

def _init_one_layer(rng, cfg: ArchConfig, mixer: str, ffn: str,
                    cross: bool) -> Params:
    rs = jax.random.split(rng, 3)
    p: Params = {}
    dt = cfg.param_dtype
    if mixer in ("attn", "swa"):
        p["mix"] = L.init_attention(rs[0], cfg.d_model, cfg.n_heads,
                                    cfg.n_kv_heads, cfg.head_dim,
                                    cfg.qkv_bias, dt)
    elif mixer == "mamba":
        p["mix"] = SSM.init_mamba(rs[0], cfg.d_model, cfg.d_state, dtype=dt)
    elif mixer == "mlstm":
        p["mix"] = SSM.init_mlstm(rs[0], cfg.d_model, cfg.n_heads, dt)
    elif mixer == "slstm":
        p["mix"] = SSM.init_slstm(rs[0], cfg.d_model, cfg.n_heads, dt)
    if cross:
        p["cross"] = L.init_attention(rs[2], cfg.d_model, cfg.n_heads,
                                      cfg.n_kv_heads, cfg.head_dim, False, dt)
    if ffn == "moe":
        p["ffn"] = MOE.init_moe(rs[1], cfg.d_model, cfg.d_ff, cfg.n_experts,
                                dt)
    elif ffn in ("mlp", "gelu"):
        variant = "swiglu" if ffn == "mlp" else "gelu"
        p["ffn"] = L.init_mlp(rs[1], cfg.d_model, cfg.d_ff, variant, dt)
    return p


def _init_stack(rng, cfg: ArchConfig, n_layers: int, cross: bool
                ) -> Tuple[Params, ...]:
    """Stacked params per period position: tuple_p of pytrees (R, ...)."""
    period = cfg.period
    repeats = n_layers // period
    out = []
    for pidx, (mixer, ffn) in enumerate(cfg.pattern):
        keys = jax.random.split(jax.random.fold_in(rng, pidx), repeats)
        out.append(jax.vmap(
            lambda k: _init_one_layer(k, cfg, mixer, ffn, cross))(keys))
    return tuple(out)


def init_params(rng, cfg: ArchConfig) -> Params:
    rs = jax.random.split(rng, 5)
    dt = cfg.param_dtype
    scale = 1.0 / math.sqrt(cfg.d_model)
    params: Params = {
        "embed": (jax.random.normal(rs[0], (cfg.vocab, cfg.d_model),
                                    jnp.float32) * scale).astype(dt),
        "final_ln": jnp.ones((cfg.d_model,), dt),
        "lm_head": (jax.random.normal(rs[1], (cfg.d_model, cfg.vocab),
                                      jnp.float32) * scale).astype(dt),
        "layers": _init_stack(rs[2], cfg, cfg.n_layers, cross=cfg.enc_dec),
    }
    if cfg.enc_dec:
        enc_cfg = cfg.with_(pattern=(("attn", "gelu"),))
        params["enc_layers"] = _init_stack(rs[3], enc_cfg, cfg.n_enc_layers,
                                           cross=False)
        params["enc_ln"] = jnp.ones((cfg.d_model,), dt)
    return params


def param_count(params: Params) -> int:
    return sum(int(jnp.size(l)) for l in jax.tree.leaves(params))


def abstract_params(rng, cfg: ArchConfig) -> Params:
    """ShapeDtypeStruct tree — dry-run init without allocation."""
    return jax.eval_shape(lambda r: init_params(r, cfg), rng)


# ------------------------------------------------------------------- blocks

def _apply_block(x, p, cfg: ArchConfig, mixer: str, ffn: str,
                 positions, causal: bool,
                 enc_kv=None):
    """Training/prefill block. Returns (x, aux, cache_entry)."""
    aux = jnp.zeros((), jnp.float32)
    cache: Dict[str, Any] = {}
    if mixer in ("attn", "swa"):
        window = cfg.swa_window if mixer == "swa" else None
        b, s, _ = x.shape
        h = L.rmsnorm(x, p["mix"]["ln"])
        q = L.dense(h, p["mix"]["wq"], p["mix"].get("bq")) \
            .reshape(b, s, cfg.n_heads, cfg.head_dim)
        k = L.dense(h, p["mix"]["wk"], p["mix"].get("bk")) \
            .reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
        v = L.dense(h, p["mix"]["wv"], p["mix"].get("bv")) \
            .reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
        if cfg.use_rope:
            q = L.rope(q, positions, cfg.rope_theta)
            k = L.rope(k, positions, cfg.rope_theta)
        out = L.chunked_attention(q, k, v, causal=causal, window=window,
                                  chunk=cfg.attn_chunk)
        x = x + L.dense(out.reshape(b, s, -1), p["mix"]["wo"])
        cache["k"], cache["v"] = k, v
    elif mixer == "mamba":
        x, st = SSM.mamba_block(x, p["mix"], cfg)
        cache["ssm"] = st
    elif mixer == "mlstm":
        x, st = SSM.mlstm_block(x, p["mix"], cfg)
        cache["lstm"] = st
    elif mixer == "slstm":
        x, st = SSM.slstm_block(x, p["mix"], cfg)
        cache["slstm"] = st

    if enc_kv is not None and "cross" in p:
        x = L.attention_block(x, p["cross"], cfg, positions, causal=False,
                              cross_kv=enc_kv)

    if ffn == "moe":
        x, aux = MOE.moe_block(x, p["ffn"], cfg)
    elif ffn in ("mlp", "gelu"):
        x = L.mlp(x, p["ffn"], "swiglu" if ffn == "mlp" else "gelu")
    return x, aux, cache


def _run_stack(x, stack, cfg: ArchConfig, pattern, positions, causal,
               enc_out=None, collect_cache: bool = False):
    """Scan over period repeats. Returns (x, aux_total, caches per pos)."""

    def one_block(x, p, positions, enc_kv, mixer, ffn):
        x = constrain_batch(_grad_cast(x))
        x, aux_i, cache = _apply_block(x, p, cfg, mixer, ffn, positions,
                                       causal, enc_kv)
        return constrain_batch(x), aux_i, cache

    if cfg.remat:
        # nested remat: backward re-materializes one block at a time, so the
        # peak holds a single block's internals, not the whole period's
        block_fns = {
            (mixer, ffn): jax.checkpoint(
                partial(one_block, mixer=mixer, ffn=ffn),
                static_argnums=())
            for mixer, ffn in set(pattern)}
    else:
        block_fns = {(mixer, ffn): partial(one_block, mixer=mixer, ffn=ffn)
                     for mixer, ffn in set(pattern)}

    def period_body(carry, layer_params):
        x, aux = carry
        caches = []
        for pidx, (mixer, ffn) in enumerate(pattern):
            p = layer_params[pidx]
            enc_kv = None
            if enc_out is not None and "cross" in p:
                b, f, _ = enc_out.shape
                k_enc = L.dense(enc_out, p["cross"]["wk"]) \
                    .reshape(b, f, cfg.n_kv_heads, cfg.head_dim)
                v_enc = L.dense(enc_out, p["cross"]["wv"]) \
                    .reshape(b, f, cfg.n_kv_heads, cfg.head_dim)
                enc_kv = (k_enc, v_enc)
                caches_entry_extra = {"xk": k_enc, "xv": v_enc}
            x, aux_i, cache = block_fns[(mixer, ffn)](x, p, positions,
                                                      enc_kv)
            if enc_out is not None and "cross" in p:
                cache.update(caches_entry_extra)
            aux = aux + aux_i
            caches.append(cache)
        return (x, aux), tuple(caches) if collect_cache else None

    # outer remat: the scan saves only the period-boundary carry; inner
    # per-block remat (above) keeps the period backward to one block's
    # internals at a time.
    body = jax.checkpoint(period_body) if cfg.remat else period_body
    (x, aux), caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                    stack)
    return x, aux, caches


# ------------------------------------------------------------------ forward

def embed_inputs(params: Params, batch: Dict[str, jnp.ndarray],
                 cfg: ArchConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Token/frontend embedding. Returns (x (B,S,D), positions (B,S))."""
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    if cfg.vision_prefix:
        patches = batch["patches"].astype(cfg.dtype)   # (B, P, D) stub
        x = jnp.concatenate([patches, x], axis=1)
    x = constrain_batch(x)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    return x, positions


def encode(params: Params, batch: Dict[str, jnp.ndarray],
           cfg: ArchConfig) -> jnp.ndarray:
    """Whisper-style encoder over precomputed frames (frontend stub)."""
    frames = batch["frames"].astype(cfg.dtype)          # (B, F, D)
    b, f, _ = frames.shape
    x = frames + L.sinusoidal_positions(f, cfg.d_model, cfg.dtype)
    pos = jnp.broadcast_to(jnp.arange(f), (b, f))
    enc_cfg = cfg.with_(pattern=(("attn", "gelu"),), use_rope=False)
    x, _, _ = _run_stack(x, params["enc_layers"], enc_cfg,
                         enc_cfg.pattern, pos, causal=False)
    return L.rmsnorm(x, params["enc_ln"])


def hidden_states(params: Params, batch: Dict[str, jnp.ndarray],
                  cfg: ArchConfig, collect_cache: bool = False):
    """Full forward to final hidden states. Returns (h, aux, caches, enc)."""
    x, positions = embed_inputs(params, batch, cfg)
    enc_out = encode(params, batch, cfg) if cfg.enc_dec else None
    x, aux, caches = _run_stack(x, params["layers"], cfg, cfg.pattern,
                                positions, causal=True, enc_out=enc_out,
                                collect_cache=collect_cache)
    return L.rmsnorm(x, params["final_ln"]), aux, caches, enc_out


@jax.custom_vjp
def _grad_cast(x):
    """Identity; casts the cotangent back to x.dtype.  Without this the f32
    loss math promotes the entire backward residual stream to f32 (2x
    activation-grad memory and bandwidth)."""
    return x


def _grad_cast_fwd(x):
    return x, jnp.zeros((0,), x.dtype)  # dtype token (residuals must be jax types)


def _grad_cast_bwd(token, g):
    return (g.astype(token.dtype),)


_grad_cast.defvjp(_grad_cast_fwd, _grad_cast_bwd)


def chunked_xent(h: jnp.ndarray, lm_head: jnp.ndarray,
                 labels: jnp.ndarray, chunk: int) -> Tuple[jnp.ndarray,
                                                           jnp.ndarray]:
    """Cross entropy over seq chunks — never materializes (B, S, V).

    labels < 0 are masked. Returns (sum_nll, n_tokens).
    """
    b, s, d = h.shape
    chunk = min(chunk, s)
    n_chunks = -(-s // chunk)
    pad = n_chunks * chunk - s
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    hc = h.reshape(b, n_chunks, chunk, d).swapaxes(0, 1)
    lc = labels.reshape(b, n_chunks, chunk).swapaxes(0, 1)

    def step(carry, inp):
        nll, cnt = carry
        hi, li = inp
        hi = constrain_batch(hi)
        logits = jnp.einsum("bsd,dv->bsv", hi,
                            lm_head.astype(hi.dtype)).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(li, 0)[..., None], axis=-1)[..., 0]
        mask = (li >= 0).astype(jnp.float32)
        nll = nll + jnp.sum((lse - gold) * mask)
        cnt = cnt + jnp.sum(mask)
        return (nll, cnt), None

    body = jax.checkpoint(step)
    (nll, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hc, lc))
    return nll, cnt


def loss_fn(params: Params, batch: Dict[str, jnp.ndarray],
            cfg: ArchConfig) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    h, aux, _, _ = hidden_states(params, batch, cfg)
    h = _grad_cast(h)
    labels = batch["labels"]
    if cfg.vision_prefix:  # loss only over the text segment
        b = labels.shape[0]
        pad = jnp.full((b, cfg.vision_prefix), -1, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    nll, cnt = chunked_xent(h, params["lm_head"], labels, cfg.loss_chunk)
    loss = nll / jnp.maximum(cnt, 1.0)
    total = loss + cfg.aux_loss_weight * aux / max(cfg.n_layers, 1)
    return total, {"nll": loss, "aux": aux, "tokens": cnt}


def logits_last(params: Params, h: jnp.ndarray, cfg: ArchConfig
                ) -> jnp.ndarray:
    """Logits for the last position only. h: (B, S, D) -> (B, V)."""
    return jnp.einsum("bd,dv->bv", h[:, -1],
                      params["lm_head"].astype(h.dtype)).astype(jnp.float32)


# ------------------------------------------------------------------- decode

def _cache_seq_len(cfg: ArchConfig, mixer: str, max_len: int) -> int:
    """SWA layers keep a ring buffer of ``window`` tokens, never more."""
    if mixer == "swa" and cfg.swa_window is not None:
        return min(max_len, cfg.swa_window)
    return max_len


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> Params:
    """Zero decode cache: per period position, stacked over repeats."""
    r = cfg.repeats
    dt = cfg.dtype
    layers = []
    for mixer, _ in cfg.pattern:
        entry: Dict[str, Any] = {}
        if mixer in ("attn", "swa"):
            c = _cache_seq_len(cfg, mixer, max_len)
            kv = (r, batch, c, cfg.n_kv_heads, cfg.head_dim)
            entry["k"] = jnp.zeros(kv, dt)
            entry["v"] = jnp.zeros(kv, dt)
        elif mixer == "mamba":
            st = SSM.init_mamba_state(
                batch, jax.tree.map(lambda x: x[0],
                                    _dummy_mamba_params(cfg)))
            entry["ssm"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (r, *x.shape)), st)
        elif mixer == "mlstm":
            st = SSM.init_mlstm_state(batch, cfg.n_heads, cfg.head_dim)
            entry["lstm"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (r, *x.shape)), st)
        elif mixer == "slstm":
            st = SSM.init_slstm_state(batch, cfg.d_model)
            entry["slstm"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (r, *x.shape)), st)
        if cfg.enc_dec:
            kv = (r, batch, cfg.enc_seq, cfg.n_kv_heads, cfg.head_dim)
            entry["xk"] = jnp.zeros(kv, dt)
            entry["xv"] = jnp.zeros(kv, dt)
        layers.append(entry)
    # per-sequence positions: each batch slot may be at a different depth
    return {"pos": jnp.zeros((batch,), jnp.int32), "layers": tuple(layers)}


def _dummy_mamba_params(cfg: ArchConfig):
    di = 2 * cfg.d_model
    return {"in_proj": jnp.zeros((1, cfg.d_model, 2 * di), cfg.dtype),
            "A_log": jnp.zeros((1, di, cfg.d_state), jnp.float32),
            "conv_w": jnp.zeros((1, 4, di), cfg.dtype)}


def _decode_block(x, p, cfg: ArchConfig, mixer: str, ffn: str,
                  entry, pos):
    """One-token block. x: (B,1,D). Returns (x, updated cache entry)."""
    new = dict(entry)
    if mixer in ("attn", "swa"):
        b = x.shape[0]
        window = cfg.swa_window if mixer == "swa" else None
        ring = (mixer == "swa" and cfg.swa_window is not None
                and entry["k"].shape[1] <= cfg.swa_window)
        h = L.rmsnorm(x, p["mix"]["ln"])
        q = L.dense(h, p["mix"]["wq"], p["mix"].get("bq")) \
            .reshape(b, 1, cfg.n_heads, cfg.head_dim)
        k = L.dense(h, p["mix"]["wk"], p["mix"].get("bk")) \
            .reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
        v = L.dense(h, p["mix"]["wv"], p["mix"].get("bv")) \
            .reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
        if cfg.use_rope:
            pp = jnp.broadcast_to(jnp.reshape(pos, (-1, 1))
                                  if jnp.ndim(pos) else pos, (b, 1))
            q = L.rope(q, pp, cfg.rope_theta)
            k = L.rope(k, pp, cfg.rope_theta)
        kc, vc = L.update_kv_cache(entry["k"], entry["v"], k, v, pos,
                                   ring=ring)
        if ring:
            out = L.decode_attention_ring(q, kc, vc, pos, cfg.swa_window)
        else:
            out = L.decode_attention(q, kc, vc, pos + 1, window=window)
        x = x + L.dense(out.reshape(b, 1, -1), p["mix"]["wo"])
        new["k"], new["v"] = kc, vc
    elif mixer == "mamba":
        x, st = SSM.mamba_block(x, p["mix"], cfg, SSM.MambaState(*entry["ssm"]),
                                decode=True)
        new["ssm"] = st
    elif mixer == "mlstm":
        x, st = SSM.mlstm_block(x, p["mix"], cfg, SSM.LstmState(*entry["lstm"]),
                                decode=True)
        new["lstm"] = st
    elif mixer == "slstm":
        x, st = SSM.slstm_block(x, p["mix"], cfg,
                                SSM.SlstmState(*entry["slstm"]), decode=True)
        new["slstm"] = st

    if cfg.enc_dec and "cross" in p:
        b = x.shape[0]
        h = L.rmsnorm(x, p["cross"]["ln"])
        q = L.dense(h, p["cross"]["wq"]) \
            .reshape(b, 1, cfg.n_heads, cfg.head_dim)
        out = L.decode_attention(q, entry["xk"], entry["xv"],
                                 jnp.asarray(cfg.enc_seq, jnp.int32))
        x = x + L.dense(out.reshape(b, 1, -1), p["cross"]["wo"])

    if ffn == "moe":
        x, _ = MOE.moe_block(x, p["ffn"], cfg)
    elif ffn in ("mlp", "gelu"):
        x = L.mlp(x, p["ffn"], "swiglu" if ffn == "mlp" else "gelu")
    return x, new


def decode_step(params: Params, cache: Params, tokens: jnp.ndarray,
                cfg: ArchConfig) -> Tuple[jnp.ndarray, Params]:
    """One decode step. tokens: (B, 1) -> (logits (B, V), new cache)."""
    pos = cache["pos"]
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)

    def body(x, slices):
        layer_params, entries = slices
        new_entries = []
        for pidx, (mixer, ffn) in enumerate(cfg.pattern):
            x, new = _decode_block(x, layer_params[pidx], cfg, mixer, ffn,
                                   entries[pidx], pos)
            new_entries.append(new)
        return x, tuple(new_entries)

    x, new_layers = jax.lax.scan(body, x, (params["layers"],
                                           cache["layers"]))
    h = L.rmsnorm(x, params["final_ln"])
    logits = logits_last(params, h, cfg)
    return logits, {"pos": pos + 1, "layers": new_layers}


def prefill(params: Params, batch: Dict[str, jnp.ndarray], cfg: ArchConfig,
            max_len: int) -> Tuple[jnp.ndarray, Params]:
    """Prefill: full forward, build a decode cache padded to ``max_len``."""
    h, _, caches, enc_out = hidden_states(params, batch, cfg,
                                          collect_cache=True)
    s = h.shape[1]
    layers = []
    for pidx, (mixer, _) in enumerate(cfg.pattern):
        entry = dict(caches[pidx]) if caches is not None else {}
        if mixer in ("attn", "swa"):
            c = _cache_seq_len(cfg, mixer, max_len)
            k, v = entry.pop("k"), entry.pop("v")          # (R,B,S,KV,Dh)
            if c >= s:
                padw = ((0, 0), (0, 0), (0, c - s), (0, 0), (0, 0))
                entry["k"] = jnp.pad(k, padw)
                entry["v"] = jnp.pad(v, padw)
            else:  # ring: keep the last c tokens, rotated so that
                   # slot (s % c) is the oldest (next write target)
                k, v = k[:, :, s - c:], v[:, :, s - c:]
                shift = s % c
                idx = (jnp.arange(c) - shift) % c
                entry["k"] = k[:, :, idx]
                entry["v"] = v[:, :, idx]
        layers.append(entry)
    logits = logits_last(params, h, cfg)
    b = h.shape[0]
    return logits, {"pos": jnp.full((b,), s, jnp.int32),
                    "layers": tuple(layers)}
