"""Mixture-of-Experts layer (token-choice top-k router).

Two execution strategies, selected by ``cfg.moe_impl``:

* ``dense``    — every expert computes every token, router probs zero out the
                 unselected ones.  Exact top-k math, O(E/k) extra FLOPs;
                 used by reduced smoke tests (tiny E).
* ``dropping`` — capacity-based dispatch in token groups (the standard GSPMD
                 MoE): one-hot combine/dispatch einsums sized
                 (groups, group_tokens, E, capacity).  Expert weights carry
                 the expert dim so the sharding rules can lay experts across
                 the ``model`` axis (EP) or shard d_ff instead (TP fallback
                 when E doesn't divide the axis).

Aux: load-balancing loss (Switch-style) is returned for the trainer.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import Params, _winit, dense, rmsnorm


def init_moe(rng, d_model: int, d_ff: int, n_experts: int, dtype) -> Params:
    rs = jax.random.split(rng, 4)
    return {
        "ln": jnp.ones((d_model,), dtype),
        "router": _winit(rs[0], (d_model, n_experts), d_model, jnp.float32),
        "w_gate": _winit(rs[1], (n_experts, d_model, d_ff), d_model, dtype),
        "w_up": _winit(rs[2], (n_experts, d_model, d_ff), d_model, dtype),
        "w_down": _winit(rs[3], (n_experts, d_ff, d_model), d_ff, dtype),
    }


def _router(h: jnp.ndarray, p: Params, top_k: int
            ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (probs (..., E) with only top-k nonzero, idx (..., k), aux)."""
    logits = jnp.einsum("...d,de->...e", h.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, top_k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    mask = jax.nn.one_hot(top_i, logits.shape[-1], dtype=probs.dtype)
    sparse_p = jnp.einsum("...ke,...k->...e", mask, top_p)
    # Switch load-balance loss: E * sum_e f_e * p_e
    e = logits.shape[-1]
    f = jnp.mean(mask.sum(-2).reshape(-1, e), axis=0)  # fraction routed
    pbar = jnp.mean(probs.reshape(-1, e), axis=0)
    aux = e * jnp.sum(f * pbar)
    return sparse_p, top_i, aux


def moe_dense(x: jnp.ndarray, p: Params, top_k: int
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    h = rmsnorm(x, p["ln"])
    sparse_p, _, aux = _router(h, p, top_k)
    g = jax.nn.silu(jnp.einsum("...d,edf->...ef", h, p["w_gate"].astype(x.dtype)))
    u = jnp.einsum("...d,edf->...ef", h, p["w_up"].astype(x.dtype))
    y = jnp.einsum("...ef,efd->...ed", g * u, p["w_down"].astype(x.dtype))
    out = jnp.einsum("...ed,...e->...d", y, sparse_p.astype(x.dtype))
    return x + out, aux


def moe_dropping(x: jnp.ndarray, p: Params, top_k: int,
                 capacity_factor: float = 1.25,
                 group_size: int = 2048) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Capacity-based dispatch (GSPMD MoE). x: (B, S, D)."""
    b, s, d = x.shape
    e = p["router"].shape[-1]
    h = rmsnorm(x, p["ln"])
    tokens = h.reshape(-1, d)
    n = tokens.shape[0]
    g_sz = min(group_size, n)
    n_groups = -(-n // g_sz)
    pad = n_groups * g_sz - n
    if pad:
        tokens = jnp.pad(tokens, ((0, pad), (0, 0)))
    grp = tokens.reshape(n_groups, g_sz, d)

    sparse_p, top_i, aux = _router(grp, p, top_k)          # (G, T, E)
    cap = max(int(g_sz * top_k / e * capacity_factor), 4)

    # position of each token within its expert's capacity buffer
    expert_mask = jax.nn.one_hot(top_i, e, dtype=jnp.int32)   # (G,T,k,E)
    pos_in_expert = (jnp.cumsum(expert_mask.sum(2), axis=1)
                     - expert_mask.sum(2))                    # (G,T,E)
    keep = pos_in_expert < cap
    disp = (jax.nn.one_hot(pos_in_expert, cap, dtype=x.dtype)
            * (expert_mask.sum(2) * keep)[..., None].astype(x.dtype))
    # disp: (G, T, E, C) 0/1 dispatch tensor
    comb = disp * sparse_p[..., None].astype(x.dtype)         # weighted

    xin = jnp.einsum("gtec,gtd->gecd", disp, grp)             # (G,E,C,D)
    gact = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xin,
                                  p["w_gate"].astype(x.dtype)))
    uact = jnp.einsum("gecd,edf->gecf", xin, p["w_up"].astype(x.dtype))
    yout = jnp.einsum("gecf,efd->gecd", gact * uact,
                      p["w_down"].astype(x.dtype))
    out = jnp.einsum("gtec,gecd->gtd", comb, yout)            # (G,T,D)
    out = out.reshape(-1, d)[:n].reshape(b, s, d)
    return x + out, aux


def moe_block(x: jnp.ndarray, p: Params, cfg) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if cfg.moe_impl == "dense":
        return moe_dense(x, p, cfg.moe_top_k)
    return moe_dropping(x, p, cfg.moe_top_k, cfg.moe_capacity_factor,
                        cfg.moe_group_size)
