"""The paper's evaluation networks: AlexNet, VGG-11/13/16/19, ResNet-18/34.

Two uses:
  1. ``conv_specs(name)`` — the per-layer conv workloads ARCO tunes.  The
     layer counts reproduce Table 3 exactly (AlexNet 5, VGG-11 8, VGG-13 10,
     VGG-16 13, VGG-19 16, ResNet-18 17, ResNet-34 33 convolution tasks;
     ResNet downsample 1x1 projections are part of the blocks but, as in the
     paper's task extraction, only the main-path convs count).
  2. ``init_params`` / ``apply`` — a runnable NHWC JAX forward pass whose conv
     layers execute through the tunable Pallas GEMM core (``kernels.ops``),
     so a tuned configuration is actually *deployable* on the model.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.kernels.gemm import GemmConfig

MODELS = ("alexnet", "vgg-11", "vgg-13", "vgg-16", "vgg-19",
          "resnet-18", "resnet-34")


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    name: str
    h: int
    w: int
    ci: int
    co: int
    kh: int
    kw: int
    stride: int
    pad: int

    def workload(self, batch: int = 1) -> Dict[str, int]:
        return dict(b=batch, h=self.h, w=self.w, ci=self.ci, co=self.co,
                    kh=self.kh, kw=self.kw, stride=self.stride, pad=self.pad)

    def out_hw(self) -> Tuple[int, int]:
        oh = (self.h + 2 * self.pad - self.kh) // self.stride + 1
        ow = (self.w + 2 * self.pad - self.kw) // self.stride + 1
        return oh, ow

    def flops(self, batch: int = 1) -> float:
        oh, ow = self.out_hw()
        return 2.0 * batch * oh * ow * self.co * self.ci * self.kh * self.kw


_VGG_STAGES = {
    "vgg-11": (1, 1, 2, 2, 2),
    "vgg-13": (2, 2, 2, 2, 2),
    "vgg-16": (2, 2, 3, 3, 3),
    "vgg-19": (2, 2, 4, 4, 4),
}
_VGG_CH = (64, 128, 256, 512, 512)

_RESNET_BLOCKS = {"resnet-18": (2, 2, 2, 2), "resnet-34": (3, 4, 6, 3)}
_RESNET_CH = (64, 128, 256, 512)


def conv_specs(model: str) -> List[ConvSpec]:
    model = model.lower()
    specs: List[ConvSpec] = []
    if model == "alexnet":
        specs = [
            ConvSpec("conv1", 224, 224, 3, 64, 11, 11, 4, 2),
            ConvSpec("conv2", 27, 27, 64, 192, 5, 5, 1, 2),
            ConvSpec("conv3", 13, 13, 192, 384, 3, 3, 1, 1),
            ConvSpec("conv4", 13, 13, 384, 256, 3, 3, 1, 1),
            ConvSpec("conv5", 13, 13, 256, 256, 3, 3, 1, 1),
        ]
    elif model in _VGG_STAGES:
        h, ci = 224, 3
        i = 0
        for stage, (reps, co) in enumerate(zip(_VGG_STAGES[model], _VGG_CH)):
            for r in range(reps):
                i += 1
                specs.append(ConvSpec(f"conv{i}", h, h, ci, co, 3, 3, 1, 1))
                ci = co
            h //= 2  # maxpool 2x2/2 after each stage
    elif model in _RESNET_BLOCKS:
        specs.append(ConvSpec("conv1", 224, 224, 3, 64, 7, 7, 2, 3))
        h, ci = 56, 64  # after maxpool 3x3/2
        i = 1
        for stage, (reps, co) in enumerate(zip(_RESNET_BLOCKS[model],
                                               _RESNET_CH)):
            for r in range(reps):
                stride = 2 if (stage > 0 and r == 0) else 1
                i += 1
                specs.append(ConvSpec(f"conv{i}a", h, h, ci, co, 3, 3,
                                      stride, 1))
                h_out = h // stride
                i_b = f"conv{i}b"
                specs.append(ConvSpec(i_b, h_out, h_out, co, co, 3, 3, 1, 1))
                ci, h = co, h_out
    else:
        raise ValueError(f"unknown model {model!r}; one of {MODELS}")
    return specs


def expected_task_count(model: str) -> int:
    """Table 3 'Number of Convolution Tasks'."""
    return {"alexnet": 5, "vgg-11": 8, "vgg-13": 10, "vgg-16": 13,
            "vgg-19": 16, "resnet-18": 17, "resnet-34": 33}[model.lower()]


# --------------------------------------------------------------------------
# Runnable forward pass (NHWC), conv layers via the tunable GEMM core
# --------------------------------------------------------------------------

def _conv_init(rng, spec: ConvSpec):
    fan_in = spec.kh * spec.kw * spec.ci
    w = jax.random.normal(rng, (spec.kh, spec.kw, spec.ci, spec.co),
                          jnp.float32) * np.sqrt(2.0 / fan_in)
    return {"w": w, "b": jnp.zeros((spec.co,), jnp.float32)}


def init_params(rng, model: str, num_classes: int = 1000,
                input_hw: int = 224) -> Dict:
    specs = conv_specs(model)
    rngs = jax.random.split(rng, len(specs) + 1)
    params = {"convs": [_conv_init(r, s) for r, s in zip(rngs, specs)]}
    # classifier head: global-avg-pool -> linear
    co = specs[-1].co
    params["fc"] = {
        "w": jax.random.normal(rngs[-1], (co, num_classes), jnp.float32)
             * np.sqrt(1.0 / co),
        "b": jnp.zeros((num_classes,), jnp.float32),
    }
    return params


def _maxpool(x, k, s, pad=0):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, s, s, 1),
        [(0, 0), (pad, pad), (pad, pad), (0, 0)])


def apply(params: Dict, x: jnp.ndarray, model: str,
          configs: Optional[List[GemmConfig]] = None,
          use_pallas: bool = False) -> jnp.ndarray:
    """Forward pass. ``configs`` optionally supplies a tuned GEMM geometry
    per conv layer (the output of ARCO tuning)."""
    model = model.lower()
    specs = conv_specs(model)
    configs = configs or [GemmConfig()] * len(specs)

    def conv(i, x, spec):
        p = params["convs"][i]
        out = ops.conv2d(x, p["w"], spec.stride, spec.pad, configs[i],
                         use_pallas)
        return out + p["b"]

    if model == "alexnet":
        pool_after = {0, 1, 4}
        for i, s in enumerate(specs):
            x = jax.nn.relu(conv(i, x, s))
            if i in pool_after:
                x = _maxpool(x, 3, 2)
    elif model in _VGG_STAGES:
        i = 0
        for reps in _VGG_STAGES[model]:
            for _ in range(reps):
                x = jax.nn.relu(conv(i, x, specs[i]))
                i += 1
            x = _maxpool(x, 2, 2)
    else:  # resnet
        x = jax.nn.relu(conv(0, x, specs[0]))
        x = _maxpool(x, 3, 2, pad=1)
        i = 1
        for stage, reps in enumerate(_RESNET_BLOCKS[model]):
            for r in range(reps):
                sa, sb = specs[i], specs[i + 1]
                y = jax.nn.relu(conv(i, x, sa))
                y = conv(i + 1, y, sb)
                if x.shape != y.shape:  # downsample skip: strided 1x1 avg
                    x = jax.lax.reduce_window(
                        x, 0.0, jax.lax.add, (1, sa.stride, sa.stride, 1),
                        (1, sa.stride, sa.stride, 1), "VALID") \
                        / (sa.stride ** 2)
                    x = jnp.pad(x, ((0, 0), (0, 0), (0, 0),
                                    (0, y.shape[-1] - x.shape[-1])))
                x = jax.nn.relu(x + y)
                i += 2
    x = x.mean(axis=(1, 2))
    return x @ params["fc"]["w"] + params["fc"]["b"]
