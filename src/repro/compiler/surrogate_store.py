"""Persistent GBT training rows — cross-network surrogate transfer.

A tuning run learns two surrogates: the network-scope **hardware** GBT
(``[log2 hw values ++ aggregate workload descriptor]`` rows, see
``repro.compiler.netopt.hwspace``) and the per-config **software** GBT
(``[log2 knob values ++ cell descriptor]`` rows, see
``DesignSpace.feature_vector``).  Both feature layouts carry the workload
half explicitly, which is what makes the rows *transferable*: a surrogate
warm-started from another network's rows can tell that network's
measurements apart from the new one's and still generalize across them.

:class:`SurrogateStore` persists those rows to JSONL so the tuner becomes
an **accumulating system** instead of a per-run tool:

* ``netopt --save-surrogates s.jsonl`` appends every GBT training row of
  the run (keyed by kind, feature dimension, and network name);
* ``netopt --warm-from s.jsonl`` on a *different* network primes both
  GBTs from the stored rows before the first measurement — the outer
  hardware search then seeds from surrogate-ranked candidates instead of
  uniform draws, and MAPPO explores against an informed reward from
  episode one.

This is **transfer**, not replay: :class:`~repro.compiler.records.
RecordLog` replays exact (task, config) measurements of the *same*
network, while the store moves surrogate knowledge across *different*
networks.  Rows whose ``network`` matches the warm-starting run are
excluded (they re-enter through the run's own records), so warming a run
from its own store is exactly the cold run — record replay still yields
zero new measurements.

Durability piggybacks on :class:`RecordLog` (atomic line appends,
torn-tail repair).  Every row carries the feature-schema version; a store
written by an incompatible version is rejected loudly
(:class:`SurrogateSchemaError`) instead of silently mis-training.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, Iterable, List, Optional, Set, Tuple, Union

import numpy as np

from repro.compiler.oracle import Oracle
from repro.compiler.records import RecordLog
from repro.core.cost_model import GBTModel

# Bump when the meaning of a row changes (feature normalization, target
# transform, kinds).  Rows additionally carry their feature dimension, so
# differently-shaped spaces coexist in one store and loading filters to
# the consumer's layout.  v2 adds the segment-descriptor variant of hw
# rows (``segs`` = pipeline stages K; K>=2 rows carry K*15-dim features)
# — v1 rows are valid v2 rows with ``segs`` = 1, so v1 stores still load.
SCHEMA = "repro-surrogate/2"
COMPATIBLE_SCHEMAS = ("repro-surrogate/1", SCHEMA)
KINDS = ("sw", "hw")   # software (per-config) / hardware (per-candidate)

# The fitness value of an executor failure-penalty row
# (-log(Oracle.penalty_latency) in the float32 the GBT trains on) —
# recognized so transient worker failures never become persistent
# cross-network training data.
_PENALTY_Y = np.float32(-np.log(Oracle.penalty_latency))


class SurrogateSchemaError(ValueError):
    """A stored row does not match this code's feature schema."""


def space_family(space) -> str:
    """Coarse feature-compatibility family of a design space.  Conv and
    GEMM spaces share the 7-knob core geometry and its feature semantics
    (``"core"``); pod-level :class:`~repro.core.shard_space.ShardSpace`
    cells reuse the same 18-dim layout but every slot means something
    else (model_axis, moment dtype, ... / cell descriptor), so their rows
    must never warm a core GBT (``"pod"``) — equal dimension is not
    equal meaning."""
    from repro.core.shard_space import ShardSpace
    return "pod" if isinstance(space, ShardSpace) else "core"


def _row_key(kind: str, x: Iterable[float], y: float) -> Tuple:
    return (kind, tuple(float(v) for v in x), float(y))


class SurrogateStore:
    """Append-only JSONL store of (features, target) GBT training rows.

    One row per line::

        {"schema": "repro-surrogate/1", "kind": "hw", "dim": 14,
         "network": "vgg-11", "x": [...], "y": 7.81}

    ``y`` is the fitness target the GBTs train on (``-log latency``).
    Exact duplicate rows (same kind, features, target — e.g. a warm
    resume re-feeding replayed measurements) are deduplicated on append.
    """

    def __init__(self, path: str, readonly: bool = False):
        self._log = RecordLog(path)
        self.readonly = readonly
        self._rows: Optional[List[Dict]] = None
        self._keys: Set[Tuple] = set()

    @property
    def path(self) -> str:
        return self._log.path

    def exists(self) -> bool:
        return self._log.exists()

    # ------------------------------------------------------------------ load
    def _load(self) -> List[Dict]:
        if self._rows is None:
            rows = []
            for row in self._log.load():
                schema = row.get("schema")
                if schema not in COMPATIBLE_SCHEMAS:
                    raise SurrogateSchemaError(
                        f"{self.path}: row schema {schema!r} != {SCHEMA!r} "
                        "— the store was written by an incompatible "
                        "version; regenerate it (rows are cheap: re-run "
                        "with --save-surrogates)")
                if row.get("kind") not in KINDS:
                    raise SurrogateSchemaError(
                        f"{self.path}: unknown row kind {row.get('kind')!r}")
                key = _row_key(row["kind"], row["x"], row["y"])
                if key in self._keys:
                    continue
                self._keys.add(key)
                rows.append(row)
            self._rows = rows
        return self._rows

    # ----------------------------------------------------------------- write
    def add(self, kind: str, x, y: float, network: str = "",
            task: str = "", family: str = "core", segs: int = 1) -> bool:
        """Append one training row; returns False when skipped (readonly
        store or exact duplicate).  ``family`` (:func:`space_family`)
        marks feature-semantic compatibility — loads filter on it.
        ``segs`` is the segment-descriptor variant marker for hw rows
        (pipeline stages K of the candidate the row scores; 1 = the v1
        single-chip layout)."""
        return self.add_many(kind, [x], [y], network=network, task=task,
                             family=family, segs=segs) == 1

    def add_many(self, kind: str, X, y, network: str = "",
                 task: str = "", family: str = "core",
                 segs: int = 1) -> int:
        """Append a batch of training rows in one write (one fd + one
        ``os.write`` for the whole batch — this sits on the tuning hot
        path, once per GBT refit); returns how many rows were actually
        added (readonly stores and exact duplicates are skipped)."""
        if self.readonly:
            return 0
        if kind not in KINDS:
            raise ValueError(f"kind must be one of {KINDS}, got {kind!r}")
        rows = self._load()
        new_rows: List[Dict] = []
        for xi, yi in zip(X, y):
            xi = [float(v) for v in np.asarray(xi, np.float32).reshape(-1)]
            yi = float(np.float32(yi))
            key = _row_key(kind, xi, yi)
            if key in self._keys:
                continue
            self._keys.add(key)
            new_rows.append({"schema": SCHEMA, "kind": kind, "dim": len(xi),
                             "family": family, "network": network,
                             "task": task, "segs": int(segs),
                             "x": xi, "y": yi})
        rows.extend(new_rows)
        self._log.append_many(new_rows)
        return len(new_rows)

    def merge_from(self, other: Union[str, "SurrogateStore"]) -> int:
        """Copy another store's rows into this one (schema-checked,
        deduplicated, one batched write); returns the number of rows
        actually added."""
        if self.readonly:
            return 0
        if isinstance(other, str):
            other = SurrogateStore(other, readonly=True)
        rows = self._load()
        new_rows: List[Dict] = []
        for row in other._load():
            key = _row_key(row["kind"], row["x"], row["y"])
            if key in self._keys:
                continue
            self._keys.add(key)
            new_rows.append({"schema": SCHEMA, "kind": row["kind"],
                             "dim": len(row["x"]),
                             "family": row.get("family", "core"),
                             "network": row.get("network", ""),
                             "task": row.get("task", ""),
                             "segs": int(row.get("segs", 1)),
                             "x": row["x"], "y": row["y"]})
        rows.extend(new_rows)
        self._log.append_many(new_rows)
        return len(new_rows)

    # ----------------------------------------------------------------- query
    def rows(self, kind: str, dim: int,
             exclude_network: Optional[str] = None,
             family: str = "core") -> Tuple[np.ndarray, np.ndarray]:
        """(X, y) of every stored row matching ``kind`` and ``family``
        whose feature dimension is ``dim``.  Rows from
        ``exclude_network`` are dropped — transfer is cross-network by
        definition; a run's own rows re-enter through its measurement
        records."""
        sel = [r for r in self._load()
               if r["kind"] == kind and r["dim"] == dim
               and r.get("family", "core") == family
               and (exclude_network is None
                    or r.get("network") != exclude_network)]
        if not sel:
            return (np.zeros((0, dim), np.float32), np.zeros(0, np.float32))
        X = np.asarray([r["x"] for r in sel], np.float32)
        y = np.asarray([r["y"] for r in sel], np.float32)
        return X, y

    def networks(self, kind: Optional[str] = None) -> Tuple[str, ...]:
        return tuple(sorted({r.get("network", "") for r in self._load()
                             if kind is None or r["kind"] == kind}))

    def counts(self) -> Dict[str, int]:
        out = {k: 0 for k in KINDS}
        for r in self._load():
            out[r["kind"]] += 1
        return out

    # ------------------------------------------------------------ warm start
    def warm_start(self, gbt: GBTModel, kind: str,
                   exclude_network: Optional[str] = None,
                   family: str = "core") -> int:
        """Prime ``gbt`` with every stored row matching its feature width
        and space family; returns the number of rows transferred (0
        leaves the model cold).  A :class:`RecordingGBT` is primed
        through ``prime`` so transferred rows are not re-saved to its own
        store."""
        X, y = self.rows(kind, gbt.n_features, exclude_network, family)
        if len(X) == 0:
            return 0
        prime = getattr(gbt, "prime", gbt.update)
        prime(X, y)
        return len(X)

    # -------------------------------------------------------------- compact
    def compact(self, keep_best: int = 32) -> Dict[str, int]:
        """Rewrite the store keeping, per (kind, network, family, dim,
        segs) group, only the *Pareto-informative* rows (each row that
        improved on every earlier fitness in its group — the search's
        improvement frontier, what a warm start needs to rank the
        promising region) plus the ``keep_best`` highest-fitness rows.
        Bounds store growth to ``O(groups * keep_best + frontier)``
        regardless of how many runs accumulated — the pre-work for
        generator-scale corpora.  Atomic rewrite (same guarantee as the
        appends); returns ``{"kept": ..., "dropped": ...}``."""
        if self.readonly:
            raise ValueError("cannot compact a readonly store")
        rows = self._load()
        groups: Dict[Tuple, List[Dict]] = {}
        for r in rows:  # insertion order == append order within a group
            key = (r["kind"], r.get("network", ""),
                   r.get("family", "core"), r["dim"],
                   int(r.get("segs", 1)))
            groups.setdefault(key, []).append(r)
        keep_ids = set()
        for grp in groups.values():
            best = -np.inf
            for r in grp:  # improvement frontier, in append order
                if r["y"] > best:
                    best = r["y"]
                    keep_ids.add(id(r))
            for r in sorted(grp, key=lambda r: -r["y"])[:keep_best]:
                keep_ids.add(id(r))
        kept = [r for r in rows if id(r) in keep_ids]
        dropped = len(rows) - len(kept)
        if dropped:
            self._log.rewrite(kept)
            self._rows = kept
            self._keys = {_row_key(r["kind"], r["x"], r["y"]) for r in kept}
        return {"kept": len(kept), "dropped": dropped}


@dataclasses.dataclass
class RecordingGBT(GBTModel):
    """A :class:`GBTModel` that tees every ``update`` batch into a
    :class:`SurrogateStore` — the seam that captures software-surrogate
    training rows without touching the tuning loops that call
    ``gbt.update``.  ``prime`` updates without recording (warm starts:
    transferred rows must not be re-saved as this run's)."""

    store: Optional[SurrogateStore] = None
    store_kind: str = "sw"
    network: str = ""
    family: str = "core"

    def update(self, X, y) -> None:
        super().update(X, y)
        if self.store is not None and not self.store.readonly:
            Xr = np.asarray(X, np.float32).reshape(-1, self.n_features)
            yr = np.asarray(y, np.float32).reshape(-1)
            # executor failure-penalty rows (a worker timed out/crashed on
            # this config) are transient environment noise: this GBT still
            # trains on them (the in-run search must avoid the config),
            # but persisting them would poison every later network's warm
            # start permanently.  Deterministic infeasibility (the
            # analytical oracle's 1e12 sentinel) is real, transferable
            # knowledge and passes through.
            keep = yr != _PENALTY_Y
            self.store.add_many(self.store_kind, Xr[keep], yr[keep],
                                network=self.network, family=self.family)

    def prime(self, X, y) -> None:
        GBTModel.update(self, X, y)


def coerce_store(surrogates: Union[None, str, SurrogateStore]
                 ) -> Optional[SurrogateStore]:
    """``surrogates=`` arguments accept a path or a store; a path is an
    accumulating (read + write) store."""
    if surrogates is None or isinstance(surrogates, SurrogateStore):
        return surrogates
    return SurrogateStore(surrogates)


def attach_sw_gbt(store: Optional[SurrogateStore], n_rounds: int, seed: int,
                  network: str, family: str
                  ) -> Tuple[RecordingGBT, Dict[str, object]]:
    """The one way a run wires its software GBT to a store (shared by
    ``Session`` and netopt's evaluator): build the recording GBT, prime
    it from every compatible foreign row, and return it with the stats
    dict reports carry.  Stats are empty without a store."""
    gbt = RecordingGBT(n_rounds=n_rounds, seed=seed, store=store,
                       store_kind="sw", network=network, family=family)
    if store is None:
        return gbt, {}
    warm = store.warm_start(gbt, "sw", exclude_network=network,
                            family=family)
    return gbt, {"store": store.path, "readonly": store.readonly,
                 "warm_sw_rows": int(warm)}


# ----------------------------------------------------------------- CLI glue

def add_surrogate_args(ap) -> None:
    """``--warm-from`` / ``--save-surrogates`` on a tuning argparse CLI."""
    ap.add_argument("--warm-from", default=None, metavar="SURR.jsonl",
                    help="surrogate store to warm-start the GBT cost "
                         "models from (cross-network transfer; rows from "
                         "the same network are excluded)")
    ap.add_argument("--save-surrogates", default=None, metavar="SURR.jsonl",
                    help="append this run's GBT training rows here "
                         "(accumulating store; may equal --warm-from)")
    ap.add_argument("--compact", action="store_true",
                    help="after the run, compact --save-surrogates down "
                         "to its Pareto-informative + per-(network, "
                         "family) best rows (bounds store growth)")


def store_from_args(args) -> Optional[SurrogateStore]:
    """Build the store the run should use from the CLI flags:

    * only ``--warm-from``: read-only (prime, never write);
    * only ``--save-surrogates``: accumulating store at that path (a
      pre-existing file also warm-starts — that is the accumulation);
    * both: rows from ``--warm-from`` are merged into the save store
      first, so the output file is self-contained.
    """
    warm, save = args.warm_from, args.save_surrogates
    if getattr(args, "compact", False) and not save:
        raise SystemExit("--compact needs --save-surrogates (it rewrites "
                         "the store this run appends to)")
    same = bool(warm and save
                and os.path.realpath(warm) == os.path.realpath(save))
    if warm and not same and not os.path.exists(warm):
        # a typo'd path must not silently degrade into a cold run (when
        # both flags name ONE file — accumulate-in-place — a first run
        # legitimately starts with no store yet)
        raise SystemExit(f"--warm-from {warm}: no such surrogate store")
    if save:
        store = SurrogateStore(save)
        if warm and not same:
            store.merge_from(warm)
        return store
    if warm:
        return SurrogateStore(warm, readonly=True)
    return None
