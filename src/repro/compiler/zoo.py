"""Workload zoo — typed, named networks for network-scope tuning.

``repro.compiler.netopt`` was born on a single ResNet-18 example; the zoo
gives it (and the transfer benchmarks) scenario diversity: classic conv
backbones, a depthwise-separable stack, a transformer GEMM stack, and a
pod-level :class:`~repro.core.shard_space.ShardSpace` network — all as
plain lists of :class:`~repro.compiler.task.TuningTask`\\ s, so every
existing surface (``Session``, ``netopt``, the CLI, the benchmarks) runs
any of them unchanged.

    from repro.compiler.zoo import get_network, network_names
    net = get_network("mobilenet-dw")
    rep = NetworkCoOptimizer(net.tasks, cfg, name=net.name).run()

CLI: ``python -m repro.compiler.cli netopt --network mobilenet-dw``.

The pod-cell network measures through a deterministic *analytical proxy*
(roofline-style step-time model over the sharding knobs) so the zoo stays
cheap enough for tests and benchmarks; swap ``TuningTask.cell`` in for
compile-measured cells.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Tuple

from repro.compiler.task import TuningTask
from repro.core.design_space import DesignSpace

__all__ = ["NetworkTask", "ZOO", "get_network", "network_names"]


@dataclasses.dataclass(frozen=True)
class NetworkTask:
    """One named network: an ordered list of tuning tasks with layer
    multiplicities — the unit ``netopt`` co-optimizes one chip for."""

    name: str
    kind: str                       # "conv" | "gemm" | "mixed" | "pod"
    description: str
    tasks: Tuple[TuningTask, ...]

    @property
    def n_tasks(self) -> int:
        return len(self.tasks)

    @property
    def n_layers(self) -> int:
        return sum(t.multiplicity for t in self.tasks)

    def summary(self) -> str:
        return (f"{self.name} [{self.kind}]: {self.n_tasks} unique tasks / "
                f"{self.n_layers} layers — {self.description}")


# ---------------------------------------------------------------- builders

def _conv(name: str, wl: Dict[str, int], mult: int) -> TuningTask:
    return TuningTask.from_space(name, DesignSpace.for_conv2d(wl),
                                 multiplicity=mult)


def _resnet18() -> NetworkTask:
    return NetworkTask(
        name="resnet-18", kind="conv",
        description="ResNet-18 conv backbone (Table-3 task extraction)",
        tasks=tuple(TuningTask.conv_tasks("resnet-18")))


def _vgg_stack() -> NetworkTask:
    return NetworkTask(
        name="vgg-11", kind="conv",
        description="VGG-11 3x3 conv stack (large-Ci/Co, stride-1)",
        tasks=tuple(TuningTask.conv_tasks("vgg-11")))


def _mobilenet_dw() -> NetworkTask:
    """MobileNet-v1-style depthwise-separable stack.  The analytical model
    has no grouped convolution, so a depthwise 3x3 over C channels is
    expressed as its FLOP-equivalent single-input-channel conv
    (ci=1, co=C) — the tiny-Ci regime that stresses a shared tile_ci very
    differently from ResNet/VGG, paired with 1x1 pointwise convs."""
    def dw(h: int, c: int, stride: int) -> Dict[str, int]:
        return dict(b=1, h=h, w=h, ci=1, co=c, kh=3, kw=3,
                    stride=stride, pad=1)

    def pw(h: int, ci: int, co: int) -> Dict[str, int]:
        return dict(b=1, h=h, w=h, ci=ci, co=co, kh=1, kw=1,
                    stride=1, pad=0)

    t = [
        _conv("mb:conv1", dict(b=1, h=224, w=224, ci=3, co=32, kh=3, kw=3,
                               stride=2, pad=1), 1),
        _conv("mb:dw112", dw(112, 32, 1), 1),
        _conv("mb:pw112", pw(112, 32, 64), 1),
        _conv("mb:dw56", dw(56, 128, 1), 2),
        _conv("mb:pw56", pw(56, 128, 128), 2),
        _conv("mb:dw28", dw(28, 256, 1), 2),
        _conv("mb:pw28", pw(28, 256, 256), 2),
        _conv("mb:dw14", dw(14, 512, 1), 5),
        _conv("mb:pw14", pw(14, 512, 512), 5),
        _conv("mb:pw7", pw(7, 512, 1024), 2),
    ]
    return NetworkTask(
        name="mobilenet-dw", kind="conv",
        description="MobileNet-style depthwise-separable stack "
                    "(FLOP-equivalent dw as ci=1 conv + 1x1 pointwise)",
        tasks=tuple(t))


def _bert_gemm() -> NetworkTask:
    """BERT-base-style encoder as its GEMM stack at seq 128: per block
    4 projection GEMMs (QKV + output) and the two FFN GEMMs, 12 blocks."""
    def gemm(name: str, m: int, n: int, k: int, mult: int) -> TuningTask:
        return TuningTask.from_space(name, DesignSpace.for_matmul(m, n, k),
                                     multiplicity=mult)

    t = [
        gemm("bert:proj", 128, 768, 768, 4 * 12),   # Q, K, V, out x 12
        gemm("bert:ffn_up", 128, 3072, 768, 12),
        gemm("bert:ffn_down", 128, 768, 3072, 12),
        gemm("bert:pool", 128, 768, 768, 1),
    ]
    return NetworkTask(
        name="bert-gemm", kind="gemm",
        description="BERT-base encoder GEMM stack (seq 128): QKV/out "
                    "projections + FFN up/down over 12 blocks",
        tasks=tuple(t))


def _resnet_bert() -> NetworkTask:
    """Mixed conv-front + GEMM-tail network — the heterogeneous-partition
    scenario: the ResNet-18 backbone's large-spatial convs and the BERT
    GEMM stack want different chip geometries (conv layers lean on
    spatial M-tiling with moderate Ci, the transformer GEMMs on deep
    K/N tiles), so a K=2 pipeline cut between the halves can beat any
    single shared chip end-to-end.  ``BENCH_hetero.json`` runs netopt
    K=1 vs K=2 vs the genetic baseline on (a truncation of) this
    network."""
    front = list(TuningTask.conv_tasks("resnet-18"))
    tail = list(_bert_gemm().tasks)
    return NetworkTask(
        name="resnet-bert", kind="mixed",
        description="ResNet-18 conv front + BERT GEMM tail — the K-chip "
                    "partitioning scenario (no single chip wins both "
                    "halves)",
        tasks=tuple(front + tail))


# ------------------------------------------------------------ pod network

def pod_proxy_measure(n_layers: int, d_model: int, seq: int, batch: int,
                      n_devices: int, train: bool
                      ) -> Callable[[Dict[str, object]], float]:
    """Deterministic roofline-style step-time proxy for one LM cell —
    compute/collective/HBM terms over the sharding knobs, with hinge
    penalties for HBM overflow.  Shaped like the real dry-run estimator
    (TP helps until collectives dominate; remat trades FLOPs for memory;
    micro-batching trades overhead for residency) but runs in
    microseconds, which is what keeps the zoo's pod network usable in
    tests and CI."""
    PEAK = 180e12          # per-device matmul FLOP/s
    NET_BW = 60e9          # per-link interconnect bytes/s
    HBM = 32e9             # per-device bytes
    flops = 8.0 * n_layers * d_model * d_model * seq * batch
    if train:
        flops *= 3.0       # fwd + bwd
    p_bytes = 14.0 * n_layers * d_model * d_model * 2.0   # bf16 params
    act_bytes = 2.0 * n_layers * seq * batch * d_model * 6.0

    def measure(s: Dict[str, object]) -> float:
        tp = float(s["model_axis"])
        dp = max(n_devices / tp, 1.0)
        micro = float(s["grad_accum"])
        remat = bool(s["remat"])
        fsdp = bool(s["fsdp"])
        sp = bool(s["sequence_parallel"])
        chunk = float(s["attn_chunk"])
        mom = 4.0 if s["moment_dtype"] == "float32" else 2.0

        t_comp = flops / (n_devices * PEAK)
        if remat:
            t_comp *= 4.0 / 3.0            # recompute the forward
        # TP collectives: two all-reduces of the activation slab per layer,
        # cheaper with sequence parallelism (reduce-scatter halves volume)
        act_slab = 2.0 * seq * batch / dp * d_model
        t_tp = (0.0 if tp <= 1 else
                2.0 * n_layers * act_slab * 2.0 * (tp - 1) / tp
                / (NET_BW * (2.0 if sp else 1.0)))
        # DP gradient sync once per step, amortized over micro-batches
        t_dp = p_bytes / tp * 2.0 * (dp - 1) / dp / NET_BW / micro if train \
            else 0.0
        # attention blocking sweet spot: chunk ~ seq/8
        t_attn = t_comp * 0.05 * abs(_log2(chunk) - _log2(max(seq / 8, 1)))
        per_step = t_comp + t_tp + t_dp + t_attn + 0.002 * micro

        # memory feasibility (hinge, not a cliff: the surrogate must see
        # the gradient toward feasibility)
        shard = tp * (dp if fsdp else 1.0)
        resident = p_bytes * (1.0 + (2.0 + mom if train else 0.0)) / shard
        resident += act_bytes / tp / micro / (4.0 if remat else 1.0) \
            / (2.0 if sp else 1.0)
        over = max(resident / HBM - 1.0, 0.0)
        return per_step * (1.0 + 10.0 * over)

    return measure


def _log2(x: float) -> float:
    import math
    return math.log2(max(x, 1e-9))


def _pod_network(name: str, arch: str, n_devices: int) -> NetworkTask:
    """A pod-level network: the train/prefill/decode cells of one LM arch
    as ShardSpace tasks under the analytical proxy oracle.  netopt over
    this network searches one shared pod geometry (model-axis degree,
    moment dtype, FSDP — the ShardSpace "hardware" knobs) across all
    three cells: the PR-4 follow-up of hardware candidates for ShardSpace
    cells.  Unlike the conv networks (whose analytical optimum tends to
    sit at the largest feasible geometry — a guaranteed seed), the pod
    optimum is *interior* (TP collectives punish over-sharding), so the
    outer search genuinely has to find it — which is what makes pod
    networks the interesting transfer pair."""
    from repro.configs import get_config
    from repro.configs.shapes import SHAPES
    from repro.core.shard_space import ShardSpace
    cfg = get_config(arch)
    tasks: List[TuningTask] = []
    # decode cells dominate serving traffic; weight them accordingly
    for shape_name, mult in (("train_4k", 1), ("prefill_32k", 2),
                             ("decode_32k", 4)):
        cell = SHAPES[shape_name]
        fn = pod_proxy_measure(cfg.n_layers, cfg.d_model, cell.seq,
                               cell.global_batch, n_devices,
                               train=cell.kind == "train")
        space = ShardSpace.for_cell(arch, shape_name, measure_fn=fn,
                                    n_devices=n_devices)
        tasks.append(TuningTask.from_space(f"pod:{arch}/{shape_name}",
                                           space, multiplicity=mult))
    return NetworkTask(
        name=name, kind="pod",
        description=f"{arch} train/prefill/decode ShardSpace cells on a "
                    f"{n_devices}-device pod (analytical proxy oracle)",
        tasks=tuple(tasks))


def _pod_cells() -> NetworkTask:
    return _pod_network("pod-cells", "qwen2-1.5b", 256)


def _pod_cells_4b() -> NetworkTask:
    return _pod_network("pod-cells-4b", "qwen1.5-4b", 256)


# ---------------------------------------------------------------- registry

ZOO: Dict[str, Callable[[], NetworkTask]] = {
    "resnet-18": _resnet18,
    "vgg-11": _vgg_stack,
    "mobilenet-dw": _mobilenet_dw,
    "bert-gemm": _bert_gemm,
    "resnet-bert": _resnet_bert,
    "pod-cells": _pod_cells,
    "pod-cells-4b": _pod_cells_4b,
}


def network_names() -> Tuple[str, ...]:
    return tuple(ZOO)


def get_network(name: str) -> NetworkTask:
    if name not in ZOO:
        raise KeyError(f"unknown zoo network {name!r}; have "
                       f"{sorted(ZOO)}")
    net = ZOO[name]()
    names = [t.name for t in net.tasks]
    assert len(set(names)) == len(names), f"duplicate task names in {name}"
    return net
