"""``RemoteExecutor`` — fan measurement jobs over TCP to worker daemons.

The network sibling of :class:`~repro.compiler.executor.pool.
SubprocessExecutor`: same :class:`~repro.compiler.executor.base.Executor`
protocol (``submit``/``poll``/``drain``/``close``, ``MeasureHandle``
semantics unchanged), same fault semantics, but the workers are
``python -m repro.compiler.executor.worker`` daemons on this or any other
host — one tuning session driving a fleet.

Routing is capability-based: each daemon advertises a
:class:`~repro.compiler.executor.wire.WorkerCapabilities` descriptor at
handshake (device count, backend, env pins, job slots) and a job is only
dispatched to a daemon compatible with its
:class:`~repro.compiler.executor.base.WorkerSpec` — heterogeneous pools,
where different hosts serve different topologies.  A job no *live*
endpoint can ever serve fails fast (``NoCompatibleWorker``) instead of
wedging the queue.

Fault semantics mirror the pool, with the network in place of the
process table:

* measure fn raises on the daemon    -> failed result, daemon survives;
* connection dies (crash, heartbeat
  loss after ``heartbeat_timeout_s``) -> in-flight jobs fail (the oracle
                                        maps them to ``penalty_latency``
                                        rows) and the endpoint enters
                                        bounded reconnect-with-backoff,
                                        so a restarted daemon rejoins the
                                        fleet without losing the session;
* a job exceeds ``timeout_s``
  (counted from the started-ack,
  with ``startup_grace_s`` before it) -> that job fails and the
                                        connection is dropped/re-dialed
                                        (the remote analog of killing a
                                        hung worker); other in-flight
                                        jobs on the endpoint are re-queued,
                                        not failed.

Stdlib-only, jax-free (the executor package's import-light rule).
"""
from __future__ import annotations

import collections
import selectors
import socket
import time
from typing import Deque, Dict, List, Optional, Tuple, Union

from repro import obs
from repro.compiler.executor.base import (Executor, MeasureHandle,
                                          MeasureResult, WorkerSpec)
from repro.compiler.executor.wire import (PROTOCOL_VERSION, FrameBuffer,
                                          ProtocolError, WorkerCapabilities,
                                          encode_frame, endpoint_label,
                                          parse_endpoints, recv_frame,
                                          spec_compatible, spec_to_wire)


class _RJob:
    __slots__ = ("handle", "deadline", "started", "dispatched")

    def __init__(self, handle: MeasureHandle):
        self.handle = handle
        self.deadline: Optional[float] = None
        self.started: Optional[float] = None
        self.dispatched: Optional[float] = None


class _Endpoint:
    """One daemon address: live socket + capabilities + per-endpoint
    stats + reconnect bookkeeping."""

    def __init__(self, addr: Tuple[str, int], backoff_s: float):
        self.addr = addr
        self.label = endpoint_label(addr)
        self.sock: Optional[socket.socket] = None
        self.caps = WorkerCapabilities()
        self.buf = FrameBuffer()
        self.jobs: Dict[int, _RJob] = {}   # in flight on this connection
        self.last_rx = 0.0
        self.last_tx = 0.0
        self.alive = True                  # False = reconnects exhausted
        self.ever_connected = False
        self.attempts = 0                  # consecutive failed dials
        self.next_attempt = 0.0
        self.initial_backoff = backoff_s
        self.backoff = backoff_s
        # observability (RemoteExecutor.stats())
        self.n_jobs = 0                    # results received (ok or not)
        self.n_failures = 0                # failed results + connection-lost
        self.n_reconnects = 0              # successful re-dials
        self.ack_lat_sum = 0.0             # started-ack -> result seconds
        self.ack_lat_n = 0
        # daemon-side load telemetry (heartbeat "load", wire minor 1);
        # {} until a telemetry-speaking daemon heartbeats
        self.daemon_load: Dict[str, object] = {}

    @property
    def connected(self) -> bool:
        return self.sock is not None

    def free_slots(self) -> int:
        return self.caps.slots - len(self.jobs) if self.connected else 0

    def stats(self) -> Dict[str, object]:
        return {"connected": self.connected, "alive": self.alive,
                "slots": self.caps.slots if self.connected else 0,
                "backend": self.caps.backend,
                "device_count": self.caps.device_count,
                "jobs": self.n_jobs, "failures": self.n_failures,
                "reconnects": self.n_reconnects,
                "in_flight": len(self.jobs),
                "mean_ack_to_result_s": (self.ack_lat_sum / self.ack_lat_n
                                         if self.ack_lat_n else 0.0),
                "daemon": dict(self.daemon_load)}


class RemoteExecutor(Executor):
    """Executor over one or more TCP worker daemons.

    ``endpoints``            ``"host:port"``, ``"h1:p1,h2:p2"``, or a
                             sequence of either.
    ``timeout_s``            per-measurement limit counted from the
                             daemon's started-ack (None = unlimited).
    ``startup_grace_s``      extra pre-ack allowance (dispatch -> ack
                             covers network + factory/jax import).
    ``heartbeat_s``          how often this side emits liveness frames.
    ``heartbeat_timeout_s``  silence after which a connection is declared
                             dead (daemons heartbeat every ~2s; keep this
                             several multiples of that).
    ``reconnect_backoff_s``  initial re-dial delay, doubling per failed
                             attempt up to ``max_backoff_s``.
    ``max_reconnects``       consecutive failed dials before an endpoint
                             is abandoned for the session.
    ``max_inflight``         bound on submitted-but-unresolved jobs;
                             default ``2x`` the fleet's advertised slots.

    At least one endpoint must accept the handshake at construction —
    a fleet that is entirely unreachable is a configuration error, not
    something to retry forever.
    """

    _POLL_S = 0.02

    def __init__(self, endpoints: Union[str, List[str]],
                 timeout_s: Optional[float] = None,
                 startup_grace_s: float = 120.0,
                 heartbeat_s: float = 2.0,
                 heartbeat_timeout_s: float = 15.0,
                 reconnect_backoff_s: float = 0.5,
                 max_backoff_s: float = 8.0,
                 max_reconnects: int = 8,
                 connect_timeout_s: float = 5.0,
                 max_inflight: Optional[int] = None):
        addrs = parse_endpoints(endpoints)
        if len({endpoint_label(a) for a in addrs}) != len(addrs):
            raise ValueError(f"duplicate endpoints in {endpoints!r}")
        self.timeout_s = timeout_s
        self.startup_grace_s = startup_grace_s
        self.heartbeat_s = heartbeat_s
        self.heartbeat_timeout_s = heartbeat_timeout_s
        self.max_backoff_s = max_backoff_s
        self.max_reconnects = max_reconnects
        self.connect_timeout_s = connect_timeout_s
        self.max_inflight = max_inflight
        self._eps = [_Endpoint(a, reconnect_backoff_s) for a in addrs]
        self._sel = selectors.DefaultSelector()
        self._queue: Deque[_RJob] = collections.deque()
        self._next_id = 0
        self._closed = False
        errors = []
        for ep in self._eps:
            try:
                self._connect(ep)
            except (OSError, ProtocolError) as e:
                errors.append(f"{ep.label}: {e}")
                self._mark_disconnected(ep)
        if not any(ep.connected for ep in self._eps):
            raise ConnectionError(
                "no worker daemon reachable: " + "; ".join(errors))
        self.n_workers = sum(ep.caps.slots for ep in self._eps
                             if ep.connected)

    # ------------------------------------------------------------- protocol
    def submit(self, task: str, settings: Dict[str, object],
               spec: Optional[WorkerSpec] = None) -> MeasureHandle:
        if self._closed:
            raise RuntimeError("executor is closed")
        handle = MeasureHandle(self._next_id, task, settings, executor=self,
                               spec=spec)
        self._next_id += 1
        self._queue.append(_RJob(handle))
        self._dispatch()
        while self._inflight() >= self._inflight_limit():
            self._service(self._POLL_S)
        return handle

    def poll(self) -> None:
        if not self._closed:
            self._service(0.0)

    def drain(self, handles: Optional[List[MeasureHandle]] = None) -> None:
        def pending() -> bool:
            if handles is not None:
                return any(not h.done() for h in handles)
            return self._inflight() > 0

        while pending():
            self._service(self._POLL_S)

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        for ep in self._eps:
            if ep.connected:
                try:
                    ep.sock.sendall(encode_frame({"type": "shutdown"}))
                except OSError:
                    pass
                self._disconnect_socket(ep)
            for job in ep.jobs.values():
                job.handle._resolve(MeasureResult(
                    ok=False, error="ExecutorClosed: job abandoned"))
            ep.jobs.clear()
        for job in self._queue:
            job.handle._resolve(MeasureResult(
                ok=False, error="ExecutorClosed: job abandoned"))
        self._queue.clear()
        self._sel.close()

    def stats(self) -> Dict[str, object]:
        per = {ep.label: ep.stats() for ep in self._eps}
        running = sum(len(ep.jobs) for ep in self._eps)
        return {"kind": "remote",
                "workers_alive": sum(ep.caps.slots for ep in self._eps
                                     if ep.connected),
                # the pool calls kill-and-replace "respawns"; the remote
                # analog is a successful re-dial — alias it so uniform
                # consumers need only one key
                "respawns": sum(ep.n_reconnects for ep in self._eps),
                "reconnects": sum(ep.n_reconnects for ep in self._eps),
                "queued": len(self._queue), "running": running,
                "max_inflight": self._inflight_limit(),
                "jobs": sum(ep.n_jobs for ep in self._eps),
                "failures": sum(ep.n_failures for ep in self._eps),
                "endpoints": per}

    # ---------------------------------------------------------- connections
    def _connect(self, ep: _Endpoint) -> None:
        sock = socket.create_connection(ep.addr,
                                        timeout=self.connect_timeout_s)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        try:
            sock.sendall(encode_frame({"type": "hello",
                                       "version": PROTOCOL_VERSION}))
            ep.caps = WorkerCapabilities.from_wire(
                recv_frame(sock, timeout_s=self.connect_timeout_s))
        except Exception:
            sock.close()
            raise
        sock.settimeout(self.connect_timeout_s)  # bounds steady-state sends
        ep.sock = sock
        ep.buf = FrameBuffer()
        ep.last_rx = ep.last_tx = time.monotonic()
        if ep.ever_connected:
            ep.n_reconnects += 1
        ep.ever_connected = True
        ep.attempts = 0
        ep.backoff = ep.initial_backoff
        self._sel.register(sock, selectors.EVENT_READ, ep)

    def _disconnect_socket(self, ep: _Endpoint) -> None:
        if ep.sock is not None:
            try:
                self._sel.unregister(ep.sock)
            except (KeyError, ValueError):
                pass
            ep.sock.close()
            ep.sock = None

    def _mark_disconnected(self, ep: _Endpoint) -> None:
        """Schedule the next dial; abandon after ``max_reconnects``."""
        ep.attempts += 1
        if ep.attempts > self.max_reconnects:
            ep.alive = False
            return
        ep.next_attempt = time.monotonic() + ep.backoff
        ep.backoff = min(ep.backoff * 2, self.max_backoff_s)

    def _lose(self, ep: _Endpoint, error: str, requeue: bool) -> None:
        """Connection-level failure: fail (or re-queue) its in-flight jobs
        and enter reconnect backoff."""
        self._disconnect_socket(ep)
        jobs = list(ep.jobs.values())
        ep.jobs.clear()
        for job in jobs:
            if requeue:
                job.deadline = job.started = job.dispatched = None
                self._queue.appendleft(job)
            else:
                ep.n_failures += 1
                job.handle._resolve(MeasureResult(ok=False, error=error))
        self._mark_disconnected(ep)

    # -------------------------------------------------------------- routing
    def _compatible_eps(self, spec: Optional[WorkerSpec],
                        connected_only: bool) -> List[_Endpoint]:
        out = []
        for ep in self._eps:
            if not ep.alive:
                continue
            if connected_only and not ep.connected:
                continue
            # an alive-but-never-connected endpoint has unknown caps:
            # optimistically routable (it may still come up compatible)
            if (ep.connected or ep.ever_connected) \
                    and not spec_compatible(spec, ep.caps):
                continue
            out.append(ep)
        return out

    def _dispatch(self) -> None:
        """Route queued jobs to compatible endpoints with free slots
        (least-loaded first); fail jobs that no live endpoint can ever
        serve."""
        if not self._queue:
            return
        deferred: Deque[_RJob] = collections.deque()
        while self._queue:
            job = self._queue.popleft()
            spec = job.handle.spec
            ready = [ep for ep in self._compatible_eps(spec, True)
                     if ep.free_slots() > 0]
            if not ready:
                if not self._compatible_eps(spec, False):
                    job.handle._resolve(MeasureResult(
                        ok=False,
                        error="NoCompatibleWorker: no live daemon matches "
                              f"this job's spec (env={dict(spec.env) if spec else {}}); "
                              "endpoints: "
                              + ", ".join(f"{ep.label}[{'up' if ep.connected else 'down'}]"
                                          for ep in self._eps)))
                else:
                    deferred.append(job)  # compatible capacity will return
                continue
            ep = min(ready, key=lambda e: (len(e.jobs),
                                           self._eps.index(e)))
            self._send_job(ep, job)
        self._queue.extend(deferred)

    def _send_job(self, ep: _Endpoint, job: _RJob) -> None:
        h = job.handle
        msg = {"type": "job", "job_id": h.job_id, "task": h.task,
               "settings": h.settings,
               "spec": spec_to_wire(h.spec) if h.spec is not None else None}
        if h.spec is None:
            # remote daemons rebuild measure fns from specs only — there is
            # no pickled-closure fallback across the wire
            h._resolve(MeasureResult(
                ok=False, error="NoWorkerSpec: remote jobs need a "
                                "WorkerSpec naming an importable factory"))
            return
        job.dispatched = time.monotonic()
        if self.timeout_s is not None:
            job.deadline = (job.dispatched + self.timeout_s
                            + self.startup_grace_s)
        try:
            ep.sock.sendall(encode_frame(msg))
            ep.last_tx = time.monotonic()
        except OSError as e:
            self._lose(ep, f"WorkerCrash: send to {ep.label} failed ({e})",
                       requeue=False)
            job.deadline = job.dispatched = None
            self._queue.appendleft(job)
            return
        ep.jobs[h.job_id] = job

    # -------------------------------------------------------------- service
    def _inflight(self) -> int:
        return len(self._queue) + sum(len(ep.jobs) for ep in self._eps)

    def _inflight_limit(self) -> int:
        if self.max_inflight is not None:
            return self.max_inflight
        slots = sum(ep.caps.slots for ep in self._eps if ep.connected)
        return max(2 * slots, 2)

    def _service(self, block_s: float) -> None:
        """One pump: redial due endpoints, expire deadlines and silent
        connections, send/receive frames, dispatch."""
        now = time.monotonic()
        # bounded reconnect: re-dial endpoints whose backoff has elapsed
        for ep in self._eps:
            if ep.alive and not ep.connected and now >= ep.next_attempt:
                try:
                    self._connect(ep)
                except (OSError, ProtocolError):
                    self._mark_disconnected(ep)
        # per-job deadlines (timeout counted from started-ack; pre-ack the
        # startup grace applies) — a timeout drops the connection, the
        # remote analog of killing a hung worker; innocent in-flight jobs
        # on the same endpoint are re-queued, not failed
        for ep in self._eps:
            expired = [j for j in ep.jobs.values()
                       if j.deadline is not None and now > j.deadline]
            if expired:
                job = expired[0]
                del ep.jobs[job.handle.job_id]
                ep.n_jobs += 1
                ep.n_failures += 1
                job.handle._resolve(MeasureResult(
                    ok=False,
                    error=f"TimeoutError: measurement exceeded "
                          f"{self.timeout_s:.1f}s on {ep.label}; "
                          "connection dropped"))
                self._lose(ep, "timeout", requeue=True)
        # heartbeat loss
        for ep in self._eps:
            if (ep.connected
                    and now - ep.last_rx > self.heartbeat_timeout_s):
                self._lose(ep, f"WorkerCrash: {ep.label} silent for "
                               f"{self.heartbeat_timeout_s:.1f}s "
                               "(heartbeat lost)", requeue=False)
        # our own liveness frames
        for ep in self._eps:
            if ep.connected and now - ep.last_tx > self.heartbeat_s:
                try:
                    ep.sock.sendall(encode_frame({"type": "heartbeat"}))
                    ep.last_tx = now
                except OSError as e:
                    self._lose(ep, f"WorkerCrash: heartbeat to {ep.label} "
                                   f"failed ({e})", requeue=False)
        # inbound frames
        if any(ep.connected for ep in self._eps):
            for key, _ in self._sel.select(timeout=max(block_s, 0.0)):
                ep: _Endpoint = key.data
                if not ep.connected:
                    continue
                try:
                    data = ep.sock.recv(1 << 20)
                except socket.timeout:
                    continue
                except OSError as e:
                    self._lose(ep, f"WorkerCrash: read from {ep.label} "
                                   f"failed ({e})", requeue=False)
                    continue
                if not data:
                    self._lose(ep, f"WorkerCrash: connection to {ep.label} "
                                   "closed mid-measurement", requeue=False)
                    continue
                ep.last_rx = time.monotonic()
                try:
                    msgs = ep.buf.feed(data)
                except ProtocolError as e:
                    self._lose(ep, f"WorkerCrash: protocol error from "
                                   f"{ep.label} ({e})", requeue=False)
                    continue
                for msg in msgs:
                    self._handle_frame(ep, msg)
        elif block_s > 0:
            time.sleep(min(block_s, self._POLL_S))
        # a fully-dead fleet must fail fast, not spin drain() forever
        if not any(ep.alive for ep in self._eps):
            for ep in self._eps:
                for job in ep.jobs.values():
                    ep.n_failures += 1
                    job.handle._resolve(MeasureResult(
                        ok=False, error="FleetDown: every endpoint "
                                        "exhausted its reconnect budget"))
                ep.jobs.clear()
            while self._queue:
                self._queue.popleft().handle._resolve(MeasureResult(
                    ok=False, error="FleetDown: every endpoint exhausted "
                                    "its reconnect budget"))
        self._dispatch()

    def _handle_frame(self, ep: _Endpoint, msg: Dict[str, object]) -> None:
        t = msg.get("type")
        if t == "started":
            job = ep.jobs.get(msg.get("job_id"))
            if job is not None:
                job.started = time.monotonic()
                if self.timeout_s is not None:
                    job.deadline = job.started + self.timeout_s
        elif t == "result":
            job = ep.jobs.pop(msg.get("job_id"), None)
            if job is None:
                return  # stale: a job we already timed out / re-queued
            ep.n_jobs += 1
            if job.started is not None:
                ep.ack_lat_sum += time.monotonic() - job.started
                ep.ack_lat_n += 1
            ok = bool(msg.get("ok"))
            if not ok:
                ep.n_failures += 1
            span = msg.get("span")
            if isinstance(span, dict):
                # daemon-timed measure span (wire minor 1): merge into the
                # session's timeline under this endpoint's lane
                try:
                    obs.current().add_span(
                        str(span.get("name", "measure")),
                        cat=str(span.get("cat", "measure")),
                        wall_start_s=float(span["t_wall"]),
                        dur_s=float(span["dur_s"]),
                        tid=ep.label,
                        args={"task": str(span.get("task", ""))})
                except (KeyError, TypeError, ValueError):
                    pass  # malformed telemetry must never fail a result
            job.handle._resolve(MeasureResult(
                ok=ok, value=msg.get("value") if ok else None,
                error="" if ok else str(msg.get("error", "unknown"))))
        elif t == "heartbeat":
            load = msg.get("load")
            if isinstance(load, dict):  # wire minor 1 telemetry
                ep.daemon_load = load
        # heartbeats already refreshed last_rx; ignore unknown types
