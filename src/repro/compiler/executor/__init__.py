"""``repro.compiler.executor`` — parallel, crash-isolated measurement
execution for the compile oracle.

The oracle's expensive regime (one SPMD lower+compile per measurement,
tens of seconds each) used to serialize an entire Confidence-Sampling
batch.  This package turns measurement into a submit/drain pipeline:

* :class:`Executor` — the protocol: ``submit(task, settings) -> handle``
  plus ``poll``/``drain``/``close``.
* :class:`SerialExecutor` — in-process execution, preserving the exact
  pre-executor behavior (and the determinism reference for tests).
* :class:`SubprocessExecutor` — a pool of spawned worker processes, each
  doing its own jax init with a pinned
  ``--xla_force_host_platform_device_count``; per-measurement timeouts,
  worker-crash isolation (a dead or hung worker yields a failure result
  and the pool respawns), and bounded in-flight depth.
* :class:`RemoteExecutor` — the same protocol over TCP to worker daemons
  (``python -m repro.compiler.executor.worker --listen HOST:PORT``),
  with capability-based routing across heterogeneous pools and the
  pool's fault semantics mapped onto connections (heartbeat loss,
  bounded reconnect-with-backoff).  See ``wire`` for the frame protocol
  and its trusted-network-only security posture.

Results always flow back through the one memoizing, JSONL-persisting
``Oracle`` in the parent process, so memo/records/resume semantics are
unchanged no matter which executor ran the measurement.

This package must stay importable without jax: workers that measure cheap
stub oracles (tests, the throughput micro-bench) should not pay a jax
import at spawn time.  Anything jax-flavored belongs in the worker
*factory* the :class:`WorkerSpec` names, which is resolved lazily inside
the worker process.
"""
from repro.compiler.executor.base import (Executor, MeasureHandle,
                                          MeasureResult, SerialExecutor,
                                          WorkerSpec, add_worker_args,
                                          resolve_factory,
                                          validate_worker_args)
from repro.compiler.executor.pool import SubprocessExecutor
from repro.compiler.executor.remote import RemoteExecutor
from repro.compiler.executor.wire import parse_endpoints

_WORKER_EXPORTS = ("WorkerDaemon", "spawn_daemon")


def __getattr__(name):
    # lazy: `python -m repro.compiler.executor.worker` imports this
    # package first, and an eager worker import here would trip runpy's
    # found-in-sys.modules warning on every daemon start
    if name in _WORKER_EXPORTS:
        from repro.compiler.executor import worker
        return getattr(worker, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "Executor",
    "MeasureHandle",
    "MeasureResult",
    "RemoteExecutor",
    "SerialExecutor",
    "SubprocessExecutor",
    "WorkerDaemon",
    "WorkerSpec",
    "add_worker_args",
    "parse_endpoints",
    "resolve_factory",
    "spawn_daemon",
    "validate_worker_args",
]
