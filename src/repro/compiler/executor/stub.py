"""Deterministic stub measure functions for executor tests and benches.

``make_stub`` is the :class:`~repro.compiler.executor.base.WorkerSpec`
factory used by ``tests/test_executor.py`` and
``benchmarks/measure_throughput.py``: a cheap, jax-free oracle whose
latency is a pure function of the settings dict (CRC-based, so parent and
spawned workers agree), with opt-in delay / raise / hang behaviors keyed
on settings subsets to exercise every failure path.
"""
from __future__ import annotations

import json
import time
import zlib
from typing import Callable, Dict, Optional


def _matches(settings: Dict[str, object],
             cond: Optional[Dict[str, object]]) -> bool:
    return bool(cond) and all(settings.get(k) == v for k, v in cond.items())


def stub_latency(settings: Dict[str, object]) -> float:
    """Deterministic pseudo-latency in (0, 1], identical across processes
    (``hash()`` is salted per process; CRC32 of the sorted JSON is not)."""
    crc = zlib.crc32(json.dumps(settings, sort_keys=True,
                                default=str).encode())
    return (crc % 10_000 + 1) / 10_000.0


def make_stub(delay_s: float = 0.0,
              fail_when: Optional[Dict[str, object]] = None,
              hang_when: Optional[Dict[str, object]] = None,
              exit_when: Optional[Dict[str, object]] = None,
              hang_s: float = 3600.0
              ) -> Callable[[Dict[str, object]], float]:
    """Build ``fn(settings) -> latency``.

    ``delay_s``   sleep per measurement (models compile latency);
    ``fail_when`` settings subset that raises (feasibility failure);
    ``hang_when`` settings subset that sleeps ``hang_s`` (timeout path);
    ``exit_when`` settings subset that hard-kills the process via
                  ``os._exit`` (worker-crash path).
    """

    def fn(settings: Dict[str, object]) -> float:
        if _matches(settings, exit_when):
            import os
            os._exit(17)
        if _matches(settings, hang_when):
            time.sleep(hang_s)
        if _matches(settings, fail_when):
            raise RuntimeError("stub measurement failed")
        if delay_s:
            time.sleep(delay_s)
        return stub_latency(settings)

    return fn
