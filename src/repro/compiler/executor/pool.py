"""``SubprocessExecutor`` — a crash-isolated pool of measurement workers.

Each worker is a *spawned* (never forked — jax state does not survive a
fork) process serving ``(job_id, spec, task, settings) -> (job_id, ok,
payload)`` over a duplex pipe.  The :class:`~repro.compiler.executor.
base.WorkerSpec` travels with each job: the worker applies its env
(``XLA_FLAGS`` device-count pin) and resolves its measure-fn factory once
per distinct spec, so one pool can serve every task of a session.

The parent keeps all the bookkeeping: a bounded submission queue, one
in-flight job per worker, per-job deadlines.  Three failure classes all
resolve to a failed :class:`MeasureResult` without disturbing the rest of
the pool:

* the measure fn raises          -> worker survives, reports the error;
* the worker process dies        -> detected via its sentinel, respawned;
* the job exceeds ``timeout_s``  -> the (hung) worker is killed and
                                    respawned.

Every respawn is lazy — a replacement is only spawned when there is
queued work to give it.
"""
from __future__ import annotations

import collections
import math
import os
import time
import traceback
from multiprocessing import connection, get_context
from typing import Deque, Dict, List, Optional

from repro import obs
from repro.compiler.executor.base import (Executor, MeasureHandle,
                                          MeasureResult, WorkerSpec,
                                          resolve_factory)

_SHUTDOWN = None  # sentinel job telling a worker to exit cleanly
_STARTED = "__started__"  # worker -> parent: measurement underway


def _worker_main(conn) -> None:
    """Worker process entry point (module-level: spawn-picklable).

    Each job carries its :class:`WorkerSpec`; the worker applies the
    spec's env and resolves its factory once per distinct spec, then
    caches the measure fn — so one pool serves every task of a
    multi-task session.  A spec whose factory fails to resolve fails its
    jobs identically instead of crash-looping the pool through respawns.
    """
    fns = {}  # spec.cache_key() -> (measure fn | None, init_error | None)
    while True:
        try:
            msg = conn.recv()
        except (EOFError, OSError):
            return  # parent went away
        if msg is _SHUTDOWN:
            return
        job_id, spec, _task, settings = msg
        key = spec.cache_key()
        if key not in fns:
            # Env pins only take effect before the runtime (jax) first
            # initializes in this process — i.e. before the first factory
            # resolution.  Once any factory has resolved, a later spec's
            # env entries must already be in force (same value, whether
            # set by an earlier spec or inherited from the parent);
            # anything else would silently measure the wrong topology,
            # so it fails this spec's jobs loudly instead.
            stale = {k: v for k, v in spec.env.items()
                     if os.environ.get(k) != v}
            if fns and stale:
                fns[key] = (None, "WorkerEnvConflict: spec needs "
                            f"{stale} but this worker's runtime already "
                            "initialized under "
                            f"{ {k: os.environ.get(k) for k in stale} }")
            else:
                try:
                    os.environ.update(dict(spec.env))
                    fns[key] = (resolve_factory(spec), None)
                except Exception:
                    fns[key] = (None, "WorkerInitError: "
                                + traceback.format_exc(limit=4).strip())
        fn, init_error = fns[key]
        if init_error is not None:
            conn.send((job_id, False, init_error))
            continue
        # ack: startup (spawn + factory/jax import) is done, the
        # measurement itself starts now — the parent restarts the
        # timeout clock so slow worker start-up is never billed to the
        # configuration being measured
        conn.send((_STARTED, job_id))
        try:
            out = fn(settings)
        except Exception as e:  # infeasible configuration
            conn.send((job_id, False, f"{type(e).__name__}: {e}"))
        else:
            conn.send((job_id, True, out))


def adaptive_inflight(workers: int, ema_duration_s: Optional[float],
                      lead_s: float = 0.25, max_depth: int = 8) -> int:
    """In-flight bound from observed measurement durations.

    The bound balances two failure modes: *short* measurements starve the
    pool between parent service pumps unless a deep queue keeps workers
    fed, while *long* measurements (SPMD compiles) should keep the classic
    shallow bound so ``submit`` hands control back to the parent quickly
    (overlapping MAPPO/GBT work) and queued work tracks the freshest
    surrogate.  The queue is sized to ~``lead_s`` seconds of work per
    worker on top of the one job each runs, clamped to [2, ``max_depth``]x
    the worker count; with no observations yet it is the historical
    ``2 * workers`` default.
    """
    if ema_duration_s is None:
        return 2 * workers
    depth = 1 + math.ceil(lead_s / max(ema_duration_s, 1e-6))
    return workers * int(min(max(depth, 2), max_depth))


class _Job:
    __slots__ = ("handle", "deadline", "started", "dispatched")

    def __init__(self, handle: MeasureHandle):
        self.handle = handle
        self.deadline: Optional[float] = None  # set at dispatch time
        self.started: Optional[float] = None   # set at the worker's ack
        self.dispatched: Optional[float] = None  # sent to a worker


class _Worker:
    __slots__ = ("proc", "conn", "job")

    def __init__(self, proc, conn):
        self.proc = proc
        self.conn = conn
        self.job: Optional[_Job] = None


class SubprocessExecutor(Executor):
    """Fan measurement jobs across ``workers`` spawned processes.

    ``spec``           default measure-fn factory; jobs may override it
                       per ``submit`` (a session shares one pool across
                       all its tasks this way).  ``None`` is allowed when
                       every job brings its own spec.
    ``timeout_s``      per-measurement wall-clock limit (None = unlimited),
                       counted from the worker's started-ack — never from
                       dispatch — so cold-worker startup (spawn + factory/
                       jax import) is not billed to the configuration
                       being measured.
    ``startup_grace_s``extra allowance a dispatched job gets *before* the
                       ack arrives; a worker hung in startup is killed
                       after ``timeout_s + startup_grace_s``.
    ``max_inflight``   bound on submitted-but-unresolved jobs; ``submit``
                       blocks (servicing the pool) once it is reached.
                       ``None`` (default) adapts the bound to observed
                       measurement durations (``adaptive_inflight``):
                       starts at the classic ``2 * workers`` and deepens
                       up to ``8 * workers`` for sub-second measurements
                       that would otherwise starve the pool between
                       service pumps; an explicit int pins the bound.
    """

    _POLL_S = 0.02  # service granularity when blocking

    def __init__(self, spec: Optional[WorkerSpec] = None, workers: int = 2,
                 timeout_s: Optional[float] = None,
                 max_inflight: Optional[int] = None,
                 startup_grace_s: float = 120.0):
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        self.spec = spec
        self.n_workers = int(workers)
        self.timeout_s = timeout_s
        self.startup_grace_s = startup_grace_s
        self.max_inflight = max_inflight  # None = adaptive
        self._ema_duration_s: Optional[float] = None
        self.respawns = 0  # workers killed (timeout) or found dead (crash)
        self.jobs_done = 0  # resolved jobs (ok or failed)
        self.failures = 0   # resolved with ok=False (incl. crashes)
        self._ctx = get_context("spawn")
        self._workers: List[_Worker] = []
        self._queue: Deque[_Job] = collections.deque()
        self._next_id = 0
        self._closed = False

    # ------------------------------------------------------------- protocol
    def submit(self, task: str, settings: Dict[str, object],
               spec: Optional[WorkerSpec] = None) -> MeasureHandle:
        if self._closed:
            raise RuntimeError("executor is closed")
        spec = spec or self.spec
        if spec is None:
            raise ValueError("no WorkerSpec: executor has no default and "
                             "the job carried none")
        handle = MeasureHandle(self._next_id, task, settings, executor=self,
                               spec=spec)
        self._next_id += 1
        self._queue.append(_Job(handle))
        self._dispatch()
        while self._inflight() >= self._inflight_limit():
            self._service(self._POLL_S)
        return handle

    def poll(self) -> None:
        if not self._closed:
            self._service(0.0)

    def drain(self, handles: Optional[List[MeasureHandle]] = None) -> None:
        def pending() -> bool:
            if handles is not None:
                return any(not h.done() for h in handles)
            return self._inflight() > 0

        while pending():
            self._dispatch()
            self._service(self._POLL_S)

    def start(self) -> None:
        """Pre-spawn the full pool (optional — dispatch spawns lazily)."""
        while len(self._workers) < self.n_workers:
            self._spawn()

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        for w in self._workers:
            if w.job is None:
                try:
                    w.conn.send(_SHUTDOWN)
                except (OSError, BrokenPipeError):
                    pass
            else:  # abandon in-flight work
                w.proc.kill()
                w.job.handle._resolve(MeasureResult(
                    ok=False, error="ExecutorClosed: job abandoned"))
                w.job = None
        for w in self._workers:
            w.proc.join(timeout=2.0)
            if w.proc.is_alive():
                w.proc.kill()
                w.proc.join(timeout=1.0)
            w.conn.close()
        self._workers.clear()
        for job in self._queue:  # never dispatched
            job.handle._resolve(MeasureResult(
                ok=False, error="ExecutorClosed: job abandoned"))
        self._queue.clear()

    def stats(self) -> Dict[str, object]:
        return {"kind": "subprocess",
                "workers_alive": len(self._workers),
                "respawns": self.respawns,
                "queued": len(self._queue),
                "running": sum(1 for w in self._workers
                               if w.job is not None),
                "max_inflight": self._inflight_limit(),
                "jobs": self.jobs_done,
                "failures": self.failures}

    # ------------------------------------------------------------ internals
    def _inflight_limit(self) -> int:
        if self.max_inflight is not None:
            return self.max_inflight
        return adaptive_inflight(self.n_workers, self._ema_duration_s)

    def _observe_duration(self, duration_s: float) -> None:
        """Fold one measurement's ack-to-result duration into the EMA the
        adaptive in-flight bound is computed from."""
        if self._ema_duration_s is None:
            self._ema_duration_s = duration_s
        else:
            self._ema_duration_s = (0.7 * self._ema_duration_s
                                    + 0.3 * duration_s)

    def _inflight(self) -> int:
        return len(self._queue) + sum(1 for w in self._workers
                                      if w.job is not None)

    def _spawn(self) -> _Worker:
        parent_conn, child_conn = self._ctx.Pipe(duplex=True)
        proc = self._ctx.Process(target=_worker_main,
                                 args=(child_conn,), daemon=True)
        proc.start()
        child_conn.close()  # parent keeps its end only
        w = _Worker(proc, parent_conn)
        self._workers.append(w)
        return w

    def _dispatch(self) -> None:
        """Hand queued jobs to idle workers, spawning up to the pool size."""
        idle = [w for w in self._workers if w.job is None]
        while self._queue and (idle or len(self._workers) < self.n_workers):
            w = idle.pop() if idle else self._spawn()
            job = self._queue.popleft()
            if self.timeout_s is not None:
                # pre-ack deadline: measurement budget + startup grace;
                # the _STARTED ack re-arms it to the pure timeout_s
                job.deadline = (time.monotonic() + self.timeout_s
                                + self.startup_grace_s)
            job.dispatched = time.monotonic()
            try:
                w.conn.send((job.handle.job_id, job.handle.spec,
                             job.handle.task, job.handle.settings))
            except (OSError, BrokenPipeError):
                self._reap(w, "WorkerCrash: pipe closed before dispatch")
                self._queue.appendleft(job)
                job.deadline = None
                job.dispatched = None
                continue
            w.job = job

    def _reap(self, w: _Worker, error: str) -> None:
        """Remove a dead/hung worker, failing its in-flight job."""
        self.respawns += 1
        if w.proc.is_alive():
            w.proc.kill()
        w.proc.join(timeout=2.0)
        w.conn.close()
        self._workers.remove(w)
        if w.job is not None:
            self.jobs_done += 1
            self.failures += 1
            w.job.handle._resolve(MeasureResult(ok=False, error=error))
            w.job = None

    def _service(self, block_s: float) -> None:
        """One pump of the event loop: expire deadlines, collect results,
        detect crashes, refill workers.  Blocks at most ``block_s``."""
        now = time.monotonic()
        for w in list(self._workers):
            if (w.job is not None and w.job.deadline is not None
                    and now > w.job.deadline and not w.conn.poll()):
                self._reap(w, "TimeoutError: measurement exceeded "
                              f"{self.timeout_s:.1f}s; worker killed")
        busy = [w for w in self._workers if w.job is not None]
        if not busy:
            self._dispatch()
            return
        timeout = block_s
        deadlines = [w.job.deadline for w in busy
                     if w.job.deadline is not None]
        if deadlines:
            timeout = max(0.0, min(timeout, min(deadlines) - now))
        sources, by_source = [], {}
        for w in busy:
            sources += [w.conn, w.proc.sentinel]
            by_source[w.conn] = w
            by_source[w.proc.sentinel] = w
        ready = connection.wait(sources, timeout=timeout)
        seen = set()
        for src in ready:
            w = by_source[src]
            if id(w) in seen or w.job is None:
                continue
            seen.add(id(w))
            # Prefer the pipe even when the sentinel fired: a worker that
            # wrote its result and then died still counts as a success.
            if w.conn.poll():
                try:
                    msg = w.conn.recv()
                except (EOFError, OSError):
                    self._reap(w, "WorkerCrash: worker process died "
                                  "mid-measurement")
                    continue
                if msg[0] == _STARTED:
                    # measurement begins now: restart the clock so worker
                    # start-up (spawn + jax/factory import) is not billed
                    # to this configuration
                    if msg[1] == w.job.handle.job_id:
                        w.job.started = time.monotonic()
                        if w.job.deadline is not None:
                            w.job.deadline = w.job.started + self.timeout_s
                        if w.job.dispatched is not None:
                            # dispatch->ack: worker startup + queue latency
                            obs.current().add_span_mono(
                                "dispatch", cat="executor",
                                start_mono_s=w.job.dispatched,
                                dur_s=w.job.started - w.job.dispatched,
                                tid=f"pool-w{w.proc.pid}",
                                args={"task": w.job.handle.task})
                    continue
                job_id, ok, payload = msg
                if job_id != w.job.handle.job_id:
                    # stale result from a pre-timeout job on a reused
                    # worker cannot happen (workers are killed on
                    # timeout), but guard against protocol drift
                    continue
                if w.job.started is not None:  # feed the adaptive bound
                    dur = time.monotonic() - w.job.started
                    self._observe_duration(dur)
                    obs.current().add_span_mono(
                        "measure", cat="measure",
                        start_mono_s=w.job.started, dur_s=dur,
                        tid=f"pool-w{w.proc.pid}",
                        args={"task": w.job.handle.task})
                self.jobs_done += 1
                if not ok:
                    self.failures += 1
                w.job.handle._resolve(
                    MeasureResult(ok=bool(ok), value=payload if ok else None,
                                  error="" if ok else str(payload)))
                w.job = None
            elif not w.proc.is_alive():
                self._reap(w, "WorkerCrash: worker process died "
                              "mid-measurement (exitcode "
                              f"{w.proc.exitcode})")
        self._dispatch()
