"""Wire protocol for the remote measurement fabric.

One framing, both sides: a frame is a 4-byte big-endian payload length
followed by a UTF-8 JSON object.  Every message carries a ``"type"``; the
handshake additionally carries the protocol ``"version"`` so a stale
daemon and a newer executor fail loudly instead of mis-parsing each
other.  Message types:

``hello``          client -> worker: opens a session, names the version.
``capabilities``   worker -> client: the handshake reply — a
                   :class:`WorkerCapabilities` descriptor (device count,
                   backend, env pins, job slots) the executor routes
                   against.
``job``            client -> worker: one measurement — job id, task name,
                   decoded settings, and the serialized
                   :class:`~repro.compiler.executor.base.WorkerSpec`.
``started``        worker -> client: the measure fn is running (factory
                   resolved); the executor re-arms the job's timeout from
                   this ack so daemon-side startup is never billed to the
                   configuration being measured.
``result``         worker -> client: ``{job_id, ok, value | error}``.
                   Since minor 1 it may carry a ``"span"`` object —
                   ``{name, cat, t_wall, dur_s}``, the daemon's own
                   timing of the measure fn — which the executor merges
                   into the session's ambient tracer (``repro.obs``).
``heartbeat``      either direction: liveness; the executor declares a
                   connection dead after ``heartbeat_timeout_s`` without
                   any inbound frame.  Since minor 1 daemon-side
                   heartbeats may carry a ``"load"`` object — ``{busy,
                   jobs_done, mean_measure_s}`` — surfaced per endpoint
                   in ``RemoteExecutor.stats()``.
``shutdown``       client -> worker: close this connection cleanly
                   (``scope: "daemon"`` stops the whole daemon — used by
                   tests and fleet teardown).
``error``          worker -> client: handshake-level rejection.

Everything here is stdlib-only and jax-free (the executor package's
import-light rule).  The protocol is **trusted-network-only**: frames are
neither authenticated nor encrypted, and a job names an importable
factory the worker will call — never expose a daemon beyond a network
where every peer may already run arbitrary code.
"""
from __future__ import annotations

import dataclasses
import json
import re
import socket
import struct
import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.compiler.executor.base import WorkerSpec

PROTOCOL_VERSION = 1
# Minor revisions are additive-only: new *optional* keys on existing
# frame types (result ``span``, heartbeat ``load``), which both sides
# already ignore when unknown.  The handshake advertises ``minor`` but
# never rejects on it — an old daemon (no minor field) still speaks to a
# new executor and vice versa; only the major ``version`` gates.
PROTOCOL_MINOR = 1
_LEN = struct.Struct(">I")
# A settings dict plus a spec is tiny; 64 MiB guards against a garbage
# peer making the receiver allocate unbounded memory, not real payloads.
MAX_FRAME_BYTES = 64 << 20


class ProtocolError(RuntimeError):
    """Malformed frame or version/handshake mismatch."""


def encode_frame(msg: Dict[str, object]) -> bytes:
    payload = json.dumps(msg, separators=(",", ":"), default=str).encode()
    if len(payload) > MAX_FRAME_BYTES:
        raise ProtocolError(f"frame of {len(payload)} bytes exceeds "
                            f"{MAX_FRAME_BYTES}")
    return _LEN.pack(len(payload)) + payload


class FrameBuffer:
    """Incremental decoder: feed raw socket bytes, get whole messages."""

    def __init__(self):
        self._buf = bytearray()

    def feed(self, data: bytes) -> List[Dict[str, object]]:
        self._buf.extend(data)
        out: List[Dict[str, object]] = []
        while True:
            if len(self._buf) < _LEN.size:
                return out
            (n,) = _LEN.unpack_from(self._buf)
            if n > MAX_FRAME_BYTES:
                raise ProtocolError(f"peer announced a {n}-byte frame "
                                    f"(max {MAX_FRAME_BYTES})")
            if len(self._buf) < _LEN.size + n:
                return out
            payload = bytes(self._buf[_LEN.size:_LEN.size + n])
            del self._buf[:_LEN.size + n]
            try:
                msg = json.loads(payload)
            except ValueError as e:
                raise ProtocolError(f"undecodable frame: {e}") from None
            if not isinstance(msg, dict) or "type" not in msg:
                raise ProtocolError(f"frame without a type: {msg!r}")
            out.append(msg)


def send_frame(sock: socket.socket, msg: Dict[str, object]) -> None:
    sock.sendall(encode_frame(msg))


def recv_frame(sock: socket.socket,
               timeout_s: Optional[float] = None) -> Dict[str, object]:
    """Blocking single-frame read (handshakes only — steady-state traffic
    goes through :class:`FrameBuffer` under a selector)."""
    sock.settimeout(timeout_s)
    buf = FrameBuffer()
    deadline = None if timeout_s is None else time.monotonic() + timeout_s
    while True:
        data = sock.recv(65536)
        if not data:
            raise ProtocolError("connection closed mid-frame")
        msgs = buf.feed(data)
        if msgs:
            if len(msgs) > 1:
                raise ProtocolError("unexpected pipelined handshake frames")
            return msgs[0]
        if deadline is not None and time.monotonic() > deadline:
            raise socket.timeout("frame incomplete within timeout")


# --------------------------------------------------------------- endpoints

def parse_endpoints(remote) -> List[Tuple[str, int]]:
    """``"h1:p1,h2:p2"`` (or a sequence of ``"h:p"``) -> [(host, port)].
    IPv6 literals use ``[addr]:port``."""
    if isinstance(remote, str):
        parts: Sequence[str] = [p for p in remote.split(",") if p.strip()]
    else:
        parts = list(remote)
    if not parts:
        raise ValueError("no remote endpoints given")
    out: List[Tuple[str, int]] = []
    for p in parts:
        p = p.strip()
        m = re.match(r"^\[(.+)\]:(\d+)$", p)  # [v6]:port
        if m:
            out.append((m.group(1), int(m.group(2))))
            continue
        host, sep, port = p.rpartition(":")
        if not sep or not port.isdigit():
            raise ValueError(f"endpoint {p!r} is not HOST:PORT")
        out.append((host or "127.0.0.1", int(port)))
    return out


def endpoint_label(addr: Tuple[str, int]) -> str:
    return f"{addr[0]}:{addr[1]}"


# ------------------------------------------------------------ capabilities

@dataclasses.dataclass(frozen=True)
class WorkerCapabilities:
    """What one daemon advertises at handshake — the WorkerSpec-shaped
    half the executor routes on (``device_count``/``backend``/``env``
    mirror the spec's env pins) plus scheduling facts (``slots``)."""

    slots: int = 1
    backend: str = "cpu"
    device_count: Optional[int] = None  # None = serves any topology
    env: Dict[str, str] = dataclasses.field(default_factory=dict)
    pid: int = 0
    host: str = ""

    def to_wire(self) -> Dict[str, object]:
        return {"type": "capabilities", "version": PROTOCOL_VERSION,
                "minor": PROTOCOL_MINOR,
                "slots": self.slots, "backend": self.backend,
                "device_count": self.device_count, "env": dict(self.env),
                "pid": self.pid, "host": self.host}

    @staticmethod
    def from_wire(msg: Dict[str, object]) -> "WorkerCapabilities":
        if msg.get("type") == "error":
            raise ProtocolError(f"daemon rejected handshake: "
                                f"{msg.get('error', 'unknown')}")
        if msg.get("type") != "capabilities":
            raise ProtocolError(f"expected capabilities, got {msg!r}")
        if msg.get("version") != PROTOCOL_VERSION:
            raise ProtocolError(
                f"protocol version mismatch: daemon speaks "
                f"{msg.get('version')}, this executor speaks "
                f"{PROTOCOL_VERSION}")
        dc = msg.get("device_count")
        return WorkerCapabilities(
            slots=max(int(msg.get("slots", 1)), 1),
            backend=str(msg.get("backend", "cpu")),
            device_count=None if dc is None else int(dc),
            env={str(k): str(v) for k, v in (msg.get("env") or {}).items()},
            pid=int(msg.get("pid", 0)), host=str(msg.get("host", "")))


_DEVICE_PIN = re.compile(r"--xla_force_host_platform_device_count=(\d+)")


def device_count_pin(env) -> Optional[int]:
    """The placeholder device count a spec's env pins (via ``XLA_FLAGS``),
    or None when the spec doesn't care about topology."""
    m = _DEVICE_PIN.search(str((env or {}).get("XLA_FLAGS", "")))
    return int(m.group(1)) if m else None


def spec_compatible(spec: Optional[WorkerSpec],
                    caps: WorkerCapabilities) -> bool:
    """Can this daemon serve jobs of this spec?  Heterogeneous-pool
    routing: a spec pinning a device count only matches daemons
    advertising that count (or none — a wildcard daemon applies the pin
    itself at factory resolution); any other env pin the daemon
    *advertises* must agree (pins it doesn't advertise are applied
    daemon-side with the worker-pool conflict semantics)."""
    if spec is None:
        return True
    want = device_count_pin(spec.env)
    if (want is not None and caps.device_count is not None
            and caps.device_count != want):
        return False
    for k, v in spec.env.items():
        if k == "XLA_FLAGS":
            continue  # topology handled above; full-string equality is
            #           too strict (flag order, unrelated flags)
        if k in caps.env and caps.env[k] != str(v):
            return False
    return True


# ------------------------------------------------------------ spec on wire

def spec_to_wire(spec: WorkerSpec) -> Dict[str, object]:
    return {"factory": spec.factory, "args": list(spec.args),
            "kwargs": dict(spec.kwargs), "env": dict(spec.env)}


def spec_from_wire(d: Dict[str, object]) -> WorkerSpec:
    return WorkerSpec(factory=str(d["factory"]),
                      args=tuple(d.get("args") or ()),
                      kwargs=dict(d.get("kwargs") or {}),
                      env={str(k): str(v)
                           for k, v in (d.get("env") or {}).items()})
