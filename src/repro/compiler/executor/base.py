"""Executor protocol, result/handle types, and the in-process executor.

Measurement jobs are *data*: a task name plus a decoded knob-settings
dict.  What actually runs them is a measure function built by a factory —
either a plain callable (``SerialExecutor(fn=...)``) or a
:class:`WorkerSpec` naming an importable module-level factory, so a
spawned worker process can rebuild the function on its side without
pickling closures.

Stdlib-only on purpose: see the package docstring.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Callable, Dict, List, Mapping, Optional, Tuple

from repro import obs


@dataclasses.dataclass(frozen=True)
class WorkerSpec:
    """How a worker (re)builds its measure function.

    ``factory`` is ``"package.module:callable"``; the callable is invoked
    with ``*args, **kwargs`` and must return ``fn(settings) -> result``.
    ``env`` entries are applied to ``os.environ`` *before* the factory
    module is imported — this is where ``XLA_FLAGS`` pins the placeholder
    device count so each worker's own jax init sees the right topology.
    """

    factory: str
    args: Tuple = ()
    kwargs: Mapping[str, object] = dataclasses.field(default_factory=dict)
    env: Mapping[str, str] = dataclasses.field(default_factory=dict)

    def cache_key(self) -> Tuple:
        """Stable identity for caching resolved measure fns: one executor
        can serve jobs from many specs (one per tuning task), resolving
        each factory once per worker."""
        return (self.factory, tuple(self.args),
                tuple(sorted((k, repr(v)) for k, v in self.kwargs.items())),
                tuple(sorted(self.env.items())))


def resolve_factory(spec: WorkerSpec) -> Callable[[Dict[str, object]], object]:
    """Import ``spec.factory`` and call it -> the measure function."""
    mod_name, sep, attr = spec.factory.partition(":")
    if not sep or not attr:
        raise ValueError(f"WorkerSpec.factory must be 'module:callable', "
                         f"got {spec.factory!r}")
    factory = getattr(importlib.import_module(mod_name), attr)
    return factory(*spec.args, **dict(spec.kwargs))


@dataclasses.dataclass
class MeasureResult:
    """Outcome of one measurement job, however it was executed.

    ``ok=False`` covers all three failure classes — the measure function
    raised, the worker process died, or the job exceeded its timeout —
    distinguished only by the ``error`` string.  The oracle maps every
    failed result to its ``penalty_latency`` row.
    """

    ok: bool
    value: object = None
    error: str = ""


def add_worker_args(parser) -> None:
    """The one definition of the ``--workers``/``--timeout-s``/``--remote``
    CLI surface (every tuning entry point shares it — keep help text and
    defaults from drifting apart)."""
    parser.add_argument(
        "--workers", type=int, default=0,
        help="parallel measurement worker processes (0 = in-process; "
             "batched analytical oracles ignore this)")
    parser.add_argument(
        "--timeout-s", type=float, default=None,
        help="per-measurement timeout in seconds, counted from when the "
             "measurement starts on a worker (needs --workers >= 1 or "
             "--remote)")
    parser.add_argument(
        "--remote", metavar="HOST:PORT[,HOST:PORT...]", default=None,
        help="measure on remote worker daemons (python -m "
             "repro.compiler.executor.worker --listen HOST:PORT) instead "
             "of a local pool; mutually exclusive with --workers")
    parser.add_argument(
        "--trace", metavar="PATH", default=None,
        help="write a span-level trace of the run: Chrome-trace JSON "
             "(load in Perfetto / chrome://tracing; summarize with "
             "tools/trace_summary.py), or raw JSONL if PATH ends in "
             ".jsonl")
    parser.add_argument(
        "--trace-sample-rate", type=float, default=1.0, metavar="RATE",
        help="keep this fraction of per-measurement measure/dispatch "
             "spans in the trace (phase-level spans are always kept; "
             "dropped spans stay accounted in the trace's sampling "
             "metadata); needs --trace")
    parser.add_argument(
        "--monitor", type=int, default=None, metavar="PORT",
        help="serve live /metrics (Prometheus), /status (JSON), and "
             "/trace on http://127.0.0.1:PORT for the duration of the "
             "run (0 = ephemeral port)")


def validate_worker_args(parser, args) -> None:
    """Shared checks: one transport per session, and a timeout is only
    enforceable where measurements can be preempted."""
    if getattr(args, "remote", None) and args.workers:
        parser.error("--remote and --workers are mutually exclusive: one "
                     "measurement transport per session (remote daemons "
                     "bring their own slots; drop --workers)")
    if (args.timeout_s is not None and not args.workers
            and not getattr(args, "remote", None)):
        parser.error("--timeout-s needs --workers >= 1 or --remote "
                     "(in-process measurements cannot be preempted)")
    rate = getattr(args, "trace_sample_rate", 1.0)
    if not 0.0 <= rate <= 1.0:
        parser.error("--trace-sample-rate must be in [0, 1]")
    if rate < 1.0 and not getattr(args, "trace", None):
        parser.error("--trace-sample-rate needs --trace (there is no "
                     "trace to sample without it)")


class MeasureHandle:
    """Future for one submitted job; resolved by its executor."""

    __slots__ = ("job_id", "task", "settings", "spec", "_result",
                 "_executor")

    def __init__(self, job_id: int, task: str, settings: Dict[str, object],
                 executor: Optional["Executor"] = None,
                 spec: Optional[WorkerSpec] = None):
        self.job_id = job_id
        self.task = task
        self.settings = settings
        self.spec = spec
        self._result: Optional[MeasureResult] = None
        self._executor = executor

    def done(self) -> bool:
        return self._result is not None

    def result(self) -> MeasureResult:
        """Block (by driving the executor) until the job resolves."""
        if self._result is None and self._executor is not None:
            self._executor.drain([self])
        if self._result is None:
            raise RuntimeError(f"job {self.job_id} never resolved")
        return self._result

    def _resolve(self, result: MeasureResult) -> None:
        self._result = result


class Executor:
    """Protocol: ``submit(task, settings) -> handle`` / ``drain()``.

    ``poll()`` services any completions without blocking (so callers can
    ask ``handle.done()`` meaningfully); ``drain(handles)`` blocks until
    the given handles — or everything in flight, if ``None`` — resolve.

    ``submit``'s optional ``spec`` names the measure-fn factory for *this
    job*, overriding the executor's default — that is what lets one
    worker pool serve every task of a multi-task session instead of each
    task spawning its own ``tasks * workers`` processes.
    """

    n_workers: int = 1

    def submit(self, task: str, settings: Dict[str, object],
               spec: Optional[WorkerSpec] = None) -> MeasureHandle:
        raise NotImplementedError

    def poll(self) -> None:
        """Service completions that are already available; never blocks."""

    def drain(self, handles: Optional[List[MeasureHandle]] = None) -> None:
        raise NotImplementedError

    def close(self) -> None:
        """Release workers; the executor must not be used afterwards."""

    def stats(self) -> Dict[str, object]:
        """Uniform observability snapshot — every executor answers the
        same keys so reports never ``hasattr``-sniff the transport.
        Executors without workers or queues return the zeroed shape."""
        return {"kind": "serial", "workers_alive": 0, "respawns": 0,
                "queued": 0, "running": 0, "max_inflight": 0,
                "jobs": 0, "failures": 0}

    def __enter__(self) -> "Executor":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class SerialExecutor(Executor):
    """In-process executor: ``submit`` runs the measurement immediately.

    Exactly today's behavior — one measurement at a time, in submission
    order, in the parent process — which makes it both the zero-overhead
    default and the determinism reference for ``SubprocessExecutor``.
    Per-measurement timeouts cannot preempt in-process work and are
    therefore not enforced here; likewise per-spec ``env`` pins are *not*
    applied (the parent process already initialized its runtime — env
    mutation after the fact is a worker-only concept).
    """

    def __init__(self, fn: Optional[Callable[[Dict], object]] = None,
                 spec: Optional[WorkerSpec] = None):
        if fn is not None and spec is not None:
            raise ValueError("SerialExecutor takes fn= or spec=, not both")
        self._fn = fn if fn is not None else (
            resolve_factory(spec) if spec is not None else None)
        self._fns: Dict[Tuple, Callable] = {}  # per-job-spec resolutions
        self._next_id = 0

    def submit(self, task: str, settings: Dict[str, object],
               spec: Optional[WorkerSpec] = None) -> MeasureHandle:
        handle = MeasureHandle(self._next_id, task, settings, executor=self,
                               spec=spec)
        self._next_id += 1
        try:
            # an explicit default fn wins over the job's spec: in-process
            # the fn IS the resolved factory, so re-resolving the spec
            # would only build a redundant copy
            fn = self._fn
            if fn is None and spec is not None:
                key = spec.cache_key()
                if key not in self._fns:
                    self._fns[key] = resolve_factory(spec)
                fn = self._fns[key]
            if fn is None:
                raise ValueError("no measure fn: executor has no default "
                                 "and the job carried no spec")
            with obs.current().span("measure", cat="measure", task=task):
                value = fn(settings)
            handle._resolve(MeasureResult(ok=True, value=value))
        except Exception as e:  # infeasible configuration
            handle._resolve(MeasureResult(
                ok=False, error=f"{type(e).__name__}: {e}"))
        return handle

    def drain(self, handles: Optional[List[MeasureHandle]] = None) -> None:
        pass  # everything resolves at submit time
