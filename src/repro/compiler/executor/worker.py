"""Measurement worker daemon — the server side of the remote fabric.

    python -m repro.compiler.executor.worker --listen HOST:PORT \
        [--slots N] [--backend cpu] [--device-count N]

One daemon serves measurement jobs over TCP to any number of
:class:`~repro.compiler.executor.remote.RemoteExecutor` clients, speaking
the versioned frame protocol of :mod:`repro.compiler.executor.wire`.  Per
connection: handshake (hello -> capabilities), then jobs fan across
``slots`` runner threads while a heartbeat thread keeps the client's
liveness detector fed.  Factory resolution follows the subprocess pool's
worker semantics exactly — each distinct :class:`~repro.compiler.executor
.base.WorkerSpec` resolves once per daemon *process*, its env pins are
applied before the first resolution, and a spec whose pins contradict the
already-initialized runtime fails its jobs loudly (``WorkerEnvConflict``)
instead of silently measuring the wrong topology.

``slots > 1`` runs jobs as threads of ONE process (they share a runtime);
that is right for stub/IO-bound oracles, while jax compile oracles want
``--slots 1`` and one daemon per core — crash isolation then comes from
daemon granularity, with the executor's reconnect logic riding out a
restarted daemon.

Security: trusted networks only.  A job names an importable factory this
process will call — the protocol deliberately has no authentication
(see the ``wire`` module docstring); bind to loopback or a private
fabric, never a public interface.
"""
from __future__ import annotations

import argparse
import os
import socket
import sys
import threading
import time
import traceback
from typing import Dict, Optional, Tuple

from repro.compiler.executor.base import WorkerSpec, resolve_factory
from repro.compiler.executor.wire import (PROTOCOL_VERSION, FrameBuffer,
                                          ProtocolError, WorkerCapabilities,
                                          device_count_pin, encode_frame,
                                          parse_endpoints, spec_from_wire)
from repro.obs import log


class _FactoryCache:
    """Daemon-wide spec -> measure-fn cache with the pool's env-pin
    semantics (env is process-global, so the cache must be too)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._fns: Dict[Tuple, Tuple[Optional[object], Optional[str]]] = {}

    def resolve(self, spec: WorkerSpec):
        key = spec.cache_key()
        with self._lock:
            if key not in self._fns:
                stale = {k: v for k, v in spec.env.items()
                         if os.environ.get(k) != v}
                if self._fns and stale:
                    self._fns[key] = (
                        None, "WorkerEnvConflict: spec needs "
                        f"{stale} but this daemon's runtime already "
                        "initialized under "
                        f"{ {k: os.environ.get(k) for k in stale} }")
                else:
                    try:
                        os.environ.update(dict(spec.env))
                        self._fns[key] = (resolve_factory(spec), None)
                    except Exception:
                        self._fns[key] = (
                            None, "WorkerInitError: "
                            + traceback.format_exc(limit=4).strip())
            return self._fns[key]


class _Connection:
    """One client connection: reader loop + heartbeat + job runners."""

    def __init__(self, daemon: "WorkerDaemon", sock: socket.socket,
                 peer: str):
        self.daemon = daemon
        self.sock = sock
        self.peer = peer
        self._wlock = threading.Lock()
        self._closed = threading.Event()
        self._slots = threading.Semaphore(daemon.capabilities.slots)

    # every write shares one lock: job runners, heartbeats, and the
    # handshake interleave on this socket
    def send(self, msg: Dict[str, object]) -> bool:
        if self._closed.is_set():
            return False
        try:
            with self._wlock:
                self.sock.sendall(encode_frame(msg))
            return True
        except OSError:
            self.close()
            return False

    def close(self) -> None:
        if not self._closed.is_set():
            self._closed.set()
            try:
                self.sock.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            self.sock.close()

    # ----------------------------------------------------------- lifecycle
    def run(self) -> None:
        try:
            if not self._handshake():
                return
            hb = threading.Thread(target=self._heartbeat_loop, daemon=True)
            hb.start()
            self._read_loop()
        finally:
            self.close()

    def _handshake(self) -> bool:
        buf = FrameBuffer()
        self.sock.settimeout(self.daemon.handshake_timeout_s)
        try:
            while True:
                data = self.sock.recv(65536)
                if not data:
                    return False
                msgs = buf.feed(data)
                if msgs:
                    hello = msgs[0]
                    break
        except (OSError, ProtocolError):
            return False
        if (hello.get("type") != "hello"
                or hello.get("version") != PROTOCOL_VERSION):
            self.send({"type": "error",
                       "error": f"unsupported hello {hello.get('type')!r} "
                                f"v{hello.get('version')} (this daemon "
                                f"speaks v{PROTOCOL_VERSION})"})
            return False
        self.sock.settimeout(self.daemon.read_timeout_s)
        return self.send(self.daemon.capabilities.to_wire())

    def _heartbeat_loop(self) -> None:
        while not self._closed.wait(self.daemon.heartbeat_s):
            # minor-1 extension: load telemetry rides the liveness frame
            # (old executors ignore unknown keys)
            if not self.send({"type": "heartbeat",
                              "load": self.daemon.load_snapshot()}):
                return

    def _read_loop(self) -> None:
        buf = FrameBuffer()
        while not self._closed.is_set() and not self.daemon.stopping:
            try:
                data = self.sock.recv(65536)
            except socket.timeout:
                continue  # periodic stop-flag check
            except OSError:
                return
            if not data:
                return  # client went away
            try:
                msgs = buf.feed(data)
            except ProtocolError:
                return
            for msg in msgs:
                t = msg.get("type")
                if t == "job":
                    threading.Thread(target=self._run_job, args=(msg,),
                                     daemon=True).start()
                elif t == "shutdown":
                    if msg.get("scope") == "daemon":
                        self.daemon.stop()
                    return
                # heartbeats (and unknown types, for forward compat) are
                # liveness only — nothing to do

    # ----------------------------------------------------------------- jobs
    def _run_job(self, msg: Dict[str, object]) -> None:
        job_id = msg.get("job_id")
        with self._slots:  # the client never oversubscribes; belt-and-braces
            try:
                spec = spec_from_wire(msg["spec"])
                settings = dict(msg.get("settings") or {})
            except Exception as e:
                self.send({"type": "result", "job_id": job_id, "ok": False,
                           "error": f"ProtocolError: bad job frame: {e}"})
                return
            fn, init_error = self.daemon.factories.resolve(spec)
            if init_error is not None:
                self.send({"type": "result", "job_id": job_id, "ok": False,
                           "error": init_error})
                return
            # started-ack: factory/runtime import is done, the measurement
            # itself begins now — the executor re-arms the job's timeout
            # clock on this frame (same contract as the subprocess pool)
            if not self.send({"type": "started", "job_id": job_id}):
                return
            # the daemon times its own measure fn and ships the span in
            # the result frame (minor-1 extension), so the session's
            # trace carries daemon-side extents, not client-side guesses
            t_wall = time.time()
            t0 = time.monotonic()
            self.daemon.job_started()
            try:
                value = fn(settings)
            except Exception as e:  # infeasible configuration
                dur = time.monotonic() - t0
                self.daemon.job_finished(dur)
                self.send({"type": "result", "job_id": job_id, "ok": False,
                           "error": f"{type(e).__name__}: {e}",
                           "span": self.daemon.job_span(msg, t_wall, dur)})
            else:
                dur = time.monotonic() - t0
                self.daemon.job_finished(dur)
                self.send({"type": "result", "job_id": job_id, "ok": True,
                           "value": value,
                           "span": self.daemon.job_span(msg, t_wall, dur)})


class WorkerDaemon:
    """TCP measurement daemon; embeddable (``start()``) or standalone
    (``serve_forever()`` via the module CLI)."""

    def __init__(self, host: str = "127.0.0.1", port: int = 0,
                 slots: int = 1, backend: str = "cpu",
                 device_count: Optional[int] = None,
                 heartbeat_s: float = 2.0, verbose: bool = False,
                 status_port: Optional[int] = None):
        if device_count is None:
            # advertise the topology this process is already pinned to, so
            # heterogeneous routing works without repeating --device-count
            device_count = device_count_pin(os.environ)
        self.capabilities = WorkerCapabilities(
            slots=max(int(slots), 1), backend=backend,
            device_count=device_count,
            env=({"XLA_FLAGS": os.environ["XLA_FLAGS"]}
                 if "XLA_FLAGS" in os.environ else {}),
            pid=os.getpid(), host=socket.gethostname())
        self.heartbeat_s = heartbeat_s
        self.handshake_timeout_s = 10.0
        self.read_timeout_s = 0.25
        self.verbose = verbose
        self.factories = _FactoryCache()
        # load telemetry shipped inside heartbeat frames (see wire.py)
        self._load_lock = threading.Lock()
        self.busy = 0            # jobs currently measuring
        self.jobs_done = 0       # measure fn completions (ok or raised)
        self.measure_s_sum = 0.0
        self.stopping = False
        # self-served monitoring (--status-port): each daemon exposes its
        # own /metrics + /status, so fleet health is scrapeable even for
        # daemons no executor is currently connected to
        self.monitor = None
        if status_port is not None:
            from repro.obs.serve import MonitorServer
            self.monitor = MonitorServer(port=int(status_port), host=host)
        self._conns: list[_Connection] = []
        self._thread: Optional[threading.Thread] = None
        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind((host, port))
        self._listener.listen(16)
        self._listener.settimeout(0.25)
        self.address: Tuple[str, int] = self._listener.getsockname()[:2]

    @property
    def endpoint(self) -> str:
        return f"{self.address[0]}:{self.address[1]}"

    # --------------------------------------------------- load telemetry
    def job_started(self) -> None:
        with self._load_lock:
            self.busy += 1

    def job_finished(self, dur_s: float) -> None:
        with self._load_lock:
            self.busy -= 1
            self.jobs_done += 1
            self.measure_s_sum += dur_s

    def load_snapshot(self) -> Dict[str, object]:
        with self._load_lock:
            mean = (self.measure_s_sum / self.jobs_done
                    if self.jobs_done else None)
            return {"busy": self.busy, "jobs_done": self.jobs_done,
                    "mean_measure_s": mean}

    @staticmethod
    def job_span(msg: Dict[str, object], t_wall: float,
                 dur_s: float) -> Dict[str, object]:
        """Result-frame span payload for one measure-fn execution."""
        return {"name": "measure", "cat": "measure",
                "t_wall": t_wall, "dur_s": dur_s,
                "task": str(msg.get("task", ""))}

    def _status(self) -> Dict[str, object]:
        caps = self.capabilities
        return {"kind": "worker", "endpoint": self.endpoint,
                "slots": caps.slots, "backend": caps.backend,
                "device_count": caps.device_count,
                "pid": caps.pid, "host": caps.host,
                "connections": sum(1 for c in list(self._conns)
                                   if not c._closed.is_set()),
                "load": self.load_snapshot()}

    def _collect_metrics(self, metrics) -> None:
        load = self.load_snapshot()
        metrics.counter("worker.jobs_done").value = float(load["jobs_done"])
        metrics.gauge("worker.busy").set(float(load["busy"]))
        with self._load_lock:
            metrics.counter("worker.measure_s").value = self.measure_s_sum

    def serve_forever(self) -> None:
        if self.monitor is not None:
            # attach BEFORE start: the instant `running` flips true a
            # scraper may hit /status, and it must already see "worker"
            self.monitor.attach("worker", self._status,
                                collector=self._collect_metrics)
            self.monitor.start()
            log.log("warn" if self.verbose else "info",
                    f"worker daemon status at {self.monitor.url}")
        log.log("warn" if self.verbose else "info",
                f"worker daemon listening on {self.endpoint} "
                f"(slots={self.capabilities.slots}, "
                f"backend={self.capabilities.backend}, "
                f"device_count={self.capabilities.device_count})")
        while not self.stopping:
            try:
                sock, peer = self._listener.accept()
            except socket.timeout:
                continue
            except OSError:
                break  # listener closed by stop()
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            conn = _Connection(self, sock, f"{peer[0]}:{peer[1]}")
            self._conns.append(conn)
            threading.Thread(target=conn.run, daemon=True).start()
        self._listener.close()

    def start(self) -> "WorkerDaemon":
        """Serve on a background thread (in-process daemons for tests and
        the loopback throughput bench)."""
        self._thread = threading.Thread(target=self.serve_forever,
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self.stopping = True
        self._listener.close()
        for conn in self._conns:
            conn.close()
        if self.monitor is not None:
            self.monitor.stop()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
            self._thread = None


# ------------------------------------------------------------------ spawn

def spawn_daemon(slots: int = 1, backend: str = "cpu",
                 device_count: Optional[int] = None,
                 host: str = "127.0.0.1", port: int = 0,
                 heartbeat_s: float = 2.0, timeout_s: float = 30.0,
                 env: Optional[Dict[str, str]] = None):
    """Spawn ``python -m repro.compiler.executor.worker`` as a subprocess;
    returns ``(Popen, "host:port")`` once the daemon is accepting.  The
    bound port is discovered through ``--port-file`` (so ``port=0`` works),
    making this the one spawn path tests and benches share."""
    import subprocess
    import tempfile
    import time
    src = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    penv = dict(os.environ if env is None else env)
    penv["PYTHONPATH"] = src + os.pathsep + penv.get("PYTHONPATH", "")
    fd, port_file = tempfile.mkstemp(prefix="worker-port-")
    os.close(fd)
    os.unlink(port_file)  # the daemon creates it once bound
    cmd = [sys.executable, "-m", "repro.compiler.executor.worker",
           "--listen", f"{host}:{port}", "--slots", str(slots),
           "--backend", backend, "--heartbeat-s", str(heartbeat_s),
           "--port-file", port_file]
    if device_count is not None:
        cmd += ["--device-count", str(device_count)]
    proc = subprocess.Popen(cmd, env=penv)
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        if os.path.exists(port_file):
            with open(port_file) as f:
                endpoint = f.read().strip()
            if endpoint:
                os.unlink(port_file)
                return proc, endpoint
        if proc.poll() is not None:
            raise RuntimeError(f"worker daemon exited rc={proc.returncode} "
                               "before binding")
        time.sleep(0.02)
    proc.kill()
    raise RuntimeError(f"worker daemon did not bind within {timeout_s}s")


# -------------------------------------------------------------------- CLI

def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.compiler.executor.worker",
        description="Measurement worker daemon for RemoteExecutor "
                    "(trusted networks only — no authentication).")
    ap.add_argument("--listen", required=True, metavar="HOST:PORT",
                    help="bind address (port 0 = ephemeral; see "
                         "--port-file)")
    ap.add_argument("--slots", type=int, default=1,
                    help="concurrent jobs (threads of one process; keep 1 "
                         "for jax compile oracles)")
    ap.add_argument("--backend", default="cpu",
                    help="advertised backend tag for heterogeneous routing")
    ap.add_argument("--device-count", type=int, default=None,
                    help="advertised device count (default: parsed from "
                         "this process's XLA_FLAGS pin, else wildcard)")
    ap.add_argument("--heartbeat-s", type=float, default=2.0,
                    help="liveness frame interval")
    ap.add_argument("--port-file", default=None,
                    help="write the bound HOST:PORT here once listening "
                         "(spawners using port 0 read it back)")
    ap.add_argument("--status-port", type=int, default=None,
                    metavar="PORT",
                    help="self-serve /metrics + /status on this HTTP port "
                         "(0 = ephemeral; off by default)")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)
    (host, port), = parse_endpoints(args.listen)
    daemon = WorkerDaemon(host=host, port=port, slots=args.slots,
                          backend=args.backend,
                          device_count=args.device_count,
                          heartbeat_s=args.heartbeat_s,
                          verbose=args.verbose or args.port_file is None,
                          status_port=args.status_port)
    if args.port_file:
        tmp = args.port_file + ".tmp"
        with open(tmp, "w") as f:
            f.write(daemon.endpoint)
        os.replace(tmp, args.port_file)  # atomic: readers see whole lines
    try:
        daemon.serve_forever()
    except KeyboardInterrupt:
        daemon.stop()
    return 0


if __name__ == "__main__":
    sys.exit(main())
