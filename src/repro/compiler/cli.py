"""Command-line entry point for tuning sessions.

    # two ResNet-18 conv cells, shared GBT, 2-measurement smoke budget
    PYTHONPATH=src python -m repro.compiler.cli \
        --model resnet-18 --max-tasks 2 --budget 2

    # one GEMM, AutoTVM baseline, persisted + resumable records
    PYTHONPATH=src python -m repro.compiler.cli \
        --matmul 512x512x512 --algo autotvm --budget 64 \
        --records artifacts/gemm.jsonl

    # pod-level compile oracle (expensive: one SPMD compile per measurement)
    PYTHONPATH=src python -m repro.compiler.cli \
        --arch qwen2-1.5b --shape train_4k --oracle compile --budget 8

    # same, fanned across 4 crash-isolated measurement workers with a
    # 300s per-compile timeout (timed-out/crashed measurements record the
    # failure-penalty row; the pool respawns and the session keeps going)
    PYTHONPATH=src python -m repro.compiler.cli \
        --arch qwen2-1.5b --shape train_4k --oracle compile --budget 8 \
        --workers 4 --timeout-s 300
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List

from repro.compiler.executor import add_worker_args, validate_worker_args
from repro.compiler.session import ALGOS, Session
from repro.compiler.task import TuningTask
from repro.core.tuner import TunerConfig


def _tasks_from_args(args) -> List[TuningTask]:
    picked = [bool(args.model), bool(args.matmul), bool(args.arch)]
    if sum(picked) != 1:
        raise SystemExit("pick exactly one of --model / --matmul / --arch")
    if args.oracle == "compile" and not args.arch:
        raise SystemExit("--oracle compile requires --arch/--shape "
                         "(conv/GEMM tasks are measured analytically)")
    if args.model:
        tasks = TuningTask.conv_tasks(args.model)
        return tasks[:args.max_tasks] if args.max_tasks else tasks
    if args.matmul:
        tasks = []
        for spec in args.matmul:
            m, n, k = (int(x) for x in spec.lower().split("x"))
            tasks.append(TuningTask.matmul(m, n, k))
        return tasks
    if args.oracle != "compile":
        raise SystemExit("--arch/--shape needs --oracle compile")
    return [TuningTask.cell(args.arch, s) for s in args.shape]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.compiler.cli",
        description="Unified tuning session over conv/GEMM analytical tasks "
                    "or pod-level compile cells.")
    ap.add_argument("--model", help="CNN model: tune its conv tasks "
                                    "(e.g. resnet-18)")
    ap.add_argument("--max-tasks", type=int, default=0,
                    help="cap the number of conv tasks (0 = all)")
    ap.add_argument("--matmul", action="append", default=[],
                    metavar="MxNxK", help="GEMM task (repeatable)")
    ap.add_argument("--arch", help="LM arch for the compile oracle")
    ap.add_argument("--shape", action="append", default=[],
                    help="cell shape(s) for --arch (default train_4k)")
    ap.add_argument("--oracle", choices=("analytical", "compile"),
                    default="analytical")
    ap.add_argument("--algo", choices=ALGOS, default="arco")
    ap.add_argument("--budget", type=int, default=None,
                    help="measurements per task")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-cs", action="store_true",
                    help="ablate Confidence Sampling")
    ap.add_argument("--independent", action="store_true",
                    help="per-task GBT instead of the shared cost model")
    ap.add_argument("--records", default=None,
                    help="JSONL measurement records (persist + warm resume)")
    add_worker_args(ap)
    ap.add_argument("--out", default=None, help="write session JSON here")
    args = ap.parse_args(argv)
    validate_worker_args(ap, args)
    if args.arch and not args.shape:
        args.shape = ["train_4k"]

    tasks = _tasks_from_args(args)
    session = Session(tasks, tuner=TunerConfig.fast(), algo=args.algo,
                      budget=args.budget, use_cs=not args.no_cs,
                      share_cost_model=not args.independent,
                      records=args.records, seed=args.seed,
                      workers=args.workers, timeout_s=args.timeout_s)
    result = session.run()

    summary = result.to_dict()
    for rep in summary["reports"].values():  # keep stdout compact
        rep.pop("measurements", None)
        rep["history"] = rep["history"][-3:]
    print(json.dumps(summary, indent=1, default=str))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result.to_dict(), f, indent=1, default=str)
    return 0


if __name__ == "__main__":
    sys.exit(main())
