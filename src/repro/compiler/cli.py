"""Command-line entry point for tuning sessions.

Two subcommands (a bare flag list still means ``tune``, so historical
invocations keep working):

    # two ResNet-18 conv cells, shared GBT, 2-measurement smoke budget
    PYTHONPATH=src python -m repro.compiler.cli tune \
        --model resnet-18 --max-tasks 2 --budget 2

    # one GEMM, AutoTVM baseline, persisted + resumable records
    PYTHONPATH=src python -m repro.compiler.cli tune \
        --matmul 512x512x512 --algo autotvm --budget 64 \
        --records artifacts/gemm.jsonl

    # pod-level compile oracle fanned across 4 crash-isolated measurement
    # workers with a 300s per-compile timeout
    PYTHONPATH=src python -m repro.compiler.cli tune \
        --arch qwen2-1.5b --shape train_4k --oracle compile --budget 8 \
        --workers 4 --timeout-s 300

    # network-scope co-optimization: ONE shared accelerator config for the
    # whole network, per-layer software mappings under it (repro.compiler
    # .netopt); --baseline runs the comparison points at equal budget
    PYTHONPATH=src python -m repro.compiler.cli netopt \
        --model resnet-18 --layer-budget 16 --records artifacts/r18.jsonl
    PYTHONPATH=src python -m repro.compiler.cli netopt \
        --model resnet-18 --baseline hw-frozen

    # cross-network surrogate transfer over the workload zoo: tune one
    # network saving its GBT training rows, then warm-start another
    # network's search from them (repro.compiler.surrogate_store)
    PYTHONPATH=src python -m repro.compiler.cli netopt \
        --network vgg-11 --save-surrogates artifacts/surr.jsonl
    PYTHONPATH=src python -m repro.compiler.cli netopt \
        --network resnet-18 --warm-from artifacts/surr.jsonl
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List

from repro.compiler.executor import add_worker_args, validate_worker_args
from repro.compiler.session import ALGOS, Session
from repro.compiler.surrogate_store import add_surrogate_args, store_from_args
from repro.compiler.task import TuningTask
from repro.compiler.zoo import get_network, network_names

from repro.core.tuner import TunerConfig

SUBCOMMANDS = ("tune", "netopt")


def _network_label(args) -> str:
    """The ONE network label for this invocation's task set, shared by
    tune and netopt: surrogate-store rows are keyed (and own-network
    excluded) by it, so the two subcommands must always derive it the
    same way for the same workload."""
    return args.network or args.model or ",".join(args.matmul)


def _network_tasks(args) -> List[TuningTask]:
    """Tasks from the network-defining flags shared by both subcommands."""
    if args.network:
        tasks = list(get_network(args.network).tasks)
    elif args.model:
        tasks = TuningTask.conv_tasks(args.model)
    else:
        tasks = []
        for spec in args.matmul:
            m, n, k = (int(x) for x in spec.lower().split("x"))
            tasks.append(TuningTask.matmul(m, n, k))
        return tasks
    return tasks[:args.max_tasks] if args.max_tasks else tasks


def _tasks_from_args(args) -> List[TuningTask]:
    picked = [bool(args.model), bool(args.matmul), bool(args.arch),
              bool(args.network)]
    if sum(picked) != 1:
        raise SystemExit("pick exactly one of --model / --matmul / "
                         "--network / --arch")
    if args.oracle == "compile" and not args.arch:
        raise SystemExit("--oracle compile requires --arch/--shape "
                         "(conv/GEMM tasks are measured analytically)")
    if not args.arch:
        return _network_tasks(args)
    if args.oracle != "compile":
        raise SystemExit("--arch/--shape needs --oracle compile")
    return [TuningTask.cell(args.arch, s) for s in args.shape]


def _add_task_args(ap) -> None:
    ap.add_argument("--model", help="CNN model: tune its conv tasks "
                                    "(e.g. resnet-18)")
    ap.add_argument("--network", choices=network_names(), default=None,
                    help="workload-zoo network (repro.compiler.zoo)")
    ap.add_argument("--max-tasks", type=int, default=0,
                    help="cap the number of network tasks (0 = all)")
    ap.add_argument("--matmul", action="append", default=[],
                    metavar="MxNxK", help="GEMM task (repeatable)")


def _emit(summary, args) -> None:
    """Shared JSON output: full document to --out, compact to stdout."""
    if args.out:
        with open(args.out, "w") as f:
            json.dump(summary, f, indent=1, default=str)
    for rep in summary.get("reports", {}).values():  # keep stdout compact
        rep.pop("measurements", None)
        rep["history"] = rep["history"][-3:]
    print(json.dumps(summary, indent=1, default=str))


def _run_tune(args) -> int:
    if args.arch and not args.shape:
        args.shape = ["train_4k"]
    tasks = _tasks_from_args(args)
    if args.independent and (args.warm_from or args.save_surrogates):
        # reject before store_from_args touches the filesystem
        raise SystemExit("--warm-from/--save-surrogates need the shared "
                         "cost model (drop --independent)")
    store = store_from_args(args)
    label = _network_label(args) or None
    session = Session(tasks, tuner=TunerConfig.fast(), algo=args.algo,
                      budget=args.budget, use_cs=not args.no_cs,
                      share_cost_model=not args.independent,
                      records=args.records, seed=args.seed,
                      workers=args.workers, timeout_s=args.timeout_s,
                      remote=args.remote, trace=args.trace,
                      trace_sample_rate=args.trace_sample_rate,
                      monitor=args.monitor,
                      surrogates=store, network=label)
    summary = session.run().to_dict()
    if args.compact and store is not None:
        stats = store.compact()
        print(f"compacted {store.path}: kept {stats['kept']}, dropped "
              f"{stats['dropped']}", file=sys.stderr)
    _emit(summary, args)
    return 0


def _run_netopt(args) -> int:
    from repro.compiler.netopt import (NetOptConfig, NetworkCoOptimizer,
                                       network_genetic_hw_tune,
                                       network_hw_frozen_tune,
                                       network_random_hw_tune)
    if sum(bool(x) for x in (args.model, args.matmul, args.network)) != 1:
        raise SystemExit("netopt needs exactly one of --model / --matmul "
                         "/ --network")
    tasks = _network_tasks(args)
    cfg = NetOptConfig(seed_candidates=args.seed_candidates,
                       hw_rounds=args.hw_rounds,
                       hw_per_round=args.hw_per_round,
                       layer_budget=args.layer_budget,
                       refine_budget=args.refine_budget,
                       tuner=TunerConfig.fast(), seed=args.seed,
                       k_chips=args.k_chips,
                       stop_on_stable_ranking=args.stop_on_stable_ranking)
    name = _network_label(args)
    store = store_from_args(args)
    kw = dict(records=args.records, workers=args.workers,
              timeout_s=args.timeout_s, remote=args.remote, name=name,
              surrogates=store, trace=args.trace,
              trace_sample_rate=args.trace_sample_rate,
              monitor=args.monitor)
    if args.baseline == "hw-frozen":
        rep = network_hw_frozen_tune(tasks, cfg, **kw)
    elif args.baseline == "random-hw":
        rep = network_random_hw_tune(tasks, cfg, **kw)
    elif args.baseline == "genetic":
        rep = network_genetic_hw_tune(tasks, cfg, **kw)
    else:
        rep = NetworkCoOptimizer(tasks, cfg, **kw).run()
    if args.compact and store is not None:
        stats = store.compact()
        print(f"compacted {store.path}: kept {stats['kept']}, dropped "
              f"{stats['dropped']}", file=sys.stderr)
    print(rep.summary(), file=sys.stderr)
    _emit(rep.to_dict(), args)
    return 0


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if argv and argv[0].startswith("-") and argv[0] not in ("-h", "--help"):
        argv = ["tune"] + argv  # legacy flag-only invocation
    ap = argparse.ArgumentParser(
        prog="python -m repro.compiler.cli",
        description="Unified tuning sessions (tune) and network-scope "
                    "HW/SW co-optimization (netopt).")
    sub = ap.add_subparsers(dest="cmd", required=True)

    tune = sub.add_parser(
        "tune", help="tuning session over conv/GEMM analytical tasks or "
                     "pod-level compile cells")
    _add_task_args(tune)
    tune.add_argument("--arch", help="LM arch for the compile oracle")
    tune.add_argument("--shape", action="append", default=[],
                      help="cell shape(s) for --arch (default train_4k)")
    tune.add_argument("--oracle", choices=("analytical", "compile"),
                      default="analytical")
    tune.add_argument("--algo", choices=ALGOS, default="arco")
    tune.add_argument("--budget", type=int, default=None,
                      help="measurements per task")
    tune.add_argument("--seed", type=int, default=0)
    tune.add_argument("--no-cs", action="store_true",
                      help="ablate Confidence Sampling")
    tune.add_argument("--independent", action="store_true",
                      help="per-task GBT instead of the shared cost model")
    tune.add_argument("--records", default=None,
                      help="JSONL measurement records (persist + warm resume)")
    add_surrogate_args(tune)
    add_worker_args(tune)
    tune.add_argument("--out", default=None, help="write session JSON here")
    tune.set_defaults(run=_run_tune)

    net = sub.add_parser(
        "netopt", help="network co-optimization: one shared accelerator "
                       "config, per-layer software mappings")
    _add_task_args(net)
    net.add_argument("--baseline",
                     choices=("hw-frozen", "random-hw", "genetic"),
                     default=None,
                     help="run a network-level baseline instead of the "
                          "co-optimizer (equal total budget; genetic = "
                          "DiGamma-style GA over the same partition space)")
    net.add_argument("--k-chips", type=int, default=1,
                     help="heterogeneous pipeline stages (1-3): partition "
                          "the network at contiguous cuts, one accelerator "
                          "config per stage (1 = the single shared chip)")
    net.add_argument("--stop-on-stable-ranking", type=int, default=0,
                     help="end the outer search once the hw surrogate's "
                          "top-k candidate ranking is unchanged for this "
                          "many consecutive refits (0 = off)")
    net.add_argument("--seed-candidates", type=int, default=3,
                     help="round-0 hw candidates (incl. the default chip)")
    net.add_argument("--hw-rounds", type=int, default=2,
                     help="CS-guided outer rounds after seeding")
    net.add_argument("--hw-per-round", type=int, default=2,
                     help="hw candidates measured per CS round")
    net.add_argument("--layer-budget", type=int, default=16,
                     help="software measurements per layer per candidate")
    net.add_argument("--refine-budget", type=int, default=32,
                     help="extra winner budget per layer (warm resume)")
    net.add_argument("--seed", type=int, default=0)
    net.add_argument("--records", default=None,
                     help="JSONL records: per-(hw, layer) warm resume")
    add_surrogate_args(net)
    add_worker_args(net)
    net.add_argument("--out", default=None, help="write NetworkReport JSON")
    net.set_defaults(run=_run_netopt)

    args = ap.parse_args(argv)
    validate_worker_args(ap, args)
    return args.run(args)


if __name__ == "__main__":
    sys.exit(main())
