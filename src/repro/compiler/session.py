"""Tuning sessions — one API over both tuning stacks.

A :class:`Session` runs ARCO or any baseline over *one or many*
:class:`~repro.compiler.task.TuningTask`\\ s:

* every measurement routes through one memoizing, record-persisting
  :class:`~repro.compiler.oracle.Oracle`;
* with ``share_cost_model=True`` (default) all tasks feed **one** GBT
  surrogate — cross-task transfer via the cell-descriptor half of the
  feature vector (Algorithm 1's refit step, batched over cells);
* ``records=<path.jsonl>`` persists every measurement and resumes warm:
  re-running the same session replays from cache, a larger budget
  continues the search without re-paying oracle cost;
* ``surrogates=<store.jsonl>`` persists the GBT *training rows* instead
  (:class:`~repro.compiler.surrogate_store.SurrogateStore`): the shared
  cost model warm-starts from other task sets' rows — cross-network
  transfer, where records replay only ever covers the same network;
* ``workers=N`` fans expensive per-settings measurements (the compile
  oracle) across a crash-isolated subprocess pool with ``timeout_s``
  per-measurement timeouts; the interleaved ARCO scheduler then overlaps
  one task's GBT refits and MAPPO updates with another's in-flight
  compiles so all workers stay busy across tasks (analytical tasks are
  batched and cheap — they ignore ``workers``);
* ``remote="host:port[,host:port]"`` fans the same measurements over TCP
  worker daemons (``python -m repro.compiler.executor.worker``) instead
  of local processes — heterogeneous fleets, jobs routed by each
  oracle's ``WorkerSpec`` capabilities; the final ``Executor.stats()``
  snapshot lands in ``SessionReport.executor_stats``;
* the result is a typed :class:`SessionReport` of per-task
  :class:`~repro.compiler.report.TuneReport`\\ s.

Quickstart::

    from repro.compiler import Session, TuningTask
    rep = Session(TuningTask.matmul(512, 512, 512), budget=64).run().single
    reports = Session(TuningTask.conv_tasks("resnet-18")[:3],
                      budget=128, records="artifacts/r18.jsonl").run()
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Dict, Iterable, Optional, Union

from repro import obs
from repro.compiler.records import RecordLog
from repro.compiler.report import TuneReport
from repro.compiler.surrogate_store import (SurrogateStore, attach_sw_gbt,
                                            coerce_store, space_family)
from repro.compiler.task import TuningTask
from repro.core.cost_model import GBTModel
from repro.core.tuner import ArcoLoop, TunerConfig

ALGOS = ("arco", "random", "autotvm", "chameleon")


@dataclasses.dataclass
class SessionReport:
    """Typed result of one session: per-task reports + run metadata."""

    reports: Dict[str, TuneReport]
    wall_time_s: float
    algo: str
    shared_cost_model: bool
    budget_per_task: int
    # cross-task surrogate transfer (repro.compiler.surrogate_store):
    # {"store": path, "warm_sw_rows": int} — empty on sessions run
    # without a store (old documents deserialize with the default)
    surrogates: Dict[str, object] = dataclasses.field(default_factory=dict)
    # final Executor.stats() snapshot (jobs/failures/respawns; remote runs
    # add per-endpoint detail) — empty for in-process sessions and for
    # documents written before the field existed
    executor_stats: Dict[str, object] = dataclasses.field(
        default_factory=dict)

    @property
    def single(self) -> TuneReport:
        """The sole report of a single-task session."""
        if len(self.reports) != 1:
            raise ValueError(f"session tuned {len(self.reports)} tasks; "
                             "use report['name']")
        return next(iter(self.reports.values()))

    def __getitem__(self, name: str) -> TuneReport:
        return self.reports[name]

    def __iter__(self):
        return iter(self.reports.values())

    def total_best_latency(self,
                           multiplicity: Optional[Dict[str, int]] = None
                           ) -> float:
        """Sum of per-task best latencies (optionally layer-weighted)."""
        mult = multiplicity or {}
        return sum(r.best_latency * mult.get(name, 1)
                   for name, r in self.reports.items())

    def network_latency(self) -> float:
        """End-to-end network latency: per-task bests weighted by each
        task's own layer multiplicity (``TuningTask.multiplicity``, carried
        on the reports) — no hand-built multiplicity dict needed."""
        return sum(r.best_latency * r.multiplicity
                   for r in self.reports.values())

    def to_dict(self) -> Dict:
        return {"algo": self.algo, "shared_cost_model": self.shared_cost_model,
                "budget_per_task": self.budget_per_task,
                "wall_time_s": self.wall_time_s,
                "surrogates": dict(self.surrogates),
                "executor_stats": dict(self.executor_stats),
                "reports": {n: r.to_dict() for n, r in self.reports.items()}}

    @staticmethod
    def from_dict(d: Dict) -> "SessionReport":
        return SessionReport(
            reports={n: TuneReport.from_dict(r)
                     for n, r in d["reports"].items()},
            wall_time_s=d["wall_time_s"], algo=d["algo"],
            shared_cost_model=d["shared_cost_model"],
            budget_per_task=d["budget_per_task"],
            surrogates=d.get("surrogates", {}),
            executor_stats=d.get("executor_stats", {}))


class Session:
    """One tuning run over one or many tasks with a shared cost model."""

    def __init__(self, tasks: Union[TuningTask, Iterable[TuningTask]],
                 tuner: Optional[TunerConfig] = None, algo: str = "arco",
                 budget: Optional[int] = None, use_cs: bool = True,
                 share_cost_model: bool = True,
                 records: Union[None, str, RecordLog] = None,
                 seed: Optional[int] = None,
                 workers: int = 0, timeout_s: Optional[float] = None,
                 remote: Union[None, str, list] = None,
                 gbt: Optional[GBTModel] = None,
                 executor=None,
                 surrogates: Union[None, str, SurrogateStore] = None,
                 network: Optional[str] = None,
                 trace: Optional[str] = None,
                 obs=None,
                 monitor=None,
                 trace_sample_rate: float = 1.0):
        if isinstance(tasks, TuningTask):
            tasks = [tasks]
        self.tasks = list(tasks)
        if not self.tasks:
            raise ValueError("Session needs at least one task")
        names = [t.name for t in self.tasks]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate task names: {names}")
        if algo not in ALGOS:
            raise ValueError(f"unknown algo {algo!r}; have {ALGOS}")
        cfg = tuner or TunerConfig()
        if seed is not None:
            cfg = dataclasses.replace(cfg, seed=seed)
        self.cfg = cfg
        self.algo = algo
        self.budget = budget or cfg.iteration_opt * cfg.b_measure
        self.use_cs = use_cs
        self.share_cost_model = share_cost_model
        self.records = (RecordLog(records) if isinstance(records, str)
                        else records)
        if remote and workers:
            raise ValueError("remote= and workers= are mutually exclusive: "
                             "one measurement transport per session")
        if remote and executor is not None:
            raise ValueError("remote= and executor= are mutually exclusive")
        if (timeout_s is not None and not workers and not remote
                and executor is None):
            raise ValueError("timeout_s needs workers >= 1 or remote=: "
                             "in-process measurements cannot be preempted")
        self.workers = workers
        self.timeout_s = timeout_s
        self.remote = remote
        # an externally supplied cost model is shared across this session's
        # tasks AND whoever else holds it (netopt shares one software GBT
        # across every hardware candidate's session)
        self.gbt = gbt
        # surrogate store: warm-start the shared software GBT from other
        # networks' rows and record this session's training rows.  The
        # ``network`` label keys the own-rows exclusion — pass the SAME
        # name a netopt run of these tasks would use (the CLI passes the
        # zoo network name) or the cross-surface exclusion cannot match;
        # the default label is the joined task names.
        self.surrogates = coerce_store(surrogates)
        self.surrogate_network = network or \
            ",".join(t.name for t in self.tasks)[:120]
        if self.surrogates is not None:
            if gbt is not None:
                raise ValueError(
                    "surrogates= with an external gbt= is ambiguous — the "
                    "gbt's owner (e.g. netopt) manages the store itself")
            if not share_cost_model:
                raise ValueError("surrogates= needs share_cost_model=True "
                                 "(transfer targets the shared GBT)")
            families = {space_family(t.space) for t in self.tasks}
            if len(families) > 1:
                # rows are stamped with ONE family; a mixed session would
                # mislabel half of them and poison later warm starts
                raise ValueError("surrogates= needs tasks of one space "
                                 f"family, got {sorted(families)}")
        # tracing: ``obs=`` is an externally owned Tracer (e.g. netopt's,
        # shared so inner sessions land on one timeline); ``trace=`` makes
        # this session build its own and save it there after run().  With
        # neither, run() does NOT touch the ambient tracer — a session
        # inside an active netopt trace inherits it.
        self.trace_path = trace
        self._obs = obs
        self.trace_sample_rate = float(trace_sample_rate)
        # live monitoring (repro.obs.serve): ``monitor=PORT`` starts an
        # owned MonitorServer for this run; ``monitor=MonitorServer`` is
        # borrowed (a shared server hosting several runs) — either way the
        # session attaches a /status source + scrape-time collector and
        # finalizes it (freezing the last snapshot) before teardown.
        # Monitoring never touches session state, so reports stay
        # byte-identical with it on vs off.
        self._monitor_arg = monitor
        self._monitor = None
        self._monitor_owned = False
        self._monitor_source = None
        self._loops = []  # live ArcoLoop list (status snapshots read it)
        self._live_reports: Dict[str, TuneReport] = {}
        self._oracles = []  # created by run(), closed in its finally
        # ONE worker pool shared by all tasks; an external executor= is the
        # caller's pool (outlives the session — never closed here)
        self._executor = executor
        self._own_executor = executor is None

    # ------------------------------------------------------ live monitoring
    def _live_progress(self):
        """Copy-on-read progress numbers for the monitor: per-task state,
        total paid measurements, and the weighted best-so-far network
        latency (defined once every task has a finite best)."""
        mult = {t.name: t.multiplicity for t in self.tasks}
        tasks: Dict[str, Dict[str, object]] = {}
        for loop in list(self._loops):
            tr = loop.track
            best = float(tr.best_lat)
            tasks[tr.task] = {
                "measurements": int(tr.count),
                "best_latency": best if best < float("inf") else None,
            }
        for name, rep in dict(self._live_reports).items():
            tasks[name] = {"measurements": int(rep.n_measurements),
                           "best_latency": float(rep.best_latency),
                           "done": True}
        total = sum(int(t["measurements"]) for t in tasks.values())
        net = None
        if tasks and all(t["best_latency"] is not None
                         for t in tasks.values()):
            net = sum(float(t["best_latency"]) * mult.get(n, 1)
                      for n, t in tasks.items())
        return tasks, total, net

    def _live_status(self) -> Dict[str, object]:
        tasks, total, net = self._live_progress()
        oracle = {"hits": 0, "misses": 0, "failures": 0}
        for o in list(self._oracles):
            st = o.stats()
            for k in oracle:
                oracle[k] += int(st.get(k, 0))
        executor = self._executor
        return {
            "kind": "session", "algo": self.algo,
            "budget_per_task": int(self.budget),
            "n_tasks": len(self.tasks),
            "measurements": total,
            "best_network_latency": net,
            "tasks": tasks,
            "oracle": oracle,
            "executor": executor.stats() if executor is not None else {},
        }

    def _collect_metrics(self, metrics) -> None:
        """Scrape-time collector: map live progress + executor stats onto
        the monitor's own registry (never the ambient tracer's)."""
        tasks, total, net = self._live_progress()
        metrics.counter("session.measurements").value = float(total)
        if net is not None:
            metrics.gauge("session.network_latency").set(net)
        executor = self._executor
        if executor is not None:
            metrics.record_executor_stats(executor.stats())

    def _make_oracle(self, task: TuningTask):
        oracle = task.make_oracle(self.records, workers=self.workers,
                                  timeout_s=self.timeout_s,
                                  executor=self._executor)
        self._oracles.append(oracle)
        return oracle

    # ----------------------------------------------------------------- run
    def run(self) -> SessionReport:
        tracer = self._obs
        if tracer is None and self.trace_path:
            tracer = obs.Tracer(name="session",
                                sample_rate=self.trace_sample_rate)
        # no trace requested -> leave the ambient tracer alone (an outer
        # netopt trace keeps collecting through this session)
        scope = obs.use(tracer) if tracer is not None \
            else contextlib.nullcontext()
        if self._monitor_arg is not None:
            from repro.obs.serve import coerce_monitor
            self._monitor, self._monitor_owned = \
                coerce_monitor(self._monitor_arg)
            self._monitor.start()
            self._monitor_source = self._monitor.attach(
                "session", self._live_status,
                collector=self._collect_metrics, tracer=tracer)
        try:
            with scope:
                with obs.current().span("session", cat="session",
                                        algo=self.algo):
                    return self._run()
        finally:
            if tracer is not None and self.trace_path:
                tracer.save(self.trace_path)
            if self._monitor is not None and self._monitor_owned:
                self._monitor.stop()
                self._monitor = None

    def _run(self) -> SessionReport:
        t0 = time.perf_counter()
        surrogate_stats: Dict[str, object] = {}
        if self.surrogates is not None:
            # the network label plays the exclusion role: rows saved here
            # are excluded when the same network warm-starts later (its
            # own measurements replay through records instead)
            shared_gbt, surrogate_stats = attach_sw_gbt(
                self.surrogates, n_rounds=self.cfg.gbt_rounds,
                seed=self.cfg.seed, network=self.surrogate_network,
                family=space_family(self.tasks[0].space))
        else:
            shared_gbt = self.gbt if self.gbt is not None else (
                GBTModel(n_rounds=self.cfg.gbt_rounds, seed=self.cfg.seed)
                if self.share_cost_model else None)
        if self.workers > 0 and self._executor is None:
            # one pool for the whole session — N workers total, not
            # N per task; jobs carry each oracle's own WorkerSpec.
            # Workers spawn lazily, so this is free for tasks that never
            # submit (e.g. analytical oracles, fully-warm resumes).
            from repro.compiler.executor import SubprocessExecutor
            self._executor = SubprocessExecutor(workers=self.workers,
                                                timeout_s=self.timeout_s)
        elif self.remote and self._executor is None:
            # same sharing story over TCP: one fleet connection serving
            # every task, jobs routed to capability-compatible daemons
            from repro.compiler.executor import RemoteExecutor
            self._executor = RemoteExecutor(self.remote,
                                            timeout_s=self.timeout_s)
        executor_stats: Dict[str, object] = {}
        try:
            if self.algo == "arco":
                reports = self._run_arco(shared_gbt)
            else:
                reports = self._run_baseline(shared_gbt)
        finally:
            # freeze the monitor's last snapshot FIRST, while oracles,
            # trackers, and the executor are all still readable — a
            # post-run scrape then answers with final values
            if self._monitor is not None and self._monitor_source:
                self._monitor.finalize(self._monitor_source)
            for oracle in self._oracles:  # tear down any worker pools
                oracle.close()
            self._oracles = []
            if self._executor is not None and self._own_executor:
                executor_stats = self._executor.stats()
                obs.current().metrics.record_executor_stats(executor_stats)
                self._executor.close()
                self._executor = None
        for t in self.tasks:  # reports carry their task's layer weight
            reports[t.name].multiplicity = t.multiplicity
        return SessionReport(reports=reports,
                             wall_time_s=time.perf_counter() - t0,
                             algo=self.algo,
                             shared_cost_model=self.share_cost_model,
                             budget_per_task=self.budget,
                             surrogates=surrogate_stats,
                             executor_stats=executor_stats)

    def _run_arco(self, shared_gbt: Optional[GBTModel]
                  ) -> Dict[str, TuneReport]:
        """Interleaved ARCO: one iteration per task per round, every task
        refitting the same surrogate when the cost model is shared.

        The loop drives each task through ``step_submit``/``collect``
        halves: with in-process oracles a batch resolves at submit time and
        the schedule reduces to the classic one-iteration-per-task round
        robin, while executor-backed oracles leave batches in flight — the
        scheduler then runs other tasks' MAPPO/GBT work (keeping every
        worker busy across tasks) and only blocks when *all* remaining
        tasks are waiting on measurements.
        """
        loops = [
            ArcoLoop(t.space, self.cfg,
                     oracle=self._make_oracle(t),
                     gbt=shared_gbt if shared_gbt is not None else GBTModel(
                         n_rounds=self.cfg.gbt_rounds, seed=self.cfg.seed),
                     use_cs=self.use_cs, task=t.name)
            for t in self.tasks]
        self._loops = loops  # live-status snapshots read the trackers
        # Seed all tasks first, collecting (and refitting) in task order —
        # identical refit order to the sequential path, but the seed
        # batches of all tasks share the worker pool.
        for loop in loops:
            loop.seed_submit(self.budget)
        for loop in loops:
            loop.collect(block=True)
        active = list(loops)
        while active:
            progressed = False
            for loop in list(active):
                if loop.has_pending:
                    if not loop.collect(block=False):
                        continue  # still compiling; run the other tasks
                    progressed = True
                if loop.exhausted or loop.track.count >= self.budget:
                    active.remove(loop)
                    progressed = True
                    continue
                if loop.step_submit(self.budget):
                    progressed = True
                    if loop.pending_ready():
                        # in-process oracle: finish the iteration now, so
                        # the schedule matches the synchronous loop exactly
                        loop.collect(block=True)
                else:
                    active.remove(loop)
                    progressed = True
            if not progressed and active:
                # every remaining task is waiting on the oracle — block on
                # the first one instead of spinning
                next(l for l in active if l.has_pending).collect(block=True)
        return {t.name: loop.report()
                for t, loop in zip(self.tasks, loops)}

    def _run_baseline(self, shared_gbt: Optional[GBTModel]
                      ) -> Dict[str, TuneReport]:
        """Baselines run sequentially per task; GBT-based ones still share
        the surrogate across tasks when the cost model is shared.  (Their
        ``oracle.measure`` calls still fan each *batch* across the worker
        pool when the oracle is executor-backed.)"""
        from repro.core import baselines as B
        self._live_reports.clear()
        reports = self._live_reports  # filled per task; /status reads it
        for t in self.tasks:
            oracle = self._make_oracle(t)
            kw = dict(cfg=self.cfg, budget=self.budget, oracle=oracle,
                      task=t.name)
            if self.algo == "random":
                reports[t.name] = B.random_tune(t.space, **kw)
            elif self.algo == "autotvm":
                reports[t.name] = B.autotvm_tune(t.space, gbt=shared_gbt,
                                                 **kw)
            else:
                reports[t.name] = B.chameleon_tune(t.space, gbt=shared_gbt,
                                                   **kw)
        return reports
