"""Typed, JSON-serializable tuning results + shared loop bookkeeping.

``TuneReport`` replaces the old ``TuneResult``-vs-ad-hoc-dict split: every
tuner (ARCO and all baselines), the session API, ``launch.autotune`` and the
benchmark sweep all emit the same record, and ``to_dict``/``from_dict``
round-trip it through JSON without hand re-packing.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core.design_space import DesignSpace
from repro.hw import analytical


@dataclasses.dataclass
class TuneReport:
    """Result of tuning one task (ARCO or any baseline)."""

    task: str
    best_config: List[int]              # per-knob choice indices
    best_latency: float
    n_measurements: int
    wall_time_s: float
    # rows: (measurement_count, best_latency_so_far, wall_time)
    history: List[Tuple[int, float, float]]
    # every measurement in order: (measurement_index, latency)
    measurements: List[Tuple[int, float]]
    best_settings: Optional[Dict[str, object]] = None  # decoded knob values
    oracle_stats: Dict[str, int] = dataclasses.field(default_factory=dict)
    # layers sharing this workload (from TuningTask.multiplicity) — what
    # SessionReport.network_latency() weights per-task bests by
    multiplicity: int = 1

    def best_gflops(self, space: DesignSpace) -> float:
        if space.kind == "conv2d":
            return analytical.conv2d_gflops(space.workload, self.best_latency)
        m, n, k = (space.workload[d] for d in "mnk")
        return 2.0 * m * n * k / self.best_latency / 1e9

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["best_config"] = [int(x) for x in self.best_config]
        d["history"] = [list(r) for r in self.history]
        d["measurements"] = [list(r) for r in self.measurements]
        return d

    @staticmethod
    def from_dict(d: Dict) -> "TuneReport":
        fields = {f.name for f in dataclasses.fields(TuneReport)}
        kw = {k: v for k, v in d.items() if k in fields}
        kw["history"] = [tuple(r) for r in kw.get("history", [])]
        kw["measurements"] = [tuple(r) for r in kw.get("measurements", [])]
        return TuneReport(**kw)


class Tracker:
    """Shared per-task loop bookkeeping for every tuner (ARCO + baselines):
    budget counting, best-so-far, convergence history, and the session-level
    already-proposed set (``seen``).  Value memoization lives in the Oracle —
    this only dedups *proposals* within one tuning run."""

    def __init__(self, task: str = ""):
        self.task = task
        self.t0 = time.perf_counter()
        self.best_lat = np.inf
        self.best_cfg: Optional[np.ndarray] = None
        self.count = 0
        self.history: List[Tuple[int, float, float]] = []
        self.measurements: List[Tuple[int, float]] = []
        self.seen: Set[Tuple[int, ...]] = set()
        # Interleaved multi-task sessions account per-task *active* time via
        # add_active(); None = sequential wall-clock mode (since t0).
        self.active_s: Optional[float] = None

    def is_new(self, config) -> bool:
        return tuple(int(x) for x in config) not in self.seen

    def add_active(self, dt: float) -> None:
        self.active_s = (self.active_s or 0.0) + dt

    def _elapsed(self) -> float:
        if self.active_s is not None:
            return self.active_s
        return time.perf_counter() - self.t0

    def record(self, configs: np.ndarray, lats: np.ndarray) -> None:
        for cfg, lat in zip(configs, lats):
            self.count += 1
            self.seen.add(tuple(int(x) for x in cfg))
            self.measurements.append((self.count, float(lat)))
            if lat < self.best_lat:
                self.best_lat = float(lat)
                self.best_cfg = np.asarray(cfg)
        self.history.append((self.count, self.best_lat, self._elapsed()))

    def report(self, oracle=None,
               best_settings: Optional[Dict[str, object]] = None
               ) -> TuneReport:
        stats = oracle.stats() if oracle is not None else {}
        best = ([] if self.best_cfg is None
                else [int(x) for x in self.best_cfg])
        return TuneReport(
            task=self.task, best_config=best, best_latency=self.best_lat,
            n_measurements=self.count, wall_time_s=self._elapsed(),
            history=list(self.history), measurements=list(self.measurements),
            best_settings=best_settings, oracle_stats=stats)
