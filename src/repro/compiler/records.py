"""JSONL measurement records — the persistence layer of a tuning session.

One row per *new* oracle measurement:

    {"task": "...", "config": [idx, ...], "latency": 1.2e-4,
     "features": [...18 floats...], ...extras...}

Extras carry decoded ``settings`` (shard-space oracles), compact compile
``result`` summaries, or an ``error`` string for failed measurements.  A
session pointed at an existing record file resumes *warm*: every oracle
primes its memo cache from the rows matching its task, so re-running the
same session replays from cache instead of re-paying oracle cost, and a
larger budget continues the search where the file left off.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional


class RecordLog:
    """Append-only JSONL file of oracle measurements (shared across tasks)."""

    def __init__(self, path: str):
        self.path = path

    def exists(self) -> bool:
        return os.path.exists(self.path)

    def load(self, task: Optional[str] = None) -> List[Dict]:
        """All persisted rows (optionally filtered to one task)."""
        if not self.exists():
            return []
        rows: List[Dict] = []
        with open(self.path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                row = json.loads(line)
                if task is None or row.get("task") == task:
                    rows.append(row)
        return rows

    def append(self, row: Dict) -> None:
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(self.path, "a") as f:
            f.write(json.dumps(row) + "\n")
