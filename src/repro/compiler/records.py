"""JSONL measurement records — the persistence layer of a tuning session.

One row per *new* oracle measurement:

    {"task": "...", "config": [idx, ...], "latency": 1.2e-4,
     "features": [...18 floats...], ...extras...}

Extras carry decoded ``settings`` (shard-space oracles), compact compile
``result`` summaries, or an ``error`` string for failed measurements.  A
session pointed at an existing record file resumes *warm*: every oracle
primes its memo cache from the rows matching its task, so re-running the
same session replays from cache instead of re-paying oracle cost, and a
larger budget continues the search where the file left off.

Durability contract (what parallel measurement leans on): every append is
one ``os.write`` of a whole ``json.dumps(row) + "\n"`` line to an
``O_APPEND`` descriptor — atomic on POSIX, so rows from a run killed
mid-write can corrupt at most the trailing line, and ``load()`` drops a
corrupt *trailing* line so a killed run always warm-resumes.  Corruption
anywhere else is a real error and still raises.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

from repro.obs import log


class RecordLog:
    """Append-only JSONL file of oracle measurements (shared across tasks)."""

    def __init__(self, path: str):
        self.path = path
        self._tail_checked = False  # torn-tail repair runs once per instance

    def exists(self) -> bool:
        return os.path.exists(self.path)

    def load(self, task: Optional[str] = None) -> List[Dict]:
        """All persisted rows (optionally filtered to one task).

        A corrupt trailing line — the signature of a run killed mid-append
        — is dropped with a warning instead of failing the resume; corrupt
        rows anywhere else raise.
        """
        if not self.exists():
            return []
        with open(self.path) as f:
            lines = [ln.strip() for ln in f.read().splitlines()]
        idx_nonempty = [i for i, ln in enumerate(lines) if ln]
        rows: List[Dict] = []
        for i in idx_nonempty:
            try:
                row = json.loads(lines[i])
            except ValueError:
                if i == idx_nonempty[-1]:
                    log.warn(f"RecordLog: dropping corrupt trailing line "
                             f"{i + 1} of {self.path} (killed mid-append?)")
                    break
                raise ValueError(
                    f"{self.path}:{i + 1}: corrupt record mid-file") from None
            if task is None or row.get("task") == task:
                rows.append(row)
        return rows

    def append(self, row: Dict) -> None:
        """Atomic line append: a single ``os.write`` of the whole line to an
        ``O_APPEND`` fd, so concurrent appenders and kills never interleave
        or tear a row (beyond the trailing line ``load`` tolerates).  A
        torn tail left by a killed run is truncated first — otherwise the
        new row would merge into it and turn recoverable trailing
        corruption into a mid-file error on the next resume."""
        self.append_many([row])

    def append_many(self, rows: List[Dict]) -> None:
        """Append a batch of rows with ONE ``os.write`` of all the lines —
        same whole-line atomicity contract as :meth:`append`, without
        paying an open/write/close round-trip per row (the surrogate
        store appends every GBT refit batch through this)."""
        if not rows:
            return
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        if not self._tail_checked:
            # only a *previous* run's kill can leave a torn tail — our own
            # appends are whole-line writes — so one check per instance
            self._truncate_torn_tail()
            self._tail_checked = True
        data = "".join(json.dumps(row) + "\n" for row in rows).encode()
        fd = os.open(self.path, os.O_WRONLY | os.O_APPEND | os.O_CREAT, 0o644)
        try:
            os.write(fd, data)
        finally:
            os.close(fd)

    def rewrite(self, rows: List[Dict]) -> None:
        """Atomically replace the whole file with ``rows`` (tmp file in
        the same directory + ``os.replace``, so a reader or a kill never
        sees a partial state) — the seam store compaction rewrites
        through.  The append-only contract still holds for *measurement*
        records; rewrite exists for derived stores that prune."""
        import tempfile
        d = os.path.dirname(self.path) or "."
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(prefix=".rewrite-", suffix=".jsonl",
                                   dir=d)
        try:
            os.write(fd, "".join(json.dumps(row) + "\n"
                                 for row in rows).encode())
            os.fsync(fd)
        finally:
            os.close(fd)
        os.replace(tmp, self.path)
        self._tail_checked = True

    def _truncate_torn_tail(self) -> None:
        """Drop a trailing partial line (no terminating newline) — the same
        row ``load()`` already ignores, removed for good before we append
        behind it.  O(1) when the file is healthy (checks the last byte)."""
        try:
            size = os.path.getsize(self.path)
        except OSError:
            return
        if size == 0:
            return
        with open(self.path, "rb+") as f:
            f.seek(-1, os.SEEK_END)
            if f.read(1) == b"\n":
                return
            f.seek(0)
            data = f.read()
            f.truncate(data.rfind(b"\n") + 1)
