"""DiGamma-style genetic search over the joint (partition, hw-tuple) space.

DiGamma (PAPERS.md) optimizes accelerator configs with a genetic
algorithm; this module is that baseline for the netopt comparison,
running over the SAME candidate space as the co-optimizer
(:class:`~repro.compiler.netopt.partition.HwPartition`: contiguous
pipeline cuts + per-stage hw value-tuples) and the SAME pinned-session
evaluator, at the SAME total measurement budget — so the only difference
left is the search strategy (GBT + Confidence Sampling + refinement vs
tournament selection + crossover + mutation).  Keeping the MARL claim
honest requires exactly this control.

Budget protocol mirrors the random baseline: the co-optimizer's
``total_layer_budget()`` upper bound split evenly over the same number
of candidate evaluations netopt gets (``n_candidates + 1``, counting its
refinement pass).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Union

import numpy as np

from repro.compiler.netopt.loop import NetOptConfig, _Evaluator
from repro.compiler.netopt.partition import HwPartition, PartitionSpace
from repro.compiler.netopt.report import NetworkReport
from repro.compiler.records import RecordLog
from repro.compiler.surrogate_store import SurrogateStore
from repro.compiler.task import TuningTask


def mutate(ps: PartitionSpace, p: HwPartition,
           rng: np.random.Generator) -> HwPartition:
    """One random gene step: either one segment's knob value moves one
    step in that segment's value table, or one cut shifts by +-1 task
    (staying strictly between its neighbors — contiguity is preserved by
    construction)."""
    n = len(ps.tasks)
    segs = p.segments(n)
    nk = ps.base.n_knobs
    value_genes = p.k * nk
    g = int(rng.integers(0, value_genes + len(p.cuts)))
    step = 1 if int(rng.integers(0, 2)) else -1
    if g < value_genes:
        j, knob = divmod(g, nk)
        ss = ps.segment_space(*segs[j])
        idx = list(ss.index_config(p.hw_values[j]))
        idx[knob] = int(np.clip(idx[knob] + step, 0,
                                len(ss.choices[knob]) - 1))
        vals = list(p.hw_values)
        vals[j] = ss.values(idx)
        return HwPartition(p.cuts, tuple(vals))
    j = g - value_genes
    cuts = list(p.cuts)
    lo = cuts[j - 1] + 1 if j > 0 else 1
    hi = cuts[j + 1] - 1 if j + 1 < len(cuts) else n - 1
    cuts[j] = int(np.clip(cuts[j] + step, lo, hi))
    # segment boundaries moved: re-clamp values onto the new segments
    return ps.canonical(tuple(cuts), p.hw_values)


def crossover(ps: PartitionSpace, a: HwPartition, b: HwPartition,
              rng: np.random.Generator) -> HwPartition:
    """Uniform crossover: cuts from one parent, each stage's values from
    either (clamped onto the child's segment tables)."""
    cuts = a.cuts if int(rng.integers(0, 2)) else b.cuts
    vals = [(a if int(rng.integers(0, 2)) else b).hw_values[j]
            for j in range(len(cuts) + 1)]
    return ps.canonical(cuts, vals)


def network_genetic_hw_tune(tasks: Iterable[TuningTask],
                            cfg: Optional[NetOptConfig] = None,
                            k_chips: Optional[int] = None,
                            population: int = 6,
                            records: Union[None, str, RecordLog] = None,
                            workers: int = 0,
                            timeout_s: Optional[float] = None,
                            name: str = "network",
                            surrogates: Union[None, str,
                                              SurrogateStore] = None,
                            remote=None,
                            trace: Optional[str] = None,
                            obs=None,
                            monitor=None,
                            trace_sample_rate: float = 1.0
                            ) -> NetworkReport:
    """DiGamma-style GA over (cuts, per-stage hw values) at netopt's
    budget: seed a population, then tournament-select two parents,
    crossover, mutate, evaluate — until the evaluation budget is spent.
    ``k_chips`` overrides ``cfg.k_chips`` (the GA is the K>=2 comparison
    point, but runs at K=1 too)."""
    cfg = cfg or NetOptConfig()
    if k_chips is not None:
        cfg = dataclasses.replace(cfg, k_chips=int(k_chips))
    ev = _Evaluator(tasks, cfg, records, workers, timeout_s, name,
                    "genetic", surrogates=surrogates, remote=remote,
                    trace=trace, obs=obs, monitor=monitor,
                    trace_sample_rate=trace_sample_rate)
    ps = ev.pspace
    rng = np.random.default_rng(cfg.seed)
    n_evals = cfg.n_candidates + 1     # netopt's candidate count + refine
    per_layer = max(cfg.total_layer_budget() // n_evals, 1)
    try:
        with ev.obs_scope():
            ev.open()
            fit: Dict[HwPartition, float] = {}
            for p in ps.seed_partitions(min(population, n_evals), rng):
                if p not in fit and len(fit) < n_evals:
                    fit[p] = ev.evaluate(p, per_layer, "genetic")
            attempts = 0
            while len(fit) < n_evals and attempts < 64:
                attempts += 1
                pool: List[HwPartition] = list(fit)

                def pick() -> HwPartition:  # size-2 tournament
                    i, j = rng.integers(0, len(pool), size=2)
                    a, b = pool[int(i)], pool[int(j)]
                    return a if fit[a] <= fit[b] else b

                child = mutate(ps, crossover(ps, pick(), pick(), rng), rng)
                for _ in range(8):
                    if child not in fit:
                        break
                    child = mutate(ps, child, rng)
                if child in fit:
                    child = ps.random_partition(rng)  # diversity fallback
                if child in fit:
                    continue
                fit[child] = ev.evaluate(child, per_layer, "genetic")
            return ev.report()
    finally:
        ev.close()
