"""Heterogeneous K-accelerator partitions — netopt v2's candidate space.

The v1 outer search proposed ONE hardware value-tuple for the whole
network.  A :class:`HwPartition` generalizes that to the MATCHA/DiGamma
setting: the ordered task list is split at ``k - 1`` contiguous cut
points into pipeline stages, and each stage gets its own accelerator
config from that stage's own :class:`~repro.compiler.netopt.hwspace.
HwCandidateSpace` (value unions over the stage's layers only).
Contiguity is the default enumeration constraint — a stage must be a
pipeline-realizable prefix-to-suffix slab, not an arbitrary subset.

``k = 1`` is the regression anchor: a single-segment partition delegates
every operation (features, seeding, enumeration, tags) to the v1
single-chip space, so the partition-generic loop reproduces the
pre-refactor behavior bit-for-bit.

The reward is pipeline-aware end-to-end latency: the slowest stage's
multiplicity-weighted layer sum, plus the inter-stage transfer of each
boundary activation over ICI (:func:`repro.hw.analytical.
interchip_transfer_s`).  For ``k = 1`` this reduces exactly to v1's
weighted sum.  The area axis of the multi-objective Pareto is the sum of
per-chip :func:`~repro.hw.analytical.chip_area_mm2` proxies.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Iterable, List, Sequence, Tuple

import numpy as np

from repro.compiler.netopt.hwspace import (HwCandidateSpace, N_HW_FEAT,
                                           hw_dict, hw_tag)
from repro.compiler.task import TuningTask
from repro.hw import analytical
from repro.hw.tpu_spec import DEFAULT, TpuSpec

MAX_K = 3  # K in {1, 2, 3}: beyond 3 stages the toy pipelines fragment


@dataclasses.dataclass(frozen=True)
class HwPartition:
    """One candidate: contiguous cut points + one hw value-tuple per
    segment.  ``cuts`` are the ``k - 1`` interior task indices where a
    new stage starts (ascending, in ``[1, n_tasks - 1]``); ``hw_values``
    has one entry per stage."""

    cuts: Tuple[int, ...]
    hw_values: Tuple[Tuple[int, ...], ...]

    def __post_init__(self):
        if len(self.hw_values) != len(self.cuts) + 1:
            raise ValueError(f"{len(self.cuts)} cuts need "
                             f"{len(self.cuts) + 1} hw tuples, got "
                             f"{len(self.hw_values)}")

    @property
    def k(self) -> int:
        return len(self.hw_values)

    def segments(self, n_tasks: int) -> List[Tuple[int, int]]:
        """Per-stage ``[start, end)`` task ranges."""
        bounds = (0,) + self.cuts + (n_tasks,)
        return [(bounds[i], bounds[i + 1]) for i in range(self.k)]

    def tags(self) -> Tuple[str, ...]:
        """Per-segment record tags.  K=1 keeps the v1 ``hw[...]`` tag
        (same task names, same record keys — warm resume across the
        refactor); K>=2 appends the segment: ``hw[...]#seg0``."""
        if self.k == 1:
            return (hw_tag(self.hw_values[0]),)
        return tuple(f"{hw_tag(v)}#seg{j}"
                     for j, v in enumerate(self.hw_values))

    def to_dict(self) -> Dict[str, object]:
        return {"k": self.k, "cuts": list(self.cuts),
                "hw": [hw_dict(v) for v in self.hw_values]}


class PartitionSpace:
    """The joint (cuts x per-segment hw values) candidate space over one
    ordered task list.  Composes one :class:`HwCandidateSpace` per
    contiguous segment (cached — segments recur across cut positions) on
    top of the shared ``base`` space (the v1 all-tasks union, which also
    bounds every segment's tables).

    Features: ``k = 1`` -> the v1 14-dim layout unchanged; ``k >= 2`` ->
    per-segment 14-dim blocks (log2 values ++ segment-local aggregate
    descriptor) ++ ``k`` segment multiplicity weights, ``k * 15`` dims
    total — which is also what keys the surrogate-store variant (rows of
    different ``dim`` never mix).
    """

    def __init__(self, tasks: Iterable[TuningTask], k_chips: int = 1,
                 spec: TpuSpec = DEFAULT):
        self.tasks = list(tasks)
        if not self.tasks:
            raise ValueError("PartitionSpace needs at least one task")
        self.k = max(1, min(int(k_chips), len(self.tasks), MAX_K))
        self.spec = spec
        self.base = HwCandidateSpace.from_tasks(self.tasks)
        self._segspaces: Dict[Tuple[int, int], HwCandidateSpace] = {}
        self._cuts: List[Tuple[int, ...]] = list(
            itertools.combinations(range(1, len(self.tasks)), self.k - 1))

    # ------------------------------------------------------------ geometry
    @property
    def n_features(self) -> int:
        return N_HW_FEAT if self.k == 1 else self.k * (N_HW_FEAT + 1)

    def all_cuts(self) -> List[Tuple[int, ...]]:
        return list(self._cuts)

    def segment_space(self, start: int, end: int) -> HwCandidateSpace:
        key = (int(start), int(end))
        if key not in self._segspaces:
            self._segspaces[key] = HwCandidateSpace.from_tasks(
                self.tasks[key[0]:key[1]])
        return self._segspaces[key]

    def canonical(self, cuts: Sequence[int],
                  values: Sequence[Sequence[int]]) -> HwPartition:
        """Clamp arbitrary per-segment values to each segment's own value
        tables (log2-nearest, like ``DesignSpace.pin``) so equal
        partitions compare equal."""
        cuts = tuple(int(c) for c in cuts)
        p = HwPartition(cuts, tuple(tuple(int(x) for x in v)
                                    for v in values))
        out = []
        for (a, b), v in zip(p.segments(len(self.tasks)), p.hw_values):
            ss = self.segment_space(a, b)
            out.append(ss.values(ss.index_config(v)))
        return HwPartition(cuts, tuple(out))

    # ------------------------------------------------------------ features
    def features(self, p: HwPartition) -> np.ndarray:
        """Dispatches on the *partition's* k (an evaluator built at
        ``k_chips=2`` still scores the single-chip baselines' K=1
        candidates in the v1 14-dim layout)."""
        if p.k == 1:
            return self.base.features(p.hw_values[0])
        total = float(sum(t.multiplicity for t in self.tasks))
        blocks, weights = [], []
        for (a, b), v in zip(p.segments(len(self.tasks)), p.hw_values):
            blocks.append(self.segment_space(a, b).features(v))
            weights.append(
                sum(t.multiplicity for t in self.tasks[a:b]) / total)
        return np.concatenate(
            blocks + [np.asarray(weights, np.float32)]).astype(np.float32)

    # ------------------------------------------------------------- seeding
    def balanced_cuts(self) -> Tuple[int, ...]:
        """Cuts that split the multiplicity-weighted layer count as
        evenly as k contiguous stages allow — the partition analog of the
        default chip."""
        n = len(self.tasks)
        if self.k == 1:
            return ()
        cum = np.cumsum([t.multiplicity for t in self.tasks]).astype(float)
        total = cum[-1]
        cuts, prev = [], 0
        for j in range(1, self.k):
            c = int(np.argmin(np.abs(cum[:-1] - total * j / self.k))) + 1
            c = min(max(c, prev + 1), n - (self.k - j))
            cuts.append(c)
            prev = c
        return tuple(cuts)

    def default_partition(self) -> HwPartition:
        cuts = self.balanced_cuts()
        p = HwPartition(cuts, tuple((0,) * self.base.n_knobs
                                    for _ in range(self.k)))
        vals = [self.segment_space(a, b).default_values(self.tasks[a:b])
                for a, b in p.segments(len(self.tasks))]
        return HwPartition(cuts, tuple(vals))

    def random_partition(self, rng: np.random.Generator) -> HwPartition:
        cuts = self._cuts[int(rng.integers(0, len(self._cuts)))]
        p = HwPartition(cuts, tuple((0,) * self.base.n_knobs
                                    for _ in range(self.k)))
        vals = []
        for a, b in p.segments(len(self.tasks)):
            ss = self.segment_space(a, b)
            vals.append(ss.values([int(rng.integers(0, len(c)))
                                   for c in ss.choices]))
        return HwPartition(cuts, tuple(vals))

    def seed_partitions(self, n: int,
                        rng: np.random.Generator) -> List[HwPartition]:
        """K=1: exactly the v1 seeds (same rng call sequence — the
        bit-for-bit anchor).  K>=2: balanced-cut default, the largest
        geometry on every stage (VMEM frontier probe), then random."""
        if self.k == 1:
            return [HwPartition((), (v,)) for v in
                    self.base.seed_values(n, self.tasks, rng)]
        out = [self.default_partition()]
        largest = self.canonical(
            self.balanced_cuts(),
            [tuple(int(c[-1]) for c in self.base.choices)] * self.k)
        if largest not in out:
            out.append(largest)
        attempts = 0
        while len(out) < n and attempts < 64:
            cand = self.random_partition(rng)
            if cand not in out:
                out.append(cand)
            attempts += 1
        return out[:max(n, 1)]

    # --------------------------------------- CS encoding (sampled pool)
    @property
    def n_choices(self) -> np.ndarray:
        """Per-slot choice counts of the encoded layout:
        ``[cut_id] ++ k * base-space knob indices``."""
        return np.asarray(
            [len(self._cuts)]
            + [len(c) for c in self.base.choices] * self.k, np.int32)

    def encode(self, p: HwPartition) -> np.ndarray:
        vec = [self._cuts.index(p.cuts)]
        for v in p.hw_values:
            vec.extend(int(i) for i in self.base.index_config(v))
        return np.asarray(vec, np.int64)

    def decode(self, vec: Sequence[int]) -> HwPartition:
        """Inverse of :meth:`encode`, total over out-of-range inputs
        (Confidence Sampling's mode synthesis can produce any index
        combination): clamp the cut id, clamp each knob index to the base
        table, then canonicalize onto the segment tables."""
        vec = np.asarray(vec, np.int64)
        cuts = self._cuts[int(np.clip(vec[0], 0, len(self._cuts) - 1))]
        nk = self.base.n_knobs
        vals = []
        for j in range(self.k):
            idx = vec[1 + j * nk: 1 + (j + 1) * nk]
            idx = [int(np.clip(i, 0, len(c) - 1))
                   for i, c in zip(idx, self.base.choices)]
            vals.append(self.base.values(idx))
        return self.canonical(cuts, vals)

    def candidate_pool(self, seed: int, limit: int = 256
                       ) -> List[HwPartition]:
        """Deterministic sampled enumeration for the outer search (the
        full ``cuts x values^k`` product is too large to score): every
        cut position with per-segment defaults (the cut axis is covered
        exactly), topped up with seeded random draws."""
        rng = np.random.default_rng(seed)
        pool: List[HwPartition] = []
        seen = set()
        for cuts in self._cuts:
            p = HwPartition(cuts, tuple((0,) * self.base.n_knobs
                                        for _ in range(self.k)))
            vals = [self.segment_space(a, b).default_values(self.tasks[a:b])
                    for a, b in p.segments(len(self.tasks))]
            p = HwPartition(cuts, tuple(vals))
            if p not in seen:
                seen.add(p)
                pool.append(p)
        attempts = 0
        while len(pool) < limit and attempts < 4 * limit:
            p = self.random_partition(rng)
            if p not in seen:
                seen.add(p)
                pool.append(p)
            attempts += 1
        return pool

    # ------------------------------------------------------------- reward
    def boundary_bytes(self, p: HwPartition) -> List[float]:
        """Activation bytes crossing each of the ``k - 1`` stage
        boundaries (the output of the last task before each cut)."""
        out = []
        for _, b in p.segments(len(self.tasks))[:-1]:
            t = self.tasks[b - 1]
            out.append(analytical.activation_out_bytes(
                getattr(t.space, "kind", ""),
                getattr(t.space, "workload", {})))
        return out

    def pipeline_latency(self, p: HwPartition,
                         task_latency: Dict[str, float]) -> float:
        """End-to-end latency of the partitioned network: slowest stage's
        multiplicity-weighted sum + ICI transfer per boundary.  K=1
        degenerates to the v1 weighted sum (same tasks, same order, same
        float additions)."""
        segs = p.segments(len(self.tasks))
        stage = [sum(task_latency[t.name] * t.multiplicity
                     for t in self.tasks[a:b]) for a, b in segs]
        if p.k == 1:
            return float(stage[0])
        transfer = sum(analytical.interchip_transfer_s(bb, self.spec)
                       for bb in self.boundary_bytes(p))
        return float(max(stage) + transfer)

    def area_mm2(self, p: HwPartition) -> float:
        """Total silicon of the partition's chip set (the second Pareto
        objective)."""
        return float(sum(analytical.chip_area_mm2(*v) for v in p.hw_values))
