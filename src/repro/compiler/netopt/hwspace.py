"""The network-wide hardware candidate space.

One accelerator serves every layer, so a hardware candidate is a vector of
knob *values* (``tile_b``, ``tile_ci``, ``tile_co`` — the GEMM-core
geometry the paper's hardware agent owns), not per-layer choice indices:
choice tables differ per layer (powers of two bounded by each workload)
but the chip is one.  The global value lists are the union of every
layer's hardware choice tables; pinning a candidate onto a layer clamps
each value to that layer's nearest feasible choice
(``DesignSpace.pin``) — a small layer simply underutilizes the shared
dimension.

Candidates are scored by a network-scope GBT over
``[log2 hw values ++ aggregate workload features]`` where the aggregate
is the multiplicity-weighted mean of the per-layer cell descriptors —
constant within one network, but what lets a hardware surrogate transfer
across networks sharing one record store.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Sequence, Tuple

import numpy as np

from repro.compiler.task import TuningTask
from repro.core.design_space import AGENT_KNOBS, KNOB_NAMES

HW_KNOBS: Tuple[int, ...] = AGENT_KNOBS["hardware"]
HW_KNOB_NAMES: Tuple[str, ...] = tuple(KNOB_NAMES[k] for k in HW_KNOBS)
N_HW_FEAT = len(HW_KNOBS) + 11  # log2 values ++ aggregate cell descriptor


def hw_tag(values: Sequence[int]) -> str:
    """Stable per-candidate tag embedded in task names (and therefore in
    record rows): ``hw[b1,ci64,co128]`` — what keys per-(hw, layer) warm
    resume."""
    return "hw[" + ",".join(f"{n.split('_')[1]}{int(v)}"
                            for n, v in zip(HW_KNOB_NAMES, values)) + "]"


def hw_dict(values: Sequence[int]) -> Dict[str, int]:
    return {n: int(v) for n, v in zip(HW_KNOB_NAMES, values)}


@dataclasses.dataclass(frozen=True)
class HwCandidateSpace:
    """Global hardware-knob value lists + the aggregate network descriptor."""

    choices: Tuple[Tuple[int, ...], ...]   # per-hw-knob sorted value union
    agg_wfeat: Tuple[float, ...]           # multiplicity-weighted mean (11,)

    @staticmethod
    def from_tasks(tasks: Iterable[TuningTask]) -> "HwCandidateSpace":
        tasks = list(tasks)
        if not tasks:
            raise ValueError("HwCandidateSpace needs at least one task")
        unions: List[set] = [set() for _ in HW_KNOBS]
        for t in tasks:
            for j, k in enumerate(HW_KNOBS):
                unions[j].update(int(v) for v in t.space.choices[k])
        wsum = sum(t.multiplicity for t in tasks)
        agg = sum(t.multiplicity * np.asarray(t.descriptor(), np.float64)
                  for t in tasks) / wsum
        return HwCandidateSpace(
            choices=tuple(tuple(sorted(u)) for u in unions),
            agg_wfeat=tuple(float(x) for x in agg))

    # ------------------------------------------------------------ geometry
    @property
    def n_knobs(self) -> int:
        return len(self.choices)

    @property
    def n_choices(self) -> np.ndarray:
        return np.asarray([len(c) for c in self.choices], np.int32)

    @property
    def size(self) -> int:
        return int(np.prod([len(c) for c in self.choices]))

    def values(self, idx_config: Sequence[int]) -> Tuple[int, ...]:
        return tuple(int(self.choices[j][int(i)])
                     for j, i in enumerate(idx_config))

    def index_config(self, values: Sequence[int]) -> np.ndarray:
        """Values -> choice indices (nearest in log2, like pinning)."""
        out = np.zeros(self.n_knobs, np.int64)
        for j, v in enumerate(values):
            tab = np.log2(np.maximum(np.asarray(self.choices[j], float), 1e-9))
            out[j] = int(np.argmin(np.abs(tab - np.log2(max(float(v), 1e-9)))))
        return out

    def all_index_configs(self) -> np.ndarray:
        """(size, n_knobs) full enumeration — hardware spaces are small
        (tens to a few hundred candidates), so the outer search scores
        every candidate instead of sampling."""
        grids = np.meshgrid(*[np.arange(len(c)) for c in self.choices],
                            indexing="ij")
        return np.stack([g.reshape(-1) for g in grids], axis=1)

    # ------------------------------------------------------------ features
    def features(self, values: Sequence[int]) -> np.ndarray:
        """Network-scope GBT features: log2 hw values ++ aggregate workload
        descriptor (same normalization as ``DesignSpace.feature_vector``)."""
        v = np.log2(np.maximum(np.asarray(values, np.float64), 1.0)) / 16.0
        return np.concatenate([v, np.asarray(self.agg_wfeat)]).astype(
            np.float32)

    # ----------------------------------------------------------- seeding
    def default_values(self, tasks: Iterable[TuningTask]) -> Tuple[int, ...]:
        """Network-wide default geometry (the shared-chip analog of
        ``baselines.default_hardware_config``): MXU-native targets — batch
        tile 1, K-tile ~256 input elements under the multiplicity-weighted
        modal kernel window, N-tile ~128 — snapped to the global lists."""
        counts: Dict[int, int] = {}
        for t in tasks:
            wl = t.space.workload
            khkw = int(wl.get("kh", 1) * wl.get("kw", 1))
            counts[khkw] = counts.get(khkw, 0) + t.multiplicity
        khkw = max(counts, key=counts.get) if counts else 1
        targets = (1, max(256 // khkw, 1), 128)
        return self.values(self.index_config(targets))

    def seed_values(self, n: int, tasks: Iterable[TuningTask],
                    rng: np.random.Generator) -> List[Tuple[int, ...]]:
        """``n`` distinct round-0 candidates: the network default first
        (so the co-optimizer's candidate set always contains the frozen
        baseline's chip), the largest geometry second (probes the VMEM
        feasibility frontier), then uniform draws."""
        out = [self.default_values(tasks)]
        largest = tuple(int(c[-1]) for c in self.choices)
        if largest not in out:
            out.append(largest)
        attempts = 0
        while len(out) < min(n, self.size) and attempts < 64:
            cand = self.values([rng.integers(0, len(c))
                                for c in self.choices])
            if cand not in out:
                out.append(cand)
            attempts += 1
        return out[:max(n, 1)]
