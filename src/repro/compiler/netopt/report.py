"""Typed, JSON-serializable result of a network-scope co-optimization.

A :class:`NetworkReport` is to ``repro.compiler.netopt`` what
:class:`~repro.compiler.report.TuneReport` is to one task: the chosen
hardware partition (K accelerator configs + contiguous pipeline cuts —
K=1 is the v1 single shared chip), every layer's software mapping under
its assigned chip, pipeline-aware end-to-end latency, the
hardware-candidate trace with its best-so-far progress curve, and the
multi-objective latency-vs-silicon Pareto frontier over the evaluated
candidates.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass
class NetworkReport:
    """Result of co-optimizing one network on a K-chip partition."""

    network: str
    algo: str            # "netopt" | "hw_frozen" | "random_hw" | "genetic"
    # one geometry (knob values) per pipeline stage, in stage order; K=1
    # reports additionally expose the single entry as ``hw_config``
    hw_configs: List[Dict[str, int]]
    # per unique task: {"mapping": software knob settings,
    #                   "hardware": the stage's hw config,
    #                   "hw_utilized": per-layer clamped tile actually
    #                                  exercised (<= hardware, small layers
    #                                  underutilize the shared dimension),
    #                   "latency": best per-layer latency (s),
    #                   "multiplicity": layers sharing this workload,
    #                   "segment": pipeline stage index}
    layers: Dict[str, Dict[str, object]]
    network_latency: float           # pipeline-aware end-to-end (s); K=1:
                                     # sum(latency * multiplicity)
    n_layers: int                    # sum of multiplicities
    hw_candidates: int               # distinct partitions evaluated
    total_measurements: int          # new oracle measurements paid (misses)
    wall_time_s: float
    # one row per candidate evaluation, in evaluation order:
    # {"hw": {...} (K=1) | [{...}, ...] (K>=2), "network_latency": float,
    #  "new_measurements": int, "cum_measurements": int, "best_so_far":
    #  float, "phase": "seed" | "cs" | "refine" | "frozen" | "random" |
    #  "genetic", "area_mm2": float, "trajectory": [[paid, latency], ...],
    #  "cuts": [...] (K>=2 only)} — plus one marker row {"phase":
    #  "early_stop", "measurements_saved": int, ...} when the
    #  stable-ranking stop ended the outer loop
    trace: List[Dict[str, object]] = dataclasses.field(default_factory=list)
    # cross-network surrogate transfer (repro.compiler.surrogate_store):
    # {"store": path|None, "warm_hw_rows": int, "warm_sw_rows": int,
    #  "hw_rows_saved": int, "warm_seeded": bool} — all zero/absent on a
    # cold run (old documents deserialize with the default)
    surrogates: Dict[str, object] = dataclasses.field(default_factory=dict)
    # the winning partition: {"k": int, "cuts": [...], "assignment":
    # {task_name: stage index}} — empty on pre-v2 documents
    partition: Dict[str, object] = dataclasses.field(default_factory=dict)
    k_chips: int = 1
    # transfer-aware early stop bookkeeping ({} = did not trigger):
    # {"round", "stable_refits", "skipped_candidates", "measurements_saved"}
    early_stop: Dict[str, object] = dataclasses.field(default_factory=dict)
    # final Executor.stats() snapshot of the run's measurement transport
    # (jobs/failures/respawns; remote runs add per-endpoint reconnect and
    # ack-to-result detail) — {} for in-process runs and old documents
    executor_stats: Dict[str, object] = dataclasses.field(
        default_factory=dict)

    # ------------------------------------------------------------- queries
    @property
    def hw_config(self) -> Dict[str, int]:
        """The single shared geometry — only defined for K=1 reports (the
        v1 accessor every single-chip consumer keeps using)."""
        if len(self.hw_configs) != 1:
            raise ValueError(
                f"hw_config is only defined for K=1 reports; this one has "
                f"{len(self.hw_configs)} chips — use hw_configs")
        return self.hw_configs[0]

    def verify_shared_hardware(self) -> bool:
        """True iff every layer's mapping runs on its assigned stage's
        hardware config (for K=1: the SAME config everywhere — the
        co-optimization invariant the per-layer-fantasy sum violates)."""
        assign = self.partition.get("assignment", {})
        return all(
            layer["hardware"] == self.hw_configs[int(assign.get(name, 0))]
            for name, layer in self.layers.items())

    def measurements_to(self, target_latency: float) -> Optional[int]:
        """Full cumulative measurement spend (every candidate, every
        layer) at the first time the search reached ``target_latency``
        (None if it never did) — the sample-efficiency readout the
        transfer benchmark compares cold vs warm-started runs on.  Rows
        carrying a within-candidate ``trajectory`` resolve the hit inside
        the candidate's session; old documents fall back to
        candidate-granularity ``cum_measurements``."""
        for row in self.trace:
            if "network_latency" not in row:
                continue  # early-stop marker rows
            cum = int(row["cum_measurements"])
            base = cum - int(row.get("new_measurements", 0))
            for paid, lat in row.get("trajectory", []):
                if float(lat) <= target_latency:
                    return base + int(paid)
            if float(row["best_so_far"]) <= target_latency:
                return cum
        return None

    def progress(self) -> List[Tuple[int, float]]:
        """Best-so-far frontier over measurement spend:
        (cum_measurements, network_latency) rows where a candidate
        improved on everything evaluated before it (v1's ``pareto()``)."""
        out: List[Tuple[int, float]] = []
        best = float("inf")
        for row in self.trace:
            if "network_latency" not in row:
                continue
            if row["network_latency"] < best:
                best = float(row["network_latency"])
                out.append((int(row["cum_measurements"]), best))
        return out

    def pareto(self) -> List[Tuple[float, float]]:
        """Multi-objective frontier over the evaluated candidates:
        non-dominated (network_latency, chip area) points, latency
        ascending — what a heterogeneous partition trades silicon
        against.  Old documents without per-row ``area_mm2`` degenerate
        to the single best-latency point at area 0."""
        pts = sorted({(float(r["network_latency"]),
                       float(r.get("area_mm2", 0.0)))
                      for r in self.trace if "network_latency" in r})
        out: List[Tuple[float, float]] = []
        best_area = float("inf")
        for lat, area in pts:
            if area < best_area:
                out.append((lat, area))
                best_area = area
        return out

    # --------------------------------------------------------------- (de)ser
    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        if len(self.hw_configs) == 1:
            # keep the v1 field in serialized K=1 documents (benchmarks,
            # dashboards, and the golden regression anchor read it)
            d["hw_config"] = dict(self.hw_configs[0])
        return d

    @staticmethod
    def from_dict(d: Dict) -> "NetworkReport":
        d = dict(d)
        if "hw_configs" not in d and "hw_config" in d:
            d["hw_configs"] = [d["hw_config"]]  # pre-v2 document
        fields = {f.name for f in dataclasses.fields(NetworkReport)}
        return NetworkReport(**{k: v for k, v in d.items() if k in fields})

    def summary(self) -> str:
        chips = " | ".join(", ".join(f"{k}={v}" for k, v in cfg.items())
                           for cfg in self.hw_configs)
        k = len(self.hw_configs)
        stage = f"{k}-chip pipeline" if k > 1 else "chip"
        return (f"{self.algo}: {self.network} on {stage} [{chips}] -> "
                f"{self.network_latency * 1e6:.1f} us over {self.n_layers} "
                f"layers ({self.hw_candidates} hw candidate(s), "
                f"{self.total_measurements} measurements, "
                f"{self.wall_time_s:.1f}s)")
