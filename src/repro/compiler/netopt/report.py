"""Typed, JSON-serializable result of a network-scope co-optimization.

A :class:`NetworkReport` is to ``repro.compiler.netopt`` what
:class:`~repro.compiler.report.TuneReport` is to one task: the chosen
shared hardware config, every layer's software mapping under it,
multiplicity-weighted end-to-end latency, and the hardware-candidate
trace (with its Pareto / best-so-far frontier over measurement spend).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass
class NetworkReport:
    """Result of co-optimizing one network on one shared accelerator."""

    network: str
    algo: str                        # "netopt" | "hw_frozen" | "random_hw"
    hw_config: Dict[str, int]        # the ONE shared geometry (knob values)
    # per unique task: {"mapping": software knob settings,
    #                   "hardware": the shared hw_config (identical rows),
    #                   "hw_utilized": per-layer clamped tile actually
    #                                  exercised (<= hardware, small layers
    #                                  underutilize the shared dimension),
    #                   "latency": best per-layer latency (s),
    #                   "multiplicity": layers sharing this workload}
    layers: Dict[str, Dict[str, object]]
    network_latency: float           # sum(latency * multiplicity), seconds
    n_layers: int                    # sum of multiplicities
    hw_candidates: int               # distinct hardware configs evaluated
    total_measurements: int          # new oracle measurements paid (misses)
    wall_time_s: float
    # one row per candidate evaluation, in evaluation order:
    # {"hw": {...}, "network_latency": float, "new_measurements": int,
    #  "cum_measurements": int, "best_so_far": float, "phase": "seed" |
    #  "cs" | "refine" | "frozen" | "random"}
    trace: List[Dict[str, object]] = dataclasses.field(default_factory=list)
    # cross-network surrogate transfer (repro.compiler.surrogate_store):
    # {"store": path|None, "warm_hw_rows": int, "warm_sw_rows": int,
    #  "hw_rows_saved": int, "warm_seeded": bool} — all zero/absent on a
    # cold run (old documents deserialize with the default)
    surrogates: Dict[str, object] = dataclasses.field(default_factory=dict)

    # ------------------------------------------------------------- queries
    def verify_shared_hardware(self) -> bool:
        """True iff every layer's mapping runs on the SAME hardware config
        (the co-optimization invariant the per-layer-fantasy sum violates)."""
        return all(layer["hardware"] == self.hw_config
                   for layer in self.layers.values())

    def measurements_to(self, target_latency: float) -> Optional[int]:
        """Cheapest cumulative measurement count at which the search had
        already reached ``target_latency`` (None if it never did) — the
        sample-efficiency readout the transfer benchmark compares cold vs
        warm-started runs on."""
        for row in self.trace:
            if float(row["best_so_far"]) <= target_latency:
                return int(row["cum_measurements"])
        return None

    def pareto(self) -> List[Tuple[int, float]]:
        """Best-so-far frontier over measurement spend:
        (cum_measurements, network_latency) rows where a candidate improved
        on everything evaluated before it."""
        out: List[Tuple[int, float]] = []
        best = float("inf")
        for row in self.trace:
            if row["network_latency"] < best:
                best = float(row["network_latency"])
                out.append((int(row["cum_measurements"]), best))
        return out

    # --------------------------------------------------------------- (de)ser
    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: Dict) -> "NetworkReport":
        fields = {f.name for f in dataclasses.fields(NetworkReport)}
        return NetworkReport(**{k: v for k, v in d.items() if k in fields})

    def summary(self) -> str:
        hw = ", ".join(f"{k}={v}" for k, v in self.hw_config.items())
        return (f"{self.algo}: {self.network} on [{hw}] -> "
                f"{self.network_latency * 1e6:.1f} us over {self.n_layers} "
                f"layers ({self.hw_candidates} hw candidate(s), "
                f"{self.total_measurements} measurements, "
                f"{self.wall_time_s:.1f}s)")
