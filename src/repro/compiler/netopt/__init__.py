"""``repro.compiler.netopt`` — network-scope HW/SW co-optimization.

One shared accelerator configuration for the whole DNN, per-layer
software mappings under it: an outer hardware-candidate search
(network-scope GBT + Confidence Sampling over the global hardware value
lists) drives inner pinned-subspace :class:`~repro.compiler.session.
Session`\\ s (``DesignSpace.pin`` per layer, shared software GBT, one
worker pool, per-(hw, layer) JSONL warm resume).  Result is a typed
:class:`NetworkReport`: chosen chip, per-layer mappings, end-to-end
multiplicity-weighted latency, hardware-candidate Pareto trace.

Quickstart::

    from repro.compiler import TuningTask
    from repro.compiler.netopt import NetworkCoOptimizer, NetOptConfig
    rep = NetworkCoOptimizer(TuningTask.conv_tasks("resnet-18"),
                             NetOptConfig(layer_budget=16),
                             records="artifacts/r18.netopt.jsonl",
                             name="resnet-18").run()
    print(rep.summary())           # one chip, 17 layers, end-to-end us

CLI: ``python -m repro.compiler.cli netopt --model resnet-18``.
"""
from repro.compiler.netopt.hwspace import (HW_KNOB_NAMES, HW_KNOBS,
                                           HwCandidateSpace, hw_dict, hw_tag)
from repro.compiler.netopt.loop import (NetOptConfig, NetworkCoOptimizer,
                                        netopt_tune, network_hw_frozen_tune,
                                        network_random_hw_tune)
from repro.compiler.netopt.report import NetworkReport

__all__ = [
    "HW_KNOBS", "HW_KNOB_NAMES", "HwCandidateSpace", "hw_dict", "hw_tag",
    "NetOptConfig", "NetworkCoOptimizer", "NetworkReport", "netopt_tune",
    "network_hw_frozen_tune", "network_random_hw_tune",
]
