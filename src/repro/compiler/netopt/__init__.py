"""``repro.compiler.netopt`` — network-scope HW/SW co-optimization.

K accelerator configurations for the whole DNN (K=1: one shared chip —
the v1 behavior; K=2..3: a heterogeneous pipeline over contiguous
network cuts), per-layer software mappings under them: an outer
partition search (network-scope GBT + Confidence Sampling over
:class:`PartitionSpace`) drives inner pinned-subspace
:class:`~repro.compiler.session.Session`\\ s (``DesignSpace.pin`` per
layer, shared software GBT, one worker pool, per-(hw, layer[, segment])
JSONL warm resume).  Result is a typed :class:`NetworkReport`: chosen
chip set + cuts, per-layer mappings, pipeline-aware end-to-end latency,
best-so-far progress curve, latency-vs-silicon Pareto frontier.

Quickstart::

    from repro.compiler import TuningTask
    from repro.compiler.netopt import NetworkCoOptimizer, NetOptConfig
    rep = NetworkCoOptimizer(TuningTask.conv_tasks("resnet-18"),
                             NetOptConfig(layer_budget=16, k_chips=2),
                             records="artifacts/r18.netopt.jsonl",
                             name="resnet-18").run()
    print(rep.summary())           # chip set, 17 layers, end-to-end us

CLI: ``python -m repro.compiler.cli netopt --model resnet-18 --k-chips 2``.
"""
from repro.compiler.netopt.hwspace import (HW_KNOB_NAMES, HW_KNOBS,
                                           HwCandidateSpace, hw_dict, hw_tag)
from repro.compiler.netopt.partition import HwPartition, PartitionSpace
from repro.compiler.netopt.loop import (NetOptConfig, NetworkCoOptimizer,
                                        netopt_tune, network_hw_frozen_tune,
                                        network_random_hw_tune)
from repro.compiler.netopt.genetic import network_genetic_hw_tune
from repro.compiler.netopt.report import NetworkReport

__all__ = [
    "HW_KNOBS", "HW_KNOB_NAMES", "HwCandidateSpace", "hw_dict", "hw_tag",
    "HwPartition", "PartitionSpace",
    "NetOptConfig", "NetworkCoOptimizer", "NetworkReport", "netopt_tune",
    "network_hw_frozen_tune", "network_random_hw_tune",
    "network_genetic_hw_tune",
]
