"""Network-scope HW/SW co-optimization — the paper's actual claim.

A small set of K accelerator configurations serves the whole DNN while
per-layer software agents map every layer onto its assigned chip.  The
outer loop proposes :class:`~repro.compiler.netopt.partition.HwPartition`
candidates — contiguous pipeline cuts plus one hw value-tuple per stage
(K=1 is exactly the v1 single-chip search) — scored by a network-scope
GBT with Confidence Sampling picking which candidates to pay for.  The
inner loop evaluates one partition by pinning every layer's hardware
knobs to its stage's values (``DesignSpace.pin``) and running the
per-layer software agents as one interleaved
:class:`~repro.compiler.session.Session` — shared software GBT across
layers *and* across candidates, per-layer measurements fanned over one
:class:`~repro.compiler.executor.SubprocessExecutor` pool, per-(hw,
layer[, segment]) JSONL records so a revisited candidate (the refinement
pass, a resumed run) replays from cache.  A candidate's reward is the
pipeline-aware end-to-end latency: the slowest stage's
multiplicity-weighted layer sum plus the inter-stage ICI transfer — for
K=1, the plain multiplicity-weighted network latency.

This is the DiGamma-style joint HW-config x per-layer-mapping search on
top of the pieces PRs 2-3 built (and ``netopt.genetic`` supplies the
DiGamma GA itself as the honest baseline); contrast with ``examples/
tune_resnet18.py``'s historical sum of per-layer optima, which gives
every conv layer its own fictional chip.

``surrogates=`` (a :class:`~repro.compiler.surrogate_store.
SurrogateStore` or path) makes the run part of an *accumulating* system:
both GBTs warm-start from other networks' stored training rows (the
outer search then seeds from surrogate-ranked candidates) and save their
own rows for future runs — cross-network transfer, orthogonal to the
same-network record replay above.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import shutil
import tempfile
import time
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro import obs as obslib
from repro.compiler.netopt.hwspace import (HW_KNOBS, HW_KNOB_NAMES,
                                           HwCandidateSpace, hw_dict, hw_tag)
from repro.compiler.netopt.partition import HwPartition, PartitionSpace
from repro.compiler.netopt.report import NetworkReport
from repro.compiler.oracle import Oracle, decode_config
from repro.compiler.records import RecordLog
from repro.compiler.session import Session
from repro.compiler.surrogate_store import (SurrogateStore, attach_sw_gbt,
                                            coerce_store, space_family)
from repro.compiler.task import TuningTask
from repro.core import confidence_sampling as CS
from repro.core.cost_model import GBTModel
from repro.core.tuner import TunerConfig


@dataclasses.dataclass(frozen=True)
class NetOptConfig:
    """Budget split of one network co-optimization.

    ``total_layer_budget`` is the *upper bound* on the co-optimizer's
    per-layer measurement spend — exploration of ``n_candidates *
    layer_budget`` plus a refinement session of ``layer_budget +
    refine_budget``.  The refinement replays its winner's cached prefix
    from the per-(hw, layer) records, so the real spend is usually lower
    (the replay is partial by design: the shared software surrogate has
    learned from other candidates in between, steering Confidence
    Sampling toward fresh configs).  The equal-budget baselines receive
    the full upper bound, keeping the comparison conservative *against*
    the co-optimizer.
    """

    seed_candidates: int = 3      # round-0 hw candidates (incl. the default)
    hw_rounds: int = 2            # CS-guided outer rounds after seeding
    hw_per_round: int = 2         # candidates measured per CS round
    layer_budget: int = 16        # software measurements / layer / candidate
    refine_budget: int = 32       # extra winner budget (replays warm, then
                                  # continues the software search deeper)
    tuner: TunerConfig = dataclasses.field(default_factory=TunerConfig.fast)
    hw_gbt_rounds: int = 24       # network-scope hardware surrogate
    seed: int = 0
    k_chips: int = 1              # heterogeneous pipeline stages (1..3)
    # Transfer-aware early stop: end the outer CS loop once the hardware
    # surrogate's top-``stable_top_k`` candidate ranking has been
    # unchanged for this many consecutive refits (0 = never stop early).
    # A warm-started surrogate converges its ranking in fewer rounds, so
    # this is what converts transferred rows into measurement savings.
    stop_on_stable_ranking: int = 0
    stable_top_k: int = 3

    @property
    def n_candidates(self) -> int:
        return self.seed_candidates + self.hw_rounds * self.hw_per_round

    def total_layer_budget(self) -> int:
        return ((self.n_candidates + 1) * self.layer_budget
                + self.refine_budget)


def _coerce_partition(cand) -> HwPartition:
    """Accept a bare hw value-tuple wherever a partition is expected (the
    single-chip baselines, pre-v2 callers): it is the K=1 partition."""
    if isinstance(cand, HwPartition):
        return cand
    return HwPartition((), (tuple(int(v) for v in cand),))


class _Evaluator:
    """Shared candidate-evaluation machinery for the co-optimizer and the
    network baselines (frozen / random / genetic): owns the task list,
    the partition space, the shared software GBT, the (optional) worker
    pool and record log, evaluates one partition as a pinned multi-task
    session, and keeps the running trace the final
    :class:`NetworkReport` is built from."""

    def __init__(self, tasks: Iterable[TuningTask], cfg: NetOptConfig,
                 records: Union[None, str, RecordLog], workers: int,
                 timeout_s: Optional[float], name: str, algo: str,
                 surrogates: Union[None, str, SurrogateStore] = None,
                 remote=None, trace: Optional[str] = None, obs=None,
                 monitor=None, trace_sample_rate: float = 1.0):
        self.tasks = list(tasks)
        if not self.tasks:
            raise ValueError("network co-optimization needs >= 1 task")
        if remote and workers:
            raise ValueError("remote= and workers= are mutually exclusive: "
                             "one measurement transport per run")
        self.cfg = cfg
        # Sessions build a fresh oracle per (candidate, layer), so the
        # RecordLog is the only replay path — and the refinement pass
        # *must* replay its winner's earlier measurements or the
        # equal-budget comparison against the fixed-chip baselines would
        # silently re-pay (and re-count) them.  With no user-supplied
        # records, measurements land in an ephemeral file removed by
        # ``close()``.
        self._tmp_records_dir = None
        if records is None:
            self._tmp_records_dir = tempfile.mkdtemp(prefix="netopt-rec-")
            records = os.path.join(self._tmp_records_dir, "records.jsonl")
        self.records = (RecordLog(records) if isinstance(records, str)
                        else records)
        self.workers = int(workers)
        self.timeout_s = timeout_s
        # endpoints string/list, or an already-built RemoteExecutor the
        # caller owns (tests tune reconnect knobs this way) — the latter
        # is borrowed, never closed here
        self.remote = remote
        self._owns_executor = not (remote is not None
                                   and hasattr(remote, "submit"))
        self.name = name
        self.algo = algo
        self.pspace = PartitionSpace(self.tasks, cfg.k_chips)
        self.hw = self.pspace.base  # the v1 all-tasks value unions
        # ONE software surrogate across layers and hardware candidates:
        # config features carry the hw knob values, so measurements under
        # candidate A warm-start the mapping search under candidate B.
        # With a surrogate store it also records its training rows (and
        # primes from *other* networks' rows — cross-network transfer;
        # own-network rows are excluded so a warm-from-self run stays
        # bit-identical to the cold run and replays from records).
        self.store = coerce_store(surrogates)
        # rows are only compatible within one space family (core conv/gemm
        # vs pod shard cells reuse the same dims for different semantics)
        self.family = space_family(self.tasks[0].space)
        self.sw_gbt, self.surrogate_stats = attach_sw_gbt(
            self.store, n_rounds=cfg.tuner.gbt_rounds, seed=cfg.seed,
            network=name, family=self.family)
        if self.surrogate_stats:
            self.surrogate_stats.update(warm_hw_rows=0, hw_rows_saved=0,
                                        warm_seeded=False)
        self.executor = None
        self.trace: List[Dict[str, object]] = []
        self.evaluated: Dict[HwPartition, Dict[str, object]] = {}
        self.cum_measurements = 0
        self.early_stop: Dict[str, object] = {}
        # span tracing (repro.obs): ``obs=`` borrows the caller's Tracer,
        # ``trace=`` builds one and saves it to that path at close()
        self.trace_path = trace
        self.tracer = obs if obs is not None else (
            obslib.Tracer(name=name, sample_rate=trace_sample_rate)
            if trace else None)
        # live monitoring (repro.obs.serve): port -> owned server, a
        # MonitorServer instance -> borrowed.  The /status source and
        # scrape-time collector only *read* evaluator/executor state, so
        # reports stay byte-identical with monitoring on vs off.
        self.current_phase = ""
        self.monitor = None
        self._owns_monitor = False
        self._monitor_source = None
        if monitor is not None:
            from repro.obs.serve import coerce_monitor
            self.monitor, self._owns_monitor = coerce_monitor(monitor)
        self.t0 = time.perf_counter()

    def obs_scope(self):
        """Ambient-tracer activation for the whole run (no-op when the
        run is untraced, so an *outer* tracer keeps collecting)."""
        if self.tracer is None:
            return contextlib.nullcontext()
        return obslib.use(self.tracer)

    def open(self) -> None:
        if self.monitor is not None and self._monitor_source is None:
            self.monitor.start()
            self._monitor_source = self.monitor.attach(
                f"netopt:{self.name}", self._live_status,
                collector=self._collect_metrics, tracer=self.tracer)
        if self.executor is not None:
            return
        if self.workers > 0:
            # one crash-isolated pool serves every (candidate, layer)
            # measurement of the whole co-optimization
            from repro.compiler.executor import SubprocessExecutor
            self.executor = SubprocessExecutor(workers=self.workers,
                                               timeout_s=self.timeout_s)
        elif self.remote is not None:
            if hasattr(self.remote, "submit"):  # borrowed executor
                self.executor = self.remote
            else:
                from repro.compiler.executor import RemoteExecutor
                self.executor = RemoteExecutor(self.remote,
                                               timeout_s=self.timeout_s)

    def close(self) -> None:
        # freeze the monitor's final snapshot while the executor is
        # still scrapeable; an owned server then stops with the run, a
        # borrowed one keeps serving the frozen values
        if self.monitor is not None and self._monitor_source:
            self.monitor.finalize(self._monitor_source)
        if self.executor is not None:
            if self.tracer is not None:
                self.tracer.metrics.record_executor_stats(
                    self.executor.stats())
            if self._owns_executor:
                self.executor.close()
            self.executor = None
        if self.monitor is not None and self._owns_monitor:
            self.monitor.stop()
            self.monitor = None
        if self._tmp_records_dir is not None:
            shutil.rmtree(self._tmp_records_dir, ignore_errors=True)
            self._tmp_records_dir = None
        if self.tracer is not None and self.trace_path:
            path, self.trace_path = self.trace_path, None  # save once
            self.tracer.save(path)

    # ------------------------------------------------------ live monitoring
    def best_latency_or_none(self) -> Optional[float]:
        vals = [float(e["network_latency"]) for e in self.evaluated.values()]
        return min(vals) if vals else None

    def _live_status(self) -> Dict[str, object]:
        """Copy-on-read /status section: outer-search progress + fleet
        health (the remote executor's per-endpoint detail, including
        daemon heartbeat load, rides in ``executor``)."""
        return {
            "kind": "netopt", "network": self.name, "algo": self.algo,
            "phase": self.current_phase,
            "k_chips": int(self.cfg.k_chips),
            "hw_candidates": len(self.evaluated),
            "cum_measurements": int(self.cum_measurements),
            "budget_upper_bound": int(self.cfg.total_layer_budget()
                                      * len(self.tasks)),
            "best_network_latency": self.best_latency_or_none(),
            "surrogates": dict(self.surrogate_stats),
            "early_stop": dict(self.early_stop),
            "executor": (self.executor.stats()
                         if self.executor is not None else {}),
        }

    def _collect_metrics(self, metrics) -> None:
        metrics.counter("netopt.measurements").value = \
            float(self.cum_measurements)
        metrics.counter("netopt.hw_candidates").value = \
            float(len(self.evaluated))
        best = self.best_latency_or_none()
        if best is not None:
            metrics.gauge("netopt.best_network_latency_s").set(best)
        if self.executor is not None:
            metrics.record_executor_stats(self.executor.stats())

    # ------------------------------------------------------------- evaluate
    def evaluate(self, cand, layer_budget: int, phase: str) -> float:
        """Score one partition (or bare K=1 value-tuple): pin every task
        to its stage's values, run the per-layer software agents as one
        interleaved session, return the pipeline-aware end-to-end
        latency.  Re-evaluating the same candidate (refinement, resume)
        replays warm from the per-(hw, layer) records before paying for
        anything new."""
        self.current_phase = phase
        with obslib.current().span(f"phase:{phase}", cat="phase",
                                   budget=int(layer_budget)):
            return self._evaluate(cand, layer_budget, phase)

    def _evaluate(self, cand, layer_budget: int, phase: str) -> float:
        part = _coerce_partition(cand)
        segs = part.segments(len(self.tasks))
        tags = part.tags()
        ptasks: List[TuningTask] = []
        report_key: Dict[str, str] = {}
        for (a, b), values, tag in zip(segs, part.hw_values, tags):
            for t in self.tasks[a:b]:
                ptasks.append(t.pinned(HW_KNOBS, values, tag))
                report_key[t.name] = f"{t.name}#{tag}"
        sr = Session(ptasks, tuner=self.cfg.tuner, budget=layer_budget,
                     records=self.records, gbt=self.sw_gbt,
                     executor=self.executor).run()
        if part.k == 1:
            # literally the session's weighted sum — the v1 reward, kept
            # verbatim as the K=1 bit-for-bit anchor
            net_lat = sr.network_latency()
        else:
            per_task = {t.name: float(sr.reports[report_key[t.name]]
                                      .best_latency) for t in self.tasks}
            net_lat = self.pspace.pipeline_latency(part, per_task)
        new = sum(r.oracle_stats.get("misses", 0) for r in sr)
        self.cum_measurements += new
        # a layer whose best is the executor failure-penalty sentinel
        # means transient worker noise contaminated net_lat — keep it out
        # of the persistent store (mirror of RecordingGBT's sw-row
        # filter; deterministic analytical infeasibility, a different
        # sentinel, still transfers)
        tainted = any(r.best_latency == Oracle.penalty_latency for r in sr)
        if self.store is not None and not tainted and self.store.add(
                "hw", self.pspace.features(part),
                -np.log(max(float(net_lat), 1e-12)), network=self.name,
                family=self.family, segs=part.k):
            self.surrogate_stats["hw_rows_saved"] = \
                int(self.surrogate_stats.get("hw_rows_saved", 0)) + 1
        prev = self.evaluated.get(part)
        if prev is None or net_lat <= float(prev["network_latency"]):
            self.evaluated[part] = {"network_latency": net_lat,
                                    "session": sr}
        best = min(float(e["network_latency"])
                   for e in self.evaluated.values())
        row = {
            "hw": (hw_dict(part.hw_values[0]) if part.k == 1
                   else [hw_dict(v) for v in part.hw_values]),
            "network_latency": float(net_lat),
            "layer_budget": int(layer_budget), "new_measurements": int(new),
            "cum_measurements": int(self.cum_measurements),
            "best_so_far": best, "phase": phase,
            "area_mm2": self.pspace.area_mm2(part),
            "trajectory": self._trajectory(part, sr, report_key, new)}
        if part.k > 1:
            row["cuts"] = list(part.cuts)
        self.trace.append(row)
        return float(net_lat)

    def _trajectory(self, part: HwPartition, sr, report_key: Dict[str, str],
                    new: int) -> List[List[float]]:
        """Within-candidate improvement points ``[paid_measurements,
        network_latency]`` reconstructed from the per-task tuning
        histories, merged round-major (the session schedules tasks
        round-robin, so round r of every task precedes round r+1 of any).
        History counts include record-replayed hits; they are rescaled so
        the trajectory ends at exactly this evaluation's paid (miss)
        count — what lets ``NetworkReport.measurements_to`` resolve the
        first target hit *inside* a candidate's session instead of at
        candidate granularity."""
        hists = {t.name: list(sr.reports[report_key[t.name]].history)
                 for t in self.tasks}
        n_rounds = max((len(h) for h in hists.values()), default=0)
        recorded_total = sum(h[-1][0] for h in hists.values() if h)
        if recorded_total <= 0:
            return []
        per_task: Dict[str, float] = {}
        prev_count = {name: 0 for name in hists}
        recorded = 0
        best_net = float("inf")
        traj: List[List[float]] = []
        for rnd in range(n_rounds):
            for t in self.tasks:
                h = hists[t.name]
                if rnd >= len(h):
                    continue
                count, task_best = int(h[rnd][0]), float(h[rnd][1])
                recorded += count - prev_count[t.name]
                prev_count[t.name] = count
                per_task[t.name] = task_best
                if len(per_task) < len(self.tasks):
                    continue  # network latency undefined until all tasks
                net = self.pspace.pipeline_latency(part, per_task)
                if net < best_net:
                    best_net = net
                    paid = int(round(recorded * new / recorded_total))
                    traj.append([paid, float(net)])
        return traj

    def best_partition(self) -> HwPartition:
        return min(self.evaluated,
                   key=lambda p: float(self.evaluated[p]["network_latency"]))

    # --------------------------------------------------------------- report
    def report(self) -> NetworkReport:
        part = self.best_partition()
        entry = self.evaluated[part]
        sr = entry["session"]
        segs = part.segments(len(self.tasks))
        tags = part.tags()
        hw_cfgs = [hw_dict(v) for v in part.hw_values]
        layers: Dict[str, Dict[str, object]] = {}
        assignment: Dict[str, int] = {}
        n_layers = 0
        for j, ((a, b), values, tag) in enumerate(
                zip(segs, part.hw_values, tags)):
            for t in self.tasks[a:b]:
                rep = sr.reports[f"{t.name}#{tag}"]
                pspace = t.space.pin(HW_KNOBS, values)
                settings = (decode_config(pspace, rep.best_config)
                            if rep.best_config else {})
                layers[t.name] = {
                    "mapping": {k: v for k, v in settings.items()
                                if k not in HW_KNOB_NAMES},
                    "hardware": dict(hw_cfgs[j]),
                    "hw_utilized": {k: settings[k] for k in HW_KNOB_NAMES
                                    if k in settings},
                    "latency": float(rep.best_latency),
                    "multiplicity": int(t.multiplicity),
                    "segment": j,
                }
                assignment[t.name] = j
                n_layers += t.multiplicity
        return NetworkReport(
            network=self.name, algo=self.algo, hw_configs=hw_cfgs,
            layers=layers,
            network_latency=float(entry["network_latency"]),
            n_layers=n_layers, hw_candidates=len(self.evaluated),
            total_measurements=self.cum_measurements,
            wall_time_s=time.perf_counter() - self.t0, trace=self.trace,
            surrogates=dict(self.surrogate_stats),
            partition={"k": part.k, "cuts": list(part.cuts),
                       "assignment": assignment},
            k_chips=part.k, early_stop=dict(self.early_stop),
            executor_stats=(self.executor.stats()
                            if self.executor is not None else {}))


class NetworkCoOptimizer:
    """The outer partition search: seed candidates (always including the
    network-default chip set, so the candidate set dominates the frozen
    baseline's), then ``hw_rounds`` rounds of GBT-scored Confidence
    Sampling over the candidate enumeration (full for K=1, a
    deterministic sampled pool for K>=2), then a refinement pass
    deepening the winner's software mappings with the leftover budget."""

    def __init__(self, tasks: Iterable[TuningTask],
                 cfg: Optional[NetOptConfig] = None,
                 records: Union[None, str, RecordLog] = None,
                 workers: int = 0, timeout_s: Optional[float] = None,
                 name: str = "network",
                 surrogates: Union[None, str, SurrogateStore] = None,
                 remote=None, trace: Optional[str] = None, obs=None,
                 monitor=None, trace_sample_rate: float = 1.0):
        self.cfg = cfg or NetOptConfig()
        self._ev = _Evaluator(tasks, self.cfg, records, workers, timeout_s,
                              name, "netopt", surrogates=surrogates,
                              remote=remote, trace=trace, obs=obs,
                              monitor=monitor,
                              trace_sample_rate=trace_sample_rate)
        self.pspace = self._ev.pspace
        self._pool: Optional[List[HwPartition]] = None
        self.hw_gbt = GBTModel(n_rounds=self.cfg.hw_gbt_rounds,
                               n_features=self.pspace.n_features,
                               seed=self.cfg.seed)
        # Cross-network transfer of the hardware surrogate: prime from
        # other networks' stored (hw features, fitness) rows — the
        # aggregate-descriptor half of the features is what lets one GBT
        # rank candidates for a network it has never measured.  The row
        # dimension (14 for K=1, 15K for the segment-descriptor variant)
        # keys which stored rows are compatible.
        self.warm_hw_rows = (self._ev.store.warm_start(
            self.hw_gbt, "hw", exclude_network=name,
            family=self._ev.family)
            if self._ev.store is not None else 0)
        if self._ev.surrogate_stats:
            self._ev.surrogate_stats["warm_hw_rows"] = int(self.warm_hw_rows)

    @property
    def hw(self) -> HwCandidateSpace:
        return self._ev.hw

    def run(self) -> NetworkReport:
        cfg, ev, ps = self.cfg, self._ev, self.pspace
        rng = np.random.default_rng(cfg.seed)
        prev_rank: Optional[Tuple[int, ...]] = None
        stable = 0
        try:
            with ev.obs_scope():
                return self._run(cfg, ev, ps, rng, prev_rank, stable)
        finally:
            ev.close()

    def _run(self, cfg, ev, ps, rng, prev_rank, stable) -> NetworkReport:
        try:
            ev.open()
            if self.warm_hw_rows > 0:
                # transferred hardware surrogate: spend the seed round on
                # its ranked proposals instead of uniform draws.  The two
                # guaranteed seeds stay — the network-default chip (the
                # candidate set must dominate the frozen baseline's) and
                # the largest geometry (VMEM frontier probe; a weakly
                # trained transfer surrogate must not cost that insurance).
                cands = ps.seed_partitions(min(cfg.seed_candidates, 2), rng)
                if cfg.seed_candidates > len(cands):
                    with obslib.current().span("phase:hw-select", cat="phase",
                                               rnd=-1):
                        props = self._propose(
                            cfg.seed_candidates - len(cands),
                            cfg.seed, exclude=cands)
                    cands += props
                    # only claim warm seeding when ranked proposals
                    # actually made it into the seed set (with <= 2 seed
                    # slots the guaranteed candidates fill it; a
                    # degenerate space can leave nothing to propose)
                    ev.surrogate_stats["warm_seeded"] = bool(props)
            else:
                cands = ps.seed_partitions(cfg.seed_candidates, rng)
            for rnd in range(cfg.hw_rounds + 1):
                fresh: List[Tuple[HwPartition, float]] = []
                for part in cands:
                    if part in ev.evaluated:
                        continue
                    lat = ev.evaluate(part, cfg.layer_budget,
                                      "seed" if rnd == 0 else "cs")
                    fresh.append((part, lat))
                if fresh:  # refit the hardware surrogate on the new points
                    X = np.stack([ps.features(p) for p, _ in fresh])
                    y = -np.log(np.maximum(
                        np.asarray([l for _, l in fresh]), 1e-12))
                    with obslib.current().span("phase:hw-refit", cat="phase",
                                               n=len(fresh)):
                        self.hw_gbt.update(X, y)
                    if cfg.stop_on_stable_ranking > 0:
                        rank = self._top_ranking(cfg.stable_top_k)
                        stable = stable + 1 if rank == prev_rank else 0
                        prev_rank = rank
                        if (stable >= cfg.stop_on_stable_ranking
                                and rnd < cfg.hw_rounds):
                            self._mark_early_stop(rnd, stable)
                            break
                if rnd == cfg.hw_rounds:
                    break
                with obslib.current().span("phase:hw-select", cat="phase",
                                           rnd=rnd):
                    cands = self._propose(cfg.hw_per_round,
                                          cfg.seed + rnd + 1)
            if cfg.refine_budget > 0:
                # the winner replays its layer_budget measurements from the
                # records cache, then continues the software search deeper
                ev.evaluate(ev.best_partition(),
                            cfg.layer_budget + cfg.refine_budget, "refine")
            return ev.report()
        finally:
            ev.close()

    def _mark_early_stop(self, rnd: int, stable: int) -> None:
        """Record the transfer-aware early stop: remaining CS rounds are
        skipped; ``measurements_saved`` is the per-layer budget they
        would have spent (upper bound — sessions can replay part of it),
        summed over layers."""
        cfg, ev = self.cfg, self._ev
        skipped = (cfg.hw_rounds - rnd) * cfg.hw_per_round
        saved = skipped * cfg.layer_budget * len(ev.tasks)
        ev.early_stop = {"round": int(rnd), "stable_refits": int(stable),
                         "skipped_candidates": int(skipped),
                         "measurements_saved": int(saved)}
        ev.trace.append({"phase": "early_stop",
                         "cum_measurements": int(ev.cum_measurements),
                         **ev.early_stop})

    def _top_ranking(self, top_k: int) -> Tuple[int, ...]:
        """The surrogate's current top-k candidate ranking over a FIXED
        enumeration (full for K=1, the seed-0 pool for K>=2) — comparing
        it across refits is what detects ranking convergence."""
        ps = self.pspace
        if ps.k == 1:
            feats = np.stack([ps.base.features(ps.base.values(ix))
                              for ix in ps.base.all_index_configs()])
        else:
            feats = np.stack([ps.features(p) for p in self._scored_pool()])
        scores = np.asarray(self.hw_gbt.predict(feats), np.float64)
        order = np.lexsort((np.arange(len(scores)), -scores))
        return tuple(int(i) for i in order[:max(top_k, 0)])

    def _scored_pool(self) -> List[HwPartition]:
        if self._pool is None:
            self._pool = self.pspace.candidate_pool(self.cfg.seed)
        return self._pool

    def _propose(self, n: int, seed: int,
                 exclude: Sequence[HwPartition] = ()
                 ) -> List[HwPartition]:
        """Confidence Sampling over the candidate enumeration, scored by
        the network-scope GBT; already-evaluated (and ``exclude``d)
        candidates are skipped and the batch is topped up by predicted
        score."""
        ev, ps = self._ev, self.pspace
        if ps.k == 1:
            hw = ps.base
            all_idx = hw.all_index_configs()
            feats = np.stack([hw.features(hw.values(ix))
                              for ix in all_idx])
            scores = np.asarray(self.hw_gbt.predict(feats), np.float64)
            picked = CS.confidence_sampling(
                all_idx, scores, n + len(ev.evaluated) + len(exclude),
                hw.n_choices, seed=seed)
            out: List[HwPartition] = []
            seen = ({p.hw_values[0] for p in ev.evaluated}
                    | {p.hw_values[0] for p in exclude})
            for ix in picked:
                v = hw.values(ix)
                if v not in seen:
                    seen.add(v)
                    out.append(HwPartition((), (v,)))
                if len(out) >= n:
                    return out
            for i in np.argsort(-scores):  # top-up: best predicted
                v = hw.values(all_idx[i])
                if v not in seen:
                    seen.add(v)
                    out.append(HwPartition((), (v,)))
                if len(out) >= n:
                    break
            return out
        pool = self._scored_pool()
        enc = np.stack([ps.encode(p) for p in pool])
        feats = np.stack([ps.features(p) for p in pool])
        scores = np.asarray(self.hw_gbt.predict(feats), np.float64)
        picked = CS.confidence_sampling(
            enc, scores, n + len(ev.evaluated) + len(exclude),
            ps.n_choices, seed=seed)
        seen_p = set(ev.evaluated) | set(exclude)
        out = []
        for vec in picked:
            p = ps.decode(vec)
            if p not in seen_p:
                seen_p.add(p)
                out.append(p)
            if len(out) >= n:
                return out
        for i in np.argsort(-scores):
            p = pool[int(i)]
            if p not in seen_p:
                seen_p.add(p)
                out.append(p)
            if len(out) >= n:
                break
        return out


def netopt_tune(tasks: Iterable[TuningTask],
                cfg: Optional[NetOptConfig] = None,
                **kw) -> NetworkReport:
    """One-call co-optimization: ``NetworkCoOptimizer(tasks, cfg, ...).run()``."""
    return NetworkCoOptimizer(tasks, cfg, **kw).run()


def network_hw_frozen_tune(tasks: Iterable[TuningTask],
                           cfg: Optional[NetOptConfig] = None,
                           records: Union[None, str, RecordLog] = None,
                           workers: int = 0,
                           timeout_s: Optional[float] = None,
                           name: str = "network",
                           surrogates: Union[None, str,
                                             SurrogateStore] = None,
                           remote=None,
                           trace: Optional[str] = None,
                           obs=None,
                           monitor=None,
                           trace_sample_rate: float = 1.0
                           ) -> NetworkReport:
    """Network-scope hw-frozen baseline: the single network-default chip,
    with the co-optimizer's *entire* per-layer budget spent on software
    mapping under it (equal-measurement-budget comparison)."""
    cfg = cfg or NetOptConfig()
    ev = _Evaluator(tasks, cfg, records, workers, timeout_s, name,
                    "hw_frozen", surrogates=surrogates, remote=remote,
                    trace=trace, obs=obs, monitor=monitor,
                    trace_sample_rate=trace_sample_rate)
    try:
        with ev.obs_scope():
            ev.open()
            ev.evaluate(ev.hw.default_values(ev.tasks),
                        cfg.total_layer_budget(), "frozen")
            return ev.report()
    finally:
        ev.close()


def network_random_hw_tune(tasks: Iterable[TuningTask],
                           cfg: Optional[NetOptConfig] = None,
                           n_candidates: int = 4,
                           records: Union[None, str, RecordLog] = None,
                           workers: int = 0,
                           timeout_s: Optional[float] = None,
                           name: str = "network",
                           surrogates: Union[None, str,
                                             SurrogateStore] = None,
                           remote=None,
                           trace: Optional[str] = None,
                           obs=None,
                           monitor=None,
                           trace_sample_rate: float = 1.0
                           ) -> NetworkReport:
    """Network-scope random-hardware baseline: uniform candidates, budget
    split evenly — ablates the GBT + CS outer search."""
    cfg = cfg or NetOptConfig()
    ev = _Evaluator(tasks, cfg, records, workers, timeout_s, name,
                    "random_hw", surrogates=surrogates, remote=remote,
                    trace=trace, obs=obs, monitor=monitor,
                    trace_sample_rate=trace_sample_rate)
    rng = np.random.default_rng(cfg.seed)
    n_candidates = max(min(n_candidates, ev.hw.size), 1)
    per_layer = max(cfg.total_layer_budget() // n_candidates, 1)
    try:
        with ev.obs_scope():
            ev.open()
            attempts = 0
            while len(ev.evaluated) < n_candidates and attempts < 64:
                attempts += 1
                v = ev.hw.values([rng.integers(0, len(c))
                                  for c in ev.hw.choices])
                if _coerce_partition(v) in ev.evaluated:
                    continue
                ev.evaluate(v, per_layer, "random")
            return ev.report()
    finally:
        ev.close()
