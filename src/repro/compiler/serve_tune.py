"""Online tuning-as-a-service: a netopt/:class:`Session` search measuring
candidate decode/prefill ``ShardSpace`` geometries on a live server's
*idle decode slots* while it keeps serving traffic under a p99 SLA.

The control inversion is the whole trick.  ``Session.run()`` is a blocking
search loop that thinks it owns the world; a serving host owns the clock
and only has capacity to spare when the request queue is empty and a
decode slot is free.  :class:`IdleSlotExecutor` reconciles them: it speaks
the ordinary :class:`~repro.compiler.executor.Executor` protocol (so the
whole Session stack — records, surrogates, warm resume, ``monitor=`` —
drives the search *unchanged*), but ``submit`` only queues a
:class:`MeasureJob` with the host, and ``drain`` pumps the host's serve
loop forward until the requested handles resolve.  Measurement progress
accrues exclusively inside idle windows (queue empty AND >= 1 free slot);
the moment a request arrives the in-flight candidate is preempted — the
admission-aware preemption contract of the Resource-Allocation-RL
exemplar (latency-critical service + best-effort work on one machine).

SLA violations that occur while a candidate is being measured are folded
into its reward as a hard penalty (``ServeSLA.measure_penalty_s`` per
violating request), so the search itself learns not to measure its way
into SLA trouble.

Two hosts share the contract:

* :class:`SimServeHost` — a virtual-time discrete-event model of the
  continuous-batching server (lockstep decode, serialized prefill,
  admission on free slots), with decode/prefill step times supplied by a
  :class:`ServeModel` proxy.  Virtual time means a synthetic
  million-request trace plays in seconds of wall clock; it is what
  ``benchmarks/serve_runs.py`` runs.
* :class:`LiveServeHost` — the real :class:`repro.train.server.Server`,
  plugged in through its ``best_effort`` hook (one measurement chunk per
  idle tick).  Geometry switches are advisory there — the toy server
  cannot reshard a live cache — but the measurement/preemption/SLA
  bookkeeping is identical.
"""
from __future__ import annotations

import dataclasses
import math
import time
from array import array
from collections import deque
from typing import (Any, Callable, Dict, Iterable, Iterator, List, Optional,
                    Tuple, Union)

import numpy as np

from repro.compiler.executor.base import (Executor, MeasureHandle,
                                          MeasureResult)
from repro.compiler.oracle import SettingsOracle
from repro.compiler.records import RecordLog
from repro.compiler.session import Session, SessionReport
from repro.compiler.task import TuningTask
from repro.core.shard_space import ShardSpace, knob_values_to_settings
from repro.obs import log

# ----------------------------------------------------------------- trace


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    """Synthetic request trace: Poisson arrivals with a bursty mode.

    The process alternates between a base mode (rate ``rate_per_s``) and
    bursts (rate ``rate_per_s * burst_factor``); mode dwell times are
    exponential with means ``burst_every_s`` / ``burst_len_s``.  Prompt
    and decode lengths are uniform over inclusive ranges.  Fully
    deterministic under ``seed``.
    """

    n_requests: int = 1_000_000
    rate_per_s: float = 60.0
    burst_factor: float = 2.5
    burst_every_s: float = 120.0
    burst_len_s: float = 10.0
    prompt_len: Tuple[int, int] = (8, 48)
    max_new: Tuple[int, int] = (8, 48)
    seed: int = 0


def synthetic_trace(cfg: TraceConfig
                    ) -> Iterator[Tuple[float, int, int]]:
    """Yield ``(arrival_s, prompt_len, max_new)`` tuples, in arrival
    order.  Draws are chunked so a million-request trace costs a handful
    of numpy calls, not a million."""
    rng = np.random.default_rng(cfg.seed)
    bursty = cfg.burst_factor > 1.0 and cfg.burst_every_s > 0.0
    in_burst = False
    mode_until = rng.exponential(cfg.burst_every_s) if bursty else math.inf
    t = 0.0
    remaining = cfg.n_requests
    while remaining > 0:
        k = min(8192, remaining)
        remaining -= k
        gaps = rng.exponential(1.0, size=k)
        plens = rng.integers(cfg.prompt_len[0], cfg.prompt_len[1] + 1,
                             size=k)
        mnews = rng.integers(cfg.max_new[0], cfg.max_new[1] + 1, size=k)
        for i in range(k):
            rate = cfg.rate_per_s * (cfg.burst_factor if in_burst else 1.0)
            t += gaps[i] / rate
            while t >= mode_until:
                in_burst = not in_burst
                mode_until += rng.exponential(
                    cfg.burst_len_s if in_burst else cfg.burst_every_s)
            yield (t, int(plens[i]), int(mnews[i]))


# ------------------------------------------------------------------- SLA


@dataclasses.dataclass(frozen=True)
class ServeSLA:
    """p99 end-to-end latency SLA + how violations shape the reward.

    ``measure_penalty_s`` is added to a candidate's measured step time
    once per request that violated the SLA while that candidate's
    measurement was in flight — a hard penalty (orders of magnitude above
    any real step time), so a candidate that measures at the cost of live
    traffic can never win the search.
    """

    target_s: float = 0.5
    measure_penalty_s: float = 10.0
    max_violation_pct: float = 3.0


# ------------------------------------------------------------ cost model


class ServeModel:
    """Decode/prefill ``ShardSpace`` cells of one arch + their step-time
    model, shared by the online search, the serving simulation, and the
    offline-comparison run (identical spaces and measure functions, so
    "within 10% of offline" compares like with like).

    Step times come from the zoo's deterministic roofline proxy
    (:func:`repro.compiler.zoo.pod_proxy_measure` — interior optimum in
    the model axis), calibrated so the *default* geometry (first choice
    of every knob) decodes one token in ``base_decode_step_s`` and
    prefills a full ``prefill_32k`` sequence in ``base_prefill_s``;
    everything else scales by the proxy's ratio to the default.
    """

    def __init__(self, arch: str = "qwen2-1.5b", n_devices: int = 256,
                 decode_shape: str = "decode_32k",
                 prefill_shape: str = "prefill_32k",
                 base_decode_step_s: float = 2e-3,
                 base_prefill_s: float = 60e-3):
        from repro.compiler.zoo import pod_proxy_measure
        from repro.configs import get_config
        from repro.configs.shapes import SHAPES
        self.arch = arch
        self.n_devices = n_devices
        cfg = get_config(arch)
        self.prefill_seq = SHAPES[prefill_shape].seq
        self.spaces: Dict[str, ShardSpace] = {}
        self.default_settings: Dict[str, Dict[str, object]] = {}
        self._fns: Dict[str, Callable[[Dict[str, object]], float]] = {}
        base = {"decode": base_decode_step_s, "prefill": base_prefill_s}
        for kind, shape in (("decode", decode_shape),
                            ("prefill", prefill_shape)):
            cell = SHAPES[shape]
            proxy = pod_proxy_measure(cfg.n_layers, cfg.d_model, cell.seq,
                                      cell.global_batch, n_devices,
                                      train=False)
            # calibrate against the default geometry, then bake the scale
            # into the fn the space carries: the online oracle, the sim,
            # and the offline AnalyticalOracle all measure the same units
            probe = ShardSpace.for_cell(arch, shape, measure_fn=proxy,
                                        n_devices=n_devices)
            default = knob_values_to_settings(np.asarray(
                [c[0] for c in probe.choices], np.float64))
            scale = base[kind] / proxy(default)
            fn = _scaled(proxy, scale)
            self.spaces[kind] = ShardSpace.for_cell(
                arch, shape, measure_fn=fn, n_devices=n_devices)
            self.default_settings[kind] = default
            self._fns[kind] = fn

    def cost_s(self, kind: str, settings: Dict[str, object]) -> float:
        """Calibrated step time of ``settings`` (decode: one token for
        the whole batch; prefill: one full-length sequence)."""
        return float(self._fns[kind](settings))

    def measure_fn(self, kind: str) -> Callable[[Dict[str, object]], float]:
        return self._fns[kind]

    def settings_of(self, kind: str, best_config) -> Dict[str, object]:
        """Decode a report's per-knob choice indices into settings."""
        space = self.spaces[kind]
        vals = np.asarray([space.choices[k][int(i)]
                           for k, i in enumerate(best_config)], np.float64)
        return knob_values_to_settings(vals)


def _scaled(proxy: Callable[[Dict[str, object]], float],
            scale: float) -> Callable[[Dict[str, object]], float]:
    def fn(settings: Dict[str, object]) -> float:
        return float(proxy(settings)) * scale
    return fn


# ------------------------------------------------------- measurement jobs


class MeasureJob:
    """One queued candidate measurement, executed in idle-slot windows.

    ``cost_s`` is how much idle slot time the measurement needs;
    ``progress_s`` accrues only while the host is idle and resets nothing
    on preemption (a preempted measurement resumes where it stopped — it
    loses the window, not the work).  ``violations`` counts SLA-violating
    requests that finished while this job was in flight; the completion
    folds them into the measured value as a hard penalty.
    """

    __slots__ = ("handle", "kind", "fn", "settings", "cost_s",
                 "progress_s", "violations", "running")

    def __init__(self, handle: MeasureHandle, kind: str,
                 fn: Callable[[Dict[str, object]], float],
                 cost_s: float):
        self.handle = handle
        self.kind = kind
        self.fn = fn
        self.settings = dict(handle.settings)
        self.cost_s = cost_s
        self.progress_s = 0.0
        self.violations = 0
        self.running = False


class _HostBase:
    """Shared measurement bookkeeping: the job queue, the task registry
    (Session task name -> (cell kind, measure fn)), and counters."""

    model: ServeModel
    sla: ServeSLA

    def _init_jobs(self, measure_cost_s: float) -> None:
        self.jobs: deque = deque()
        self.jobs_done = 0
        self.jobs_failed = 0
        self.preemptions = 0
        self.measure_idle_s = 0.0
        self.measure_cost_s = measure_cost_s
        self._task_fns: Dict[str, Tuple[str, Callable]] = {}

    def register_task(self, name: str, kind: str,
                      fn: Callable[[Dict[str, object]], float]) -> None:
        self._task_fns[name] = (kind, fn)

    def make_job(self, handle: MeasureHandle) -> MeasureJob:
        try:
            kind, fn = self._task_fns[handle.task]
        except KeyError:
            raise KeyError(
                f"task {handle.task!r} was never registered with this "
                f"host; have {sorted(self._task_fns)}") from None
        return MeasureJob(handle, kind, fn, self.measure_cost_s)

    def enqueue(self, job: MeasureJob) -> None:
        self.jobs.append(job)

    def _complete(self, job: MeasureJob) -> None:
        job.running = False
        self.jobs_done += 1
        try:
            raw = float(job.fn(job.settings))
        except Exception as e:  # infeasible candidate -> penalty row
            self.jobs_failed += 1
            job.handle._resolve(MeasureResult(
                ok=False, error=f"{type(e).__name__}: {e}"))
            return
        value = raw + self.sla.measure_penalty_s * job.violations
        job.handle._resolve(MeasureResult(ok=True, value=value))
        self._on_measured(job.kind, job.settings, value, raw)

    def _on_measured(self, kind: str, settings: Dict[str, object],
                     value: float, raw: float) -> None:
        """Hook: hosts may switch geometry on an improving measurement."""

    def pump(self) -> bool:
        raise NotImplementedError

    def finish_serving(self) -> None:
        """Serve (and measure) until the trace, the slots, and the job
        queue are all drained."""
        while self.pump():
            pass


# ----------------------------------------------------- virtual-time host


class SimServeHost(_HostBase):
    """Virtual-time model of the continuous-batching server.

    Faithful to :class:`repro.train.server.Server` semantics where they
    matter for scheduling: admission only onto free slots, prefill
    serialized on the host, lockstep batched decode (cost per step is the
    *decode geometry's* step time regardless of occupancy), and
    best-effort measurement progress only while the queue is empty with a
    slot free.  Decode fast-forwards in bursts — to the earliest slot
    completion, capped at the next arrival only when a free slot means
    that arrival could actually be admitted — so a million-request trace
    needs a few million pumps, not billions of per-token steps.

    Geometry: starts at the model's default; every completed measurement
    that beats the current geometry by ``switch_rel_gain`` is adopted
    immediately (a ``reconfig_pause_s`` stall models the reshard), and
    :func:`tune_while_serving` applies the session winner at the end
    regardless (warm-resumed sessions replay from records and submit no
    jobs, so switching cannot ride on job completions alone).
    """

    kind = "sim"

    def __init__(self, model: ServeModel,
                 trace: Union[TraceConfig, Iterable[Tuple[float, int, int]]],
                 sla: Optional[ServeSLA] = None, n_slots: int = 8,
                 measure_cost_s: float = 0.25,
                 reconfig_pause_s: float = 0.05,
                 switch_rel_gain: float = 0.005,
                 tune_after_s: float = 0.0):
        self.model = model
        self.sla = sla or ServeSLA()
        self.n_slots = n_slots
        self.reconfig_pause_s = reconfig_pause_s
        self.switch_rel_gain = switch_rel_gain
        # baseline observation window: measurements don't accrue before
        # this — it is what gives the bench a populated "before" phase
        # (and operators a default-geometry baseline to compare against)
        self.tune_after_s = tune_after_s
        self._init_jobs(measure_cost_s)
        if isinstance(trace, TraceConfig):
            trace = synthetic_trace(trace)
        self._trace_it = iter(trace)
        self._next = next(self._trace_it, None)
        self.t = 0.0
        self.queue: deque = deque()          # (arrival_s, plen, max_new)
        self.slots: List[List[float]] = []   # [remaining_new, arrival, new]
        self.geometry = {k: dict(model.default_settings[k])
                         for k in ("decode", "prefill")}
        self.geom_value = {k: model.cost_s(k, self.geometry[k])
                           for k in ("decode", "prefill")}
        self.switches: List[Tuple[float, str, float]] = []
        self.tuned_from_s: Optional[float] = None
        self.served = 0
        self.violations = 0
        self.sum_queue_s = 0.0
        self.sum_prefill_s = 0.0
        self._fin = array("d")
        self._lat = array("d")
        self._tok = array("d")

    # ------------------------------------------------------------ events
    def _pull_arrivals(self) -> None:
        nxt = self._next
        while nxt is not None and nxt[0] <= self.t:
            self.queue.append(nxt)
            nxt = next(self._trace_it, None)
        self._next = nxt

    def _advance(self, dt: float) -> None:
        """Advance virtual time; accrue measurement progress over the
        prefix of the interval that is genuinely idle (queue empty, free
        slot, no arrival yet)."""
        start = self.t
        self.t = start + dt
        if not self.jobs:
            return
        job = self.jobs[0]
        if self.queue or len(self.slots) >= self.n_slots:
            if job.running:
                job.running = False
                self.preemptions += 1
            return
        arrival = self._next[0] if self._next is not None else math.inf
        w_lo = max(start, self.tune_after_s)
        w_hi = min(self.t, arrival)
        window = w_hi - w_lo
        if window <= 0.0:
            if job.running:
                job.running = False
                self.preemptions += 1
            return
        job.running = True
        used = min(window, job.cost_s - job.progress_s)
        job.progress_s += used
        self.measure_idle_s += used
        if job.progress_s >= job.cost_s - 1e-12:
            self.jobs.popleft()
            self._complete(job)
        elif arrival < self.t:  # an arrival landed inside the interval
            job.running = False
            self.preemptions += 1

    def _finish_request(self, arrival: float, tokens: int) -> None:
        lat = self.t - arrival
        self._fin.append(self.t)
        self._lat.append(lat)
        self._tok.append(float(tokens))
        self.served += 1
        if lat > self.sla.target_s:
            self.violations += 1
            if self.jobs and self.jobs[0].progress_s > 0.0:
                self.jobs[0].violations += 1

    def _admit_one(self) -> None:
        arrival, plen, max_new = self.queue.popleft()
        self.sum_queue_s += self.t - arrival
        prefill = self.geom_value["prefill"] * (plen / self.model.prefill_seq)
        self._advance(prefill)
        self.sum_prefill_s += prefill
        if max_new <= 1:
            self._finish_request(arrival, max(max_new, 1))
        else:
            self.slots.append([float(max_new - 1), arrival, float(max_new)])

    def _decode_burst(self) -> None:
        step = self.geom_value["decode"]
        k = int(min(s[0] for s in self.slots))
        if len(self.slots) < self.n_slots and self._next is not None:
            # a free slot means the next arrival could be admitted: don't
            # decode past it (mirrors the real server's per-step admission)
            gap = self._next[0] - self.t
            if gap > 0.0:
                k = min(k, max(1, int(math.ceil(gap / step - 1e-9))))
        self._advance(k * step)
        keep = []
        for s in self.slots:
            s[0] -= k
            if s[0] <= 0.0:
                self._finish_request(s[1], int(s[2]))
            else:
                keep.append(s)
        self.slots = keep

    def pump(self) -> bool:
        """One scheduling decision; returns False only when everything —
        trace, queue, slots, measurement jobs — is exhausted."""
        self._pull_arrivals()
        if self.queue and len(self.slots) < self.n_slots:
            self._admit_one()
            return True
        if self.slots:
            self._decode_burst()
            return True
        if self.jobs:
            job = self.jobs[0]
            dt = job.cost_s - job.progress_s
            if self.t < self.tune_after_s:  # still in the baseline window
                dt += self.tune_after_s - self.t
            if self._next is not None:
                dt = min(dt, self._next[0] - self.t)
            self._advance(dt)
            return True
        if self._next is not None:
            self.t = self._next[0]
            return True
        return False

    # --------------------------------------------------------- geometry
    def _on_measured(self, kind: str, settings: Dict[str, object],
                     value: float, raw: float) -> None:
        # compare on the penalized value (the search's ordering) but run
        # the adopted geometry at its raw step time
        if value < self.geom_value[kind] * (1.0 - self.switch_rel_gain):
            self._switch(kind, settings, raw)

    def _switch(self, kind: str, settings: Dict[str, object],
                raw: float) -> None:
        self.geometry[kind] = dict(settings)
        self.geom_value[kind] = raw
        self.t += self.reconfig_pause_s  # reshard stall
        self.switches.append((self.t, kind, raw))

    def apply_best(self, kind: str, settings: Dict[str, object]) -> None:
        """Adopt ``settings`` if it beats the current geometry — how the
        session's final winner lands even when every measurement was a
        warm-resume record replay."""
        raw = self.model.cost_s(kind, settings)
        if raw < self.geom_value[kind] * (1.0 - self.switch_rel_gain):
            self._switch(kind, settings, raw)

    def mark_tuned(self) -> None:
        self.tuned_from_s = self.t

    # ------------------------------------------------------------ report
    def _phase(self, lo: float, hi: float) -> Dict[str, Any]:
        fin = np.frombuffer(self._fin, np.float64)
        lat = np.frombuffer(self._lat, np.float64)
        tok = np.frombuffer(self._tok, np.float64)
        mask = (fin >= lo) & (fin < hi)
        n = int(mask.sum())
        if n == 0:
            return {"n_requests": 0, "p50_latency_s": None,
                    "p99_latency_s": None, "mean_latency_s": None,
                    "tokens_per_sec": None, "violation_pct": None}
        lats = lat[mask]
        span = max(float(fin[mask].max()) - lo, 1e-9)
        return {
            "n_requests": n,
            "p50_latency_s": float(np.percentile(lats, 50)),
            "p99_latency_s": float(np.percentile(lats, 99)),
            "mean_latency_s": float(lats.mean()),
            "tokens_per_sec": float(tok[mask].sum() / span),
            "violation_pct": float(100.0 * (lats > self.sla.target_s).mean()),
        }

    def summary(self) -> Dict[str, Any]:
        """Serving + measurement stats, with a before/after split: before
        = finished under the pure default geometry (up to the first
        switch), after = finished once the session's tuning was applied."""
        first_switch = (self.switches[0][0] if self.switches
                        else self.tuned_from_s)
        overall = self._phase(0.0, math.inf)
        out = {
            "kind": self.kind,
            "sim_time_s": self.t,
            "served": self.served,
            "rejected": 0,
            "abandoned": 0,
            "sla_target_s": self.sla.target_s,
            "violations": self.violations,
            "mean_queue_s": self.sum_queue_s / max(self.served, 1),
            "mean_prefill_s": self.sum_prefill_s / max(self.served, 1),
            "before": self._phase(
                0.0, first_switch if first_switch is not None else math.inf),
            "after": (self._phase(self.tuned_from_s, math.inf)
                      if self.tuned_from_s is not None
                      else self._phase(math.inf, math.inf)),
            "geometry_default": {k: dict(v) for k, v in
                                 self.model.default_settings.items()},
            "geometry": {k: dict(v) for k, v in self.geometry.items()},
            "switches": [[float(t), k, float(v)]
                         for t, k, v in self.switches],
            "tuned_from_s": self.tuned_from_s,
            "measurements": self.jobs_done,
            "measure_failures": self.jobs_failed,
            "preempted": self.preemptions,
            "measure_idle_s": self.measure_idle_s,
        }
        out.update(overall)
        return out

    def status(self) -> Dict[str, Any]:
        """Live /status source for :class:`repro.obs.serve.MonitorServer`."""
        return {
            "kind": f"serve-{self.kind}",
            "time_s": self.t,
            "served": self.served,
            "active": len(self.slots),
            "queued": len(self.queue),
            "violations": self.violations,
            "violation_pct": (100.0 * self.violations / self.served
                              if self.served else 0.0),
            "geometry": {k: dict(v) for k, v in self.geometry.items()},
            "measurements": {"pending": len(self.jobs),
                             "done": self.jobs_done,
                             "preempted": self.preemptions},
            "switches": len(self.switches),
        }


# ------------------------------------------------------------- live host


class LiveServeHost(_HostBase):
    """The real :class:`repro.train.server.Server` as a tuning host.

    Arrivals are replayed against the wall clock (idle gaps between
    requests are skipped by advancing a clock skew, so a sparse trace
    doesn't serve in real time); measurement chunks run through the
    server's ``best_effort`` hook — at most one whole (cheap, proxy-based)
    measurement per idle tick.  Geometry switches are recorded but
    advisory: the toy server cannot reshard a live batched cache, so step
    times don't change — the sim host is where before/after timing is
    modeled, the live host is where the preemption contract meets real
    jit-compiled decode steps.
    """

    kind = "live"

    def __init__(self, server,
                 trace: Union[TraceConfig, Iterable[Tuple[float, int, int]]],
                 sla: Optional[ServeSLA] = None,
                 model: Optional[ServeModel] = None,
                 vocab: int = 1000, seed: int = 0):
        from repro.train.server import Request
        self.server = server
        self.model = model or ServeModel()
        self.sla = sla or ServeSLA()
        self._init_jobs(measure_cost_s=0.0)  # live chunks are atomic
        server.best_effort = self._best_effort
        if isinstance(trace, TraceConfig):
            trace = synthetic_trace(trace)
        self._trace_it = iter(trace)
        self._next = next(self._trace_it, None)
        self._rng = np.random.default_rng(seed)
        self._vocab = vocab
        self._Request = Request
        self._uid = 0
        self._t0 = time.perf_counter()
        self._skew = 0.0
        self._pending_violations = 0
        self.geometry = {k: dict(self.model.default_settings[k])
                         for k in ("decode", "prefill")}
        self.switches: List[Tuple[float, str, float]] = []
        self.tuned_from_s: Optional[float] = None
        self.served = 0
        self.violations = 0
        self.done: List[Any] = []
        self._lat: List[float] = []
        self._tok: List[int] = []

    def now(self) -> float:
        return time.perf_counter() - self._t0 + self._skew

    def _submit_due(self) -> None:
        nxt = self._next
        while nxt is not None and nxt[0] <= self.now():
            plen = min(nxt[1], self.server.max_len - 2)
            req = self._Request(
                uid=self._uid,
                prompt=self._rng.integers(0, self._vocab, size=max(plen, 1)
                                          ).astype(np.int32),
                max_new_tokens=nxt[2])
            self._uid += 1
            self.server.submit(req)
            nxt = next(self._trace_it, None)
        self._next = nxt

    def _best_effort(self, server) -> bool:
        """One measurement chunk per idle tick (the server only calls
        this with an empty queue and a free slot)."""
        if not self.jobs:
            return False
        job = self.jobs.popleft()
        job.progress_s = job.cost_s  # atomic chunk
        # any SLA violation since the last chunk taxes this candidate:
        # coarse, but it is the hard-penalty contract under live traffic
        job.violations = self._pending_violations
        self._pending_violations = 0
        self._complete(job)
        return True

    def _account(self, req) -> None:
        self.done.append(req)
        self.served += 1
        self._lat.append(req.latency_s)
        self._tok.append(len(req.output))
        if req.latency_s > self.sla.target_s:
            self.violations += 1
            self._pending_violations += 1

    def pump(self) -> bool:
        self._submit_due()
        srv = self.server
        if srv.queue or srv.active:
            for req in srv.step():
                self._account(req)
            return True
        if self.jobs:
            self._best_effort(srv)
            return True
        if self._next is not None:
            # fully idle: fast-forward the replay clock to the next arrival
            self._skew += self._next[0] - self.now()
            return True
        return False

    def apply_best(self, kind: str, settings: Dict[str, object]) -> None:
        self.geometry[kind] = dict(settings)
        self.switches.append((self.now(), kind,
                              self.model.cost_s(kind, settings)))

    def mark_tuned(self) -> None:
        self.tuned_from_s = self.now()

    def summary(self) -> Dict[str, Any]:
        lats = np.asarray(self._lat, np.float64)
        toks = np.asarray(self._tok, np.float64)
        wall = max(self.now(), 1e-9)
        srv = self.server
        out = {
            "kind": self.kind,
            "sim_time_s": wall,
            "served": self.served,
            "rejected": len(srv.rejected),
            "abandoned": len(srv.abandoned),
            "sla_target_s": self.sla.target_s,
            "violations": self.violations,
            "mean_queue_s": (float(np.mean([r.queue_s for r in self.done]))
                             if self.done else 0.0),
            "mean_prefill_s": (float(np.mean([r.prefill_s
                                              for r in self.done]))
                               if self.done else 0.0),
            "before": {}, "after": {},
            "geometry_default": {k: dict(v) for k, v in
                                 self.model.default_settings.items()},
            "geometry": {k: dict(v) for k, v in self.geometry.items()},
            "switches": [[float(t), k, float(v)]
                         for t, k, v in self.switches],
            "tuned_from_s": self.tuned_from_s,
            "measurements": self.jobs_done,
            "measure_failures": self.jobs_failed,
            "preempted": self.preemptions,
            "measure_idle_s": self.measure_idle_s,
            "n_requests": self.served,
        }
        if self.served:
            out.update({
                "p50_latency_s": float(np.percentile(lats, 50)),
                "p99_latency_s": float(np.percentile(lats, 99)),
                "mean_latency_s": float(lats.mean()),
                "tokens_per_sec": float(toks.sum() / wall),
                "violation_pct": float(
                    100.0 * (lats > self.sla.target_s).mean()),
            })
        else:
            out.update({"p50_latency_s": None, "p99_latency_s": None,
                        "mean_latency_s": None, "tokens_per_sec": None,
                        "violation_pct": None})
        return out

    def status(self) -> Dict[str, Any]:
        srv = self.server
        return {
            "kind": f"serve-{self.kind}",
            "time_s": self.now(),
            "served": self.served,
            "active": len(srv.active),
            "queued": len(srv.queue),
            "violations": self.violations,
            "violation_pct": (100.0 * self.violations / self.served
                              if self.served else 0.0),
            "geometry": {k: dict(v) for k, v in self.geometry.items()},
            "measurements": {"pending": len(self.jobs),
                             "done": self.jobs_done,
                             "preempted": self.preemptions},
            "switches": len(self.switches),
        }


# --------------------------------------------------------------- executor


class IdleSlotExecutor(Executor):
    """Executor whose "worker" is a serving host's idle capacity.

    ``submit`` queues the job with the host and returns immediately;
    ``drain`` pumps the host's serve loop until the requested handles
    resolve — so a blocking ``Session.run()`` transparently becomes the
    thing that drives serving forward, and every measurement it asked for
    happens inside idle-slot windows (or not yet at all)."""

    n_workers = 1

    def __init__(self, host: _HostBase):
        self.host = host
        self._next_id = 0
        self._handles: List[MeasureHandle] = []

    def submit(self, task: str, settings: Dict[str, object],
               spec=None) -> MeasureHandle:
        handle = MeasureHandle(self._next_id, task, dict(settings),
                               executor=self, spec=spec)
        self._next_id += 1
        self.host.enqueue(self.host.make_job(handle))
        self._handles.append(handle)
        return handle

    def poll(self) -> None:
        pass  # completions only happen while the host pumps (drain)

    def drain(self, handles: Optional[List[MeasureHandle]] = None) -> None:
        pending = [h for h in (self._handles if handles is None else handles)
                   if not h.done()]
        while pending:
            if not self.host.pump():
                raise RuntimeError(
                    "serve host ran dry (trace + queue + jobs exhausted) "
                    "with measurements still pending")
            pending = [h for h in pending if not h.done()]

    def stats(self) -> Dict[str, object]:
        host = self.host
        running = bool(host.jobs) and host.jobs[0].progress_s > 0.0
        return {"kind": "idle-slot", "workers_alive": 1, "respawns": 0,
                "queued": len(host.jobs), "running": int(running),
                "max_inflight": 1, "jobs": self._next_id,
                "failures": host.jobs_failed,
                "preempted": host.preemptions,
                "measure_idle_s": host.measure_idle_s}


# ------------------------------------------------------------ entry point


def serve_tuner_config():
    """Small deterministic tuner for online serving searches: each
    measurement spends real idle-slot time, so the search must be
    sample-efficient (arXiv 2507.16249's constraint) — small batches,
    heavy surrogate reuse."""
    from repro.core import mappo
    from repro.core.tuner import TunerConfig
    return TunerConfig(iteration_opt=8, b_measure=8, episodes_per_iter=2,
                       mappo=mappo.MappoConfig(n_steps=16, n_envs=8),
                       gbt_rounds=10)


@dataclasses.dataclass
class ServeReport:
    """Everything ``serve --autotune`` produced: serving stats (with the
    before/after split), the tuning session's report, the chosen online
    geometries, and — when the offline comparison ran — the offline
    winners plus per-cell convergence ratios (offline step time / online
    step time; 1.0 = the online search found the offline optimum)."""

    serve: Dict[str, Any]
    session: SessionReport
    online: Dict[str, Dict[str, Any]]
    offline: Optional[Dict[str, Dict[str, Any]]]
    convergence: Optional[Dict[str, float]]
    budget: int
    wall_s: float

    def to_dict(self) -> Dict[str, Any]:
        return {"serve": self.serve, "session": self.session.to_dict(),
                "online": self.online, "offline": self.offline,
                "convergence": self.convergence, "budget": self.budget,
                "wall_s": self.wall_s}

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "ServeReport":
        return ServeReport(
            serve=d["serve"],
            session=SessionReport.from_dict(d["session"]),
            online=d["online"], offline=d.get("offline"),
            convergence=d.get("convergence"), budget=int(d["budget"]),
            wall_s=float(d["wall_s"]))


def serve_tasks(model: ServeModel, host: Optional[_HostBase] = None
                ) -> List[TuningTask]:
    """The decode/prefill cells as Session tasks.  With a ``host``, each
    task's oracle routes measurements through the session-shared
    (idle-slot) executor; without one, the factory falls back to an
    in-process serial oracle over the same fn — which is exactly the
    offline-comparison arm."""
    tasks = []
    for kind, mult in (("decode", 4), ("prefill", 1)):
        name = f"serve:{model.arch}/{kind}"
        fn = model.measure_fn(kind)
        if host is not None:
            host.register_task(name, kind, fn)

        def factory(task, records, executor=None, _fn=fn):
            return SettingsOracle(task.space, fn=_fn, task=task.name,
                                  records=records, executor=executor,
                                  own_executor=False)

        tasks.append(TuningTask(name=name, space=model.spaces[kind],
                                multiplicity=mult, oracle_factory=factory))
    return tasks


def tune_while_serving(host: _HostBase, tuner=None, budget: int = 48,
                       records: Union[None, str, RecordLog] = None,
                       surrogates=None, monitor=None, seed: int = 0,
                       offline_compare: bool = True) -> ServeReport:
    """Run an online tuning session against ``host``'s idle capacity,
    then finish serving the trace under the tuned geometry.

    The session is the stock :class:`~repro.compiler.session.Session` —
    records (warm resume), surrogate transfer, and ``monitor=`` all work
    unchanged; only the executor is the host's idle-slot adapter.  The
    monitor (if any) additionally gains a ``serve`` /status source fed by
    the host.  ``offline_compare=True`` reruns the identical tasks with
    an unconstrained in-process oracle at the same budget and seed — the
    yardstick for "converged to within 10% of offline".
    """
    from repro.obs.serve import coerce_monitor
    model = host.model
    t0 = time.perf_counter()
    tasks = serve_tasks(model, host)
    executor = IdleSlotExecutor(host)
    mon, mon_owned = coerce_monitor(monitor)
    serve_src = None
    if mon is not None:
        mon.start()
        serve_src = mon.attach("serve", host.status)
    try:
        session = Session(tasks, tuner=tuner or serve_tuner_config(),
                          budget=budget, records=records,
                          surrogates=surrogates,
                          network=f"serve:{model.arch}",
                          seed=seed, executor=executor, monitor=mon)
        rep = session.run()
        online: Dict[str, Dict[str, Any]] = {}
        for kind in ("decode", "prefill"):
            r = rep.reports[f"serve:{model.arch}/{kind}"]
            settings = model.settings_of(kind, r.best_config)
            host.apply_best(kind, settings)
            online[kind] = {"settings": settings,
                            "step_s": model.cost_s(kind, settings)}
        host.mark_tuned()
        log.info("online tuning applied; draining the remaining trace",
                 measurements=host.jobs_done, preempted=host.preemptions)
        host.finish_serving()
    finally:
        if mon is not None:
            if serve_src is not None:
                mon.finalize(serve_src)
            if mon_owned:
                mon.stop()
    offline = convergence = None
    if offline_compare:
        off = Session(serve_tasks(model),  # no host: serial in-process
                      tuner=tuner or serve_tuner_config(), budget=budget,
                      seed=seed).run()
        offline = {}
        convergence = {}
        for kind in ("decode", "prefill"):
            r = off.reports[f"serve:{model.arch}/{kind}"]
            settings = model.settings_of(kind, r.best_config)
            step = model.cost_s(kind, settings)
            offline[kind] = {"settings": settings, "step_s": step}
            convergence[kind] = step / max(online[kind]["step_s"], 1e-12)
    return ServeReport(serve=host.summary(), session=rep, online=online,
                       offline=offline, convergence=convergence,
                       budget=budget, wall_s=time.perf_counter() - t0)
