"""Measurement oracles — the single seam every tuner measures through.

The protocol is ``measure(configs) -> (latencies, features)`` over int
choice-index configurations.  The base class owns the cross-cutting
concerns that were previously duplicated between ``core.tuner`` and
``launch.autotune``: memoization (keyed on the config tuple), JSONL record
persistence (via :class:`repro.compiler.records.RecordLog`), hit/miss/
dedup/failure accounting, and the failed-measurement penalty.

Measurement is split-phase underneath: ``measure_async(configs)`` returns
a :class:`PendingBatch` whose ``get()`` yields ``(latencies, features)``.
With the default in-process execution the split is invisible (the batch
resolves eagerly at submit time — byte-identical to the old synchronous
path), but a :class:`~repro.compiler.executor.SubprocessExecutor` keeps
the batch genuinely in flight across a worker pool, letting the session
overlap GBT refits and MAPPO updates with compiles.  Results always land
back in this parent-process oracle, so memo/records/resume semantics are
identical no matter who executed the measurement.

Two concrete oracles:

* :class:`AnalyticalOracle` — batched analytical TPU simulator
  (``DesignSpace.measure``), the paper's VTA++-simulator analog.
* :class:`CompileOracle` — one SPMD lower + compile + roofline per
  measurement (absorbs ``launch.autotune.compile_and_analyze``), the
  expensive-oracle regime Confidence Sampling targets; ``workers=N`` fans
  its measurements across a crash-isolated subprocess pool.
"""
from __future__ import annotations

import os
from typing import Callable, Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.compiler.executor import (Executor, MeasureResult, SerialExecutor,
                                     SubprocessExecutor, WorkerSpec)
from repro.obs import log
from repro.compiler.records import RecordLog
from repro.core.design_space import DesignSpace


def decode_config(space: DesignSpace, config) -> Dict[str, object]:
    """Choice indices -> human-readable knob settings for ``space``."""
    vals = np.asarray([space.choices[k][int(config[k])]
                       for k in range(space.n_knobs)], np.float64)
    from repro.core.shard_space import ShardSpace, knob_values_to_settings
    if isinstance(space, ShardSpace):
        return knob_values_to_settings(vals)
    return {name: int(v) for name, v in zip(space.knob_names, vals)}


class _EagerBatch:
    """In-flight facade over results that were computed at submit time."""

    def __init__(self, results):
        self._results = results  # (lat, feats, extras)

    def ready(self) -> bool:
        return True

    def collect(self):
        return self._results


class PendingBatch:
    """One ``measure_async`` call: cache misses possibly still in flight.

    ``ready()`` is non-blocking; ``get()`` blocks until every miss has a
    result, fills the memo cache / JSONL records / counters exactly once,
    and returns ``(latencies, features)`` aligned with the submitted
    configs (hits and in-batch duplicates included).
    """

    def __init__(self, oracle: "Oracle", keys: List[Tuple[int, ...]],
                 n_hits: int, n_dedup: int, miss_idx: List[int], inflight):
        self._oracle = oracle
        self._keys = keys
        self._n_hits = n_hits
        self._n_dedup = n_dedup
        self._miss_idx = miss_idx
        self._inflight = inflight
        self._collected = False

    def ready(self) -> bool:
        return (self._collected or self._inflight is None
                or self._inflight.ready())

    def get(self) -> Tuple[np.ndarray, np.ndarray]:
        o = self._oracle
        if not self._collected:
            if self._inflight is not None:
                with obs.current().span("measure-wait", cat="executor-wait",
                                        task=o.task,
                                        n=len(self._miss_idx)):
                    lat, feats, extras = self._inflight.collect()
                for j, i in enumerate(self._miss_idx):
                    o._remember(self._keys[i], float(lat[j]),
                                np.asarray(feats[j], np.float32),
                                extras[j] if extras else None)
            o.misses += len(self._miss_idx)
            o.hits += self._n_hits
            o.dedup += self._n_dedup
            self._collected = True  # only after the cache is fully filled
        lat = np.asarray([o._cache[k][0] for k in self._keys], np.float64)
        feats = np.stack([o._cache[k][1] for k in self._keys])
        return lat, feats


class Oracle:
    """Memoizing, record-persisting measurement oracle (protocol base).

    Subclasses implement ``_measure_batch(configs) -> (lat, feats, extras)``
    for cache misses (or override ``_submit_batch`` for asynchronous
    execution); everything else — dedup, cache fill, JSONL rows, stats —
    is shared here.
    """

    penalty_latency = 1e6  # recorded for measurements that fail

    def __init__(self, space: DesignSpace, task: str = "",
                 records: Optional[RecordLog] = None):
        self.space = space
        self.task = task or "task"
        self.records = records
        self.hits = 0
        self.misses = 0
        self.dedup = 0     # in-batch duplicates (measured once per batch)
        self.failures = 0
        self._cache: Dict[Tuple[int, ...], Tuple[float, np.ndarray]] = {}
        if records is not None:
            for row in records.load(task=self.task):
                key = tuple(int(x) for x in row["config"])
                self._cache[key] = (float(row["latency"]),
                                    np.asarray(row["features"], np.float32))

    # ------------------------------------------------------------- protocol
    def measure(self, configs) -> Tuple[np.ndarray, np.ndarray]:
        """(n, n_knobs) choice indices -> (latencies (n,), features (n, F))."""
        return self.measure_async(configs).get()

    def measure_async(self, configs) -> PendingBatch:
        """Submit a batch; misses run on this oracle's execution backend.
        A config already in the cache is a *hit*; a config repeated within
        the batch is a *dedup* (measured once); the rest are misses."""
        configs = np.asarray(configs).reshape(-1, self.space.n_knobs)
        keys = [tuple(int(x) for x in c) for c in configs]
        miss_idx: List[int] = []
        pending = set()
        n_hits = n_dedup = 0
        for i, k in enumerate(keys):
            if k in self._cache:
                n_hits += 1
            elif k in pending:
                n_dedup += 1
            else:
                miss_idx.append(i)
                pending.add(k)
        inflight = self._submit_batch(configs[miss_idx]) if miss_idx else None
        return PendingBatch(self, keys, n_hits, n_dedup, miss_idx, inflight)

    def _submit_batch(self, configs: np.ndarray):
        """Start measuring ``configs``; returns an in-flight object with
        ``ready()`` / ``collect() -> (lat, feats, extras)``.  The default
        computes eagerly in-process via ``_measure_batch``."""
        with obs.current().span("measure", cat="measure", task=self.task,
                                n=len(configs)):
            return _EagerBatch(self._measure_batch(configs))

    def _measure_batch(self, configs: np.ndarray
                       ) -> Tuple[np.ndarray, np.ndarray, Optional[List]]:
        raise NotImplementedError

    def close(self) -> None:
        """Release any execution resources this oracle owns."""

    # ------------------------------------------------------------ internals
    def _remember(self, key: Tuple[int, ...], lat: float, feats: np.ndarray,
                  extra: Optional[Dict]) -> None:
        self._cache[key] = (lat, feats)
        if self.records is not None:
            row = {"task": self.task, "config": list(key), "latency": lat,
                   "features": [float(x) for x in feats]}
            if extra:
                row.update(extra)
            self.records.append(row)

    @property
    def seen(self):
        """Keys of every memoized configuration (incl. resumed records)."""
        return self._cache.keys()

    @property
    def n_cached(self) -> int:
        return len(self._cache)

    def stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "dedup": self.dedup, "failures": self.failures,
                "cached": self.n_cached}

    def features(self, configs) -> np.ndarray:
        return np.asarray(self.space.feature_vector(
            jnp.asarray(np.asarray(configs), jnp.int32)), np.float32)


class AnalyticalOracle(Oracle):
    """Batched analytical simulator oracle over ``space.measure`` (also
    covers :class:`~repro.core.shard_space.ShardSpace` instances that carry
    their own python ``measure_fn``, e.g. mock oracles in tests).  Cheap
    and vectorized — always measured in-process."""

    def _measure_batch(self, configs):
        c = jnp.asarray(configs, jnp.int32)
        lat = np.asarray(self.space.measure(c), np.float64)
        return lat, self.features(configs), None


class _ExecutorBatch:
    """Handles for one batch of per-settings jobs on an executor."""

    def __init__(self, oracle: "SettingsOracle", handles, feats):
        self._oracle = oracle
        self._handles = handles
        self._feats = feats

    def ready(self) -> bool:
        self._oracle.executor.poll()
        return all(h.done() for h in self._handles)

    def collect(self):
        o = self._oracle
        o.executor.drain(self._handles)
        lats = np.empty(len(self._handles), np.float64)
        extras: List[Dict] = []
        for i, h in enumerate(self._handles):
            lats[i], extra = o._settle(h.settings, h.result())
            extras.append(extra)
        return lats, self._feats, extras


class SettingsOracle(Oracle):
    """Per-config oracle over decoded knob *settings* with failure penalty.

    ``fn(settings)`` returns either a latency float or a result dict with a
    ``step_penalized_s`` entry.  A failed measurement — the fn raised, the
    worker died, or the job timed out — records the hinge
    ``penalty_latency`` plus the error string: an infeasible configuration
    must never win the search, but the surrogate still learns from it.

    Execution goes through an :class:`~repro.compiler.executor.Executor`;
    the default :class:`SerialExecutor` runs each measurement in-process
    at submit time (today's behavior), while a ``SubprocessExecutor`` fans
    the batch across workers — ``measure`` still blocks for the whole
    batch, but ``measure_async`` lets a session overlap other work.
    """

    def __init__(self, space: DesignSpace,
                 fn: Optional[Callable[[Dict], object]] = None,
                 task: str = "", records: Optional[RecordLog] = None,
                 verbose: bool = False,
                 executor: Optional[Executor] = None,
                 own_executor: Optional[bool] = None,
                 worker_spec: Optional[WorkerSpec] = None):
        if fn is None and executor is None:
            raise ValueError("SettingsOracle needs fn= and/or executor=")
        self.fn = fn
        self.verbose = verbose
        self.executor = executor or SerialExecutor(fn=fn)
        # jobs carry this spec so a *shared* executor (one pool serving a
        # whole multi-task session) measures with this oracle's factory
        self.worker_spec = worker_spec
        # close() tears the executor down iff we built it (or told to)
        self._own_executor = (executor is None if own_executor is None
                              else own_executor)
        super().__init__(space, task=task, records=records)

    _RESULT_KEYS = ("step_s", "compile_s", "hbm_residency_gib", "feasible",
                    "dominant")

    def _submit_batch(self, configs):
        feats = self.features(configs) if len(configs) else \
            np.zeros((0, 0), np.float32)
        handles = [self.executor.submit(self.task,
                                        decode_config(self.space, cfg),
                                        spec=self.worker_spec)
                   for cfg in configs]
        return _ExecutorBatch(self, handles, feats)

    def _settle(self, settings: Dict[str, object],
                res: MeasureResult) -> Tuple[float, Dict]:
        """Map one executor result to (latency, JSONL extras)."""
        extra: Dict[str, object] = {"settings": settings}
        error = res.error
        lat = None
        if res.ok:
            out = res.value
            try:  # a malformed result is a failure, not a session crash
                if isinstance(out, dict):
                    lat = float(out["step_penalized_s"])
                    extra["result"] = {k: out[k] for k in self._RESULT_KEYS
                                       if k in out}
                else:
                    lat = float(out)
            except Exception as e:
                error = f"{type(e).__name__}: {e}"
        if lat is None:  # infeasible / crashed / timed out / malformed
            self.failures += 1
            lat = self.penalty_latency
            extra["error"] = error[:300]
            # verbose oracles surface every failure; quiet ones still log
            # it at debug so REPRO_LOG=debug exposes the penalty rows
            log.log("warn" if self.verbose else "debug",
                    f"  measure {settings}: FAILED {extra['error'][:140]}")
        return lat, extra

    def close(self) -> None:
        if self._own_executor:
            self.executor.close()


def _compile_measure_factory(arch: str, shape: str, verbose: bool = False
                             ) -> Callable[[Dict[str, object]], Dict]:
    """WorkerSpec factory for :class:`CompileOracle` subprocess workers:
    imported inside the worker *after* its XLA_FLAGS env pin, so the
    worker's own jax init sees the pinned placeholder device count."""
    from repro.launch.autotune import compile_and_analyze

    def fn(settings: Dict[str, object]) -> Dict[str, object]:
        return compile_and_analyze(arch, shape, settings, verbose=verbose)

    return fn


def _pinned_xla_flags(n_devices: int) -> str:
    """Current XLA_FLAGS with the placeholder device count forced to
    ``n_devices`` (workers must match the parent's topology)."""
    kept = [f for f in os.environ.get("XLA_FLAGS", "").split()
            if not f.startswith("--xla_force_host_platform_device_count")]
    kept.append(f"--xla_force_host_platform_device_count={n_devices}")
    return " ".join(kept)


class CompileOracle(SettingsOracle):
    """Pod-level compile oracle: lower + compile + roofline one LM cell per
    measurement (absorbs the old ``launch.autotune.make_measurer``).

    ``workers=0`` (default) compiles in-process, one at a time, exactly as
    before.  ``workers=N`` fans measurements across N spawned worker
    processes — each doing its own jax init against the same pinned
    device count — with ``timeout_s`` per-measurement timeouts and
    crash isolation (a dead or hung worker records the failure-penalty
    row and the pool respawns).  A multi-task session passes one shared
    ``executor=`` instead, so *all* its cells measure on one pool of
    ``workers`` processes (jobs carry this oracle's spec); the pool then
    belongs to the session, not this oracle.  Call ``close()`` (the
    Session does) to tear down an owned pool.
    """

    def __init__(self, arch: str, shape: str, n_devices: Optional[int] = None,
                 task: str = "", records: Optional[RecordLog] = None,
                 verbose: bool = True,
                 space: Optional[DesignSpace] = None,
                 workers: int = 0, timeout_s: Optional[float] = None,
                 executor: Optional[Executor] = None):
        if space is None:
            import jax
            from repro.core.shard_space import ShardSpace
            space = ShardSpace.for_cell(
                arch, shape, measure_fn=None,
                n_devices=n_devices or len(jax.devices()))
        if n_devices is None:
            import jax
            n_devices = len(jax.devices())
        self.arch, self.shape = arch, shape
        self.workers = int(workers)
        self.timeout_s = timeout_s

        spec = WorkerSpec(
            factory="repro.compiler.oracle:_compile_measure_factory",
            kwargs={"arch": arch, "shape": shape, "verbose": verbose},
            env={"XLA_FLAGS": _pinned_xla_flags(n_devices)})
        own = executor is None
        if executor is None and self.workers > 0:
            executor = SubprocessExecutor(spec, workers=self.workers,
                                          timeout_s=timeout_s)

        # same wiring in-process and in workers: one factory, two homes
        fn = _compile_measure_factory(arch, shape, verbose=verbose)
        super().__init__(space, fn, task=task or f"{arch}/{shape}",
                         records=records, verbose=verbose,
                         executor=executor, own_executor=own,
                         worker_spec=spec)
