"""Measurement oracles — the single seam every tuner measures through.

The protocol is ``measure(configs) -> (latencies, features)`` over int
choice-index configurations.  The base class owns the cross-cutting
concerns that were previously duplicated between ``core.tuner`` and
``launch.autotune``: memoization (keyed on the config tuple), JSONL record
persistence (via :class:`repro.compiler.records.RecordLog`), hit/miss/
failure accounting, and the failed-measurement penalty.

Two concrete oracles:

* :class:`AnalyticalOracle` — batched analytical TPU simulator
  (``DesignSpace.measure``), the paper's VTA++-simulator analog.
* :class:`CompileOracle` — one SPMD lower + compile + roofline per
  measurement (absorbs ``launch.autotune.compile_and_analyze``), the
  expensive-oracle regime Confidence Sampling targets.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.compiler.records import RecordLog
from repro.core.design_space import DesignSpace


def decode_config(space: DesignSpace, config) -> Dict[str, object]:
    """Choice indices -> human-readable knob settings for ``space``."""
    vals = np.asarray([space.choices[k][int(config[k])]
                       for k in range(space.n_knobs)], np.float64)
    from repro.core.shard_space import ShardSpace, knob_values_to_settings
    if isinstance(space, ShardSpace):
        return knob_values_to_settings(vals)
    return {name: int(v) for name, v in zip(space.knob_names, vals)}


class Oracle:
    """Memoizing, record-persisting measurement oracle (protocol base).

    Subclasses implement ``_measure_batch(configs) -> (lat, feats, extras)``
    for cache misses; everything else — dedup, cache fill, JSONL rows,
    stats — is shared here.
    """

    penalty_latency = 1e6  # recorded for measurements that raise

    def __init__(self, space: DesignSpace, task: str = "",
                 records: Optional[RecordLog] = None):
        self.space = space
        self.task = task or "task"
        self.records = records
        self.hits = 0
        self.misses = 0
        self.failures = 0
        self._cache: Dict[Tuple[int, ...], Tuple[float, np.ndarray]] = {}
        if records is not None:
            for row in records.load(task=self.task):
                key = tuple(int(x) for x in row["config"])
                self._cache[key] = (float(row["latency"]),
                                    np.asarray(row["features"], np.float32))

    # ------------------------------------------------------------- protocol
    def measure(self, configs) -> Tuple[np.ndarray, np.ndarray]:
        """(n, n_knobs) choice indices -> (latencies (n,), features (n, F))."""
        configs = np.asarray(configs).reshape(-1, self.space.n_knobs)
        keys = [tuple(int(x) for x in c) for c in configs]
        miss_idx, pending = [], set()
        for i, k in enumerate(keys):
            if k not in self._cache and k not in pending:
                miss_idx.append(i)
                pending.add(k)
        if miss_idx:
            lat, feats, extras = self._measure_batch(configs[miss_idx])
            for j, i in enumerate(miss_idx):
                self._remember(keys[i], float(lat[j]),
                               np.asarray(feats[j], np.float32),
                               extras[j] if extras else None)
        self.misses += len(miss_idx)
        self.hits += len(keys) - len(miss_idx)
        lat = np.asarray([self._cache[k][0] for k in keys], np.float64)
        feats = np.stack([self._cache[k][1] for k in keys])
        return lat, feats

    def _measure_batch(self, configs: np.ndarray
                       ) -> Tuple[np.ndarray, np.ndarray, Optional[List]]:
        raise NotImplementedError

    # ------------------------------------------------------------ internals
    def _remember(self, key: Tuple[int, ...], lat: float, feats: np.ndarray,
                  extra: Optional[Dict]) -> None:
        self._cache[key] = (lat, feats)
        if self.records is not None:
            row = {"task": self.task, "config": list(key), "latency": lat,
                   "features": [float(x) for x in feats]}
            if extra:
                row.update(extra)
            self.records.append(row)

    @property
    def seen(self):
        """Keys of every memoized configuration (incl. resumed records)."""
        return self._cache.keys()

    @property
    def n_cached(self) -> int:
        return len(self._cache)

    def stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "failures": self.failures, "cached": self.n_cached}

    def features(self, configs) -> np.ndarray:
        return np.asarray(self.space.feature_vector(
            jnp.asarray(np.asarray(configs), jnp.int32)), np.float32)


class AnalyticalOracle(Oracle):
    """Batched analytical simulator oracle over ``space.measure`` (also
    covers :class:`~repro.core.shard_space.ShardSpace` instances that carry
    their own python ``measure_fn``, e.g. mock oracles in tests)."""

    def _measure_batch(self, configs):
        c = jnp.asarray(configs, jnp.int32)
        lat = np.asarray(self.space.measure(c), np.float64)
        return lat, self.features(configs), None


class SettingsOracle(Oracle):
    """Per-config oracle over decoded knob *settings* with failure penalty.

    ``fn(settings)`` returns either a latency float or a result dict with a
    ``step_penalized_s`` entry.  A raising measurement records the hinge
    ``penalty_latency`` plus the error string — an infeasible configuration
    must never win the search, but the surrogate still learns from it.
    """

    def __init__(self, space: DesignSpace, fn: Callable[[Dict], object],
                 task: str = "", records: Optional[RecordLog] = None,
                 verbose: bool = False):
        self.fn = fn
        self.verbose = verbose
        super().__init__(space, task=task, records=records)

    _RESULT_KEYS = ("step_s", "compile_s", "hbm_residency_gib", "feasible",
                    "dominant")

    def _measure_batch(self, configs):
        feats = self.features(configs)
        lats = np.empty(len(configs), np.float64)
        extras: List[Dict] = []
        for i, cfg in enumerate(configs):
            settings = decode_config(self.space, cfg)
            extra: Dict[str, object] = {"settings": settings}
            try:
                out = self.fn(settings)
                if isinstance(out, dict):
                    lats[i] = float(out["step_penalized_s"])
                    extra["result"] = {k: out[k] for k in self._RESULT_KEYS
                                       if k in out}
                else:
                    lats[i] = float(out)
            except Exception as e:  # infeasible configuration
                self.failures += 1
                lats[i] = self.penalty_latency
                extra["error"] = f"{type(e).__name__}: {e}"[:300]
                if self.verbose:
                    print(f"  measure {settings}: FAILED {extra['error'][:140]}",
                          flush=True)
            extras.append(extra)
        return lats, feats, extras


class CompileOracle(SettingsOracle):
    """Pod-level compile oracle: lower + compile + roofline one LM cell per
    measurement (absorbs the old ``launch.autotune.make_measurer``)."""

    def __init__(self, arch: str, shape: str, n_devices: Optional[int] = None,
                 task: str = "", records: Optional[RecordLog] = None,
                 verbose: bool = True,
                 space: Optional[DesignSpace] = None):
        if space is None:
            import jax
            from repro.core.shard_space import ShardSpace
            space = ShardSpace.for_cell(
                arch, shape, measure_fn=None,
                n_devices=n_devices or len(jax.devices()))
        self.arch, self.shape = arch, shape

        def fn(settings: Dict[str, object]) -> Dict[str, object]:
            from repro.launch.autotune import compile_and_analyze
            return compile_and_analyze(arch, shape, settings, verbose=verbose)

        super().__init__(space, fn, task=task or f"{arch}/{shape}",
                         records=records, verbose=verbose)
