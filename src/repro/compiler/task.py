"""``TuningTask`` — one unit of tuning work, any oracle kind.

Unifies the two task notions that previously lived apart: conv/GEMM
analytical tasks (``repro.core.task.Task``) and pod-level (arch x shape)
shard-space cells.  Every task carries a cell-descriptor feature vector
(``descriptor``) — the workload half of the GBT features — which is what
makes cross-task cost-model transfer work: a shared surrogate sees
``[config features ++ cell descriptor]`` rows from every cell it serves.
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Callable, List, Optional

import numpy as np

from repro.compiler.oracle import AnalyticalOracle, Oracle
from repro.compiler.records import RecordLog
from repro.core.design_space import DesignSpace


@dataclasses.dataclass(frozen=True)
class TuningTask:
    """One tuning task: a design space, a name, and how to build its oracle."""

    name: str
    space: DesignSpace
    multiplicity: int = 1           # layers sharing this workload
    # oracle_factory(task, records) -> Oracle; None = AnalyticalOracle
    oracle_factory: Optional[Callable[["TuningTask", Optional[RecordLog]],
                                      Oracle]] = None

    def make_oracle(self, records: Optional[RecordLog] = None,
                    workers: int = 0,
                    timeout_s: Optional[float] = None,
                    executor=None) -> Oracle:
        """Build this task's oracle.  ``workers``/``timeout_s`` configure
        subprocess fan-out for expensive per-settings oracles, and
        ``executor`` is a session-shared worker pool (one pool serving
        every task, jobs carrying per-task specs); factories that don't
        take them (and the batched analytical oracle, which is cheap and
        vectorized) simply ignore them."""
        if self.oracle_factory is not None:
            params = inspect.signature(self.oracle_factory).parameters
            kw = {}
            var_kw = any(p.kind == inspect.Parameter.VAR_KEYWORD
                         for p in params.values())
            if var_kw or "workers" in params:
                kw.update(workers=workers, timeout_s=timeout_s)
            if var_kw or "executor" in params:
                kw["executor"] = executor
            return self.oracle_factory(self, records, **kw)
        return AnalyticalOracle(self.space, task=self.name, records=records)

    def descriptor(self) -> np.ndarray:
        """Cell-descriptor features — the workload half that
        ``space.feature_vector`` appends to every config row, which is what
        lets a shared GBT tell this task's measurements apart from another's
        (cross-task transfer). Exposed for inspection/diagnostics."""
        return np.asarray(self.space.workload_features(), np.float32)

    def pinned(self, knob_idxs, values, tag: str) -> "TuningTask":
        """This task with knobs frozen at shared *values*
        (``DesignSpace.pin``) — e.g. one network-wide hardware config.  The
        name gains ``#tag`` so oracle caches and JSONL records key per
        (pin, task): revisiting the same pin replays from cache.
        Multiplicity and the oracle factory carry over (factories build
        from ``task.space``, which is now the pinned subspace)."""
        return dataclasses.replace(self, name=f"{self.name}#{tag}",
                                   space=self.space.pin(knob_idxs, values))

    # ---------------------------------------------------------- constructors
    @staticmethod
    def from_space(name: str, space: DesignSpace,
                   multiplicity: int = 1) -> "TuningTask":
        return TuningTask(name=name, space=space, multiplicity=multiplicity)

    @staticmethod
    def matmul(m: int, n: int, k: int,
               name: Optional[str] = None) -> "TuningTask":
        return TuningTask(name=name or f"matmul_{m}x{n}x{k}",
                          space=DesignSpace.for_matmul(m, n, k))

    @staticmethod
    def conv_tasks(model: str, batch: int = 1) -> List["TuningTask"]:
        """All unique conv tasks of a network (Table-3 extraction)."""
        from repro.core.task import conv_tasks
        return [TuningTask(name=t.name, space=t.space,
                           multiplicity=t.multiplicity)
                for t in conv_tasks(model, batch=batch)]

    @staticmethod
    def cell(arch: str, shape: str, n_devices: Optional[int] = None,
             verbose: bool = True) -> "TuningTask":
        """Pod-level (arch x shape) cell measured by the compile oracle."""
        from repro.compiler.oracle import CompileOracle
        from repro.core.shard_space import ShardSpace
        if n_devices is None:
            # The pod mesh needs the placeholder device count pinned *before*
            # jax initializes (same dance as repro.launch.autotune's import);
            # a no-op if the backend is already up — hence the check below.
            import os
            if "--xla_force_host_platform_device_count" not in \
                    os.environ.get("XLA_FLAGS", ""):
                os.environ["XLA_FLAGS"] = (
                    os.environ.get("XLA_FLAGS", "")
                    + " --xla_force_host_platform_device_count="
                    + os.environ.get("REPRO_DRYRUN_DEVICES", "256")).strip()
            import jax
            n_devices = len(jax.devices())
        space = ShardSpace.for_cell(arch, shape, measure_fn=None,
                                    n_devices=n_devices)
        if not space.choices[0]:
            raise ValueError(
                f"no model-axis choice fits {n_devices} device(s); jax was "
                "initialized before the device count was pinned — set "
                "XLA_FLAGS=--xla_force_host_platform_device_count=N (or "
                "REPRO_DRYRUN_DEVICES) before first jax use, or pass "
                "n_devices explicitly")

        def factory(task: "TuningTask", records: Optional[RecordLog],
                    workers: int = 0, timeout_s: Optional[float] = None,
                    executor=None) -> Oracle:
            # the session loop and the oracle share one space object
            return CompileOracle(arch, shape, n_devices=n_devices,
                                 task=task.name, records=records,
                                 verbose=verbose, space=task.space,
                                 workers=workers, timeout_s=timeout_s,
                                 executor=executor)

        return TuningTask(name=f"{arch}/{shape}", space=space,
                          oracle_factory=factory)
