"""``repro.compiler`` — the unified tuning-session API.

One seam over both tuning stacks: the conv/analytical path (paper Fig. 2)
and the pod-level compile path (beyond-paper §Perf) both run as a
:class:`Session` over :class:`TuningTask`\\ s measured through one memoizing
:class:`Oracle`, sharing a GBT cost model across tasks and persisting /
resuming from JSONL records.  See ``session.py`` for the quickstart and
``python -m repro.compiler.cli --help`` for the command line.

Exports resolve lazily: ``repro.core.tuner`` imports the oracle/report
submodules directly, so an eager ``from .session import Session`` here
would close an import cycle.
"""
import importlib

_EXPORTS = {
    "Oracle": "repro.compiler.oracle",
    "AnalyticalOracle": "repro.compiler.oracle",
    "SettingsOracle": "repro.compiler.oracle",
    "CompileOracle": "repro.compiler.oracle",
    "decode_config": "repro.compiler.oracle",
    "Executor": "repro.compiler.executor",
    "SerialExecutor": "repro.compiler.executor",
    "SubprocessExecutor": "repro.compiler.executor",
    "WorkerSpec": "repro.compiler.executor",
    "MeasureResult": "repro.compiler.executor",
    "RecordLog": "repro.compiler.records",
    "TuneReport": "repro.compiler.report",
    "Tracker": "repro.compiler.report",
    "TuningTask": "repro.compiler.task",
    "Session": "repro.compiler.session",
    "SessionReport": "repro.compiler.session",
    "SurrogateStore": "repro.compiler.surrogate_store",
    "SurrogateSchemaError": "repro.compiler.surrogate_store",
    "RecordingGBT": "repro.compiler.surrogate_store",
    "NetworkTask": "repro.compiler.zoo",
    "get_network": "repro.compiler.zoo",
    "network_names": "repro.compiler.zoo",
    "IdleSlotExecutor": "repro.compiler.serve_tune",
    "LiveServeHost": "repro.compiler.serve_tune",
    "ServeModel": "repro.compiler.serve_tune",
    "ServeReport": "repro.compiler.serve_tune",
    "ServeSLA": "repro.compiler.serve_tune",
    "SimServeHost": "repro.compiler.serve_tune",
    "TraceConfig": "repro.compiler.serve_tune",
    "synthetic_trace": "repro.compiler.serve_tune",
    "tune_while_serving": "repro.compiler.serve_tune",
}
__all__ = sorted(_EXPORTS)


def __getattr__(name):
    if name in _EXPORTS:
        return getattr(importlib.import_module(_EXPORTS[name]), name)
    raise AttributeError(f"module 'repro.compiler' has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))
