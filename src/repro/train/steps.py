"""Step builders: sharded train_step / prefill_step / serve_step.

These are what the dry-run lowers and what the trainer/server execute.
Everything is built from an ``ArchConfig`` + mesh + ``TrainConfig``; the
returned callables are ``jax.jit``s with explicit in/out shardings.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist import sharding as SH
from repro.models import transformer as T
from repro.optim.adam import Adam, AdamState, cosine_schedule


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    grad_accum: int = 1            # microbatches (compute/comm overlap)
    moment_dtype: Optional[Any] = None  # e.g. jnp.bfloat16 halves opt memory
    seed: int = 0


def make_optimizer(tc: TrainConfig) -> Adam:
    return Adam(lr=cosine_schedule(tc.lr, tc.warmup_steps, tc.total_steps),
                weight_decay=tc.weight_decay,
                grad_clip_norm=tc.grad_clip,
                moment_dtype=tc.moment_dtype)


def _loss_for_grad(params, batch, cfg):
    loss, metrics = T.loss_fn(params, batch, cfg)
    return loss, metrics


def train_step_fn(cfg: T.ArchConfig, tc: TrainConfig
                  ) -> Callable[..., Tuple[Any, Any, Dict]]:
    """Returns f(params, opt_state, batch) -> (params, opt_state, metrics).

    grad_accum > 1 splits the batch into microbatches and accumulates via
    lax.scan — XLA overlaps the gradient all-reduce of microbatch i with the
    compute of microbatch i+1 (latency-hiding scheduler).
    """
    opt = make_optimizer(tc)

    def step(params, opt_state: AdamState, batch):
        if tc.grad_accum > 1:
            def micro(carry, mb):
                gacc, lacc = carry
                (loss, metrics), grads = jax.value_and_grad(
                    _loss_for_grad, has_aux=True)(params, mb, cfg)
                gacc = jax.tree.map(jnp.add, gacc, grads)
                return (gacc, lacc + loss), None

            mbs = jax.tree.map(
                lambda x: x.reshape(tc.grad_accum,
                                    x.shape[0] // tc.grad_accum,
                                    *x.shape[1:]), batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(
                micro, (zeros, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g / tc.grad_accum, grads)
            loss = loss / tc.grad_accum
            metrics = {"nll": loss}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                _loss_for_grad, has_aux=True)(params, batch, cfg)
        params, opt_state = opt.update(grads, opt_state, params)
        metrics = dict(metrics, loss=loss,
                       grad_norm=_global_norm(grads))
        return params, opt_state, metrics

    return step


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def serve_step_fn(cfg: T.ArchConfig) -> Callable:
    """f(params, cache, tokens(B,1)) -> (logits (B,V), cache)."""

    def step(params, cache, tokens):
        return T.decode_step(params, cache, tokens, cfg)

    return step


def prefill_fn(cfg: T.ArchConfig, max_len: int) -> Callable:
    def step(params, batch):
        return T.prefill(params, batch, cfg, max_len)

    return step


# --------------------------------------------------------------------------
# Jitted, sharded builders
# --------------------------------------------------------------------------

def build_sharded_train_step(cfg: T.ArchConfig, tc: TrainConfig, mesh: Mesh,
                             rules: SH.ShardingRules = SH.ShardingRules(),
                             abstract_params=None):
    """jit(train_step) with explicit in/out shardings for (params, opt,
    batch). Returns (jitted_fn, state_shardings dict)."""
    if abstract_params is None:
        abstract_params = T.abstract_params(jax.random.PRNGKey(0), cfg)
    p_sh = SH.param_shardings(abstract_params, mesh, cfg, rules)
    opt = make_optimizer(tc)
    abstract_opt = jax.eval_shape(opt.init, abstract_params)
    o_sh = AdamState(step=NamedSharding(mesh, P()),
                     mu=p_sh, nu=p_sh)
    step = train_step_fn(cfg, tc)

    def batch_sh(batch_tree):
        return SH.batch_specs(batch_tree, mesh)

    def jitted(batch_abstract):
        b_sh = batch_sh(batch_abstract)
        b = jax.tree.leaves(batch_abstract)[0].shape[0]
        T.set_batch_axes(
            SH.fit_axes(b, SH.data_axes(mesh), mesh),
            seq_axis=rules.tp_axis if rules.sequence_parallel else None,
            seq_divisor=SH.axis_size(mesh, rules.tp_axis))
        return jax.jit(step,
                       in_shardings=(p_sh, o_sh, b_sh),
                       out_shardings=(p_sh, o_sh, None),
                       donate_argnums=(0, 1))

    return jitted, {"params": p_sh, "opt": o_sh}


def build_sharded_serve_step(cfg: T.ArchConfig, mesh: Mesh,
                             rules: SH.ShardingRules = SH.ShardingRules(),
                             abstract_params=None, abstract_cache=None,
                             batch: int = 1, max_len: int = 1024):
    if abstract_params is None:
        abstract_params = T.abstract_params(jax.random.PRNGKey(0), cfg)
    if abstract_cache is None:
        abstract_cache = jax.eval_shape(
            lambda: T.init_cache(cfg, batch, max_len))
    p_sh = SH.param_shardings(abstract_params, mesh, cfg, rules)
    c_sh = SH.cache_shardings(abstract_cache, mesh, cfg, rules)
    tok_sh = SH.batch_sharding(mesh, batch, 1)
    T.set_batch_axes(SH.fit_axes(batch, SH.data_axes(mesh), mesh))
    # (decode steps are seq-len 1 — SP constraint is a no-op there)
    step = serve_step_fn(cfg)
    jitted = jax.jit(step,
                     in_shardings=(p_sh, c_sh, tok_sh),
                     out_shardings=(None, c_sh),
                     donate_argnums=(1,))
    return jitted, {"params": p_sh, "cache": c_sh}


def build_sharded_prefill(cfg: T.ArchConfig, mesh: Mesh, max_len: int,
                          rules: SH.ShardingRules = SH.ShardingRules(),
                          abstract_params=None):
    if abstract_params is None:
        abstract_params = T.abstract_params(jax.random.PRNGKey(0), cfg)
    p_sh = SH.param_shardings(abstract_params, mesh, cfg, rules)
    step = prefill_fn(cfg, max_len)

    def jitted(batch_abstract):
        b_sh = SH.batch_specs(batch_abstract, mesh)
        b = jax.tree.leaves(batch_abstract)[0].shape[0]
        T.set_batch_axes(
            SH.fit_axes(b, SH.data_axes(mesh), mesh),
            seq_axis=rules.tp_axis if rules.sequence_parallel else None,
            seq_divisor=SH.axis_size(mesh, rules.tp_axis))
        return jax.jit(step, in_shardings=(p_sh, b_sh))

    return jitted, {"params": p_sh}
