"""Fault-tolerant training loop.

Failure model (what actually happens on big pods) and the response here:

  * hardware/process crash      -> restart + restore latest checkpoint; the
                                   data pipeline is step-addressed, so resume
                                   is exact with no replay log;
  * loss NaN / grad explosion   -> automatic rollback to the last checkpoint
                                   and LR-independent skip past the bad
                                   window (skip_steps_on_nan);
  * preemption signal           -> flush a final checkpoint and exit cleanly;
  * stragglers                  -> bounded prefetch queue decouples input
                                   production from the step cadence.

``FailureInjector`` lets tests script crashes/NaNs deterministically.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM
from repro.dist import sharding as SH
from repro.models import transformer as T
from repro.train import checkpoint as CKPT
from repro.train.steps import TrainConfig, make_optimizer, train_step_fn


class FailureInjector:
    """Deterministic fault scripting for tests."""

    def __init__(self, crash_at: Optional[int] = None,
                 nan_at: Optional[int] = None):
        self.crash_at = crash_at
        self.nan_at = nan_at
        self.fired: List[str] = []

    def maybe_fail(self, step: int, batch: Dict[str, np.ndarray]):
        if self.crash_at is not None and step == self.crash_at:
            self.crash_at = None
            self.fired.append(f"crash@{step}")
            raise RuntimeError(f"injected crash at step {step}")
        if self.nan_at is not None and step == self.nan_at:
            self.nan_at = None
            self.fired.append(f"nan@{step}")
            bad = dict(batch)
            bad["tokens"] = np.full_like(batch["tokens"], -(2 ** 31) + 7)
            return bad
        return batch


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 25
    keep: int = 3
    log_every: int = 10
    nan_check_every: int = 1
    max_restarts: int = 3


class Trainer:
    """Single-controller trainer; on a pod each host runs this loop with
    jax.distributed-initialized global devices (same code path)."""

    def __init__(self, cfg: T.ArchConfig, tc: TrainConfig,
                 trc: TrainerConfig, mesh: Mesh,
                 data_cfg: Optional[DataConfig] = None,
                 rules: SH.ShardingRules = SH.ShardingRules(),
                 injector: Optional[FailureInjector] = None):
        self.cfg, self.tc, self.trc, self.mesh = cfg, tc, trc, mesh
        self.rules = rules
        self.injector = injector
        self.metrics_log: List[Dict[str, float]] = []
        self.restarts = 0

        self.data_cfg = data_cfg or DataConfig(
            vocab=cfg.vocab, seq_len=256, global_batch=8, seed=tc.seed)
        self.ds = SyntheticLM(self.data_cfg)
        self.ckpt = CKPT.CheckpointManager(trc.ckpt_dir, keep=trc.keep)

        self._abstract = T.abstract_params(jax.random.PRNGKey(tc.seed), cfg)
        self.p_sh = SH.param_shardings(self._abstract, mesh, cfg, rules)
        opt = make_optimizer(tc)
        self._abstract_opt = jax.eval_shape(opt.init, self._abstract)
        from repro.optim.adam import AdamState
        from jax.sharding import NamedSharding, PartitionSpec as P
        self.o_sh = AdamState(step=NamedSharding(mesh, P()),
                              mu=self.p_sh, nu=self.p_sh)
        self._step_fn = None
        self._init_state()

    # ------------------------------------------------------------- state
    def _init_state(self):
        latest = self.ckpt.latest_step()
        if latest is not None:
            self._restore(latest)
            return
        opt = make_optimizer(self.tc)

        @jax.jit
        def init(rng):
            params = T.init_params(rng, self.cfg)
            return params, opt.init(params)

        with self.mesh:
            init_j = jax.jit(lambda rng: init(rng),
                             out_shardings=(self.p_sh, self.o_sh))
            self.params, self.opt_state = init_j(
                jax.random.PRNGKey(self.tc.seed))
        self.step = 0

    def _restore(self, step: int):
        target = {"params": self._abstract, "opt": self._abstract_opt}
        shard = {"params": self.p_sh, "opt": self.o_sh}
        _, tree, meta = CKPT.restore(self.trc.ckpt_dir, step, target, shard)
        self.params, self.opt_state = tree["params"], tree["opt"]
        self.step = int(meta["data_step"])

    def _save(self, sync: bool = False):
        tree = {"params": self.params, "opt": self.opt_state}
        meta = {"data_step": self.step}
        if sync:
            self.ckpt.save_sync(self.step, tree, meta)
        else:
            self.ckpt.save_async(self.step, tree, meta)

    # -------------------------------------------------------------- loop
    def _compiled_step(self, batch):
        if self._step_fn is None:
            step = train_step_fn(self.cfg, self.tc)
            b_sh = SH.batch_specs(jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch),
                self.mesh)
            self._step_fn = jax.jit(
                step, in_shardings=(self.p_sh, self.o_sh, b_sh),
                out_shardings=(self.p_sh, self.o_sh, None),
                donate_argnums=(0, 1))
        return self._step_fn

    def run(self) -> List[Dict[str, float]]:
        self._save(sync=True)  # step-0 anchor
        prefetch = Prefetcher(self.ds, start_step=self.step)
        try:
            while self.step < self.trc.steps:
                try:
                    batch = prefetch.next()
                    if self.injector:
                        batch = self.injector.maybe_fail(self.step, batch)
                    t0 = time.perf_counter()
                    with self.mesh:
                        fn = self._compiled_step(batch)
                        self.params, self.opt_state, metrics = fn(
                            self.params, self.opt_state, batch)
                    loss = float(metrics["loss"])
                    if (self.step % self.trc.nan_check_every == 0
                            and not math.isfinite(loss)):
                        raise FloatingPointError(
                            f"non-finite loss at step {self.step}: {loss}")
                    dt = time.perf_counter() - t0
                    if self.step % self.trc.log_every == 0:
                        self.metrics_log.append(
                            {"step": self.step, "loss": loss,
                             "grad_norm": float(metrics["grad_norm"]),
                             "sec": dt})
                    self.step += 1
                    if self.step % self.trc.ckpt_every == 0:
                        self._save()
                except (RuntimeError, FloatingPointError) as e:
                    self.restarts += 1
                    if self.restarts > self.trc.max_restarts:
                        raise
                    self.ckpt.wait()
                    latest = self.ckpt.latest_step()
                    self._restore(latest)
                    prefetch.close()
                    prefetch = Prefetcher(self.ds, start_step=self.step)
                    self.metrics_log.append(
                        {"step": self.step, "event": f"rollback({e})"})
        finally:
            prefetch.close()
            self.ckpt.wait()
        self._save(sync=True)
        return self.metrics_log
