"""Batched serving with continuous-batching slots.

A fixed decode batch of ``n_slots``; requests are prefilled individually
(disaggregated prefill), inserted into free slots of the live batched cache
(per-sequence positions — slots run at different depths), and decoded
together.  Finished slots free immediately and new requests join without
draining the batch.

Latency accounting is end-to-end: ``Request.latency_s`` runs from
``submit()`` to finish, with a ``queue_s`` / ``prefill_s`` / ``decode_s``
breakdown per request — an SLA on p99 latency is meaningless if queue wait
and prefill are invisible, which is exactly what the pre-fix timer (started
after prefill, at admission) got wrong.

Idle capacity is a first-class resource: a ``best_effort`` hook (one small
chunk of background work per call — e.g. one candidate measurement of an
online tuning session, see :mod:`repro.compiler.serve_tune`) runs only when
the queue is empty and at least one decode slot is free, so live requests
always preempt background work at chunk granularity.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T

# Request.status values, in lifecycle order.
QUEUED, ACTIVE, DONE, REJECTED, ABANDONED = (
    "queued", "active", "done", "rejected", "abandoned")


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (L,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # filled by the server
    output: Optional[List[int]] = None
    status: str = QUEUED
    error: Optional[str] = None
    # end-to-end latency (submit -> finish) + its breakdown; all None until
    # the request finishes (or forever, for rejected/abandoned requests)
    latency_s: Optional[float] = None
    queue_s: Optional[float] = None
    prefill_s: Optional[float] = None
    decode_s: Optional[float] = None
    # internal timeline stamps (perf_counter): set by submit()/_admit()
    submit_s: Optional[float] = None
    admit_s: Optional[float] = None
    finish_s: Optional[float] = None

    @property
    def ok(self) -> bool:
        return self.status == DONE


def _insert_slot(cache, req_cache, slot: int):
    """Copy a single-request cache into batch slot ``slot``."""

    def ins(batched, single):
        if batched.ndim == 1:        # pos: (B,)
            return batched.at[slot].set(single[0])
        # layer leaves: (R, B, ...)
        return jax.lax.dynamic_update_slice_in_dim(
            batched, single, slot, axis=1)

    return jax.tree.map(ins, cache, req_cache)


class Server:
    """Continuous-batching server; see the module docstring.

    ``best_effort`` is an optional callable ``(server) -> bool`` invoked
    from :meth:`step` whenever there is idle capacity (queue empty AND at
    least one free slot).  It must do at most one *small* chunk of work
    per call and return True if it did any — the server never calls it
    while requests wait, which is the admission-aware preemption contract
    background measurement schedulers rely on.
    """

    def __init__(self, params, cfg: T.ArchConfig, n_slots: int = 4,
                 max_len: int = 512,
                 decode_fn: Optional[Callable] = None,
                 greedy: bool = True,
                 best_effort: Optional[Callable[["Server"], bool]] = None):
        self.params, self.cfg = params, cfg
        self.n_slots, self.max_len = n_slots, max_len
        self.cache = T.init_cache(cfg, n_slots, max_len)
        self.free = list(range(n_slots))
        self.active: Dict[int, Request] = {}
        self.last_tok = np.zeros((n_slots, 1), np.int32)
        self.new_counts: Dict[int, int] = {}
        self.queue: Deque[Request] = deque()
        self.rejected: List[Request] = []
        self.abandoned: List[Request] = []
        self.best_effort = best_effort
        self._decode = decode_fn or jax.jit(
            lambda p, c, t: T.decode_step(p, c, t, cfg), donate_argnums=(1,))
        self._prefill = jax.jit(
            lambda p, b: T.prefill(p, b, cfg, max_len),
            static_argnums=())

    # ------------------------------------------------------------- intake
    def submit(self, req: Request) -> Request:
        """Queue ``req`` (stamping its end-to-end latency clock), or fail
        it gracefully: an oversized or empty prompt is rejected here with
        ``status="rejected"`` + an ``error`` instead of corrupting the
        batched cache at admission (prefill pads the cache to ``max_len``;
        a longer prompt would silently truncate/overwrite it)."""
        req.submit_s = time.perf_counter()
        if len(req.prompt) == 0:
            req.status, req.error = REJECTED, "empty prompt"
        elif len(req.prompt) >= self.max_len:
            req.status, req.error = REJECTED, (
                f"prompt length {len(req.prompt)} >= max_len "
                f"{self.max_len}: no room in the slot cache")
        if req.status == REJECTED:
            req.output = []
            self.rejected.append(req)
            return req
        req.status = QUEUED
        self.queue.append(req)
        return req

    def _admit(self):
        while self.free and self.queue:
            req = self.queue.popleft()
            slot = self.free.pop()
            req.admit_s = time.perf_counter()
            req.queue_s = req.admit_s - req.submit_s
            batch = {"tokens": jnp.asarray(req.prompt[None, :], jnp.int32)}
            if self.cfg.vision_prefix:
                batch["patches"] = jnp.zeros(
                    (1, self.cfg.vision_prefix, self.cfg.d_model),
                    self.cfg.dtype)
            if self.cfg.enc_dec:
                batch["frames"] = jnp.zeros(
                    (1, self.cfg.enc_seq, self.cfg.d_model), self.cfg.dtype)
            logits, rc = self._prefill(self.params, batch)
            self.cache = _insert_slot(self.cache, rc, slot)
            first = int(jnp.argmax(logits[0]))   # also syncs the prefill
            req.prefill_s = time.perf_counter() - req.admit_s
            req.output = [first]
            req.status = ACTIVE
            self.last_tok[slot, 0] = first
            self.active[slot] = req
            self.new_counts[slot] = 1

    # ---------------------------------------------------------- idle work
    def idle_capacity(self) -> int:
        """Free decode slots available for best-effort work right now —
        zero whenever any request is waiting for admission (live traffic
        preempts background measurements)."""
        return 0 if self.queue else len(self.free)

    def _tick_best_effort(self) -> bool:
        if self.best_effort is None or not self.idle_capacity():
            return False
        return bool(self.best_effort(self))

    # ------------------------------------------------------------- decode
    def _finish(self, slot: int, status: str = DONE) -> Request:
        req = self.active.pop(slot)
        req.finish_s = time.perf_counter()
        req.status = status
        # end-to-end: queue wait + prefill + decode (the pre-fix timer
        # started at admission *after* prefill and missed the first two)
        req.latency_s = req.finish_s - req.submit_s
        req.decode_s = req.finish_s - req.admit_s - req.prefill_s
        self.new_counts.pop(slot)
        self.free.append(slot)
        return req

    def step(self) -> List[Request]:
        """One decode step for all active slots; returns finished requests.
        With idle capacity (free slots + empty queue) one chunk of
        best-effort work runs first — alongside the decode when other
        slots are busy, or alone when the server is idle."""
        self._admit()
        self._tick_best_effort()
        if not self.active:
            return []
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(self.last_tok))
        toks = np.asarray(jnp.argmax(logits, axis=-1))
        done: List[Request] = []
        for slot, req in list(self.active.items()):
            t = int(toks[slot])
            req.output.append(t)
            self.last_tok[slot, 0] = t
            self.new_counts[slot] += 1
            ended = (req.eos_id is not None and t == req.eos_id)
            full = (self.new_counts[slot] >= req.max_new_tokens)
            too_long = (len(req.prompt) + self.new_counts[slot]
                        >= self.max_len - 1)
            if ended or full or too_long:
                done.append(self._finish(slot))
        return done

    def run_until_drained(self, max_steps: int = 10000) -> List[Request]:
        """Serve until queue + slots are empty.  Hitting ``max_steps``
        with requests still in flight is not silent: every live request
        is marked ``status="abandoned"`` (latency fields stay None), the
        slots are reclaimed, and the abandoned list is returned alongside
        the server's ``abandoned`` attribute — callers must report them,
        not average over their ``None`` latencies."""
        out: List[Request] = []
        for _ in range(max_steps):
            out.extend(self.step())
            if not self.active and not self.queue:
                return out
        for slot in sorted(self.active):
            req = self._finish(slot, status=ABANDONED)
            req.latency_s = req.decode_s = None   # never finished
            self.abandoned.append(req)
        while self.queue:
            req = self.queue.popleft()
            req.status = ABANDONED
            self.abandoned.append(req)
        return out
