"""Batched serving with continuous-batching slots.

A fixed decode batch of ``n_slots``; requests are prefilled individually
(disaggregated prefill), inserted into free slots of the live batched cache
(per-sequence positions — slots run at different depths), and decoded
together.  Finished slots free immediately and new requests join without
draining the batch.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (L,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # filled by the server
    output: Optional[List[int]] = None
    latency_s: Optional[float] = None


def _insert_slot(cache, req_cache, slot: int):
    """Copy a single-request cache into batch slot ``slot``."""

    def ins(batched, single):
        if batched.ndim == 1:        # pos: (B,)
            return batched.at[slot].set(single[0])
        # layer leaves: (R, B, ...)
        return jax.lax.dynamic_update_slice_in_dim(
            batched, single, slot, axis=1)

    return jax.tree.map(ins, cache, req_cache)


class Server:
    def __init__(self, params, cfg: T.ArchConfig, n_slots: int = 4,
                 max_len: int = 512,
                 decode_fn: Optional[Callable] = None,
                 greedy: bool = True):
        self.params, self.cfg = params, cfg
        self.n_slots, self.max_len = n_slots, max_len
        self.cache = T.init_cache(cfg, n_slots, max_len)
        self.free = list(range(n_slots))
        self.active: Dict[int, Request] = {}
        self.last_tok = np.zeros((n_slots, 1), np.int32)
        self.new_counts: Dict[int, int] = {}
        self.queue: Deque[Request] = deque()
        self._t0: Dict[int, float] = {}
        self._decode = decode_fn or jax.jit(
            lambda p, c, t: T.decode_step(p, c, t, cfg), donate_argnums=(1,))
        self._prefill = jax.jit(
            lambda p, b: T.prefill(p, b, cfg, max_len),
            static_argnums=())

    # ------------------------------------------------------------- intake
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        while self.free and self.queue:
            req = self.queue.popleft()
            slot = self.free.pop()
            batch = {"tokens": jnp.asarray(req.prompt[None, :], jnp.int32)}
            if self.cfg.vision_prefix:
                batch["patches"] = jnp.zeros(
                    (1, self.cfg.vision_prefix, self.cfg.d_model),
                    self.cfg.dtype)
            if self.cfg.enc_dec:
                batch["frames"] = jnp.zeros(
                    (1, self.cfg.enc_seq, self.cfg.d_model), self.cfg.dtype)
            logits, rc = self._prefill(self.params, batch)
            self.cache = _insert_slot(self.cache, rc, slot)
            first = int(jnp.argmax(logits[0]))
            req.output = [first]
            self.last_tok[slot, 0] = first
            self.active[slot] = req
            self.new_counts[slot] = 1
            self._t0[slot] = time.perf_counter()

    # ------------------------------------------------------------- decode
    def _finish(self, slot: int):
        req = self.active.pop(slot)
        req.latency_s = time.perf_counter() - self._t0.pop(slot)
        self.new_counts.pop(slot)
        self.free.append(slot)
        return req

    def step(self) -> List[Request]:
        """One decode step for all active slots; returns finished requests."""
        self._admit()
        if not self.active:
            return []
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(self.last_tok))
        toks = np.asarray(jnp.argmax(logits, axis=-1))
        done: List[Request] = []
        for slot, req in list(self.active.items()):
            t = int(toks[slot])
            req.output.append(t)
            self.last_tok[slot, 0] = t
            self.new_counts[slot] += 1
            ended = (req.eos_id is not None and t == req.eos_id)
            full = (self.new_counts[slot] >= req.max_new_tokens)
            too_long = (len(req.prompt) + self.new_counts[slot]
                        >= self.max_len - 1)
            if ended or full or too_long:
                done.append(self._finish(slot))
        return done

    def run_until_drained(self, max_steps: int = 10000) -> List[Request]:
        out: List[Request] = []
        for _ in range(max_steps):
            out.extend(self.step())
            if not self.active and not self.queue:
                break
        return out
