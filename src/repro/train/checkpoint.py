"""Checkpointing: zstd-compressed msgpack shards, atomic, async, elastic.

Layout:   <dir>/step_<N>/manifest.msgpack       (tree structure + hashes)
          <dir>/step_<N>/data.msgpack.zst       (leaf bytes)

Properties needed at scale, all implemented here and exercised by tests:
  * atomic publish — written to ``.tmp-...`` then renamed; a crash mid-save
    never corrupts the latest checkpoint;
  * integrity — per-leaf crc32 verified on load;
  * async — a single background writer thread; ``wait()`` drains;
  * keep-last-k garbage collection;
  * elastic restore — leaves are stored as *global* arrays with dtype/shape
    metadata and re-placed under any target sharding/mesh on load (different
    device count than at save time is fine).

On a multi-host deployment the natural extension is per-host shard files
keyed by (leaf, shard-index); the manifest format already carries global
shapes so only the writer changes.
"""
from __future__ import annotations

import dataclasses
import os
import shutil
import threading
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:                         # optional dep: fall back to stdlib zlib when
    import zstandard as zstd  # zstandard isn't installed (dependency-light
except ImportError:           # environments); the manifest records which
    zstd = None               # codec wrote each checkpoint.

DEFAULT_CODEC = "zstd" if zstd is not None else "zlib"


def _compress_fn(codec: str):
    if codec == "zstd":
        return zstd.ZstdCompressor(level=3).compress
    return lambda raw: zlib.compress(raw, 6)


def _decompress_fn(codec: str):
    if codec == "zstd":
        if zstd is None:
            raise ImportError(
                "checkpoint was written with the zstd codec but the "
                "zstandard package is not installed")
        return zstd.ZstdDecompressor().decompress
    return zlib.decompress


def _flatten(tree: Any) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(
            p, "name", p)))) for p in path)
        out.append((key, leaf))
    return out


def save(path: str, step: int, tree: Any,
         meta: Optional[Dict[str, Any]] = None) -> str:
    """Synchronous atomic checkpoint write. Returns final directory."""
    final = os.path.join(path, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)

    leaves = _flatten(tree)
    compress = _compress_fn(DEFAULT_CODEC)
    blobs: Dict[str, bytes] = {}
    manifest = {"step": step, "meta": meta or {}, "leaves": {},
                "codec": DEFAULT_CODEC}
    for key, leaf in leaves:
        arr = np.asarray(leaf)
        raw = arr.tobytes()
        blobs[key] = compress(raw)
        manifest["leaves"][key] = {
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "crc": zlib.crc32(raw),
        }
    with open(os.path.join(tmp, "data.msgpack.zst"), "wb") as f:
        f.write(msgpack.packb(blobs))
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def available_steps(path: str) -> List[int]:
    if not os.path.isdir(path):
        return []
    steps = []
    for d in os.listdir(path):
        if d.startswith("step_") and not d.endswith(".tmp"):
            try:
                steps.append(int(d[5:]))
            except ValueError:
                pass
    return sorted(steps)


def restore(path: str, step: Optional[int] = None,
            target: Any = None, shardings: Any = None
            ) -> Tuple[int, Any, Dict[str, Any]]:
    """Load a checkpoint.

    ``target``: abstract tree (structure + ShapeDtypeStruct leaves) to
    restore into; ``shardings``: matching NamedSharding tree (optional) —
    elastic re-placement happens here via device_put.
    """
    steps = available_steps(path)
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {path}")
    step = step if step is not None else steps[-1]
    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    with open(os.path.join(d, "data.msgpack.zst"), "rb") as f:
        blobs = msgpack.unpackb(f.read())
    # pre-codec checkpoints carry no codec field and are always zstd
    decompress = _decompress_fn(manifest.get("codec", "zstd"))

    arrays: Dict[str, np.ndarray] = {}
    for key, info in manifest["leaves"].items():
        raw = decompress(blobs[key])
        if zlib.crc32(raw) != info["crc"]:
            raise IOError(f"checkpoint corruption in leaf {key}")
        arrays[key] = np.frombuffer(raw, dtype=info["dtype"]).reshape(
            info["shape"])

    if target is None:
        # rebuild a flat dict
        return step, arrays, manifest["meta"]

    flat_target = _flatten(target)
    flat_shard = _flatten(shardings) if shardings is not None else None
    leaves_out = []
    for i, (key, leaf) in enumerate(flat_target):
        arr = arrays[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        arr = arr.astype(leaf.dtype)
        if flat_shard is not None:
            leaves_out.append(jax.device_put(arr, flat_shard[i][1]))
        else:
            leaves_out.append(jnp.asarray(arr))
    treedef = jax.tree_util.tree_structure(target)
    return step, jax.tree_util.tree_unflatten(treedef, leaves_out), \
        manifest["meta"]


class CheckpointManager:
    """Async writer + keep-last-k retention."""

    def __init__(self, path: str, keep: int = 3):
        self.path = path
        self.keep = keep
        os.makedirs(path, exist_ok=True)
        self._lock = threading.Lock()
        self._pending: Optional[threading.Thread] = None

    def save_async(self, step: int, tree: Any,
                   meta: Optional[Dict[str, Any]] = None) -> None:
        # snapshot to host memory *now* (training may mutate buffers after)
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self.wait()

        def work():
            save(self.path, step, host_tree, meta)
            self._gc()

        self._pending = threading.Thread(target=work, daemon=True)
        self._pending.start()

    def save_sync(self, step: int, tree: Any,
                  meta: Optional[Dict[str, Any]] = None) -> None:
        self.wait()
        save(self.path, step, jax.tree.map(lambda x: np.asarray(x), tree),
             meta)
        self._gc()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def latest_step(self) -> Optional[int]:
        steps = available_steps(self.path)
        return steps[-1] if steps else None

    def _gc(self) -> None:
        with self._lock:
            steps = available_steps(self.path)
            for s in steps[:-self.keep]:
                shutil.rmtree(os.path.join(self.path, f"step_{s:08d}"),
                              ignore_errors=True)
