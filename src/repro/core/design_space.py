"""Design space for ARCO co-optimization.

A design space is a set of *knobs*, each with a discrete list of choices
(powers of two bounded by the workload), partitioned across the three agents
exactly as in Table 2 of the paper:

    hardware   agent: tile_b, tile_ci, tile_co   (GEMM-core geometry)
    scheduling agent: h_threading, oc_threading  (work parallelization)
    mapping    agent: tile_h, tile_w             (spatial blocking)

A *configuration* is an int32 vector of per-knob choice indices.  Choice
tables are padded to a fixed width so that value lookup, mutation and fitness
evaluation are all jnp-traceable and vmappable over candidate populations.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.hw import analytical
from repro.hw.tpu_spec import DEFAULT, TpuSpec

AGENTS = ("hardware", "scheduling", "mapping")

# Knob order is fixed; agents own contiguous views via AGENT_KNOBS.
KNOB_NAMES = ("tile_b", "tile_ci", "tile_co", "h_threading", "oc_threading",
              "tile_h", "tile_w")
AGENT_KNOBS: Dict[str, Tuple[int, ...]] = {
    "hardware": (0, 1, 2),
    "scheduling": (3, 4),
    "mapping": (5, 6),
}
N_KNOBS = len(KNOB_NAMES)
MAX_CHOICES = 12  # padded choice-table width


def _pow2_choices(limit: int, lo: int = 1, cap: int = MAX_CHOICES) -> List[int]:
    """Powers of two in [lo, limit]; at most ``cap`` entries (largest kept)."""
    limit = max(int(limit), lo)
    vals = [2 ** e for e in range(0, int(math.log2(limit)) + 1) if 2 ** e >= lo]
    if not vals:
        vals = [lo]
    return vals[-cap:]


@dataclasses.dataclass(frozen=True)
class DesignSpace:
    """Discrete knob space + fitness oracle for one tuning task."""

    knob_names: Tuple[str, ...]
    choices: Tuple[Tuple[int, ...], ...]       # per-knob choice values
    agent_knobs: Dict[str, Tuple[int, ...]]
    workload: Dict[str, int]                   # static task description
    kind: str                                  # "conv2d" | "matmul"
    spec: TpuSpec = DEFAULT
    # per-knob pin mask set by ``pin()``: pinned knobs carry exactly one
    # choice and the MAPPO action heads mask their adjustments out.  None
    # (the default) means no knob was explicitly pinned.
    pinned: Tuple[bool, ...] = None

    # ---------------------------------------------------------- construction
    @staticmethod
    def for_conv2d(workload: Dict[str, int], spec: TpuSpec = DEFAULT) -> "DesignSpace":
        oh, ow, m, n, k = analytical.conv2d_im2col_dims(
            workload["b"], workload["h"], workload["w"], workload["ci"],
            workload["co"], workload["kh"], workload["kw"],
            workload["stride"], workload["pad"])
        choices = (
            tuple(_pow2_choices(workload["b"])),        # tile_b
            tuple(_pow2_choices(workload["ci"])),       # tile_ci
            tuple(_pow2_choices(workload["co"])),       # tile_co
            (1, 2, 4),                                  # h_threading
            (1, 2, 4),                                  # oc_threading
            tuple(_pow2_choices(oh)),                   # tile_h
            tuple(_pow2_choices(ow)),                   # tile_w
        )
        return DesignSpace(KNOB_NAMES, choices, dict(AGENT_KNOBS), dict(workload),
                           "conv2d", spec)

    @staticmethod
    def for_matmul(m: int, n: int, k: int, spec: TpuSpec = DEFAULT) -> "DesignSpace":
        """Matmul task: tile_b/tile_h/tile_w jointly block M; ci->K; co->N."""
        workload = {"m": m, "n": n, "k": k}
        choices = (
            tuple(_pow2_choices(min(m, 256))),          # tile_b   (M blocking)
            tuple(_pow2_choices(k)),                    # tile_ci  (K blocking)
            tuple(_pow2_choices(n)),                    # tile_co  (N blocking)
            (1, 2, 4),                                  # h_threading
            (1, 2, 4),                                  # oc_threading
            tuple(_pow2_choices(min(m, 256))),          # tile_h   (M blocking)
            (1,),                                       # tile_w unused
        )
        return DesignSpace(KNOB_NAMES, choices, dict(AGENT_KNOBS), workload,
                           "matmul", spec)

    # ------------------------------------------------------------ properties
    @property
    def n_knobs(self) -> int:
        return len(self.knob_names)

    @property
    def n_choices(self) -> np.ndarray:
        return np.array([len(c) for c in self.choices], np.int32)

    @property
    def size(self) -> int:
        return int(np.prod([len(c) for c in self.choices]))

    def choice_table(self) -> jnp.ndarray:
        """(n_knobs, MAX_CHOICES) float table, padded with the last value."""
        tab = np.zeros((self.n_knobs, MAX_CHOICES), np.float32)
        for i, ch in enumerate(self.choices):
            padded = list(ch) + [ch[-1]] * (MAX_CHOICES - len(ch))
            tab[i] = padded
        return jnp.asarray(tab)

    # ------------------------------------------------------- config handling
    def values(self, config: jnp.ndarray) -> jnp.ndarray:
        """config (..., n_knobs) int -> knob values (..., n_knobs) float."""
        tab = self.choice_table()
        return jax.vmap(lambda c: tab[jnp.arange(self.n_knobs), c])(
            config.reshape(-1, self.n_knobs)).reshape(*config.shape)

    def random_configs(self, rng: jax.Array, n: int) -> jnp.ndarray:
        maxc = jnp.asarray(self.n_choices)
        u = jax.random.uniform(rng, (n, self.n_knobs))
        return (u * maxc).astype(jnp.int32)

    def clip(self, config: jnp.ndarray) -> jnp.ndarray:
        return jnp.clip(config, 0, jnp.asarray(self.n_choices) - 1)

    def apply_deltas(self, config: jnp.ndarray, deltas: jnp.ndarray) -> jnp.ndarray:
        """Apply per-knob {-1,0,+1} adjustments with bound clipping."""
        return self.clip(config + deltas.astype(jnp.int32))

    def neighbor(self, rng: jax.Array, config: jnp.ndarray) -> jnp.ndarray:
        """Single random ±1 move on one random knob (for SA baselines)."""
        k_rng, d_rng = jax.random.split(rng)
        knob = jax.random.randint(k_rng, (), 0, self.n_knobs)
        delta = jax.random.choice(d_rng, jnp.asarray([-1, 1], jnp.int32))
        return self.clip(config.at[knob].add(delta))

    # ---------------------------------------------------------------- pinning
    def pinned_mask(self) -> np.ndarray:
        """(n_knobs,) bool — knobs frozen by ``pin()`` (all False if none)."""
        if self.pinned is None:
            return np.zeros(self.n_knobs, bool)
        return np.asarray(self.pinned, bool)

    def nearest_choice(self, knob: int, value: float) -> int:
        """Index of the choice closest to ``value`` in log2 distance (knob
        tables are powers of two, so log-space nearest is the natural
        rounding — an oversized value clamps to the largest choice)."""
        vals = np.asarray(self.choices[knob], np.float64)
        return int(np.argmin(np.abs(np.log2(np.maximum(vals, 1e-9))
                                    - math.log2(max(float(value), 1e-9)))))

    def pin(self, knob_idxs: Sequence[int],
            values: Sequence[float]) -> "DesignSpace":
        """Freeze knobs at fixed *values*: each pinned knob's choice list
        collapses to the single nearest available choice, so the search
        space shrinks multiplicatively and the MAPPO action heads mask the
        pinned adjustments out (``mappo.EnvParams.pinned``).

        A value outside a knob's table clamps to the nearest choice — e.g.
        a network-wide ``tile_ci=64`` on a 3-input-channel layer pins to
        that layer's largest feasible Ci-tile (the layer underutilizes the
        shared accelerator dimension).  Pinning composes: already-pinned
        knobs stay pinned.
        """
        choices = list(self.choices)
        pinned = [bool(x) for x in self.pinned_mask()]
        for k, v in zip(knob_idxs, values):
            k = int(k)
            choices[k] = (self.choices[k][self.nearest_choice(k, v)],)
            pinned[k] = True
        return dataclasses.replace(self, choices=tuple(choices),
                                   pinned=tuple(pinned))

    # --------------------------------------------------------------- fitness
    def latency_fn(self) -> Callable[[jnp.ndarray], Tuple[jnp.ndarray, jnp.ndarray]]:
        """Return jnp fn: knob values (n_knobs,) -> (latency_s, vmem_bytes).

        This is the *measurement oracle* (the VTA++-simulator analog).
        """
        wl, spec, kind = self.workload, self.spec, self.kind

        if kind == "conv2d":
            def f(v):
                return analytical.conv2d_latency(
                    wl, v[0], v[5], v[6], v[1], v[2], v[3], v[4], spec=spec)
        elif kind == "matmul":
            def f(v):
                return analytical.gemm_latency(
                    wl["m"], wl["n"], wl["k"],
                    v[0] * v[5], v[2], v[1], v[3], v[4], spec=spec)
        else:  # pragma: no cover
            raise ValueError(f"unknown kind {kind}")
        return f

    def measure(self, configs: jnp.ndarray) -> jnp.ndarray:
        """Batched oracle measurement: (n, n_knobs) int -> latency (n,)."""
        vals = self.values(configs)
        lat, _ = jax.vmap(self.latency_fn())(vals)
        return lat

    def fitness(self, configs: jnp.ndarray) -> jnp.ndarray:
        """f = 1/latency (throughput-style fitness, higher is better)."""
        return 1.0 / self.measure(configs)

    # ------------------------------------------------------------- features
    def workload_features(self) -> np.ndarray:
        """Static normalized log2 features describing the task (len 11)."""
        wl = self.workload
        if self.kind == "conv2d":
            oh, ow, m, n, k = analytical.conv2d_im2col_dims(
                wl["b"], wl["h"], wl["w"], wl["ci"], wl["co"], wl["kh"],
                wl["kw"], wl["stride"], wl["pad"])
            raw = [wl["b"], wl["h"], wl["w"], wl["ci"], wl["co"], wl["kh"],
                   wl["kw"], wl["stride"], m, n, k]
        else:
            m, n, k = wl["m"], wl["n"], wl["k"]
            raw = [1, 1, 1, k, n, 1, 1, 1, m, n, k]
        return (np.log2(np.maximum(np.array(raw, np.float32), 1.0)) / 16.0)

    def feature_vector(self, configs: jnp.ndarray) -> jnp.ndarray:
        """GBT features: log2 knob values ++ workload features, (..., 18)."""
        v = jnp.log2(jnp.maximum(self.values(configs), 1.0)) / 16.0
        wf = jnp.broadcast_to(jnp.asarray(self.workload_features()),
                              (*configs.shape[:-1], 11))
        return jnp.concatenate([v, wf], axis=-1)


def reward_with_penalty(latency: jnp.ndarray, vmem: jnp.ndarray,
                        spec: TpuSpec = DEFAULT,
                        lam: float = 1e-7) -> jnp.ndarray:
    """Eq. 5: R = 1/exec_time - P(theta), with Eq. 4 hinge penalties.

    ``area`` maps to VMEM footprint (on-chip resource), ``memory`` to HBM.
    Latency is clamped so infeasible (inf) measurements give ~0 base reward.
    """
    base = 1.0 / jnp.maximum(latency, 1e-9)
    pen = lam * (jnp.maximum(vmem - spec.vmem_bytes, 0.0))
    return base - pen
