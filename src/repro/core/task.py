"""Tuning-task extraction — the compiler front half.

Walks a model definition, emits one ``DesignSpace`` per convolution layer
(deduplicated by workload shape, with layer multiplicity retained so network
latency sums correctly), mirroring how TVM extracts tuning tasks per op.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from repro.core.design_space import DesignSpace
from repro.hw.tpu_spec import DEFAULT, TpuSpec
from repro.models import cnn


@dataclasses.dataclass(frozen=True)
class Task:
    name: str               # representative layer name
    space: DesignSpace
    multiplicity: int       # how many layers share this workload
    layer_names: Tuple[str, ...]


def conv_tasks(model: str, batch: int = 1,
               spec: TpuSpec = DEFAULT) -> List[Task]:
    """Unique conv tuning tasks for a network (counts match Table 3 before
    dedup; dedup only merges *identical* workloads, as AutoTVM does)."""
    specs = cnn.conv_specs(model)
    groups: Dict[Tuple, List[str]] = {}
    order: List[Tuple] = []
    for s in specs:
        key = tuple(sorted(s.workload(batch).items()))
        if key not in groups:
            groups[key] = []
            order.append(key)
        groups[key].append(s.name)
    tasks = []
    for key in order:
        wl = dict(key)
        names = groups[key]
        tasks.append(Task(
            name=f"{model}:{names[0]}",
            space=DesignSpace.for_conv2d(wl, spec),
            multiplicity=len(names),
            layer_names=tuple(names),
        ))
    return tasks


def total_conv_layers(model: str) -> int:
    return len(cnn.conv_specs(model))


def network_latency(tasks: List[Task], best_latency: Dict[str, float]) -> float:
    """Sum of per-layer latencies given per-task best results (seconds)."""
    return sum(best_latency[t.name] * t.multiplicity for t in tasks)


def network_flops(model: str, batch: int = 1) -> float:
    return sum(s.flops(batch) for s in cnn.conv_specs(model))
