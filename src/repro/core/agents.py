"""The three ARCO agents (Table 1/2) — observation & action encodings + nets.

Networks follow §4.1 exactly:
  policy  (per agent): 1 hidden layer, 20 neurons, ReLU; softmax output head
  critic  (shared)   : 3 hidden layers, 20 neurons each, tanh; scalar output

Each agent owns a subset of the 7 knobs and acts with a categorical action
over joint per-knob {-1, 0, +1} adjustments (3^k actions for k knobs).
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.design_space import AGENT_KNOBS, AGENTS, N_KNOBS

N_WFEAT = 11  # workload feature length (design_space.workload_features)

AGENT_N_KNOBS: Dict[str, int] = {a: len(k) for a, k in AGENT_KNOBS.items()}
AGENT_N_ACTIONS: Dict[str, int] = {a: 3 ** n for a, n in AGENT_N_KNOBS.items()}
AGENT_OBS_DIM: Dict[str, int] = {a: n + N_WFEAT for a, n in AGENT_N_KNOBS.items()}
STATE_DIM = N_KNOBS + N_WFEAT


def _dense_init(rng, n_in, n_out, scale=None):
    scale = scale if scale is not None else float(np.sqrt(2.0 / n_in))
    w_rng, _ = jax.random.split(rng)
    return {"w": jax.random.normal(w_rng, (n_in, n_out), jnp.float32) * scale,
            "b": jnp.zeros((n_out,), jnp.float32)}


def init_policy(rng, obs_dim: int, n_actions: int, hidden: int = 20):
    r1, r2 = jax.random.split(rng)
    return {"h": _dense_init(r1, obs_dim, hidden),
            "out": _dense_init(r2, hidden, n_actions, scale=0.01)}


def init_critic(rng, state_dim: int, hidden: int = 20):
    rs = jax.random.split(rng, 4)
    return {"h1": _dense_init(rs[0], state_dim, hidden),
            "h2": _dense_init(rs[1], hidden, hidden),
            "h3": _dense_init(rs[2], hidden, hidden),
            "out": _dense_init(rs[3], hidden, 1, scale=0.01)}


def policy_logits(params, obs: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.relu(obs @ params["h"]["w"] + params["h"]["b"])
    return h @ params["out"]["w"] + params["out"]["b"]


def critic_value(params, state: jnp.ndarray) -> jnp.ndarray:
    h = jnp.tanh(state @ params["h1"]["w"] + params["h1"]["b"])
    h = jnp.tanh(h @ params["h2"]["w"] + params["h2"]["b"])
    h = jnp.tanh(h @ params["h3"]["w"] + params["h3"]["b"])
    return (h @ params["out"]["w"] + params["out"]["b"])[..., 0]


def init_marl_params(rng) -> Dict:
    rs = jax.random.split(rng, len(AGENTS) + 1)
    params = {a: init_policy(rs[i], AGENT_OBS_DIM[a], AGENT_N_ACTIONS[a])
              for i, a in enumerate(AGENTS)}
    params["critic"] = init_critic(rs[-1], STATE_DIM)
    return params


# ---------------------------------------------------------------- encodings

def knob_positions(config: jnp.ndarray, n_choices: jnp.ndarray) -> jnp.ndarray:
    """Normalized knob positions in [0,1]; config (..., N_KNOBS) int."""
    denom = jnp.maximum(n_choices.astype(jnp.float32) - 1.0, 1.0)
    return config.astype(jnp.float32) / denom


def local_obs(agent: str, config: jnp.ndarray, n_choices: jnp.ndarray,
              wfeat: jnp.ndarray) -> jnp.ndarray:
    pos = knob_positions(config, n_choices)
    own = pos[..., jnp.asarray(AGENT_KNOBS[agent])]
    wf = jnp.broadcast_to(wfeat, (*config.shape[:-1], N_WFEAT))
    return jnp.concatenate([own, wf], axis=-1)


def global_state(config: jnp.ndarray, n_choices: jnp.ndarray,
                 wfeat: jnp.ndarray) -> jnp.ndarray:
    pos = knob_positions(config, n_choices)
    wf = jnp.broadcast_to(wfeat, (*config.shape[:-1], N_WFEAT))
    return jnp.concatenate([pos, wf], axis=-1)


def decode_action(agent: str, action: jnp.ndarray) -> jnp.ndarray:
    """Categorical action -> per-knob deltas in {-1,0,+1}, (..., k)."""
    k = AGENT_N_KNOBS[agent]
    digits = []
    a = action
    for _ in range(k):
        digits.append(a % 3 - 1)
        a = a // 3
    return jnp.stack(digits[::-1], axis=-1).astype(jnp.int32)


def delta_table(agent: str) -> np.ndarray:
    """Static (n_actions, k) table of the per-knob deltas each categorical
    action decodes to (same base-3 encoding as ``decode_action``)."""
    k = AGENT_N_KNOBS[agent]
    a = np.arange(AGENT_N_ACTIONS[agent])
    digits = []
    for _ in range(k):
        digits.append(a % 3 - 1)
        a = a // 3
    return np.stack(digits[::-1], axis=-1).astype(np.int32)


def action_mask(agent: str, pinned: jnp.ndarray) -> jnp.ndarray:
    """(n_actions,) bool — actions that move no *pinned* knob.

    Pinned-subspace action heads: on a ``DesignSpace.pin``-ed task the
    owning agent's head is masked down to the joint adjustments of its
    unpinned knobs (an all-pinned agent keeps exactly the no-op action),
    so exploration and entropy are spent only where the space can move.
    ``pinned`` is a traced (N_KNOBS,) bool array — shapes stay static, a
    single compilation serves pinned and unpinned tasks alike.
    """
    tab = jnp.asarray(delta_table(agent))               # (A, k) static
    own = pinned[jnp.asarray(AGENT_KNOBS[agent])]       # (k,) traced
    return jnp.all((tab == 0) | ~own, axis=-1)


def masked_policy_logits(agent: str, params, obs: jnp.ndarray,
                         pinned: jnp.ndarray) -> jnp.ndarray:
    """Policy logits with pinned-knob actions masked to -1e9 (a finite
    sentinel: softmax underflows it to exactly 0 without inf*0 NaNs)."""
    logits = policy_logits(params, obs)
    return jnp.where(action_mask(agent, pinned), logits, -1e9)


def combined_deltas(actions: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """Merge per-agent deltas into a full (..., N_KNOBS) delta vector."""
    shape = actions[AGENTS[0]].shape
    out = jnp.zeros((*shape, N_KNOBS), jnp.int32)
    for agent in AGENTS:
        d = decode_action(agent, actions[agent])
        out = out.at[..., jnp.asarray(AGENT_KNOBS[agent])].set(d)
    return out
