"""Baseline tuners the paper compares against.

* ``random_tune``     — uniform random search (sanity floor).
* ``autotvm_tune``    — AutoTVM analog: GBT (xgb-reg) cost model + parallel
                        simulated annealing over predicted fitness, measuring
                        the top-b candidates per round (Table 5 setup).
* ``chameleon_tune``  — CHAMELEON analog: single-agent PPO adaptive
                        exploration + K-means adaptive sampling of candidates.

Faithful to §4.1: neither baseline explores *hardware* knobs — they run with
the default accelerator geometry (``default_hardware_config``), exactly as the
paper pins AutoTVM/CHAMELEON to the default VTA++ specification.  ARCO is the
only method allowed to co-optimize the hardware knobs.
"""
from __future__ import annotations

from functools import partial
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.compiler.oracle import AnalyticalOracle, Oracle
from repro.compiler.report import Tracker, TuneReport
from repro.core import agents as A
from repro.core import cost_model as CM
from repro.core import mappo
from repro.core.design_space import (AGENT_KNOBS, DesignSpace, N_KNOBS)
from repro.core.tuner import TunerConfig, unique_seed_batch

HW_KNOBS = np.asarray(AGENT_KNOBS["hardware"])


def default_hardware_config(space: DesignSpace) -> np.ndarray:
    """Default accelerator geometry (the VTA++ default-spec analog).

    MXU-native: K-tile ~256 elements, N-tile ~128, batch tile 1.
    Returns per-knob choice indices for the three hardware knobs.
    """
    wl = space.workload
    khkw = wl.get("kh", 1) * wl.get("kw", 1)
    targets = {0: 1, 1: max(256 // khkw, 1), 2: 128}
    idx = np.zeros(3, np.int64)
    for j, knob in enumerate(HW_KNOBS):
        vals = np.asarray(space.choices[knob], np.float64)
        idx[j] = int(np.argmin(np.abs(np.log2(vals) - np.log2(targets[knob]))))
    return idx


def default_hardware_values(space: DesignSpace) -> np.ndarray:
    """Default accelerator geometry as knob *values* (not choice indices) —
    the form a network-wide shared hardware config takes, since choice
    tables differ per layer but the chip is one."""
    idx = default_hardware_config(space)
    return np.asarray([space.choices[k][i] for k, i in zip(HW_KNOBS, idx)],
                      np.int64)


def hw_pinned_space(space: DesignSpace,
                    values: Optional[np.ndarray] = None) -> DesignSpace:
    """The software-only subspace as a first-class ``DesignSpace``: hardware
    knobs pinned (``DesignSpace.pin``) at ``values`` (default geometry when
    None).  The pinned space shrinks multiplicatively and masks the MAPPO
    hardware head — this is what ``repro.compiler.netopt`` runs per layer
    under each shared hardware candidate."""
    if values is None:
        values = default_hardware_values(space)
    return space.pin(HW_KNOBS, values)


def frozen_mask_and_base(space: DesignSpace) -> Tuple[np.ndarray, np.ndarray]:
    """Index-space view of ``hw_pinned_space``: (frozen mask, base indices)
    for tuners that draw in the *full* space and overwrite the hardware
    slots (keeps their records/configs index-compatible with ARCO's)."""
    frozen = np.zeros(N_KNOBS, bool)
    frozen[HW_KNOBS] = True
    base = np.zeros(N_KNOBS, np.int64)
    base[HW_KNOBS] = default_hardware_config(space)
    return frozen, base


def _random_configs(space: DesignSpace, rng: np.random.Generator, n: int,
                    frozen: Optional[np.ndarray] = None,
                    base: Optional[np.ndarray] = None) -> np.ndarray:
    out = np.stack([rng.integers(0, len(c), size=n) for c in space.choices],
                   axis=1)
    if frozen is not None:
        out[:, frozen] = base[frozen]
    return np.unique(out, axis=0)


def _seed_configs(space: DesignSpace, rng: np.random.Generator, n: int,
                  frozen: Optional[np.ndarray] = None,
                  base: Optional[np.ndarray] = None) -> np.ndarray:
    """Exactly ``n`` distinct seed configs over the *unfrozen* knobs (space
    permitting) — same equal-seed-budget contract as ``ArcoLoop.seed``."""
    free = int(np.prod([len(c) for i, c in enumerate(space.choices)
                        if frozen is None or not frozen[i]]))
    return unique_seed_batch(
        lambda m: _random_configs(space, rng, m, frozen, base), n, free)


# --------------------------------------------------------------------------
# Random search
# --------------------------------------------------------------------------

def random_tune(space: DesignSpace, cfg: TunerConfig = TunerConfig(),
                budget: Optional[int] = None,
                oracle: Optional[Oracle] = None,
                task: str = "") -> TuneReport:
    rng = np.random.default_rng(cfg.seed)
    oracle = oracle or AnalyticalOracle(space, task=task)
    frozen, base = frozen_mask_and_base(space)
    track = Tracker(task)
    budget = budget or cfg.iteration_opt * cfg.b_measure
    while track.count < budget:
        n = min(cfg.b_measure, budget - track.count)
        cand = _random_configs(space, rng, 2 * n, frozen, base)
        cand = np.asarray([c for c in cand if track.is_new(c)])
        if len(cand) == 0:
            break
        cand = cand[:n]
        lat, _ = oracle.measure(cand)
        track.record(cand, lat)
    return track.report(oracle=oracle)


# --------------------------------------------------------------------------
# AutoTVM analog: GBT + parallel simulated annealing
# --------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("n_steps", "n_chains"))
def _sa_search(rng, env: mappo.EnvParams, forest: CM.Forest,
               config0: jnp.ndarray, frozen: jnp.ndarray,
               n_steps: int, n_chains: int):
    """Parallel Metropolis chains maximizing the GBT-predicted fitness."""

    def fitness(c):
        return mappo.surrogate_reward(env, forest, c)

    def step(carry, inp):
        configs, fit, temp = carry
        rng_t = inp
        r1, r2, r3 = jax.random.split(rng_t, 3)
        # propose: one random *unfrozen* knob +-1 per chain
        logits = jnp.where(frozen, -1e9, 0.0)
        knob = jax.random.categorical(r1, jnp.broadcast_to(logits,
                                                           (n_chains, N_KNOBS)))
        delta = jax.random.choice(r2, jnp.asarray([-1, 1], jnp.int32),
                                  (n_chains,))
        prop = configs.at[jnp.arange(n_chains), knob].add(delta)
        prop = jnp.clip(prop, 0, env.n_choices - 1)
        new_fit = fitness(prop)
        accept = jax.random.uniform(r3, (n_chains,)) < jnp.exp(
            jnp.clip((new_fit - fit) / jnp.maximum(temp, 1e-6), -50, 50))
        configs = jnp.where(accept[:, None], prop, configs)
        fit = jnp.where(accept, new_fit, fit)
        return (configs, fit, temp * 0.98), (configs, fit)

    rngs = jax.random.split(rng, n_steps)
    fit0 = fitness(config0)
    (_, _, _), (visited, vfit) = jax.lax.scan(
        step, (config0, fit0, jnp.asarray(1.0)), rngs)
    return visited.reshape(-1, N_KNOBS), vfit.reshape(-1)


def autotvm_tune(space: DesignSpace, cfg: TunerConfig = TunerConfig(),
                 budget: Optional[int] = None,
                 n_chains: int = 64, sa_steps: Optional[int] = None,
                 eps_greedy: float = 0.1,
                 oracle: Optional[Oracle] = None,
                 gbt: Optional[CM.GBTModel] = None,
                 task: str = "") -> TuneReport:
    rng = jax.random.PRNGKey(cfg.seed)
    np_rng = np.random.default_rng(cfg.seed)
    env = mappo.env_params_from_space(space)
    oracle = oracle or AnalyticalOracle(space, task=task)
    gbt = gbt if gbt is not None else CM.GBTModel(n_rounds=cfg.gbt_rounds,
                                                  seed=cfg.seed)
    frozen_np, base = frozen_mask_and_base(space)
    frozen = jnp.asarray(frozen_np)
    track = Tracker(task)
    budget = budget or cfg.iteration_opt * cfg.b_measure
    sa_steps = sa_steps or cfg.mappo.n_steps  # matched search effort

    seed_cfgs = _seed_configs(space, np_rng, min(cfg.b_measure, budget),
                              frozen_np, base)
    lat, feats = oracle.measure(seed_cfgs)
    track.record(seed_cfgs, lat)
    gbt.update(feats, -np.log(np.maximum(lat, 1e-12)))

    while track.count < budget:
        forest = gbt.to_forest()
        rng, r_sa, r_init = jax.random.split(rng, 3)
        c0 = _random_configs(space, np_rng, n_chains, frozen_np, base)
        c0 = np.resize(c0, (n_chains, N_KNOBS))
        visited, vfit = _sa_search(r_sa, env, forest,
                                   jnp.asarray(c0, jnp.int32), frozen,
                                   sa_steps, n_chains)
        visited, vfit = np.asarray(visited), np.asarray(vfit)
        order = np.argsort(-vfit)
        n_meas = min(cfg.b_measure, budget - track.count)
        n_rand = int(n_meas * eps_greedy)
        cand: List[np.ndarray] = []
        seen = set(track.seen)
        for i in order:
            t = tuple(visited[i])
            if t not in seen:
                seen.add(t)
                cand.append(visited[i])
            if len(cand) >= n_meas - n_rand:
                break
        rand = _random_configs(space, np_rng, n_rand + 1, frozen_np, base)
        for c in rand:
            if len(cand) >= n_meas:
                break
            if tuple(c) not in seen:
                seen.add(tuple(c))
                cand.append(c)
        if not cand:  # software knob space exhausted
            break
        cand_np = np.asarray(cand[:n_meas]).reshape(-1, N_KNOBS)
        lat, feats = oracle.measure(cand_np)
        track.record(cand_np, lat)
        gbt.update(feats, -np.log(np.maximum(lat, 1e-12)))
    return track.report(oracle=oracle)


# --------------------------------------------------------------------------
# CHAMELEON analog: single-agent PPO + adaptive (K-means) sampling
# --------------------------------------------------------------------------

def _init_single_agent(rng):
    return {"policy": A.init_policy(rng, A.STATE_DIM, N_KNOBS * 3),
            "critic": A.init_critic(jax.random.fold_in(rng, 1), A.STATE_DIM)}


def _factored_logits(params, state):
    return A.policy_logits(params["policy"], state).reshape(
        *state.shape[:-1], N_KNOBS, 3)


@partial(jax.jit, static_argnames=("hp",))
def _chameleon_episode(params, opt_state, rng, env: mappo.EnvParams,
                       forest: CM.Forest, frozen: jnp.ndarray, base: jnp.ndarray,
                       hp: mappo.MappoConfig):
    """Single-agent PPO over the software knobs (factorized 3-way heads)."""

    def step(carry, rng_t):
        config = carry
        state = A.global_state(config, env.n_choices, env.wfeat)
        logits = _factored_logits(params, state)
        a = jax.random.categorical(rng_t, logits, axis=-1)       # (E, K)
        lp = jnp.take_along_axis(jax.nn.log_softmax(logits, -1),
                                 a[..., None], -1)[..., 0].sum(-1)
        deltas = jnp.where(frozen, 0, a - 1)
        new_config = jnp.clip(config + deltas, 0, env.n_choices - 1)
        value = A.critic_value(params["critic"], state)
        reward = mappo.surrogate_reward(env, forest, new_config)
        return new_config, (state, a, lp, value, reward, new_config)

    r_init, r_roll = jax.random.split(rng)
    u = jax.random.uniform(r_init, (hp.n_envs, N_KNOBS))
    config0 = (u * env.n_choices).astype(jnp.int32)
    config0 = jnp.where(frozen, base, config0)
    rngs = jax.random.split(r_roll, hp.n_steps)
    last, (states, acts, lps, values, rewards, configs) = jax.lax.scan(
        step, config0, rngs)
    last_v = A.critic_value(params["critic"],
                            A.global_state(last, env.n_choices, env.wfeat))
    advs, returns = mappo.gae(rewards, values, last_v, hp.gamma,
                              hp.gae_lambda)

    def loss_fn(p):
        adv_n = (advs - advs.mean()) / (advs.std() + 1e-8)
        logits = _factored_logits(p, states)
        lp_all = jax.nn.log_softmax(logits, -1)
        lp = jnp.take_along_axis(lp_all, acts[..., None], -1)[..., 0].sum(-1)
        ratio = jnp.exp(lp - lps)
        pg = jnp.minimum(ratio * adv_n,
                         jnp.clip(ratio, 1 - hp.clip, 1 + hp.clip) * adv_n)
        ent = -jnp.sum(jnp.exp(lp_all) * lp_all, -1).sum(-1).mean()
        v = A.critic_value(p["critic"], states)
        vloss = jnp.mean(jnp.square(v - returns))
        return -pg.mean() + hp.vf_coef * vloss - hp.ent_coef * ent

    from repro.optim.adam import Adam
    opt = Adam(lr=hp.lr, grad_clip_norm=1.0)
    for _ in range(hp.epochs):
        grads = jax.grad(loss_fn)(params)
        params, opt_state = opt.update(grads, opt_state, params)
    return params, opt_state, configs.reshape(-1, N_KNOBS)


def _kmeans(X: np.ndarray, k: int, rng: np.random.Generator,
            iters: int = 10) -> np.ndarray:
    """Lloyd's algorithm; returns the index of the member nearest each
    centroid (CHAMELEON's adaptive-sampling representative selection)."""
    k = min(k, len(X))
    centers = X[rng.choice(len(X), k, replace=False)].astype(np.float64)
    for _ in range(iters):
        d = ((X[:, None, :] - centers[None]) ** 2).sum(-1)
        assign = d.argmin(1)
        for j in range(k):
            pts = X[assign == j]
            if len(pts):
                centers[j] = pts.mean(0)
    d = ((X[:, None, :] - centers[None]) ** 2).sum(-1)
    return np.unique(d.argmin(0))


def chameleon_tune(space: DesignSpace, cfg: TunerConfig = TunerConfig(),
                   budget: Optional[int] = None,
                   oracle: Optional[Oracle] = None,
                   gbt: Optional[CM.GBTModel] = None,
                   task: str = "") -> TuneReport:
    rng = jax.random.PRNGKey(cfg.seed)
    np_rng = np.random.default_rng(cfg.seed)
    env = mappo.env_params_from_space(space)
    params = _init_single_agent(rng)
    from repro.optim.adam import Adam
    opt_state = Adam(lr=cfg.mappo.lr, grad_clip_norm=1.0).init(params)
    oracle = oracle or AnalyticalOracle(space, task=task)
    gbt = gbt if gbt is not None else CM.GBTModel(n_rounds=cfg.gbt_rounds,
                                                  seed=cfg.seed)
    frozen_np, base_np = frozen_mask_and_base(space)
    frozen = jnp.asarray(frozen_np)
    base = jnp.asarray(base_np, jnp.int32)
    track = Tracker(task)
    budget = budget or cfg.iteration_opt * cfg.b_measure

    seed_cfgs = _seed_configs(space, np_rng, min(cfg.b_measure, budget),
                              frozen_np, base_np)
    lat, feats = oracle.measure(seed_cfgs)
    track.record(seed_cfgs, lat)
    gbt.update(feats, -np.log(np.maximum(lat, 1e-12)))

    it = 0
    while track.count < budget:
        it += 1
        forest = gbt.to_forest()
        pool: List[np.ndarray] = []
        for _ in range(cfg.episodes_per_iter):
            rng, r_ep = jax.random.split(rng)
            params, opt_state, visited = _chameleon_episode(
                params, opt_state, r_ep, env, forest, frozen, base, cfg.mappo)
            pool.append(np.asarray(visited))
        pool_np = np.unique(np.concatenate(pool), axis=0)
        pool_np = np.asarray([c for c in pool_np if track.is_new(c)])
        if len(pool_np) == 0:
            pool_np = _random_configs(space, np_rng, cfg.b_measure, frozen_np,
                                      base_np)
            pool_np = np.asarray([c for c in pool_np if track.is_new(c)])
        if len(pool_np) == 0:  # software knob space exhausted
            break
        n_meas = min(cfg.b_measure, budget - track.count)
        # Adaptive sampling: cluster the candidate pool, measure the
        # representative nearest each centroid.
        reps = _kmeans(pool_np.astype(np.float64), n_meas, np_rng)
        cand = pool_np[reps][:n_meas].reshape(-1, N_KNOBS)
        lat, feats = oracle.measure(cand)
        track.record(cand, lat)
        gbt.update(feats, -np.log(np.maximum(lat, 1e-12)))
    return track.report(oracle=oracle)


# --------------------------------------------------------------------------
# Network-level hardware baselines (the netopt comparison points)
# --------------------------------------------------------------------------
# Implemented on the netopt machinery (imported lazily: netopt depends on
# this module for the per-layer tuners, so a module-level import would
# close a cycle).

def network_hw_frozen_tune(tasks, cfg=None, records=None, workers: int = 0,
                           timeout_s=None, name: str = "network",
                           surrogates=None):
    """Network-scope hardware-frozen baseline: ONE shared default
    accelerator geometry for every layer, with the co-optimizer's entire
    per-layer measurement budget spent on software mapping under that
    frozen chip.  The fair comparison for ``repro.compiler.netopt`` — the
    network-scope analog of pinning AutoTVM/CHAMELEON to the default VTA++
    spec (§4.1), run with ARCO's own software agents so only the hardware
    search differs."""
    from repro.compiler.netopt import loop as _netopt
    return _netopt.network_hw_frozen_tune(tasks, cfg=cfg, records=records,
                                          workers=workers,
                                          timeout_s=timeout_s, name=name,
                                          surrogates=surrogates)


def network_random_hw_tune(tasks, cfg=None, n_candidates: int = 4,
                           records=None, workers: int = 0, timeout_s=None,
                           name: str = "network", surrogates=None):
    """Network-scope random-hardware baseline: the same shared-chip
    evaluation loop as netopt but with uniformly drawn hardware candidates
    instead of the GBT + Confidence-Sampling outer search — the ablation
    separating 'searching hardware at all' from 'searching it well'."""
    from repro.compiler.netopt import loop as _netopt
    return _netopt.network_random_hw_tune(tasks, cfg=cfg,
                                          n_candidates=n_candidates,
                                          records=records, workers=workers,
                                          timeout_s=timeout_s, name=name,
                                          surrogates=surrogates)


def network_genetic_hw_tune(tasks, cfg=None, k_chips=None,
                            population: int = 6, records=None,
                            workers: int = 0, timeout_s=None,
                            name: str = "network", surrogates=None):
    """DiGamma-style genetic baseline over the joint (partition,
    hw-tuple) space: the same contiguity-constrained K-chip candidates
    and the same pinned-session evaluator as the co-optimizer, searched
    by tournament selection + crossover + mutation at the same total
    measurement budget — the control that keeps the MARL outer-search
    claim honest at K >= 2 (and an extra baseline at K = 1)."""
    from repro.compiler.netopt import genetic as _genetic
    return _genetic.network_genetic_hw_tune(tasks, cfg=cfg,
                                            k_chips=k_chips,
                                            population=population,
                                            records=records,
                                            workers=workers,
                                            timeout_s=timeout_s, name=name,
                                            surrogates=surrogates)
