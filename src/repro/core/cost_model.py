"""Learned cost models.

``GBTModel`` is the ``modeGBT = xgb-reg`` analog from Table 4/5: a gradient-
boosted ensemble of fixed-depth regression trees, fit in numpy on measured
(configuration, fitness) pairs and exported as dense arrays so predictions are
pure-jnp (and therefore usable *inside* the jitted MARL rollout as the
surrogate reward).

Trees are complete binary trees of depth ``depth``: internal node arrays
(feature index, threshold) plus a leaf-value array.  Degenerate nodes route
everything left with threshold=+inf.  The forest is refit from scratch on all
measurements each tuning iteration (as AutoTVM does), with a fixed number of
rounds so jitted consumers never change shape.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class Forest(NamedTuple):
    """Dense forest representation; all jnp consumers take this."""
    feat: jnp.ndarray    # (T, n_internal) int32
    thresh: jnp.ndarray  # (T, n_internal) float32
    leaf: jnp.ndarray    # (T, n_leaves) float32
    base: jnp.ndarray    # () float32 — mean target
    scale: jnp.ndarray   # () float32 — target std (denormalization)
    lr: jnp.ndarray      # () float32


def empty_forest(n_rounds: int, depth: int, n_features: int) -> Forest:
    n_internal = 2 ** depth - 1
    n_leaves = 2 ** depth
    return Forest(
        feat=jnp.zeros((n_rounds, n_internal), jnp.int32),
        thresh=jnp.full((n_rounds, n_internal), jnp.inf, jnp.float32),
        leaf=jnp.zeros((n_rounds, n_leaves), jnp.float32),
        base=jnp.asarray(0.0, jnp.float32),
        scale=jnp.asarray(1.0, jnp.float32),
        lr=jnp.asarray(1.0, jnp.float32),
    )


def predict(forest: Forest, x: jnp.ndarray) -> jnp.ndarray:
    """Forest prediction. x: (..., n_features) -> (...)."""
    depth = int(np.log2(forest.leaf.shape[-1]))
    n_internal = forest.feat.shape[-1]

    def one_tree(feat, thresh, leaf, xi):
        idx = jnp.zeros((), jnp.int32)

        def step(_, idx):
            go_right = xi[feat[idx]] > thresh[idx]
            return 2 * idx + 1 + go_right.astype(jnp.int32)

        idx = jax.lax.fori_loop(0, depth, step, idx)
        return leaf[idx - n_internal]

    def one_sample(xi):
        vals = jax.vmap(one_tree, in_axes=(0, 0, 0, None))(
            forest.feat, forest.thresh, forest.leaf, xi)
        return forest.base + forest.lr * jnp.sum(vals)

    flat = x.reshape(-1, x.shape[-1])
    out = jax.vmap(one_sample)(flat)
    return out.reshape(x.shape[:-1]) * forest.scale


# --------------------------------------------------------------------------
# numpy-side fitting
# --------------------------------------------------------------------------

def _best_split(Xn: np.ndarray, gn: np.ndarray, min_leaf: int):
    """Vectorized exact split search: sort + prefix sums per feature.

    Returns (gain, feature, threshold) or (0, None, None).
    SSE decomposition: sse = sum(g^2) - sum(g)^2/n per side.
    """
    n = len(gn)
    parent_sse = float(np.sum(gn * gn) - gn.sum() ** 2 / n)
    best_gain, best_f, best_t = 0.0, None, None
    for f in range(Xn.shape[1]):
        col = Xn[:, f]
        order = np.argsort(col, kind="stable")
        cs, gs = col[order], gn[order]
        csum = np.cumsum(gs)
        csum2 = np.cumsum(gs * gs)
        # valid split after position i (left = [0..i]) where value changes
        nl = np.arange(1, n)
        valid = (cs[1:] != cs[:-1]) & (nl >= min_leaf) & (n - nl >= min_leaf)
        if not valid.any():
            continue
        sl, sl2 = csum[:-1], csum2[:-1]
        sr, sr2 = csum[-1] - sl, csum2[-1] - sl2
        sse = (sl2 - sl * sl / nl) + (sr2 - sr * sr / (n - nl))
        sse = np.where(valid, sse, np.inf)
        i = int(np.argmin(sse))
        gain = parent_sse - float(sse[i])
        if gain > best_gain:
            best_gain, best_f = gain, f
            best_t = float((cs[i] + cs[i + 1]) / 2.0)
    return best_gain, best_f, best_t


def _fit_tree(X: np.ndarray, g: np.ndarray, depth: int, min_leaf: int = 4,
              rng: Optional[np.random.Generator] = None):
    """Greedy SSE regression tree on residuals g; returns dense arrays."""
    n_internal = 2 ** depth - 1
    n_leaves = 2 ** depth
    feat = np.zeros(n_internal, np.int32)
    thresh = np.full(n_internal, np.inf, np.float32)
    leaf = np.zeros(n_leaves, np.float32)

    # node -> sample indices; process level by level
    node_samples = {0: np.arange(len(g))}
    for node in range(n_internal):
        idx = node_samples.get(node, np.array([], np.int64))
        left, right = 2 * node + 1, 2 * node + 2
        if len(idx) < 2 * min_leaf:
            node_samples[left] = idx
            node_samples[right] = np.array([], np.int64)
            continue
        Xn, gn = X[idx], g[idx]
        gain, f, t = _best_split(Xn, gn, min_leaf)
        if f is None:
            node_samples[left] = idx
            node_samples[right] = np.array([], np.int64)
            continue
        feat[node] = f
        thresh[node] = t
        mask = Xn[:, f] <= t
        node_samples[left] = idx[mask]
        node_samples[right] = idx[~mask]

    for l in range(n_leaves):
        idx = node_samples.get(n_internal + l, np.array([], np.int64))
        leaf[l] = float(g[idx].mean()) if len(idx) else 0.0
    return feat, thresh, leaf


@dataclasses.dataclass
class GBTModel:
    """xgb-reg analog.  Fit in numpy, predict in jnp via ``to_forest()``."""

    n_rounds: int = 40
    depth: int = 4
    learning_rate: float = 0.15
    n_features: int = 18
    seed: int = 0

    def __post_init__(self):
        self._forest = empty_forest(self.n_rounds, self.depth, self.n_features)
        self._X: Optional[np.ndarray] = None
        self._y: Optional[np.ndarray] = None

    @property
    def n_samples(self) -> int:
        return 0 if self._X is None else len(self._X)

    def update(self, X: np.ndarray, y: np.ndarray) -> None:
        """Append measurements and refit from scratch (constant shapes)."""
        X = np.asarray(X, np.float32).reshape(-1, self.n_features)
        y = np.asarray(y, np.float32).reshape(-1)
        if self._X is None:
            self._X, self._y = X, y
        else:
            self._X = np.concatenate([self._X, X])
            self._y = np.concatenate([self._y, y])
        self._fit()

    def _fit(self) -> None:
        X, y = self._X, self._y
        scale = float(y.std()) or 1.0
        yn = (y - y.mean()) / scale
        base = 0.0
        pred = np.zeros_like(yn)
        feats, threshs, leaves = [], [], []
        rng = np.random.default_rng(self.seed)
        for _ in range(self.n_rounds):
            resid = yn - pred
            f, t, l = _fit_tree(X, resid, self.depth, rng=rng)
            feats.append(f)
            threshs.append(t)
            leaves.append(l)
            # dense re-predict via numpy traversal
            pred += self.learning_rate * _np_tree_predict(f, t, l, X, self.depth)
        self._forest = Forest(
            feat=jnp.asarray(np.stack(feats)),
            thresh=jnp.asarray(np.stack(threshs)),
            leaf=jnp.asarray(np.stack(leaves)),
            base=jnp.asarray(float(y.mean() / scale), jnp.float32),
            scale=jnp.asarray(scale, jnp.float32),
            lr=jnp.asarray(self.learning_rate, jnp.float32),
        )

    def to_forest(self) -> Forest:
        return self._forest

    def predict(self, X: np.ndarray) -> np.ndarray:
        return np.asarray(predict(self._forest, jnp.asarray(X, jnp.float32)))


def _np_tree_predict(feat, thresh, leaf, X, depth):
    n_internal = 2 ** depth - 1
    idx = np.zeros(len(X), np.int64)
    for _ in range(depth):
        f = feat[idx]
        t = thresh[idx]
        go_right = X[np.arange(len(X)), f] > t
        idx = 2 * idx + 1 + go_right.astype(np.int64)
    return leaf[idx - n_internal]
