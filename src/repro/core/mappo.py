"""MAPPO (Multi-Agent PPO) with Centralized Training / Decentralized Execution.

Implements §2.2 of the paper:
  Eq. 1  centralized critic regression to estimated returns
  Eq. 2  Generalized Advantage Estimation
  Eq. 3  per-agent PPO-clip policy objective

The environment is the knob-adjustment process over a ``DesignSpace``:
vectorized across ``n_envs`` parallel configurations, with the *surrogate*
reward supplied by the GBT cost model (the paper uses the cost model as the
stand-in for hardware measurements during exploration; real measurements only
happen on the Confidence-Sampled subset).

Everything — rollout, GAE, PPO epochs — is one jitted function whose shapes
are independent of the tuning task, so a single compilation serves all ~100
conv tasks in an end-to-end network tuning run.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import agents as A
from repro.core import cost_model as CM
from repro.core.design_space import AGENT_KNOBS, AGENTS, DesignSpace, N_KNOBS
from repro.hw.tpu_spec import DEFAULT
from repro.optim.adam import Adam


class EnvParams(NamedTuple):
    """Task description as jnp arrays — shape-stable across tasks."""
    choice_table: jnp.ndarray  # (N_KNOBS, MAX_CHOICES) float32
    n_choices: jnp.ndarray     # (N_KNOBS,) int32
    wfeat: jnp.ndarray         # (N_WFEAT,) float32
    khkw: jnp.ndarray          # () float32 — kernel window area (K-tile factor)
    vmem_limit: jnp.ndarray    # () float32
    penalty_lam: jnp.ndarray   # () float32
    pinned: jnp.ndarray        # (N_KNOBS,) bool — DesignSpace.pin mask


def env_params_from_space(space: DesignSpace, lam: float = 1e-7) -> EnvParams:
    wl = space.workload
    khkw = float(wl.get("kh", 1) * wl.get("kw", 1))
    return EnvParams(
        choice_table=space.choice_table(),
        n_choices=jnp.asarray(space.n_choices),
        wfeat=jnp.asarray(space.workload_features()),
        khkw=jnp.asarray(khkw, jnp.float32),
        vmem_limit=jnp.asarray(float(space.spec.vmem_bytes), jnp.float32),
        penalty_lam=jnp.asarray(lam, jnp.float32),
        pinned=jnp.asarray(space.pinned_mask()),
    )


def config_values(env: EnvParams, config: jnp.ndarray) -> jnp.ndarray:
    return env.choice_table[jnp.arange(N_KNOBS), config]


def config_features(env: EnvParams, config: jnp.ndarray) -> jnp.ndarray:
    """GBT features: log2 knob values ++ workload features, (..., 18)."""
    v = jnp.log2(jnp.maximum(config_values(env, config), 1.0)) / 16.0
    wf = jnp.broadcast_to(env.wfeat, (*config.shape[:-1], A.N_WFEAT))
    return jnp.concatenate([v, wf], axis=-1)


def vmem_estimate(env: EnvParams, config: jnp.ndarray) -> jnp.ndarray:
    """Analytical VMEM footprint (the ``area(theta)`` analog of Eq. 4)."""
    v = config_values(env, config)
    tm = jnp.ceil(v[..., 0] * v[..., 5] * v[..., 6] / 8.0) * 8.0
    tk = jnp.ceil(v[..., 1] * env.khkw / 128.0) * 128.0
    tn = jnp.ceil(v[..., 2] / 128.0) * 128.0
    threads = jnp.maximum(v[..., 3] * v[..., 4], 1.0)
    return threads * (tm * tk + tk * tn) * 2.0 + tm * tn * 4.0


def surrogate_reward(env: EnvParams, forest: CM.Forest,
                     config: jnp.ndarray) -> jnp.ndarray:
    """Eq. 5 with the cost model as the execution-time surrogate.

    The GBT is trained on y = -log(latency), so its prediction is already a
    "higher is better" fitness; the VMEM hinge penalty (Eq. 4) is analytic.
    """
    pred = CM.predict(forest, config_features(env, config))
    pen = env.penalty_lam * jnp.maximum(
        vmem_estimate(env, config) - env.vmem_limit, 0.0)
    return pred - pen


@dataclasses.dataclass(frozen=True)
class MappoConfig:
    n_steps: int = 64          # step_rl (paper: 500)
    n_envs: int = 16           # parallel configurations per episode
    gamma: float = 0.99
    gae_lambda: float = 0.95
    clip: float = 0.2
    lr: float = 7e-4
    vf_coef: float = 1.0
    ent_coef: float = 0.01
    epochs: int = 4


class Trajectory(NamedTuple):
    obs: Dict[str, jnp.ndarray]      # per agent: (T, E, obs_dim)
    actions: Dict[str, jnp.ndarray]  # per agent: (T, E)
    logps: Dict[str, jnp.ndarray]    # per agent: (T, E)
    states: jnp.ndarray              # (T, E, STATE_DIM)
    values: jnp.ndarray              # (T, E)
    rewards: jnp.ndarray             # (T, E)
    configs: jnp.ndarray             # (T, E, N_KNOBS) — visited configs
    last_value: jnp.ndarray          # (E,)


def rollout(params, rng, env: EnvParams, forest: CM.Forest,
            config0: jnp.ndarray, hp: MappoConfig) -> Trajectory:
    def step(carry, rng_t):
        config = carry
        rngs = jax.random.split(rng_t, len(AGENTS))
        obs, acts, logps = {}, {}, {}
        for i, agent in enumerate(AGENTS):
            o = A.local_obs(agent, config, env.n_choices, env.wfeat)
            logits = A.masked_policy_logits(agent, params[agent], o,
                                            env.pinned)
            a = jax.random.categorical(rngs[i], logits, axis=-1)
            lp = jax.nn.log_softmax(logits, axis=-1)
            obs[agent] = o
            acts[agent] = a
            logps[agent] = jnp.take_along_axis(lp, a[..., None], -1)[..., 0]
        state = A.global_state(config, env.n_choices, env.wfeat)
        value = A.critic_value(params["critic"], state)
        deltas = A.combined_deltas(acts)
        new_config = jnp.clip(config + deltas, 0, env.n_choices - 1)
        reward = surrogate_reward(env, forest, new_config)
        out = (obs, acts, logps, state, value, reward, new_config)
        return new_config, out

    rngs = jax.random.split(rng, hp.n_steps)
    last_config, (obs, acts, logps, states, values, rewards, configs) = \
        jax.lax.scan(step, config0, rngs)
    last_state = A.global_state(last_config, env.n_choices, env.wfeat)
    last_value = A.critic_value(params["critic"], last_state)
    return Trajectory(obs, acts, logps, states, values, rewards, configs,
                      last_value)


def gae(rewards: jnp.ndarray, values: jnp.ndarray, last_value: jnp.ndarray,
        gamma: float, lam: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Eq. 2 — reverse-scan GAE. Returns (advantages, returns)."""
    values_tp1 = jnp.concatenate([values[1:], last_value[None]], axis=0)
    deltas = rewards + gamma * values_tp1 - values

    def back(carry, delta):
        adv = delta + gamma * lam * carry
        return adv, adv

    _, advs = jax.lax.scan(back, jnp.zeros_like(last_value), deltas,
                           reverse=True)
    return advs, advs + values


def ppo_loss(params, traj: Trajectory, advs, returns, env: EnvParams,
             hp: MappoConfig):
    adv_n = (advs - advs.mean()) / (advs.std() + 1e-8)
    total_pg, total_ent = 0.0, 0.0
    for agent in AGENTS:
        # same pinned-action mask as the rollout, so ratios and entropy
        # are computed over the reachable action set only
        logits = A.masked_policy_logits(agent, params[agent],
                                        traj.obs[agent], env.pinned)
        lp_all = jax.nn.log_softmax(logits, axis=-1)
        lp = jnp.take_along_axis(lp_all, traj.actions[agent][..., None],
                                 -1)[..., 0]
        ratio = jnp.exp(lp - traj.logps[agent])
        # Eq. 3 — clipped surrogate
        pg = jnp.minimum(ratio * adv_n,
                         jnp.clip(ratio, 1 - hp.clip, 1 + hp.clip) * adv_n)
        total_pg = total_pg + pg.mean()
        ent = -jnp.sum(jnp.exp(lp_all) * lp_all, axis=-1).mean()
        total_ent = total_ent + ent
    v = A.critic_value(params["critic"], traj.states)
    vloss = jnp.mean(jnp.square(v - returns))  # Eq. 1
    loss = -total_pg + hp.vf_coef * vloss - hp.ent_coef * total_ent
    return loss, {"pg": total_pg, "vloss": vloss, "entropy": total_ent}


@partial(jax.jit, static_argnames=("hp",))
def train_episode(params, opt_state, rng, env: EnvParams, forest: CM.Forest,
                  hp: MappoConfig):
    """One episode: init a set of configurations, rollout, PPO update.

    Returns (params, opt_state, visited_configs (T*E, N_KNOBS), stats).
    """
    r_init, r_roll = jax.random.split(rng)
    u = jax.random.uniform(r_init, (hp.n_envs, N_KNOBS))
    config0 = (u * env.n_choices).astype(jnp.int32)

    traj = rollout(params, r_roll, env, forest, config0, hp)
    advs, returns = gae(traj.rewards, traj.values, traj.last_value,
                        hp.gamma, hp.gae_lambda)

    opt = Adam(lr=hp.lr, grad_clip_norm=1.0)
    stats = {}
    for _ in range(hp.epochs):
        (loss, stats), grads = jax.value_and_grad(ppo_loss, has_aux=True)(
            params, traj, advs, returns, env, hp)
        params, opt_state = opt.update(grads, opt_state, params)
    visited = traj.configs.reshape(-1, N_KNOBS)
    stats = dict(stats, loss=loss, mean_reward=traj.rewards.mean())
    return params, opt_state, visited, stats


def init_state(rng, hp: MappoConfig):
    params = A.init_marl_params(rng)
    opt = Adam(lr=hp.lr, grad_clip_norm=1.0)
    return params, opt.init(params)


def critic_scores(params, env: EnvParams, configs: jnp.ndarray) -> jnp.ndarray:
    """Value-network predictions for a set of configs (used by CS)."""
    state = A.global_state(configs, env.n_choices, env.wfeat)
    return A.critic_value(params["critic"], state)
