"""Confidence Sampling (CS) — Algorithm 2 of the paper.

Replaces uniform/adaptive sampling when choosing which explored
configurations get real (expensive) measurements:

  1. value-network scores for all candidates            (critic predictions)
  2. softmax -> probability distribution; probability-guided selection
  3. dynamic threshold = median of predicted values
  4. low-confidence picks are *replaced by synthesized* configs built from
     each knob's most frequent setting among the sampled configurations

Runs between episodes on small arrays — plain numpy for clarity.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def softmax(x: np.ndarray) -> np.ndarray:
    z = x - x.max()
    e = np.exp(z)
    return e / e.sum()


def select_configurations(probs: np.ndarray, n: int,
                          rng: np.random.Generator) -> np.ndarray:
    """Probability-guided selection (Alg. 2 SelectConfigurations).

    Gumbel top-k == sampling *without* replacement proportional to probs,
    which avoids burning measurement budget on duplicates.
    """
    n = min(n, len(probs))
    g = rng.gumbel(size=len(probs))
    keys = np.log(np.maximum(probs, 1e-12)) + g
    return np.argsort(-keys)[:n]


def compute_dynamic_threshold(v_preds: np.ndarray) -> float:
    return float(np.median(v_preds))


def synthesize(configs: np.ndarray, n_choices: np.ndarray,
               rng: np.random.Generator, n: int) -> np.ndarray:
    """Mode-synthesis: per-knob most frequent setting, with ±1 jitter so
    multiple synthesized configs are not all identical."""
    modes = np.empty(configs.shape[1], np.int64)
    for k in range(configs.shape[1]):
        vals, counts = np.unique(configs[:, k], return_counts=True)
        modes[k] = vals[np.argmax(counts)]
    out = np.tile(modes, (n, 1))
    if n > 1:
        jit = rng.integers(-1, 2, size=out.shape)
        jit[0] = 0  # keep the pure mode config
        out = out + jit
    return np.clip(out, 0, np.asarray(n_choices) - 1)


def confidence_sampling(configs: np.ndarray, v_preds: np.ndarray,
                        n_configs: int, n_choices: np.ndarray,
                        seed: int = 0) -> np.ndarray:
    """Full Algorithm 2. Returns unique configs to measure, <= n_configs."""
    configs = np.asarray(configs)
    v_preds = np.asarray(v_preds, np.float64)
    rng = np.random.default_rng(seed)

    probs = softmax(v_preds)                                   # line 3
    sel = select_configurations(probs, n_configs, rng)         # line 4
    threshold = compute_dynamic_threshold(v_preds)             # line 5
    high = sel[v_preds[sel] > threshold]                       # line 6
    n_low = len(sel) - len(high)

    chosen = configs[high]
    if n_low > 0:                                              # line 7
        basis = configs[high] if len(high) else configs[sel]
        chosen = np.concatenate([chosen, synthesize(basis, n_choices, rng,
                                                    n_low)])
    return np.unique(chosen, axis=0)
