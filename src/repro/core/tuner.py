"""ARCO tuning loop — Fig. 2 / Algorithm 1 of the paper.

Per tuning task (one conv layer / one GEMM / one pod cell):

  repeat iteration_opt times:
    MARL exploration episodes (MAPPO, CTDE) against the GBT surrogate
    Confidence Sampling picks <= b_measure high-confidence configs
    the measurement oracle evaluates them (memoized, record-persisted —
    see ``repro.compiler.oracle``)
    the GBT cost model is refit on all measurements

Total measurement budget matches the paper's setup:
iteration_opt * b_measure ~ Sigma(b_GBT) = 1000 hardware measurements.

The loop is exposed in stepwise form (:class:`ArcoLoop`: ``seed()`` +
``step()``) so ``repro.compiler.Session`` can interleave several tasks over
one *shared* GBT cost model (cross-task transfer via the cell-descriptor
half of the feature vector); ``arco_tune`` is the single-task adapter.

Each step is further split into ``step_submit()`` (MARL explore + CS
select + hand the batch to the oracle, possibly asynchronously) and
``collect()`` (wait for the batch, record it, refit the GBT), so a session
whose oracle measures on a worker pool can run other tasks' MAPPO updates
and GBT refits while this task's compiles are in flight.  With the default
in-process oracle the batch resolves during ``step_submit`` and
``step() == step_submit() + collect()`` reproduces the synchronous loop
exactly.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.compiler.oracle import AnalyticalOracle, Oracle, decode_config
from repro.compiler.report import Tracker, TuneReport
from repro.core import confidence_sampling as CS
from repro.core import mappo
from repro.core.cost_model import GBTModel
from repro.core.design_space import DesignSpace, N_KNOBS

# Backwards-compatible alias: the typed report replaced the old TuneResult.
TuneResult = TuneReport


@dataclasses.dataclass(frozen=True)
class TunerConfig:
    iteration_opt: int = 16        # Table 4
    b_measure: int = 64            # bGBT — measurements per iteration
    episodes_per_iter: int = 8     # episode_rl / iteration_opt
    mappo: mappo.MappoConfig = mappo.MappoConfig()
    gbt_rounds: int = 40
    seed: int = 0
    # Confidence-Sampling batch schedule: iteration t measures
    # round(b_measure * b_growth**(t-1)) configs, floored at
    # b_measure // 8 (>= 1) so a decaying schedule front-loads
    # measurements while the surrogate is weakest and refits more often
    # late WITHOUT degenerating into one-measurement iterations that
    # each pay full MAPPO episodes + a from-scratch GBT refit.  1.0
    # (default) is the paper's constant batch; 0.6 traded best at equal
    # total budget on the conv sweep (see ROADMAP).
    b_growth: float = 1.0

    @staticmethod
    def paper() -> "TunerConfig":
        """Full Table-4 hyper-parameters (episode_rl=128, step_rl=500)."""
        return TunerConfig(iteration_opt=16, b_measure=64,
                           episodes_per_iter=8,
                           mappo=mappo.MappoConfig(n_steps=500, n_envs=16))

    @staticmethod
    def fast() -> "TunerConfig":
        """Scaled-down budget for CPU tests / CI."""
        return TunerConfig(iteration_opt=4, b_measure=16,
                           episodes_per_iter=2,
                           mappo=mappo.MappoConfig(n_steps=24, n_envs=8),
                           gbt_rounds=16)


def unique_seed_batch(draw, n: int, space_size: int) -> np.ndarray:
    """Exactly ``n`` distinct configs (space permitting) from repeated calls
    to ``draw(n)``: unique-dedup may shrink a draw, so fresh draws top the
    batch back up — every method consumes the same seed budget."""
    out = np.unique(np.asarray(draw(n)), axis=0)
    attempts = 0
    while len(out) < min(n, space_size) and attempts < 16:
        out = np.unique(np.concatenate([out, np.asarray(draw(n))]), axis=0)
        attempts += 1
    return out[:n]


class ArcoLoop:
    """Stepwise ARCO on one task: MARL explore -> CS select -> measure ->
    GBT refit.  Oracle and GBT are injectable so a session can share them."""

    def __init__(self, space: DesignSpace, cfg: TunerConfig = TunerConfig(),
                 oracle: Optional[Oracle] = None,
                 gbt: Optional[GBTModel] = None,
                 use_cs: bool = True, task: str = ""):
        self.space = space
        self.cfg = cfg
        self.use_cs = use_cs
        self.oracle = oracle or AnalyticalOracle(space, task=task)
        self.gbt = gbt if gbt is not None else GBTModel(
            n_rounds=cfg.gbt_rounds, seed=cfg.seed)
        self.track = Tracker(task)
        self.rng = jax.random.PRNGKey(cfg.seed)
        self.np_rng = np.random.default_rng(cfg.seed)
        self.env = mappo.env_params_from_space(space)
        self.params, self.opt_state = mappo.init_state(self.rng, cfg.mappo)
        self.it = 0
        self.exhausted = False
        # (configs, PendingBatch) submitted but not yet collected/refit
        self._pending = None

    # ----------------------------------------------------------- async seam
    @property
    def has_pending(self) -> bool:
        return self._pending is not None

    def pending_ready(self) -> bool:
        """True when the in-flight batch (if any) can be collected without
        blocking."""
        return self._pending is None or self._pending[1].ready()

    def collect(self, block: bool = False) -> bool:
        """Finalize the in-flight measurement batch: wait for the oracle,
        record the results, refit the GBT.  Returns False when a batch is
        still in flight and ``block`` is False; True otherwise."""
        if self._pending is None:
            return True
        cfgs, batch = self._pending
        if not block and not batch.ready():
            return False
        t0 = time.perf_counter()
        lat, feats = batch.get()
        self._pending = None
        self.track.add_active(time.perf_counter() - t0)
        self.track.record(cfgs, lat)
        t_fit = time.perf_counter()
        with obs.current().span("surrogate-refit", cat="surrogate",
                                task=self.track.task, n=len(lat)):
            self.gbt.update(feats, -np.log(np.maximum(lat, 1e-12)))
        self.track.add_active(time.perf_counter() - t_fit)
        return True

    # ------------------------------------------------------------ iteration 0
    def seed(self, budget: Optional[int] = None) -> None:
        """Seed the cost model with random measurements (all methods do this
        — an untrained surrogate carries no signal)."""
        self.seed_submit(budget)
        self.collect(block=True)

    def seed_submit(self, budget: Optional[int] = None) -> None:
        """Draw and submit the seed batch; ``collect()`` finalizes it."""
        if self._pending is not None:
            raise RuntimeError("seed_submit with a batch still in flight")
        t_start = time.perf_counter()
        n = self.cfg.b_measure if budget is None else min(
            self.cfg.b_measure, budget)
        first = [True]

        def draw(m):
            if first[0]:  # first draw consumes self.rng unsplit, as before
                first[0] = False
                return self.space.random_configs(self.rng, m)
            self.rng, r = jax.random.split(self.rng)
            return self.space.random_configs(r, m)

        with obs.current().span("seed-draw", cat="select",
                                task=self.track.task, n=int(n)):
            cfgs = unique_seed_batch(draw, n, self.space.size)
        batch = self.oracle.measure_async(cfgs)
        self.track.add_active(time.perf_counter() - t_start)
        self._pending = (cfgs, batch)

    # -------------------------------------------------------- one iteration
    def step(self, budget: int) -> bool:
        """One synchronous optimization iteration; returns False once the
        search space is exhausted (nothing new to measure)."""
        out = self.step_submit(budget)
        self.collect(block=True)
        return out

    def step_submit(self, budget: int) -> bool:
        """The explore/select half of one iteration: MAPPO episodes, CS
        candidate selection, submit the batch to the oracle.  Returns False
        once the search space is exhausted."""
        if self._pending is not None:
            raise RuntimeError("step_submit with a batch still in flight")
        if self.exhausted or self.track.count >= budget:
            return not self.exhausted
        t_start = time.perf_counter()
        self.it += 1
        cfg = self.cfg
        with obs.current().span("mappo-update", cat="mappo",
                                task=self.track.task, it=self.it):
            forest = self.gbt.to_forest()
            pool = []
            for _ in range(cfg.episodes_per_iter):
                self.rng, r_ep = jax.random.split(self.rng)
                self.params, self.opt_state, visited, _stats = \
                    mappo.train_episode(self.params, self.opt_state, r_ep,
                                        self.env, forest, cfg.mappo)
                pool.append(np.asarray(visited))
            pool_np = np.unique(np.concatenate(pool), axis=0)

        # Confidence Sampling over the explored pool (critic-scored)
        scores = np.asarray(mappo.critic_scores(
            self.params, self.env, jnp.asarray(pool_np, jnp.int32)))
        b_floor = max(cfg.b_measure // 8, 1)
        b_sched = max(b_floor, int(round(cfg.b_measure
                                         * cfg.b_growth ** (self.it - 1))))
        n_meas = min(b_sched, budget - self.track.count)
        if self.use_cs:
            cand = CS.confidence_sampling(pool_np, scores, n_meas,
                                          self.space.n_choices,
                                          seed=cfg.seed + self.it)
        else:  # ablation: uniform sampling from the explored pool (Fig. 4a)
            idx = self.np_rng.choice(len(pool_np),
                                     min(n_meas, len(pool_np)),
                                     replace=False)
            cand = pool_np[idx]
        # drop configs this run already measured; top up from the pool
        cand_list = [c for c in cand if self.track.is_new(c)]
        if len(cand_list) < n_meas:
            seen = {tuple(c) for c in cand_list}
            for c in pool_np[np.argsort(-scores)]:
                if self.track.is_new(c) and tuple(c) not in seen:
                    seen.add(tuple(c))
                    cand_list.append(c)
                if len(cand_list) >= n_meas:
                    break
        if not cand_list:  # search space exhausted
            self.exhausted = True
            self.track.add_active(time.perf_counter() - t_start)
            return False
        cand = np.asarray(cand_list[:n_meas], np.int64).reshape(-1, N_KNOBS)

        batch = self.oracle.measure_async(cand)
        self.track.add_active(time.perf_counter() - t_start)
        self._pending = (cand, batch)
        return True

    # -------------------------------------------------------------- result
    def report(self) -> TuneReport:
        self.collect(block=True)  # never report around an in-flight batch
        settings = (decode_config(self.space, self.track.best_cfg)
                    if self.track.best_cfg is not None else None)
        return self.track.report(oracle=self.oracle, best_settings=settings)


def arco_tune(space: DesignSpace, cfg: TunerConfig = TunerConfig(),
              budget: Optional[int] = None,
              use_cs: bool = True,
              oracle: Optional[Oracle] = None,
              gbt: Optional[GBTModel] = None,
              task: str = "") -> TuneReport:
    """Tune one task with ARCO. ``budget`` caps total oracle measurements.

    ``use_cs=False`` ablates Confidence Sampling (Fig. 4a): candidates are
    drawn uniformly from the explored pool instead."""
    budget = budget or cfg.iteration_opt * cfg.b_measure
    loop = ArcoLoop(space, cfg, oracle=oracle, gbt=gbt, use_cs=use_cs,
                    task=task)
    loop.seed(budget)
    while loop.track.count < budget:
        if not loop.step(budget):
            break
    return loop.report()


def tune_network(tasks: Dict[str, DesignSpace],
                 tuner=arco_tune, **kw) -> Dict[str, TuneReport]:
    """Tune every (deduplicated) task of a network; returns per-task results."""
    return {name: tuner(space, **kw) for name, space in tasks.items()}
