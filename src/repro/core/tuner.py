"""ARCO tuning loop — Fig. 2 / Algorithm 1 of the paper.

Per tuning task (one conv layer / one GEMM):

  repeat iteration_opt times:
    MARL exploration episodes (MAPPO, CTDE) against the GBT surrogate
    Confidence Sampling picks <= b_measure high-confidence configs
    the measurement oracle (analytical TPU simulator) evaluates them
    the GBT cost model is refit on all measurements

Total measurement budget matches the paper's setup:
iteration_opt * b_measure ~ Sigma(b_GBT) = 1000 hardware measurements.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import confidence_sampling as CS
from repro.core import mappo
from repro.core.cost_model import GBTModel
from repro.core.design_space import DesignSpace, N_KNOBS


@dataclasses.dataclass(frozen=True)
class TunerConfig:
    iteration_opt: int = 16        # Table 4
    b_measure: int = 64            # bGBT — measurements per iteration
    episodes_per_iter: int = 8     # episode_rl / iteration_opt
    mappo: mappo.MappoConfig = mappo.MappoConfig()
    gbt_rounds: int = 40
    seed: int = 0

    @staticmethod
    def paper() -> "TunerConfig":
        """Full Table-4 hyper-parameters (episode_rl=128, step_rl=500)."""
        return TunerConfig(iteration_opt=16, b_measure=64,
                           episodes_per_iter=8,
                           mappo=mappo.MappoConfig(n_steps=500, n_envs=16))

    @staticmethod
    def fast() -> "TunerConfig":
        """Scaled-down budget for CPU tests / CI."""
        return TunerConfig(iteration_opt=4, b_measure=16,
                           episodes_per_iter=2,
                           mappo=mappo.MappoConfig(n_steps=24, n_envs=8),
                           gbt_rounds=16)


@dataclasses.dataclass
class TuneResult:
    best_config: np.ndarray
    best_latency: float
    n_measurements: int
    wall_time_s: float
    # history rows: (measurement_count, best_latency_so_far, wall_time)
    history: List[Tuple[int, float, float]]
    # every measurement in order: (measurement_index, latency)
    measurements: List[Tuple[int, float]]

    def best_gflops(self, space: DesignSpace) -> float:
        from repro.hw import analytical
        if space.kind == "conv2d":
            return analytical.conv2d_gflops(space.workload, self.best_latency)
        m, n, k = (space.workload[d] for d in "mnk")
        return 2.0 * m * n * k / self.best_latency / 1e9


def _measure(space: DesignSpace, configs: np.ndarray
             ) -> Tuple[np.ndarray, np.ndarray]:
    """Oracle measurement + GBT feature extraction."""
    c = jnp.asarray(configs, jnp.int32)
    lat = np.asarray(space.measure(c))
    feats = np.asarray(space.feature_vector(c))
    return lat, feats


class _Tracker:
    """Shared bookkeeping for every tuner (ARCO + baselines)."""

    def __init__(self):
        self.t0 = time.perf_counter()
        self.best_lat = np.inf
        self.best_cfg: Optional[np.ndarray] = None
        self.count = 0
        self.history: List[Tuple[int, float, float]] = []
        self.measurements: List[Tuple[int, float]] = []

    def record(self, configs: np.ndarray, lats: np.ndarray):
        for cfg, lat in zip(configs, lats):
            self.count += 1
            self.measurements.append((self.count, float(lat)))
            if lat < self.best_lat:
                self.best_lat = float(lat)
                self.best_cfg = np.asarray(cfg)
        self.history.append((self.count, self.best_lat,
                             time.perf_counter() - self.t0))

    def result(self) -> TuneResult:
        return TuneResult(self.best_cfg, self.best_lat, self.count,
                          time.perf_counter() - self.t0, self.history,
                          self.measurements)


def arco_tune(space: DesignSpace, cfg: TunerConfig = TunerConfig(),
              budget: Optional[int] = None,
              use_cs: bool = True) -> TuneResult:
    """Tune one task with ARCO. ``budget`` caps total oracle measurements.

    ``use_cs=False`` ablates Confidence Sampling (Fig. 4a): candidates are
    drawn uniformly from the explored pool instead."""
    rng = jax.random.PRNGKey(cfg.seed)
    np_rng = np.random.default_rng(cfg.seed)
    env = mappo.env_params_from_space(space)
    params, opt_state = mappo.init_state(rng, cfg.mappo)
    gbt = GBTModel(n_rounds=cfg.gbt_rounds, seed=cfg.seed)
    track = _Tracker()
    budget = budget or cfg.iteration_opt * cfg.b_measure

    # Iteration 0 seeds the cost model with random measurements (all methods
    # do this — an untrained surrogate carries no signal).
    seed_cfgs = np.asarray(space.random_configs(rng, cfg.b_measure))
    seed_cfgs = np.unique(seed_cfgs, axis=0)
    lat, feats = _measure(space, seed_cfgs)
    track.record(seed_cfgs, lat)
    gbt.update(feats, -np.log(np.maximum(lat, 1e-12)))

    measured = {tuple(c) for c in seed_cfgs}
    it = 0
    while track.count < budget:
        it += 1
        forest = gbt.to_forest()
        pool: List[np.ndarray] = []
        for ep in range(cfg.episodes_per_iter):
            rng, r_ep = jax.random.split(rng)
            params, opt_state, visited, stats = mappo.train_episode(
                params, opt_state, r_ep, env, forest, cfg.mappo)
            pool.append(np.asarray(visited))
        pool_np = np.unique(np.concatenate(pool), axis=0)

        # Confidence Sampling over the explored pool (critic-scored)
        scores = np.asarray(mappo.critic_scores(
            params, env, jnp.asarray(pool_np, jnp.int32)))
        n_meas = min(cfg.b_measure, budget - track.count)
        if use_cs:
            cand = CS.confidence_sampling(pool_np, scores, n_meas,
                                          space.n_choices, seed=cfg.seed + it)
        else:  # ablation: uniform sampling from the explored pool (Fig. 4a)
            idx = np_rng.choice(len(pool_np), min(n_meas, len(pool_np)),
                                replace=False)
            cand = pool_np[idx]
        # drop configs already measured; top up from the remaining pool
        cand_list = [c for c in cand if tuple(c) not in measured]
        if len(cand_list) < n_meas:
            seen = {tuple(c) for c in cand_list}
            for c in pool_np[np.argsort(-scores)]:
                if tuple(c) not in measured and tuple(c) not in seen:
                    seen.add(tuple(c))
                    cand_list.append(c)
                if len(cand_list) >= n_meas:
                    break
        if not cand_list:  # search space exhausted
            break
        cand = np.asarray(cand_list[:n_meas], np.int64).reshape(-1, N_KNOBS)

        lat, feats = _measure(space, cand)
        track.record(cand, lat)
        measured.update(tuple(c) for c in cand)
        gbt.update(feats, -np.log(np.maximum(lat, 1e-12)))
    return track.result()


def tune_network(tasks: Dict[str, DesignSpace],
                 tuner=arco_tune, **kw) -> Dict[str, TuneResult]:
    """Tune every (deduplicated) task of a network; returns per-task results."""
    return {name: tuner(space, **kw) for name, space in tasks.items()}
