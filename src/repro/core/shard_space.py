"""Beyond-paper: ARCO over the *pod-level* execution configuration.

The paper co-optimizes a single accelerator core's geometry.  Here the same
three agents tune the 512-chip execution configuration of an LM cell, with
the expensive "hardware measurement" being a full multi-device lower +
compile + roofline analysis (tens of seconds — exactly the cost profile
Confidence Sampling exists to amortize):

    hardware agent   : model-axis size (TP degree), FSDP on/off,
                       optimizer-moment dtype
    scheduling agent : gradient-accumulation microbatches, remat on/off
    mapping agent    : attention KV-chunk, loss-chunk (sequence blocking)

Fitness = 1 / roofline step time (max of compute/memory/collective terms)
of the compiled cell.  Measurements are memoized — the MARL explorer may
revisit configurations freely.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from repro.core.design_space import AGENT_KNOBS, DesignSpace, KNOB_NAMES

# knob value tables (reusing the 7-slot agent partition of Table 2)
MODEL_AXIS = (4, 8, 16, 32, 64, 128, 256)   # "tile_b" — TP degree
MOMENT_DTYPE = (1, 2)                # "tile_ci"  — 1=bf16 moments, 2=f32
FSDP = (1, 2)                        # "tile_co"  — 1=off, 2=on
GRAD_ACCUM = (1, 2, 4, 8)            # "h_threading"
REMAT = (1, 2)                       # "oc_threading" — 1=off, 2=nested
ATTN_CHUNK = (256, 512, 1024, 2048, 4096)   # "tile_h"
SEQ_PAR = (1, 2)                     # "tile_w"   — Megatron-SP on/off


def knob_values_to_settings(vals: np.ndarray) -> Dict[str, object]:
    return {
        "model_axis": int(vals[0]),
        "moment_dtype": "float32" if int(vals[1]) == 2 else "bfloat16",
        "fsdp": int(vals[2]) == 2,
        "grad_accum": int(vals[3]),
        "remat": int(vals[4]) == 2,
        "attn_chunk": int(vals[5]),
        "sequence_parallel": int(vals[6]) == 2,
    }


@dataclasses.dataclass(frozen=True)
class ShardSpace(DesignSpace):
    """Pod-level configuration space; oracle is a python compile+analyze
    callable (memoized), plugged into the unchanged ARCO tuner."""

    measure_fn: Optional[Callable[[Dict[str, object]], float]] = None
    cell_features: Tuple[float, ...] = ()

    @staticmethod
    def for_cell(arch: str, shape: str,
                 measure_fn: Callable[[Dict[str, object]], float],
                 n_devices: int = 256) -> "ShardSpace":
        from repro.configs import get_config
        from repro.configs.shapes import SHAPES
        cfg = get_config(arch)
        cell = SHAPES[shape]
        grad_accum = GRAD_ACCUM if cell.kind == "train" else (1,)
        choices = (
            tuple(m for m in MODEL_AXIS if m <= n_devices),
            MOMENT_DTYPE, FSDP, grad_accum, REMAT, ATTN_CHUNK, SEQ_PAR,
        )
        feats = (cfg.n_layers, cfg.d_model, cfg.n_heads,
                 max(cfg.d_ff, 1), cfg.vocab, max(cfg.n_experts, 1),
                 cell.seq, cell.global_batch, n_devices,
                 1.0 + (cell.kind == "train"), 1.0)
        return ShardSpace(
            knob_names=KNOB_NAMES, choices=choices,
            agent_knobs=dict(AGENT_KNOBS),
            workload={"m": cell.seq * cell.global_batch,
                      "n": cfg.d_model, "k": cfg.d_model},
            kind="matmul",  # only used for unreached base-class paths
            measure_fn=measure_fn, cell_features=tuple(feats))

    # -------- overrides: python oracle + cell-descriptor features ---------
    def measure(self, configs) -> np.ndarray:  # type: ignore[override]
        configs = np.asarray(configs).reshape(-1, self.n_knobs)
        out = np.empty(len(configs), np.float64)
        for i, c in enumerate(configs):
            vals = np.asarray([self.choices[k][int(c[k])]
                               for k in range(self.n_knobs)], np.float64)
            out[i] = self.measure_fn(knob_values_to_settings(vals))
        return out

    def workload_features(self) -> np.ndarray:  # type: ignore[override]
        return (np.log2(np.maximum(
            np.asarray(self.cell_features, np.float32), 1.0)) / 16.0)
