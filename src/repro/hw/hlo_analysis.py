"""Trip-count-aware analysis of partitioned HLO text.

XLA's ``cost_analysis()`` visits every while-loop body exactly once, so any
rolled construct (``lax.scan`` over layers, KV chunks, loss chunks...) is
undercounted by its trip count.  This module parses the *scheduled* HLO,
builds the computation call graph with ``known_trip_count`` weights, and
produces execution-weighted totals:

  * dot FLOPs (2 * numel(result) * contraction), per-device;
  * collective bytes by op kind, per-device, with ring-algorithm wire
    multipliers (all-reduce 2x);

These are the compute / collective roofline inputs in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8,
                "c128": 16}

_COMP_HEAD = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{")
_INSTR = re.compile(r"^\s+(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_WHILE = re.compile(r"while\(.*?condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_TRIP = re.compile(r'known_trip_count..\{?"?n"?.?[:=]."?(\d+)')
_REF = re.compile(r"(?:calls|to_apply|condition|body|branch_computations)="
                  r"\{?%?([\w\.\-]+(?:,\s*%?[\w\.\-]+)*)\}?")
# operands may print bare (``dot(%a, %b)``) or shape-annotated
# (``dot(f32[8,16]{1,0} %a, ...)``) depending on the XLA version; capture
# the annotation when present so the lhs shape needs no name lookup
_DOT = re.compile(r"\bdot\(\s*(?:([a-z0-9]+\[[0-9,]*\])(?:\{[^}]*\})?\s+)?"
                  r"%?([\w\.\-]+)"
                  r".*?lhs_contracting_dims=\{([0-9,]*)\}")
_COLL = re.compile(r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|"
                   r"collective-permute)(?:-start)?\(")

_WIRE_MULT = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
              "all-to-all": 1.0, "collective-permute": 1.0}


def _first_shape(text: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE.search(text)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return m.group(1), dims


def _all_shapes_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in (dims.split(",") if dims else []):
            n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Computation:
    name: str
    entry: bool
    lines: List[str]


def _split_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.split("\n"):
        if line and not line[0].isspace():
            m = _COMP_HEAD.match(line)
            if m:
                cur = Computation(m.group(2), bool(m.group(1)), [])
                comps[cur.name] = cur
                continue
            cur = None
        elif cur is not None:
            cur.lines.append(line)
    return comps


def _multipliers(comps: Dict[str, Computation]) -> Dict[str, float]:
    """Execution-count multiplier per computation (entry = 1)."""
    edges: List[Tuple[str, str, float]] = []  # (caller, callee, weight)
    for c in comps.values():
        for line in c.lines:
            w = 1.0
            wm = _WHILE.search(line)
            if wm:
                tm = _TRIP.search(line)
                trip = float(tm.group(1)) if tm else 1.0
                edges.append((c.name, wm.group(2), trip))
                edges.append((c.name, wm.group(1), trip + 1.0))
                continue
            rm = _REF.search(line)
            if rm:
                for callee in re.split(r",\s*", rm.group(1)):
                    edges.append((c.name, callee.lstrip("%"), 1.0))
    mult = {name: (1.0 if c.entry else 0.0) for name, c in comps.items()}
    for _ in range(64):  # propagate through the (acyclic) call graph
        new = {name: (1.0 if comps[name].entry else 0.0) for name in comps}
        for caller, callee, w in edges:
            if callee in new and caller in mult:
                new[callee] += mult[caller] * w
        if all(abs(new[k] - mult[k]) < 1e-9 for k in mult):
            break
        mult = new
    return mult


def analyze(hlo: str) -> Dict[str, object]:
    comps = _split_computations(hlo)
    mult = _multipliers(comps)

    flops = 0.0
    coll_bytes: Dict[str, float] = {}
    coll_counts: Dict[str, float] = {}

    for c in comps.values():
        m = mult.get(c.name, 0.0)
        if m == 0.0:
            continue
        # instruction shape table for operand lookup
        shapes: Dict[str, Tuple[str, List[int]]] = {}
        for line in c.lines:
            im = _INSTR.match(line)
            if im:
                sh = _first_shape(im.group(2))
                if sh:
                    shapes[im.group(1)] = sh
        for line in c.lines:
            im = _INSTR.match(line)
            if not im:
                continue
            name, rhs = im.groups()
            dm = _DOT.search(rhs)
            if dm and " dot(" in rhs:
                res = _first_shape(rhs)
                lhs = (_first_shape(dm.group(1)) if dm.group(1)
                       else shapes.get(dm.group(2)))
                if res and lhs:
                    rnum = 1
                    for d in res[1]:
                        rnum *= d
                    k = 1
                    for ci in (dm.group(3).split(",") if dm.group(3)
                               else []):
                        di = int(ci)
                        if di < len(lhs[1]):
                            k *= lhs[1][di]
                    flops += m * 2.0 * rnum * k
            cm = _COLL.search(rhs)
            if cm:
                op = cm.group(1)
                # result shapes only (left side of the op call)
                b = _all_shapes_bytes(rhs.split(op)[0])
                coll_bytes[op] = coll_bytes.get(op, 0.0) + m * b
                coll_counts[op] = coll_counts.get(op, 0.0) + m

    wire = sum(_WIRE_MULT[op] * b for op, b in coll_bytes.items())
    return {
        "weighted_dot_flops": flops,
        "collective_bytes_by_op": coll_bytes,
        "collective_counts": coll_counts,
        "wire_bytes_per_device": wire,
        "n_computations": len(comps),
    }
