"""Roofline terms per (arch x shape x mesh) cell.

Three terms (seconds per step, per the brief):

  compute    = HLO_FLOPs_per_device / peak_FLOP/s
               (trip-count-weighted dot FLOPs parsed from partitioned HLO —
                XLA's cost_analysis visits loop bodies once, see
                hw/hlo_analysis.py)
  memory     = HBM_bytes_per_device / HBM_bw
               (analytic traffic model: CPU-backend buffer numbers include
                f32-promotion artifacts that don't exist on TPU, so HBM
                traffic is modelled from first principles: weight streaming
                per pass, activation saves, KV-cache reads)
  collective = wire_bytes_per_device / ICI_link_bw
               (trip-count-weighted collective bytes, ring multipliers)

Plus MODEL_FLOPS = 6*N_active*D (train) / 2*N_active*tokens (inference) and
the usefulness ratio MODEL_FLOPS / HLO_FLOPS.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.hw.tpu_spec import DEFAULT, TpuSpec
from repro.models.transformer import ArchConfig, abstract_params


def _param_counts(cfg: ArchConfig) -> Dict[str, float]:
    """(total, active) parameter counts; active scales MoE experts to top_k."""
    ab = abstract_params(jax.random.PRNGKey(0), cfg)
    total = sum(float(np.prod(l.shape)) for l in jax.tree.leaves(ab))
    active = total
    if cfg.n_experts and cfg.moe_top_k:
        moe = 0.0
        for p, (mixer, ffn) in enumerate(cfg.pattern):
            if ffn != "moe":
                continue
            stack = ab["layers"][p]["ffn"]
            for name in ("w_gate", "w_up", "w_down"):
                moe += float(np.prod(stack[name].shape))
        active = total - moe * (1.0 - cfg.moe_top_k / cfg.n_experts)
    return {"total": total, "active": active}


def _attn_layers(cfg: ArchConfig) -> int:
    per_period = sum(1 for m, _ in cfg.pattern if m in ("attn", "swa"))
    return per_period * cfg.repeats


def model_flops(cfg: ArchConfig, kind: str, seq: int, batch: int,
                counts: Optional[Dict[str, float]] = None) -> float:
    """Useful model FLOPs for the whole step (all devices)."""
    c = counts or _param_counts(cfg)
    na = c["active"]
    la = _attn_layers(cfg)
    hd = cfg.head_dim * cfg.n_heads
    if kind == "train":
        tokens = batch * seq
        attn = 2.0 * 2.0 * batch * seq * seq * hd * la / 2.0  # causal half
        if cfg.swa_window:
            attn = 2.0 * 2.0 * batch * seq * min(seq, cfg.swa_window) \
                * hd * la
        return 6.0 * na * tokens + 3.0 * attn
    if kind == "prefill":
        tokens = batch * seq
        attn = 2.0 * 2.0 * batch * seq * seq * hd * la / 2.0
        if cfg.swa_window:
            attn = 2.0 * 2.0 * batch * seq * min(seq, cfg.swa_window) \
                * hd * la
        return 2.0 * na * tokens + attn
    # decode: one token per sequence; attends over the whole cache
    ctx = min(seq, cfg.swa_window) if cfg.swa_window else seq
    attn = 2.0 * 2.0 * batch * ctx * hd * la
    return 2.0 * na * batch + attn


def kv_cache_bytes(cfg: ArchConfig, seq: int, batch: int) -> float:
    """Global decode-state bytes (KV caches + recurrent states)."""
    dt = 2.0  # bf16
    total = 0.0
    for mixer, _ in cfg.pattern:
        n = cfg.repeats
        if mixer in ("attn", "swa"):
            s = min(seq, cfg.swa_window) if (mixer == "swa"
                                             and cfg.swa_window) else seq
            total += n * 2 * batch * s * cfg.n_kv_heads * cfg.head_dim * dt
        elif mixer == "mamba":
            di = 2 * cfg.d_model
            total += n * batch * di * (cfg.d_state + 3) * 4.0
        elif mixer in ("mlstm",):
            dh = cfg.head_dim
            total += n * batch * cfg.n_heads * (dh * dh + dh + 1) * 4.0
        elif mixer == "slstm":
            total += n * batch * 4 * cfg.d_model * 4.0
    return total


def memory_traffic(cfg: ArchConfig, kind: str, seq: int, batch: int,
                   mesh: Dict[str, int],
                   counts: Optional[Dict[str, float]] = None) -> float:
    """Per-device HBM bytes per step (analytic TPU model)."""
    c = counts or _param_counts(cfg)
    model_par = mesh.get("model", 1)
    n_dev = int(np.prod(list(mesh.values())))
    dp = n_dev // model_par
    p_use = c["total"] * 2.0 / model_par     # bf16 weights streamed per pass
    b_loc = max(batch // dp, 1)
    act = b_loc * seq * cfg.d_model * 2.0    # one residual-stream tensor
    if kind == "train":
        # fwd read + bwd read + remat re-read of weights; grads write+read;
        # opt m/v read+write (bf16) + param write
        weights = 3.0 * p_use + 4.0 * (c["total"] * 2.0 / n_dev) * 2.0
        # activation saves: one per layer boundary, written + read
        acts = 2.0 * act * cfg.n_layers
        return weights + acts
    if kind == "prefill":
        return p_use + act * 2.0
    # decode: weights once + full cache read, sharded across all devices
    return p_use + kv_cache_bytes(cfg, seq, batch) / n_dev + \
        2.0 * b_loc * cfg.d_model * 2.0 * cfg.n_layers


def hbm_residency(cfg: ArchConfig, kind: str, seq: int, batch: int,
                  mesh: Dict[str, int], *, fsdp: bool = True,
                  moment_dtype: str = "bfloat16", remat: bool = True,
                  grad_accum: int = 1, sequence_parallel: bool = False,
                  counts: Optional[Dict[str, float]] = None) -> float:
    """Modelled steady-state HBM bytes per device (TPU target).

    The Eq.4 'memory(theta)' analog for pod-level configurations: params +
    grads + optimizer moments (sharding-dependent) + activation saves
    (remat-policy-dependent) + a 2 GiB transient allowance.
    """
    c = counts or _param_counts(cfg)
    n_dev = int(np.prod(list(mesh.values())))
    tp = mesh.get("model", 1)
    dp = max(n_dev // tp, 1)
    if kind != "train":
        weights = c["total"] * 2.0 / (tp if not fsdp else n_dev)
        cache = kv_cache_bytes(cfg, seq, batch) / n_dev \
            if kind == "decode" else 0.0
        b_loc = max(batch // dp, 1)
        act = b_loc * seq * cfg.d_model * 2.0 if kind == "prefill" else 0.0
        return weights + cache + 2.0 * act + 2 * 2.0 ** 30
    shards = n_dev if fsdp else tp
    params = c["total"] * 2.0 / shards
    grads = params
    mom = c["total"] * (8.0 if moment_dtype == "float32" else 4.0) / shards
    b_loc = max(batch // dp, 1) / max(grad_accum, 1)
    act = b_loc * seq * cfg.d_model * 2.0
    if sequence_parallel:
        act /= tp   # SP shards the saved residual stream over the TP axis
    acts = (cfg.repeats * act) if remat else (cfg.n_layers * 2.5 * act)
    return params + grads + mom + acts + 2 * 2.0 ** 30


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops: float
    usefulness: float
    step_s: float

    def as_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def analyze_cell(cfg: ArchConfig, kind: str, seq: int, batch: int,
                 mesh: Dict[str, int], artifact: Dict[str, Any],
                 spec: TpuSpec = DEFAULT) -> Roofline:
    """Combine the dry-run artifact with the analytic model."""
    counts = _param_counts(cfg)
    n_dev = int(np.prod(list(mesh.values())))
    flops_dev = float(artifact["weighted"]["dot_flops_per_device"])
    compute_s = flops_dev / spec.peak_bf16_flops
    mem_bytes = memory_traffic(cfg, kind, seq, batch, mesh, counts)
    memory_s = mem_bytes / spec.hbm_bw
    wire = float(artifact["weighted"]["wire_bytes_per_device"])
    collective_s = wire / spec.ici_bw_per_link
    mf = model_flops(cfg, kind, seq, batch, counts)
    hlo_total = flops_dev * n_dev
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    return Roofline(
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops=mf, hlo_flops=hlo_total,
        usefulness=mf / hlo_total if hlo_total else 0.0,
        step_s=max(terms.values()))


def roofline_fraction(r: Roofline, spec: TpuSpec = DEFAULT,
                      n_dev: int = 256) -> float:
    """Achieved fraction of the hardware roofline: useful FLOPs at the
    modelled step time vs peak."""
    if r.step_s <= 0:
        return 0.0
    return (r.model_flops / n_dev / r.step_s) / spec.peak_bf16_flops
