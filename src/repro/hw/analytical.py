"""Analytical TPU latency oracle — the VTA++-simulator analog.

The paper measures candidate configurations on the VTA++ *simulator*; here the
measurement oracle is a deterministic roofline model of a blocked GEMM running
on a TPU v5e core.  It is written in pure jnp over knob *values* so the entire
MARL exploration loop (thousands of candidate evaluations per step) jits and
vectorizes.

Model (classic blocked-GEMM cost with TPU specifics):

  padded compute   ceil-padded tile dims -> MXU passes (128-aligned)
  HBM traffic      A: M*K * n_blocks_N  (A reloaded per N block)
                   B: K*N * n_blocks_M  (B reloaded per M block)
                   C: M*N write (+ k-split accumulation read-modify-write)
  overlap          "threading" (the VTA virtual-thread analog) overlaps DMA
                   with compute: latency = max(comp, mem) when threaded,
                   comp + mem when single-threaded; serial grid overhead is
                   divided by the thread count.
  VMEM             working set = threads * (A_tile + B_tile) + C_tile(fp32);
                   configurations that overflow VMEM are INFEASIBLE (inf).

Feasibility mirrors real hardware, where an oversized tiling fails to compile.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.hw.tpu_spec import DEFAULT, TpuSpec

BF16 = 2.0
F32 = 4.0
_INF = 1e12  # "measurement failed" latency sentinel (seconds)


def _ceil_div(a, b):
    return (a + b - 1) // b


def _pad_to(x, g):
    return _ceil_div(x, g) * g


def gemm_latency(
    m, n, k,
    tile_m, tile_n, tile_k,
    threads_m, threads_n,
    spec: TpuSpec = DEFAULT,
    extra_in_bytes=0.0,
):
    """Latency (s) of an (m,k)x(k,n) bf16 GEMM blocked as (tile_m,tile_n,tile_k).

    All arguments may be python ints or jnp arrays (broadcastable); the result
    is a jnp array so the function can be vmapped over candidate populations.
    ``extra_in_bytes`` charges additional input traffic (e.g. im2col overlap).
    """
    m = jnp.asarray(m, jnp.float32)
    n = jnp.asarray(n, jnp.float32)
    k = jnp.asarray(k, jnp.float32)
    tm = jnp.minimum(jnp.asarray(tile_m, jnp.float32), m)
    tn = jnp.minimum(jnp.asarray(tile_n, jnp.float32), n)
    tk = jnp.minimum(jnp.asarray(tile_k, jnp.float32), k)
    thm = jnp.asarray(threads_m, jnp.float32)
    thn = jnp.asarray(threads_n, jnp.float32)

    gm = jnp.ceil(m / tm)
    gn = jnp.ceil(n / tn)
    gk = jnp.ceil(k / tk)

    # --- compute: MXU passes run on 128-padded tile dims (8-sublane minor-2) ---
    tm_pad = jnp.ceil(tm / 8.0) * 8.0
    tn_pad = jnp.ceil(tn / 128.0) * 128.0
    tk_pad = jnp.ceil(tk / 128.0) * 128.0
    flops_padded = 2.0 * (gm * tm_pad) * (gn * tn_pad) * (gk * tk_pad)
    t_comp = flops_padded / spec.peak_bf16_flops

    # --- HBM traffic of the blocked loop nest ---
    bytes_a = m * k * BF16 * gn          # A streamed once per N block column
    bytes_b = k * n * BF16 * gm          # B streamed once per M block row
    bytes_c = m * n * BF16               # final write
    traffic = bytes_a + bytes_b + bytes_c + jnp.asarray(extra_in_bytes, jnp.float32)
    t_mem = traffic / spec.hbm_bw

    # --- serial overheads: grid sequencing + DMA issue, amortized by threading ---
    grid_steps = gm * gn * gk
    threads = jnp.maximum(thm * thn, 1.0)
    t_overhead = (grid_steps * spec.grid_step_overhead_s
                  + grid_steps * 3.0 * spec.dma_latency_s) / threads

    # --- overlap: threaded => double-buffered DMA hides behind compute ---
    overlapped = jnp.maximum(t_comp, t_mem)
    serial = t_comp + t_mem
    t_core = jnp.where(threads >= 2.0, overlapped, serial)

    latency = t_core + t_overhead

    # --- VMEM feasibility: threads x (A+B tiles, bf16) + accumulator (fp32) ---
    vmem = (threads * (tm_pad * tk_pad + tk_pad * tn_pad) * BF16
            + tm_pad * tn_pad * F32)
    feasible = vmem <= spec.vmem_bytes
    return jnp.where(feasible, latency, _INF), vmem


def conv2d_im2col_dims(b, h, w, ci, co, kh, kw, stride, pad):
    """Output dims + GEMM dims for a conv lowered via im2col (python ints)."""
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (w + 2 * pad - kw) // stride + 1
    m = b * oh * ow
    k = ci * kh * kw
    n = co
    return oh, ow, m, n, k


def conv2d_latency(
    workload,  # dict of python ints: b,h,w,ci,co,kh,kw,stride,pad
    tile_b, tile_h, tile_w, tile_ci, tile_co,
    h_threading, oc_threading,
    spec: TpuSpec = DEFAULT,
):
    """Latency of a conv2d executed as a blocked im2col GEMM.

    The mapping-agent knobs (tile_h, tile_w) + hardware tile_b compose the GEMM
    M-tile; tile_ci (x kh*kw) is the K-tile; tile_co the N-tile — the direct
    analog of VTA's BATCH/BLOCK_IN/BLOCK_OUT GEMM-core geometry.
    """
    b, h, w = workload["b"], workload["h"], workload["w"]
    ci, co = workload["ci"], workload["co"]
    kh, kw = workload["kh"], workload["kw"]
    stride, pad = workload["stride"], workload["pad"]
    oh, ow, m, n, k = conv2d_im2col_dims(b, h, w, ci, co, kh, kw, stride, pad)

    tile_m = (jnp.asarray(tile_b, jnp.float32)
              * jnp.asarray(tile_h, jnp.float32)
              * jnp.asarray(tile_w, jnp.float32))
    tile_k = jnp.asarray(tile_ci, jnp.float32) * float(kh * kw)
    tile_n = jnp.asarray(tile_co, jnp.float32)

    # im2col re-reads overlapping input windows: charge the expansion ratio
    # (kh*kw / stride^2 capped at kh*kw) on the input tensor once.
    expand = min(float(kh * kw) / float(stride * stride), float(kh * kw))
    extra = float(b * h * w * ci) * BF16 * max(expand - 1.0, 0.0)

    lat, vmem = gemm_latency(
        m, n, k, tile_m, tile_n, tile_k,
        h_threading, oc_threading, spec=spec, extra_in_bytes=extra,
    )
    return lat, vmem


def conv2d_gflops(workload, latency_s):
    """Achieved GFLOP/s of a conv at a given latency (Fig. 7 metric)."""
    _, _, m, n, k = conv2d_im2col_dims(
        workload["b"], workload["h"], workload["w"], workload["ci"],
        workload["co"], workload["kh"], workload["kw"], workload["stride"],
        workload["pad"])
    return 2.0 * m * n * k / latency_s / 1e9


def activation_out_bytes(kind: str, workload) -> float:
    """Output-activation footprint (bytes, bf16) of one task — the tensor
    that crosses chips when a pipeline partition cuts right after it.
    Conv outputs are ``b*oh*ow*co``, matmuls ``m*n``; unknown kinds (pod
    shard cells never hand an activation to another accelerator in this
    model) transfer nothing."""
    if kind == "conv2d":
        oh, ow, _, _, _ = conv2d_im2col_dims(
            workload["b"], workload["h"], workload["w"], workload["ci"],
            workload["co"], workload["kh"], workload["kw"],
            workload["stride"], workload["pad"])
        return float(workload["b"] * oh * ow * workload["co"]) * BF16
    if kind == "matmul":
        return float(workload["m"] * workload["n"]) * BF16
    return 0.0


def interchip_transfer_s(n_bytes: float, spec: TpuSpec = DEFAULT) -> float:
    """Time to move one boundary activation between pipeline stages over
    the full ICI bisection (all links striped), plus one DMA issue."""
    return float(n_bytes) / (spec.ici_links * spec.ici_bw_per_link) \
        + spec.dma_latency_s


# Area proxy constants (7nm-class, Accelergy-style orders of magnitude).
# Absolute calibration does not matter: the multi-objective Pareto only
# compares candidate chips built from the same constants.
MAC_AREA_MM2 = 6e-4           # one bf16 MAC + pipeline registers
SRAM_AREA_MM2_PER_MB = 0.45   # tile buffers


def chip_area_mm2(tile_b, tile_ci, tile_co) -> float:
    """Silicon-area proxy of one accelerator config: the GEMM-core MAC
    array (``tile_b * tile_ci * tile_co``) plus double-buffered bf16 tile
    SRAM — the cost axis a heterogeneous partition trades latency against
    (a K-chip partition pays the sum of its chips)."""
    b, ci, co = float(tile_b), float(tile_ci), float(tile_co)
    macs = b * ci * co
    tiles_mb = (b * ci + ci * co + b * co) * 2.0 * BF16 / 2.0 ** 20
    return macs * MAC_AREA_MM2 + tiles_mb * SRAM_AREA_MM2_PER_MB


def conv2d_min_latency(workload, spec: TpuSpec = DEFAULT) -> float:
    """Roofline lower bound for a conv (perfect tiling): max(comp, mem)."""
    _, _, m, n, k = conv2d_im2col_dims(
        workload["b"], workload["h"], workload["w"], workload["ci"],
        workload["co"], workload["kh"], workload["kw"], workload["stride"],
        workload["pad"])
    flops = 2.0 * m * n * k
    bytes_min = (m * k + k * n + m * n) * BF16
    return max(flops / spec.peak_bf16_flops, bytes_min / spec.hbm_bw)
