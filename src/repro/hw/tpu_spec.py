"""TPU hardware constants used by every roofline / cost computation.

Target: TPU v5e (the container is CPU-only; v5e is the *modelled* hardware).
All values are public datasheet numbers; VMEM is the per-core vector memory
budget a Pallas kernel's working set must fit in.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class TpuSpec:
    name: str
    # Compute
    peak_bf16_flops: float  # FLOP/s per chip
    peak_int8_ops: float
    mxu_dim: int            # systolic array is mxu_dim x mxu_dim
    num_mxu: int            # MXUs per core
    vpu_lanes: int          # (8, 128) vregs -> 8*128 lanes
    # Memory hierarchy
    hbm_bytes: int
    hbm_bw: float           # bytes/s
    vmem_bytes: int
    # Interconnect
    ici_links: int          # links per chip
    ici_bw_per_link: float  # bytes/s per link, per direction
    dcn_bw: float           # bytes/s per host, pod-to-pod
    # Misc timing model knobs (derived from public microbenchmarks, coarse)
    dma_latency_s: float    # fixed cost to issue an HBM->VMEM DMA
    grid_step_overhead_s: float  # per-grid-step sequencer overhead


V5E = TpuSpec(
    name="tpu_v5e",
    peak_bf16_flops=197e12,
    peak_int8_ops=394e12,
    mxu_dim=128,
    num_mxu=1,
    vpu_lanes=8 * 128,
    hbm_bytes=16 * 1024**3,
    hbm_bw=819e9,
    vmem_bytes=128 * 1024**2,
    ici_links=4,
    ici_bw_per_link=50e9,
    dcn_bw=25e9,
    dma_latency_s=1e-6,
    grid_step_overhead_s=2e-7,
)

# The spec used everywhere unless a config overrides it.
DEFAULT = V5E


def matmul_flops(m: int, n: int, k: int) -> float:
    return 2.0 * m * n * k


def mxu_efficiency(dim: int, mxu: int = 128) -> float:
    """Fraction of the systolic array utilized for a tile dimension ``dim``.

    A dim that is not a multiple of the MXU edge wastes the remainder lanes on
    the final pass: eff = dim / (ceil(dim/mxu) * mxu).
    """
    if dim <= 0:
        return 0.0
    import math

    return dim / (math.ceil(dim / mxu) * mxu)
