# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.

# Shared jax-version compat: jax renamed pltpu.TPUCompilerParams ->
# pltpu.CompilerParams; kernel modules take the alias from here so the
# next rename is one edit.
from jax.experimental.pallas import tpu as _pltpu

_CompilerParams = getattr(_pltpu, "CompilerParams", None) or \
    _pltpu.TPUCompilerParams
