"""Fused RMSNorm Pallas kernel.

Row-tiled: each grid step normalizes a (block_rows, d) tile fully in VMEM —
one HBM read + one write per element instead of the separate
square/mean/rsqrt/mul dataflow XLA emits unfused.  d stays whole per tile
(the reduction axis must be resident); block_rows is the tunable knob.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _CompilerParams


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6,
            block_rows: int = 128, interpret: Optional[bool] = None
            ) -> jnp.ndarray:
    """x: (..., d), w: (d,)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    shape = x.shape
    d = shape[-1]
    rows = 1
    for s in shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    grid = (x2.shape[0] // block_rows,)

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x2, w)
    return out[:rows].reshape(shape)
