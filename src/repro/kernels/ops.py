"""Jit'd public wrappers around the Pallas kernels.

Backend dispatch: on TPU the kernels compile through Mosaic; anywhere else
(this CPU container) they execute under ``interpret=True`` so tests validate
the exact kernel bodies.  ``use_pallas=False`` falls back to the jnp oracle —
that path is what the 512-device dry-run lowers (Pallas does not partition
across GSPMD meshes; the kernels are the per-core fast path).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.gemm import GemmConfig, gemm as _gemm, gemm_config_from_knobs


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("config", "use_pallas"))
def matmul(a: jnp.ndarray, b: jnp.ndarray,
           config: GemmConfig = GemmConfig(),
           use_pallas: bool = True) -> jnp.ndarray:
    if not use_pallas:
        return ref.matmul_ref(a, b)
    return _gemm(a, b, config, interpret=_interpret())


def im2col(x: jnp.ndarray, kh: int, kw: int, stride: int, pad: int
           ) -> Tuple[jnp.ndarray, Tuple[int, int]]:
    """x: (B, H, W, CI) -> patches (B*OH*OW, KH*KW*CI), plus (OH, OW).

    Feature ordering matches ``w.reshape(KH*KW*CI, CO)`` for HWIO weights.
    """
    b, h, w_, ci = x.shape
    patches = jax.lax.conv_general_dilated_patches(
        x, (kh, kw), (stride, stride), [(pad, pad), (pad, pad)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    oh, ow = patches.shape[1], patches.shape[2]
    # conv_general_dilated_patches emits features as (CI, KH, KW) —
    # reorder to (KH, KW, CI) to match HWIO weight flattening.
    patches = patches.reshape(b, oh, ow, ci, kh, kw)
    patches = patches.transpose(0, 1, 2, 4, 5, 3)
    return patches.reshape(b * oh * ow, kh * kw * ci), (oh, ow)


@functools.partial(jax.jit,
                   static_argnames=("stride", "pad", "config", "use_pallas"))
def conv2d(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1, pad: int = 0,
           config: GemmConfig = GemmConfig(),
           use_pallas: bool = True) -> jnp.ndarray:
    """Conv as im2col + the tunable GEMM core. x: NHWC, w: HWIO."""
    if not use_pallas:
        return ref.conv2d_ref(x, w, stride, pad)
    b = x.shape[0]
    kh, kw, ci, co = w.shape
    patches, (oh, ow) = im2col(x, kh, kw, stride, pad)
    out = _gemm(patches, w.reshape(kh * kw * ci, co), config,
                interpret=_interpret())
    return out.reshape(b, oh, ow, co)


def conv2d_from_knobs(x, w, stride, pad, *, tile_b, tile_h, tile_w,
                      tile_ci, tile_co, h_threading, oc_threading,
                      use_pallas: bool = True):
    """Execute a conv with an ARCO configuration (knob values)."""
    kh, kw = w.shape[0], w.shape[1]
    cfg = gemm_config_from_knobs(
        tile_m=tile_b * tile_h * tile_w,
        tile_n=tile_co,
        tile_k=tile_ci * kh * kw,
        h_threading=h_threading, oc_threading=oc_threading)
    return conv2d(x, w, stride, pad, cfg, use_pallas)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "use_pallas"))
def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
              causal: bool = True, window: Optional[int] = None,
              block_q: int = 128, block_k: int = 128,
              use_pallas: bool = True) -> jnp.ndarray:
    if not use_pallas:
        return ref.attention_ref(q, k, v, causal=causal, window=window)
    return _flash(q, k, v, causal=causal, window=window,
                  block_q=block_q, block_k=block_k, interpret=_interpret())
