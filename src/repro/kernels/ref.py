"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def matmul_ref(a: jnp.ndarray, b: jnp.ndarray,
               out_dtype: Optional[jnp.dtype] = None) -> jnp.ndarray:
    out_dtype = out_dtype or a.dtype
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(out_dtype)


def conv2d_ref(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1,
               pad: int = 0) -> jnp.ndarray:
    """x: (B, H, W, CI), w: (KH, KW, CI, CO) -> (B, OH, OW, CO)."""
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return out.astype(x.dtype)


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True, window: Optional[int] = None,
                  scale: Optional[float] = None) -> jnp.ndarray:
    """Grouped-query attention oracle.

    q: (B, S, HQ, D); k, v: (B, S, HKV, D). HQ % HKV == 0.
    ``window``: sliding-window size (mixtral SWA); None = full.
    """
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    qf = q.astype(jnp.float32).reshape(b, s, hkv, group, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf) * scale
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= ki > qi - window
    logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf)
    return out.reshape(b, s, hq, d).astype(q.dtype)
