"""Causal / sliding-window GQA flash attention — Pallas TPU kernel.

Online-softmax blockwise attention (Rabe-Staats / FlashAttention) tiled for
VMEM: grid (batch, q_head, q_blocks, kv_blocks), with running max / sum /
accumulator scratch carried across the innermost (kv) grid dimension.
Irrelevant kv blocks (fully masked by causality or the sliding window) are
skipped via ``pl.when`` — on TPU the sequencer never issues their DMAs.

Forward only: the training path uses the differentiable chunked-jnp
implementation in ``repro.models.layers``; this kernel is the serving /
prefill fast path.  Validated in interpret mode against ``ref.attention_ref``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _CompilerParams

_NEG_INF = -1e30
_MINLANE = 128  # scratch minor dim (TPU lane width)


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: Optional[int],
                  block_q: int, block_k: int, n_k: int, seq_len: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    q_start = iq * block_q
    k_start = ik * block_k

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Block relevance: skip fully-masked kv blocks entirely.
    relevant = jnp.asarray(True)
    if causal:
        relevant &= k_start <= q_start + block_q - 1
    if window is not None:
        relevant &= k_start + block_k - 1 >= q_start - window + 1

    @pl.when(relevant)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)            # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale

        rows = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 1)
        mask = cols < seq_len
        if causal:
            mask &= cols <= rows
        if window is not None:
            mask &= cols > rows - window
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[:, 0:1]                          # (bq, 1)
        l_prev = l_ref[:, 0:1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                          # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)                 # (bq, 1)
        l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = (acc_ref[...] * alpha
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ik == n_k - 1)
    def _finish():
        l = l_ref[:, 0:1]
        out = acc_ref[...] / jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0, :, :] = out.astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, window: Optional[int] = None,
                    scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """q: (B, S, HQ, D); k, v: (B, S, HKV, D) -> (B, S, HQ, D)."""
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)

    block_q = min(block_q, s)
    block_k = min(block_k, s)

    # (B, H, S, D) layout; pad S to block multiples
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    pq = (-s) % block_q
    pk = (-s) % block_k
    if pq:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pk), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pk), (0, 0)))
    sq = qt.shape[2]
    sk = kt.shape[2]
    grid = (b, hq, sq // block_q, sk // block_k)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_k=grid[3], seq_len=s)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bb, h, iq, ik: (bb, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bb, h, iq, ik, g=group: (bb, h // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bb, h, iq, ik, g=group: (bb, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bb, h, iq, ik: (bb, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, _MINLANE), jnp.float32),
            pltpu.VMEM((block_q, _MINLANE), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)
    return jnp.swapaxes(out[:, :, :s, :], 1, 2)
