"""Tunable tiled GEMM Pallas kernel — the TPU analog of the VTA GEMM core.

The ARCO hardware agent's knobs instantiate this kernel's geometry:

    tile_m (BATCH x spatial tiles)  -> BlockSpec M tile
    tile_k (BLOCK_IN  analog)       -> BlockSpec K tile
    tile_n (BLOCK_OUT analog)       -> BlockSpec N tile

and the scheduling agent's knobs choose grid *dimension semantics*
("threading": parallel vs arbitrary sequencing of the M/N grid) and the
K-split: whether the contraction is blocked over the grid's innermost
dimension (accumulating in a VMEM scratch accumulator) or kept whole.

Target is TPU (Mosaic); on this CPU-only container the kernel runs under
``interpret=True`` and is validated against ``ref.matmul_ref``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _CompilerParams


@dataclasses.dataclass(frozen=True)
class GemmConfig:
    """Kernel geometry — the knobs ARCO tunes."""
    block_m: int = 128
    block_n: int = 128
    block_k: int = 128
    # scheduling-agent knobs
    parallel_m: bool = True    # h_threading analog: M grid dim parallel
    parallel_n: bool = True    # oc_threading analog: N grid dim parallel
    # derived VMEM working set (bytes) for feasibility checks
    def vmem_bytes(self, in_dtype=jnp.bfloat16) -> int:
        b = jnp.dtype(in_dtype).itemsize
        return (self.block_m * self.block_k * b
                + self.block_k * self.block_n * b
                + self.block_m * self.block_n * 4)


def _gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _pad_dim(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    rem = (-x.shape[axis]) % mult
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


def gemm(a: jnp.ndarray, b: jnp.ndarray,
         config: GemmConfig = GemmConfig(),
         out_dtype: Optional[jnp.dtype] = None,
         interpret: bool = False) -> jnp.ndarray:
    """C = A @ B with explicit BlockSpec tiling. a: (M, K), b: (K, N)."""
    if a.ndim != 2 or b.ndim != 2 or a.shape[1] != b.shape[0]:
        raise ValueError(f"bad gemm shapes {a.shape} {b.shape}")
    m, k = a.shape
    _, n = b.shape
    out_dtype = out_dtype or a.dtype
    bm = min(config.block_m, m)
    bn = min(config.block_n, n)
    bk = min(config.block_k, k)

    a = _pad_dim(_pad_dim(a, 0, bm), 1, bk)
    b = _pad_dim(_pad_dim(b, 0, bk), 1, bn)
    mp, kp = a.shape
    _, np_ = b.shape
    grid = (mp // bm, np_ // bn, kp // bk)

    sem_m = "parallel" if config.parallel_m else "arbitrary"
    sem_n = "parallel" if config.parallel_n else "arbitrary"

    out = pl.pallas_call(
        functools.partial(_gemm_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=(sem_m, sem_n, "arbitrary")),
        interpret=interpret,
    )(a, b)
    return out[:m, :n]


def gemm_config_from_knobs(tile_m: int, tile_n: int, tile_k: int,
                           h_threading: int, oc_threading: int) -> GemmConfig:
    """Map ARCO knob values onto a kernel geometry.

    Tile values are rounded up to hardware granules (8 sublanes / 128 lanes);
    threading>1 marks the corresponding grid dimension parallel.
    """
    rup = lambda v, g: max(g, int(-(-int(v) // g) * g))
    return GemmConfig(
        block_m=rup(tile_m, 8),
        block_n=rup(tile_n, 128),
        block_k=rup(tile_k, 128),
        parallel_m=h_threading > 1,
        parallel_n=oc_threading > 1,
    )
