"""Deterministic synthetic data pipeline.

Design goals (the ones that matter at 1000-node scale):
  * stateless addressing — batch contents are a pure function of
    (seed, step, host_shard), so resume-after-failure needs no replay log
    and elastic re-sharding is exact;
  * per-host sharding — each host materializes only its slice;
  * background prefetch with a bounded queue (straggler smoothing);
  * checkpointable: the only state is the step counter.

The token stream is a seeded Markov-ish mix so the loss actually decreases
(pure uniform tokens would have irreducible loss = log V).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    structure: int = 64   # markov period; larger => more learnable signal


class SyntheticLM:
    """Deterministic, shardable synthetic LM token stream."""

    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.n_hosts == 0
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.n_hosts
        rng = np.random.default_rng(cfg.seed)
        # fixed transition table: token -> preferred next tokens
        self._table = rng.integers(0, cfg.vocab,
                                   size=(cfg.structure, 8)).astype(np.int64)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Pure function of step (and host shard)."""
        cfg = self.cfg
        rows = []
        base = step * cfg.global_batch + self.cfg.host_id * self.local_batch
        for i in range(self.local_batch):
            rng = np.random.default_rng((cfg.seed, base + i))
            start = rng.integers(0, cfg.structure)
            noise = rng.integers(0, cfg.vocab, size=cfg.seq_len)
            choose = rng.integers(0, 8, size=cfg.seq_len)
            idx = (start + np.arange(cfg.seq_len)) % cfg.structure
            toks = self._table[idx, choose]
            mask = rng.random(cfg.seq_len) < 0.15
            toks = np.where(mask, noise, toks)
            rows.append(toks)
        tokens = np.stack(rows).astype(np.int32)
        labels = np.concatenate([tokens[:, 1:],
                                 np.full((self.local_batch, 1), -1,
                                         np.int32)], axis=1)
        return {"tokens": tokens, "labels": labels}


class Prefetcher:
    """Bounded background prefetch; tolerates slow steps (stragglers) by
    keeping up to ``depth`` batches ready."""

    def __init__(self, ds: SyntheticLM, start_step: int = 0, depth: int = 2):
        self.ds = ds
        self.step = start_step
        self._q: "queue.Queue[Any]" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._next_produce = start_step
        self._thread.start()

    def _work(self):
        while not self._stop.is_set():
            batch = self.ds.batch_at(self._next_produce)
            while not self._stop.is_set():
                try:
                    self._q.put((self._next_produce, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            self._next_produce += 1

    def next(self) -> Dict[str, np.ndarray]:
        step, batch = self._q.get()
        self.step = step + 1
        return batch

    def state(self) -> Dict[str, int]:
        return {"step": self.step}

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
