import os
if "--xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count="
                               + os.environ.get("REPRO_DRYRUN_DEVICES",
                                                "256")).strip()

# ARCO over the pod: measurement oracle = lower + compile + roofline.
#
#     PYTHONPATH=src python -m repro.launch.autotune \
#         --arch mixtral-8x22b --shape train_4k --budget 14
#
# This is the beyond-paper §Perf engine: the same MAPPO+CS machinery from
# the paper, pointed at the 256-chip execution configuration, where each
# "hardware measurement" costs an SPMD compile (tens of seconds) — the cost
# regime Confidence Sampling was designed for.  ``search`` is a thin adapter
# over ``repro.compiler.Session`` + ``CompileOracle``; only the heavy
# measurement itself (``compile_and_analyze``) lives here.

import argparse
import json
import time
from typing import Dict

import jax

from repro.configs import get_config
from repro.configs.shapes import SHAPES, input_specs
from repro.core import mappo
from repro.core.tuner import TunerConfig
from repro.hw import hlo_analysis, roofline as RL
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.train import steps as ST


def compile_and_analyze(arch: str, shape_name: str,
                        settings: Dict[str, object],
                        verbose: bool = True) -> Dict[str, object]:
    """One 'hardware measurement': build the cell under ``settings``,
    compile for the pod mesh, return roofline numbers."""
    import jax.numpy as jnp
    cfg = get_config(arch).with_(
        attn_chunk=int(settings["attn_chunk"]),
        remat=bool(settings["remat"]))
    cell = SHAPES[shape_name]
    n_dev = len(jax.devices())
    model_axis = int(settings["model_axis"])
    data_axis = max(n_dev // model_axis, 1)
    mesh = make_host_mesh(data_axis, model_axis)

    from repro.dist.sharding import ShardingRules
    rules = ShardingRules(
        fsdp_weights=bool(settings["fsdp"]),
        sequence_parallel=bool(settings.get("sequence_parallel", False)))
    abstract = T.abstract_params(jax.random.PRNGKey(0), cfg)
    spec = input_specs(cfg, cell)
    t0 = time.time()
    with mesh:
        if cell.kind == "train":
            tc = ST.TrainConfig(
                grad_accum=int(settings.get("grad_accum", 1)),
                moment_dtype=jnp.float32
                if settings["moment_dtype"] == "float32" else jnp.bfloat16)
            jitted, _ = ST.build_sharded_train_step(
                cfg, tc, mesh, rules=rules, abstract_params=abstract)
            opt = ST.make_optimizer(tc)
            lowered = jitted(spec).lower(
                abstract, jax.eval_shape(opt.init, abstract), spec)
        elif cell.kind == "prefill":
            jitted, _ = ST.build_sharded_prefill(
                cfg, mesh, max_len=cell.seq, rules=rules,
                abstract_params=abstract)
            lowered = jitted(spec).lower(abstract, spec)
        else:
            jitted, _ = ST.build_sharded_serve_step(
                cfg, mesh, rules=rules, abstract_params=abstract,
                abstract_cache=spec["cache"], batch=cell.global_batch,
                max_len=cell.seq)
            lowered = jitted.lower(abstract, spec["cache"], spec["tokens"])
        compiled = lowered.compile()
    weighted = hlo_analysis.analyze(compiled.as_text())
    art = {"weighted": {
        "dot_flops_per_device": weighted["weighted_dot_flops"],
        "wire_bytes_per_device": weighted["wire_bytes_per_device"],
        "collective_bytes_by_op": weighted["collective_bytes_by_op"]}}
    r = RL.analyze_cell(cfg, cell.kind, cell.seq, cell.global_batch,
                        dict(mesh.shape), art)
    # Eq. 4/5 analog: hinge penalty on modelled HBM overflow — an OOM
    # configuration must never win the search.
    res = RL.hbm_residency(
        cfg, cell.kind, cell.seq, cell.global_batch, dict(mesh.shape),
        fsdp=bool(settings["fsdp"]),
        moment_dtype=str(settings["moment_dtype"]),
        remat=bool(settings["remat"]),
        grad_accum=int(settings.get("grad_accum", 1)),
        sequence_parallel=bool(settings.get("sequence_parallel", False)))
    hbm = 16 * 2.0 ** 30
    overflow_gib = max(res - hbm, 0.0) / 2.0 ** 30
    step_pen = r.step_s * (1.0 + overflow_gib) + overflow_gib
    out = dict(r.as_dict(), compile_s=time.time() - t0,
               settings=dict(settings),
               hbm_residency_gib=res / 2.0 ** 30,
               feasible=res <= hbm, step_penalized_s=step_pen)
    if verbose:
        print(f"  measure {settings}: step={r.step_s:.4f}s "
              f"residency={res / 2.0 ** 30:.1f}GiB "
              f"{'ok' if res <= hbm else 'OOM'} "
              f"dominant={r.dominant} (compile {out['compile_s']:.0f}s)",
              flush=True)
    jax.clear_caches()
    return out


def search(arch: str, shape_name: str, budget: int = 14,
           seed: int = 0, out_path: str = None,
           records_path: str = None,
           workers: int = 0, timeout_s: float = None,
           remote: str = None, trace: str = None,
           monitor=None, trace_sample_rate: float = 1.0):
    """Thin adapter over the session API: one compile-oracle cell, measured
    through ``CompileOracle``.  Re-measures from scratch unless the caller
    opts into persistence with ``records_path`` (JSONL), from which a re-run
    resumes warm — never derived implicitly, so a plain re-run after a code
    or toolchain change always reflects fresh measurements.

    ``workers=N`` fans the tens-of-seconds compiles across N spawned
    measurement workers (each with its own jax init against the same
    pinned device count); ``timeout_s`` bounds each compile — a hung or
    crashed worker records the failure-penalty row and the pool respawns,
    so the search never wedges on one bad configuration.  ``remote=
    "host:port[,host:port]"`` fans the same compiles over TCP worker
    daemons instead of local processes (mutually exclusive with
    ``workers``)."""
    from repro.compiler import Session, TuningTask
    cfg = TunerConfig(
        iteration_opt=max(budget // 4, 2), b_measure=4,
        episodes_per_iter=2,
        mappo=mappo.MappoConfig(n_steps=32, n_envs=8), gbt_rounds=12,
        seed=seed)
    task = TuningTask.cell(arch, shape_name, n_devices=len(jax.devices()))
    result = Session(task, tuner=cfg, budget=budget, records=records_path,
                     workers=workers, timeout_s=timeout_s,
                     remote=remote, trace=trace, monitor=monitor,
                     trace_sample_rate=trace_sample_rate).run().single
    summary = {
        "arch": arch, "shape": shape_name,
        "best_settings": result.best_settings,
        "best_step_s": result.best_latency,
        "n_measurements": result.n_measurements,
        "wall_s": result.wall_time_s,
        "history": [list(r) for r in result.history],
        "oracle": result.oracle_stats,
        "records": records_path,
        "workers": workers,
        "remote": remote,
    }
    if out_path:
        os.makedirs(os.path.dirname(out_path), exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(summary, f, indent=1)
    return summary


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--budget", type=int, default=14)
    ap.add_argument("--out", default=None)
    ap.add_argument("--records", default=None,
                    help="JSONL measurement records (persist + warm resume)")
    from repro.compiler.executor import add_worker_args, validate_worker_args
    add_worker_args(ap)
    args = ap.parse_args()
    validate_worker_args(ap, args)
    s = search(args.arch, args.shape, args.budget, out_path=args.out,
               records_path=args.records, workers=args.workers,
               timeout_s=args.timeout_s, remote=args.remote,
               trace=args.trace, monitor=args.monitor,
               trace_sample_rate=args.trace_sample_rate)
    print(json.dumps(s, indent=1))


if __name__ == "__main__":
    main()
