"""Serving launcher: batched continuous decoding of synthetic requests.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
        --requests 8 --slots 4
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import ARCH_NAMES, get_config
from repro.models import transformer as T
from repro.train.server import Request, Server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="qwen2-1.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    params = T.init_params(jax.random.PRNGKey(args.seed), cfg)
    srv = Server(params, cfg, n_slots=args.slots, max_len=args.max_len)

    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for i in range(args.requests):
        srv.submit(Request(
            uid=i,
            prompt=rng.integers(0, cfg.vocab,
                                size=int(rng.integers(4, 24))).astype(
                np.int32),
            max_new_tokens=args.max_new))
    done = srv.run_until_drained()
    dt = time.time() - t0
    toks = sum(len(r.output) for r in done)
    print(json.dumps({
        "arch": cfg.name, "requests": len(done),
        "generated_tokens": toks, "wall_s": round(dt, 2),
        "tok_per_s": round(toks / dt, 1),
        "mean_latency_s": round(float(np.mean(
            [r.latency_s for r in done])), 3)}, indent=1))


if __name__ == "__main__":
    main()
