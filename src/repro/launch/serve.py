"""Serving launcher: batched continuous decoding, optionally with an
online tuning session measuring candidate ShardSpace geometries on idle
decode slots (``--autotune``, see :mod:`repro.compiler.serve_tune`).

    # plain serving of synthetic requests
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
        --requests 8 --slots 4

    # timed Poisson arrivals + online tuning under a 500 ms p99 SLA
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
        --requests 64 --rate 20 --autotune --budget 24 --sla-ms 500

``--rate 0`` (default) submits every request up front — the drain-the-batch
mode the launcher always had.  With ``--rate`` the trace replays Poisson
arrivals against the wall clock (idle gaps fast-forwarded), which is what
gives ``--autotune`` idle windows to measure in.

Throughput excludes jit warm-up: one throwaway request is served before
the timed run so the first-step compile doesn't pollute ``tokens_per_sec``.
Rejected and abandoned requests are reported loudly and never averaged
into latency stats (their latency fields are None by design).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import ARCH_NAMES, get_config
from repro.models import transformer as T
from repro.train.server import Request, Server


def _latency_stats(done) -> dict:
    if not done:
        return {"mean_latency_s": None, "p50_latency_s": None,
                "p99_latency_s": None, "mean_queue_s": None,
                "mean_prefill_s": None, "mean_decode_s": None}
    lats = np.asarray([r.latency_s for r in done])
    return {
        "mean_latency_s": round(float(lats.mean()), 4),
        "p50_latency_s": round(float(np.percentile(lats, 50)), 4),
        "p99_latency_s": round(float(np.percentile(lats, 99)), 4),
        "mean_queue_s": round(float(np.mean(
            [r.queue_s for r in done])), 4),
        "mean_prefill_s": round(float(np.mean(
            [r.prefill_s for r in done])), 4),
        "mean_decode_s": round(float(np.mean(
            [r.decode_s for r in done])), 4),
    }


def _warm_up(srv: Server, vocab: int) -> None:
    """Serve one throwaway request so the jit compiles of prefill/decode
    land outside the timed run."""
    srv.submit(Request(uid=-1, prompt=np.arange(4, dtype=np.int32) % vocab,
                       max_new_tokens=2))
    srv.run_until_drained(max_steps=64)


def main():
    ap = argparse.ArgumentParser(
        description="continuous-batching LM server over synthetic "
                    "requests, with optional online geometry tuning")
    ap.add_argument("--arch", choices=ARCH_NAMES, default="qwen2-1.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--rate", type=float, default=0.0, metavar="REQ_PER_S",
                    help="Poisson arrival rate; 0 = submit everything up "
                         "front (legacy drain mode)")
    ap.add_argument("--autotune", action="store_true",
                    help="run an online tuning session on idle decode "
                         "slots while serving (needs --rate > 0)")
    ap.add_argument("--budget", type=int, default=24,
                    help="measurements per tuned cell (--autotune)")
    ap.add_argument("--sla-ms", type=float, default=500.0,
                    help="p99 end-to-end latency SLA in milliseconds")
    ap.add_argument("--records", metavar="PATH", default=None,
                    help="JSONL measurement records for warm resume "
                         "(--autotune)")
    ap.add_argument("--monitor", type=int, default=None, metavar="PORT",
                    help="live /metrics + /status + /trace on this port "
                         "for the duration of the run (0 = ephemeral)")
    ap.add_argument("--json-out", metavar="PATH", default=None,
                    help="also write the report JSON here")
    args = ap.parse_args()
    if args.autotune and args.rate <= 0:
        ap.error("--autotune needs --rate > 0: tuning measures in the "
                 "idle gaps between arrivals, and a fully up-front queue "
                 "has none")

    cfg = get_config(args.arch, reduced=args.reduced)
    params = T.init_params(jax.random.PRNGKey(args.seed), cfg)
    srv = Server(params, cfg, n_slots=args.slots, max_len=args.max_len)
    _warm_up(srv, cfg.vocab)

    doc = {"arch": cfg.name, "sla_ms": args.sla_ms}
    if args.autotune or args.rate > 0:
        from repro.compiler.serve_tune import (LiveServeHost, ServeModel,
                                               ServeSLA, TraceConfig,
                                               tune_while_serving)
        trace = TraceConfig(
            n_requests=args.requests, rate_per_s=args.rate,
            prompt_len=(4, max(args.max_len // 4, 5)),
            max_new=(2, args.max_new), seed=args.seed)
        host = LiveServeHost(
            srv, trace, sla=ServeSLA(target_s=args.sla_ms / 1e3),
            model=ServeModel(arch=args.arch), vocab=cfg.vocab,
            seed=args.seed)
        if args.autotune:
            rep = tune_while_serving(
                host, budget=args.budget, records=args.records,
                monitor=args.monitor, seed=args.seed,
                offline_compare=False)
            doc["autotune"] = {
                "budget": rep.budget,
                "online": rep.online,
                "measurements": rep.serve["measurements"],
                "preempted": rep.serve["preempted"],
            }
        else:
            host.finish_serving()
        summary = host.summary()
        done = host.done
        doc.update({
            "requests": summary["served"],
            "generated_tokens": int(sum(len(r.output) for r in done)),
            "wall_s": round(summary["sim_time_s"], 3),
            "tokens_per_sec": round(summary["tokens_per_sec"] or 0.0, 1),
            "violation_pct": round(summary["violation_pct"] or 0.0, 3),
            "rejected": summary["rejected"],
            "abandoned": summary["abandoned"],
        })
        doc.update(_latency_stats(done))
    else:
        rng = np.random.default_rng(args.seed)
        t0 = time.perf_counter()
        for i in range(args.requests):
            srv.submit(Request(
                uid=i,
                prompt=rng.integers(
                    0, cfg.vocab,
                    size=int(rng.integers(4, 24))).astype(np.int32),
                max_new_tokens=args.max_new))
        done = srv.run_until_drained()
        dt = time.perf_counter() - t0
        toks = sum(len(r.output) for r in done)
        doc.update({
            "requests": len(done),
            "generated_tokens": toks,
            "wall_s": round(dt, 3),
            "tokens_per_sec": round(toks / dt, 1),
            "rejected": len(srv.rejected),
            "abandoned": len(srv.abandoned),
        })
        doc.update(_latency_stats(done))
        if done:
            lats = np.asarray([r.latency_s for r in done])
            doc["violation_pct"] = round(float(
                100.0 * (lats > args.sla_ms / 1e3).mean()), 3)

    # loud, unmissable: these were never served and are NOT in the stats
    for kind, reqs in (("rejected", srv.rejected),
                       ("abandoned", srv.abandoned)):
        if reqs:
            print(f"WARNING: {len(reqs)} request(s) {kind}:")
            for r in reqs[:5]:
                print(f"  uid={r.uid} status={r.status} "
                      f"error={r.error or '-'}")
            if len(reqs) > 5:
                print(f"  ... and {len(reqs) - 5} more")

    out = json.dumps(doc, indent=1)
    print(out)
    if args.json_out:
        with open(args.json_out, "w") as f:
            f.write(out + "\n")


if __name__ == "__main__":
    main()
