import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count="
                           + os.environ.get("REPRO_DRYRUN_DEVICES", "512")
                           ).strip()
# ^ MUST run before any other import: jax locks the device count on first
#   initialization.  512 placeholder host devices stand in for 2 pods x 256
#   TPU v5e chips; lowering/compiling against them proves the distribution
#   config (shardings, collectives, memory) is coherent without hardware.

# Multi-pod dry-run driver.
#
# For every (architecture x input-shape x mesh) cell:
#     jit(step).lower(abstract inputs)  ->  .compile()
#     -> memory_analysis()  (fits?)  + cost_analysis()  (FLOPs / bytes)
#     -> collective bytes parsed from the partitioned HLO
# and a JSON artifact per cell under --out (EXPERIMENTS.md reads these).
#
# Usage:
#     python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k
#     python -m repro.launch.dryrun --all --mesh both --out artifacts/dryrun

import argparse
import json
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_config
from repro.configs.shapes import SHAPES, cell_supported, input_specs
from repro.launch.mesh import describe, make_dryrun_mesh, make_production_mesh
from repro.models import transformer as T
from repro.train import steps as ST
from repro.dist import sharding as SH
from repro.hw import hlo_analysis

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8,
                "c128": 16}

_COLL_RE = re.compile(
    r"=\s*(\(?[a-z0-9\[\],{}\s/#_\.]*?\)?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.IGNORECASE)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

# wire cost per device, ring-algorithm approximations
_WIRE_MULT = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
              "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> Dict[str, Any]:
    """Sum result-shape bytes of every collective in partitioned HLO."""
    per_op: Dict[str, int] = {}
    counts: Dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shapes, op = m.group(1), m.group(2).lower()
        b = _shape_bytes(shapes)
        per_op[op] = per_op.get(op, 0) + b
        counts[op] = counts.get(op, 0) + 1
    wire = sum(_WIRE_MULT[op] * b for op, b in per_op.items())
    return {"bytes_by_op": per_op, "counts": counts,
            "wire_bytes_per_device": wire}


def _while_trip_counts(hlo_text: str):
    """Best-effort trip counts of while loops (scan repeats) so cost numbers
    can be corrected for XLA's single-visit loop accounting."""
    # constants compared in while conditions: look for "trip_count" hints
    out = []
    for m in re.finditer(r'known_trip_count=\{?"?n"?[:=](\d+)', hlo_text):
        out.append(int(m.group(1)))
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: Optional[str] = None,
             batch_override: Optional[int] = None,
             rules: Optional[SH.ShardingRules] = None) -> Dict[str, Any]:
    rules = rules or SH.ShardingRules()
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_dryrun_mesh(multi_pod=multi_pod)
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    result: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "mesh_desc": describe(mesh), "kind": shape.kind,
    }

    ok, reason = cell_supported(cfg, shape)
    if not ok:
        result["status"] = "skipped"
        result["reason"] = reason
        _emit(result, out_dir)
        return result

    t0 = time.time()
    try:
        abstract = T.abstract_params(jax.random.PRNGKey(0), cfg)
        spec = input_specs(cfg, shape, batch_override)
        with mesh:
            if shape.kind == "train":
                tc = ST.TrainConfig()
                jitted, sh = ST.build_sharded_train_step(
                    cfg, tc, mesh, rules=rules, abstract_params=abstract)
                opt = ST.make_optimizer(tc)
                abstract_opt = jax.eval_shape(opt.init, abstract)
                fn = jitted(spec)
                lowered = fn.lower(abstract, abstract_opt, spec)
            elif shape.kind == "prefill":
                jitted, sh = ST.build_sharded_prefill(
                    cfg, mesh, max_len=shape.seq, rules=rules,
                    abstract_params=abstract)
                fn = jitted(spec)
                lowered = fn.lower(abstract, spec)
            else:  # decode
                b = batch_override or shape.global_batch
                jitted, sh = ST.build_sharded_serve_step(
                    cfg, mesh, rules=rules, abstract_params=abstract,
                    abstract_cache=spec["cache"], batch=b,
                    max_len=shape.seq)
                lowered = jitted.lower(abstract, spec["cache"],
                                       spec["tokens"])
            compiled = lowered.compile()

        result["compile_s"] = round(time.time() - t0, 2)
        mem = compiled.memory_analysis()
        if mem is not None:
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes"):
                v = getattr(mem, k, None)
                if v is not None:
                    result[k] = int(v)
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):  # older jax returns [dict]
            cost = cost[0] if cost else None
        if cost:
            result["cost_flops"] = float(cost.get("flops", 0.0))
            result["cost_bytes"] = float(cost.get("bytes accessed", 0.0))
        hlo = compiled.as_text()
        result["collectives"] = collective_stats(hlo)   # raw (loop-body once)
        weighted = hlo_analysis.analyze(hlo)            # trip-count weighted
        result["weighted"] = {
            "dot_flops_per_device": weighted["weighted_dot_flops"],
            "collective_bytes_by_op": weighted["collective_bytes_by_op"],
            "wire_bytes_per_device": weighted["wire_bytes_per_device"],
        }
        result["hlo_chars"] = len(hlo)
        result["trip_counts"] = _while_trip_counts(hlo)
        result["status"] = "ok"
        result["param_bytes_global"] = int(sum(
            int(jnp.dtype(l.dtype).itemsize) * int(
                __import__("numpy").prod(l.shape))
            for l in jax.tree.leaves(abstract)))
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        result["status"] = "error"
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-4000:]
    _emit(result, out_dir)
    return result


def _emit(result: Dict[str, Any], out_dir: Optional[str]):
    line = (f"[{result['mesh']}] {result['arch']} x {result['shape']}: "
            f"{result['status']}")
    if result["status"] == "ok":
        coll = result["weighted"]["wire_bytes_per_device"]
        line += (f"  dotF/dev={result['weighted']['dot_flops_per_device']:.3e}"
                 f" tempB={result.get('temp_size_in_bytes', 0):.3e}"
                 f" collB/dev={coll:.3e}"
                 f" compile={result['compile_s']}s")
    elif result["status"] == "skipped":
        line += f"  ({result['reason'][:60]}...)"
    else:
        line += f"  {result['error'][:200]}"
    print(line, flush=True)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fname = (f"{result['arch']}__{result['shape']}__"
                 f"{result['mesh']}.json")
        result = dict(result)
        result.pop("traceback", None)
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(result, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"],
                    default="pod")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--batch", type=int, default=None,
                    help="override global batch (debug)")
    ap.add_argument("--sp", action="store_true",
                    help="optimized rules: Megatron-style sequence "
                         "parallelism on the residual stream")
    args = ap.parse_args()
    rules = SH.ShardingRules(sequence_parallel=args.sp)

    archs = ARCH_NAMES if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]

    n_bad = 0
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                r = run_cell(arch, shape, mp, args.out, args.batch,
                             rules=rules)
                n_bad += r["status"] == "error"
    print(f"done; {n_bad} errors", flush=True)
    raise SystemExit(1 if n_bad else 0)


if __name__ == "__main__":
    main()
