"""Mesh construction. Functions only — importing this module never touches
jax device state (device count is locked at first jax init)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """The production mesh: 16x16 (data, model) per pod; 2 pods multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_dryrun_mesh(*, multi_pod: bool = False) -> Mesh:
    """Production mesh when 512 devices exist; proportionally scaled-down
    mesh for debug runs with fewer placeholder devices."""
    n = len(jax.devices())
    if n >= 512 or (not multi_pod and n >= 256):
        return make_production_mesh(multi_pod=multi_pod)
    if multi_pod:
        per_pod = n // 2
        model = max(1, int(per_pod ** 0.5))
        while per_pod % model:
            model -= 1
        return jax.make_mesh((2, per_pod // model, model),
                             ("pod", "data", "model"))
    model = max(1, int(n ** 0.5))
    while n % model:
        model -= 1
    return jax.make_mesh((n // model, model), ("data", "model"))


def make_host_mesh(data: int = 1, model: int = 1,
                   pod: Optional[int] = None) -> Mesh:
    """Small mesh over however many (host) devices exist — for tests."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def describe(mesh: Mesh) -> str:
    return " x ".join(f"{k}={v}" for k, v in mesh.shape.items())
