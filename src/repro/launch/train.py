"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --reduced --steps 200 --batch 8 --seq 256 --ckpt /tmp/ck

On a real pod, run one process per host with jax.distributed env vars; the
mesh helper then spans global devices and this same script drives the run
(single-controller-per-host SPMD).
"""
from __future__ import annotations

import argparse
import json
import time

import jax

from repro.configs import ARCH_NAMES, get_config
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_host_mesh
from repro.train.steps import TrainConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="smollm-360m")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced smoke config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data", type=int, default=1, help="data mesh axis")
    ap.add_argument("--model", type=int, default=1, help="model mesh axis")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    mesh = make_host_mesh(args.data, args.model)
    tc = TrainConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                     total_steps=args.steps, grad_accum=args.grad_accum)
    trc = TrainerConfig(steps=args.steps, ckpt_dir=args.ckpt,
                        ckpt_every=args.ckpt_every,
                        log_every=max(args.steps // 50, 1))
    dc = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                    global_batch=args.batch)
    trainer = Trainer(cfg, tc, trc, mesh, data_cfg=dc)

    from repro.models.transformer import param_count
    n = param_count(trainer.params)
    print(f"arch={cfg.name} params={n/1e6:.1f}M mesh={dict(mesh.shape)} "
          f"batch={args.batch}x{args.seq}", flush=True)
    t0 = time.time()
    log = trainer.run()
    dt = time.time() - t0
    losses = [e for e in log if "loss" in e]
    print(json.dumps({"first_loss": losses[0]["loss"],
                      "last_loss": losses[-1]["loss"],
                      "steps": trainer.step,
                      "wall_s": round(dt, 1),
                      "tokens_per_s": round(
                          trainer.step * args.batch * args.seq / dt)},
                     indent=1))


if __name__ == "__main__":
    main()
