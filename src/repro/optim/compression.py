"""int8 error-feedback gradient compression over the data-parallel axes.

Large-scale trick: the data-parallel all-reduce moves int8 instead of
bf16/f32 (4x less ICI/DCN traffic), with per-leaf scale synchronization and
error-feedback accumulation so the quantization error is re-injected next
step (convergence-preserving; Seide et al. / 1-bit Adam lineage).

Implemented with shard_map so the collective is explicit: the training step
computes *local* (per-shard) gradients inside shard_map, calls
``compressed_psum_mean``, and proceeds with the synchronized result.  The
GSPMD path (default) keeps native psum; this is the opt-in wire-efficient
mode, exercised end-to-end by tests on a small host mesh.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def init_error_state(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum_mean(grads: Any, err: Any, axis_names,
                         ) -> Tuple[Any, Any]:
    """Inside shard_map: int8-quantized psum-mean with error feedback.

    Returns (synced mean grads fp32, new error state).
    """
    n = 1
    for a in (axis_names if isinstance(axis_names, (tuple, list))
              else (axis_names,)):
        # jax.lax.axis_size is missing from older jax; psum(1) is the
        # version-stable way to read a mapped axis size under shard_map
        n = n * (jax.lax.axis_size(a) if hasattr(jax.lax, "axis_size")
                 else jax.lax.psum(1, a))

    def one(g, e):
        g = g.astype(jnp.float32) + e
        # shared scale: max |g| across shards so dequantization agrees
        amax = jax.lax.pmax(jnp.max(jnp.abs(g)), axis_names)
        scale = jnp.maximum(amax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        deq_local = q.astype(jnp.float32) * scale
        new_e = g - deq_local                       # error feedback
        summed = jax.lax.psum(q.astype(jnp.int32), axis_names)
        return summed.astype(jnp.float32) * scale / n, new_e

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_flatten(err)[0]
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    synced = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_err = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    return synced, new_err


def make_ddp_compressed_step(loss_fn, opt, mesh: Mesh,
                             data_axis: str = "data"):
    """Explicit-DP training step with compressed gradient all-reduce.

    params/opt replicated; batch sharded on ``data_axis``.  loss_fn(params,
    batch) -> (loss, metrics).  Returns f(params, opt_state, err, batch).
    """
    pspec_rep = P()
    bspec = P(data_axis)

    def local_step(params, opt_state, err, batch):
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        grads, err = compressed_psum_mean(grads, err, data_axis)
        loss = jax.lax.pmean(loss, data_axis)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, err, loss

    smapped = shard_map(
        local_step, mesh=mesh,
        in_specs=(pspec_rep, pspec_rep, pspec_rep, bspec),
        out_specs=(pspec_rep, pspec_rep, pspec_rep, pspec_rep),
        check_rep=False)
    return jax.jit(smapped, donate_argnums=(0, 1, 2))
