"""Minimal, pytree-generic optimizers (no external deps).

Used by both the MARL nets in ``repro.core`` and the LM trainer in
``repro.train``.  State is a pytree mirroring the params, so it shards with
whatever sharding the params carry (ZeRO-style sharding is applied by the
caller via sharding constraints in ``repro.dist``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class Adam:
    lr: float | Callable[[jnp.ndarray], jnp.ndarray] = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip_norm: Optional[float] = None
    # dtype for first/second moments; bf16 moments halve optimizer memory
    moment_dtype: Optional[jnp.dtype] = None

    def init(self, params: Any) -> AdamState:
        dt = self.moment_dtype

        def z(p):
            return jnp.zeros_like(p, dtype=dt or p.dtype)

        return AdamState(step=jnp.zeros((), jnp.int32),
                         mu=jax.tree.map(z, params),
                         nu=jax.tree.map(z, params))

    def _lr(self, step: jnp.ndarray) -> jnp.ndarray:
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)

    def update(self, grads: Any, state: AdamState, params: Any
               ) -> Tuple[Any, AdamState]:
        step = state.step + 1
        if self.grad_clip_norm is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.grad_clip_norm / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)

        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(m.dtype),
                          state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2)
                          * jnp.square(g).astype(v.dtype), state.nu, grads)
        t = step.astype(jnp.float32)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t
        lr = self._lr(step)

        def upd(p, m, v):
            mhat = m.astype(jnp.float32) / bc1
            vhat = v.astype(jnp.float32) / bc2
            delta = lr * mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay:
                delta = delta + lr * self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - delta).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamState(step=step, mu=mu, nu=nu)


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def cosine_schedule(base_lr: float, warmup_steps: int, total_steps: int,
                    final_frac: float = 0.1) -> Callable[[jnp.ndarray], jnp.ndarray]:
    def f(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps)
                        / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup_steps, warm, base_lr * cos)
    return f
