"""Beyond-paper demo: ARCO tunes the pod-level execution configuration.

    PYTHONPATH=src python examples/arco_sharding_search.py \
        --arch qwen2-1.5b --shape train_4k --budget 10

Each "hardware measurement" is a full 256-device SPMD compile + roofline
analysis — the expensive-oracle regime the paper's Confidence Sampling
targets.  See EXPERIMENTS.md §Perf for the three-cell hillclimb this drives.
"""
import sys
from repro.launch.autotune import main

if __name__ == "__main__":
    main()
