"""End-to-end LM training driver: real data pipeline, fault-tolerant
trainer, checkpoints — CPU-sized by default, --full for the ~360M config.

    PYTHONPATH=src python examples/train_lm.py --steps 300
    PYTHONPATH=src python examples/train_lm.py --full --steps 100  # ~360M
"""
import argparse
import json
import tempfile
import time

from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_host_mesh
from repro.models.transformer import param_count
from repro.train.steps import TrainConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--full", action="store_true",
                    help="full smollm-360m (heavy on CPU)")
    ap.add_argument("--batch", type=int, default=0)
    ap.add_argument("--seq", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config("smollm-360m", reduced=not args.full)
    batch = args.batch or (4 if args.full else 8)
    seq = args.seq or (512 if args.full else 128)

    mesh = make_host_mesh(1, 1)
    tc = TrainConfig(lr=1e-3, warmup_steps=args.steps // 10,
                     total_steps=args.steps)
    with tempfile.TemporaryDirectory() as ckpt:
        trc = TrainerConfig(steps=args.steps, ckpt_dir=ckpt,
                            ckpt_every=max(args.steps // 4, 10),
                            log_every=max(args.steps // 20, 1))
        dc = DataConfig(vocab=cfg.vocab, seq_len=seq, global_batch=batch,
                        structure=64)
        trainer = Trainer(cfg, tc, trc, mesh, data_cfg=dc)
        print(f"model: smollm-360m{'' if args.full else ' (reduced)'} — "
              f"{param_count(trainer.params) / 1e6:.1f}M params, "
              f"batch {batch}x{seq}")
        t0 = time.time()
        log = trainer.run()
        dt = time.time() - t0
    losses = [e for e in log if "loss" in e]
    print(json.dumps({
        "first_loss": round(losses[0]["loss"], 4),
        "last_loss": round(losses[-1]["loss"], 4),
        "steps": trainer.step,
        "tokens_per_s": round(trainer.step * batch * seq / dt)}, indent=1))
    assert losses[-1]["loss"] < losses[0]["loss"], "training must learn"


if __name__ == "__main__":
    main()
