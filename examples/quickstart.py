"""Quickstart: co-optimize one convolution with ARCO and deploy the result.

    PYTHONPATH=src python examples/quickstart.py

1. builds the 7-knob design space (Table 2) for a ResNet-style conv;
2. runs the MAPPO+CS tuning loop against the TPU latency oracle;
3. compares against the software-only baselines;
4. executes the tuned configuration through the Pallas GEMM core and
   checks it against the jnp conv oracle.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mappo
from repro.core.baselines import autotvm_tune, random_tune
from repro.core.design_space import KNOB_NAMES, DesignSpace
from repro.core.tuner import TunerConfig, arco_tune
from repro.hw.analytical import conv2d_gflops, conv2d_min_latency
from repro.kernels import ops, ref


def main():
    workload = dict(b=1, h=14, w=14, ci=256, co=256, kh=3, kw=3,
                    stride=1, pad=1)
    space = DesignSpace.for_conv2d(workload)
    print(f"design space: {space.size} configurations "
          f"({len(KNOB_NAMES)} knobs)")

    cfg = TunerConfig(iteration_opt=6, b_measure=48, episodes_per_iter=3,
                      mappo=mappo.MappoConfig(n_steps=64, n_envs=16),
                      gbt_rounds=20)

    t0 = time.time()
    result = arco_tune(space, cfg)
    print(f"\nARCO:    best latency {result.best_latency * 1e6:9.2f} us  "
          f"({conv2d_gflops(workload, result.best_latency):7.1f} GFLOP/s)  "
          f"[{result.n_measurements} measurements, "
          f"{time.time() - t0:.1f}s]")

    for name, fn in (("AutoTVM*", autotvm_tune), ("random", random_tune)):
        r = fn(space, cfg)
        print(f"{name:8s} best latency {r.best_latency * 1e6:9.2f} us  "
              f"({conv2d_gflops(workload, r.best_latency):7.1f} GFLOP/s)  "
              f"[hardware knobs frozen at default geometry]")
    print(f"roofline lower bound: "
          f"{conv2d_min_latency(workload) * 1e6:.2f} us")

    vals = np.asarray(space.values(jnp.asarray(result.best_config)))
    named = dict(zip(KNOB_NAMES, vals.astype(int)))
    print(f"\ntuned configuration: {named}")

    x = jax.random.normal(jax.random.PRNGKey(0), (1, 14, 14, 256),
                          jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 256, 256),
                          jnp.float32)
    out = ops.conv2d_from_knobs(
        x, w, 1, 1, tile_b=named["tile_b"], tile_h=named["tile_h"],
        tile_w=named["tile_w"], tile_ci=named["tile_ci"],
        tile_co=named["tile_co"], h_threading=named["h_threading"],
        oc_threading=named["oc_threading"])
    err = float(jnp.abs(out - ref.conv2d_ref(x, w, 1, 1)).max())
    print(f"deployed through Pallas GEMM core (interpret mode): "
          f"max |err| vs oracle = {err:.2e}")


if __name__ == "__main__":
    main()
