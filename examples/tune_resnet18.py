"""Paper end-to-end flow: tune every ResNet-18 conv task, compare ARCO vs
the software-only baselines (Table 6 / Fig. 5 protocol at reduced budget).

One multi-task tuning session per framework: ARCO interleaves all tasks
over a *shared* GBT cost model (cross-task transfer via the workload
descriptor features), the baselines run the same tasks at the same budget.

    PYTHONPATH=src python examples/tune_resnet18.py [--budget 256]
"""
import argparse

from repro.compiler import Session, TuningTask
from repro.core import mappo
from repro.core.tuner import TunerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", type=int, default=192)
    ap.add_argument("--records", default=None,
                    help="JSONL records prefix; one file per framework so "
                         "no framework warm-starts from another's cache")
    from repro.compiler.executor import add_worker_args, validate_worker_args
    add_worker_args(ap)
    args = ap.parse_args()
    validate_worker_args(ap, args)

    n_iter = max(args.budget // 32, 2)
    cfg = TunerConfig(iteration_opt=n_iter, b_measure=32,
                      episodes_per_iter=3,
                      mappo=mappo.MappoConfig(n_steps=64, n_envs=16),
                      gbt_rounds=20)
    tasks = TuningTask.conv_tasks("resnet-18")
    mult = {t.name: t.multiplicity for t in tasks}
    print(f"ResNet-18: {sum(mult.values())} conv layers, "
          f"{len(tasks)} unique tuning tasks, "
          f"budget {args.budget} measurements/task\n")

    totals, walls = {}, {}
    for fw in ("arco", "autotvm", "chameleon"):
        records = args.records and f"{args.records}.{fw}.jsonl"
        sr = Session(tasks, tuner=cfg, algo=fw, budget=args.budget,
                     records=records, workers=args.workers,
                     timeout_s=args.timeout_s).run()
        totals[fw] = sr.total_best_latency(mult)
        walls[fw] = sr.wall_time_s
        print(f"{fw:10s} network conv latency "
              f"{totals[fw] * 1e6:10.1f} us   tuning wall {walls[fw]:6.1f}s")

    print(f"\nthroughput vs AutoTVM*: "
          f"ARCO {totals['autotvm'] / totals['arco']:.2f}x  "
          f"(paper Fig.5: ResNet-18 ~1.38x), "
          f"CHAMELEON {totals['autotvm'] / totals['chameleon']:.2f}x")


if __name__ == "__main__":
    main()
