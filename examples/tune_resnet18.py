"""Paper end-to-end flow on ResNet-18.

Default mode (Table 6 / Fig. 5 protocol at reduced budget): tune every
conv task, compare ARCO vs the software-only baselines.  One multi-task
tuning session per framework: ARCO interleaves all tasks over a *shared*
GBT cost model, the baselines run the same tasks at the same budget.

``--coopt`` runs the paper's actual headline claim instead — network-scope
co-optimization (``repro.compiler.netopt``): ONE shared accelerator
configuration for the whole network with per-layer software mappings under
it, compared at equal measurement budget against

* the network-level hw-frozen baseline (default chip, all budget on
  software mapping), and
* the per-layer fantasy (classic per-task ARCO, where every conv layer
  gets its own fictional chip and the summed optima are unrealizable on
  any single accelerator).

    PYTHONPATH=src python examples/tune_resnet18.py [--budget 256]
    PYTHONPATH=src python examples/tune_resnet18.py --coopt [--layer-budget 16]
"""
import argparse
import contextlib

from repro import obs
from repro.compiler import Session, TuningTask
from repro.core import mappo
from repro.core.tuner import TunerConfig


def software_only_comparison(args, cfg, tasks):
    totals, walls = {}, {}
    for fw in ("arco", "autotvm", "chameleon"):
        records = args.records and f"{args.records}.{fw}.jsonl"
        sr = Session(tasks, tuner=cfg, algo=fw, budget=args.budget,
                     records=records, workers=args.workers,
                     timeout_s=args.timeout_s, remote=args.remote,
                     monitor=args.monitor_server).run()
        # per-task bests weighted by each task's own layer multiplicity
        totals[fw] = sr.network_latency()
        walls[fw] = sr.wall_time_s
        print(f"{fw:10s} network conv latency "
              f"{totals[fw] * 1e6:10.1f} us   tuning wall {walls[fw]:6.1f}s")

    print(f"\nthroughput vs AutoTVM*: "
          f"ARCO {totals['autotvm'] / totals['arco']:.2f}x  "
          f"(paper Fig.5: ResNet-18 ~1.38x), "
          f"CHAMELEON {totals['autotvm'] / totals['chameleon']:.2f}x")


def coopt_comparison(args, cfg, tasks):
    """Co-optimized vs per-layer-fantasy vs hw-frozen at equal budget."""
    from repro.compiler.netopt import (NetOptConfig, NetworkCoOptimizer,
                                       network_hw_frozen_tune)
    ncfg = NetOptConfig(seed_candidates=args.seed_candidates,
                        hw_rounds=args.hw_rounds,
                        hw_per_round=args.hw_per_round,
                        layer_budget=args.layer_budget,
                        refine_budget=args.refine_budget, tuner=cfg)
    total = ncfg.total_layer_budget()
    print(f"budget: {ncfg.n_candidates} hw candidates x "
          f"{ncfg.layer_budget} + a {ncfg.layer_budget}+"
          f"{ncfg.refine_budget} refinement session = {total} "
          "measurements/layer (co-opt upper bound; its refinement replays "
          "cached rows) for every method\n")

    from repro.compiler.surrogate_store import store_from_args
    coopt = NetworkCoOptimizer(
        tasks, ncfg, records=args.records and f"{args.records}.netopt.jsonl",
        workers=args.workers, timeout_s=args.timeout_s, remote=args.remote,
        name="resnet-18", surrogates=store_from_args(args),
        monitor=args.monitor_server).run()
    if coopt.surrogates:
        print(f"surrogate transfer: {coopt.surrogates}")
    frozen = network_hw_frozen_tune(
        tasks, ncfg, records=args.records and f"{args.records}.frozen.jsonl",
        workers=args.workers, timeout_s=args.timeout_s, remote=args.remote,
        name="resnet-18", monitor=args.monitor_server)
    fantasy = Session(tasks, tuner=cfg, budget=total,
                      records=args.records and f"{args.records}.fantasy.jsonl",
                      workers=args.workers, timeout_s=args.timeout_s,
                      remote=args.remote, monitor=args.monitor_server).run()

    hw = ", ".join(f"{k}={v}" for k, v in coopt.hw_config.items())
    print(f"co-optimized       {coopt.network_latency * 1e6:10.1f} us   "
          f"shared chip [{hw}]")
    print(f"hw-frozen baseline {frozen.network_latency * 1e6:10.1f} us   "
          "default chip, software-only search")
    print(f"per-layer fantasy  {fantasy.network_latency() * 1e6:10.1f} us   "
          f"{len(tasks)} different chips (unrealizable)")

    shared = coopt.verify_shared_hardware()
    print(f"\nshared hardware config identical across all "
          f"{len(coopt.layers)} layer mappings: {shared}")
    assert shared, "co-optimization must yield ONE hardware config"
    assert coopt.network_latency <= frozen.network_latency, (
        "co-optimization found no chip at least as good as the default "
        f"({coopt.network_latency} vs {frozen.network_latency})")
    ratio = coopt.network_latency / fantasy.network_latency()
    note = ("decomposed search even beats the per-layer joint search at "
            "this budget" if ratio <= 1 else
            "remaining cost of sharing one chip")
    print(f"co-optimized vs frozen: "
          f"{frozen.network_latency / coopt.network_latency:.2f}x faster; "
          f"co-optimized / fantasy = {ratio:.2f} ({note})")
    print("\nhw-candidate progress trace (cum. measurements -> network us):")
    for meas, lat in coopt.progress():
        print(f"  {meas:6d} -> {lat * 1e6:9.1f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", type=int, default=192,
                    help="measurements/task for the software-only comparison")
    ap.add_argument("--coopt", action="store_true",
                    help="network-scope co-optimization comparison "
                         "(repro.compiler.netopt)")
    ap.add_argument("--seed-candidates", type=int, default=3)
    ap.add_argument("--hw-rounds", type=int, default=2)
    ap.add_argument("--hw-per-round", type=int, default=2)
    ap.add_argument("--layer-budget", type=int, default=16)
    ap.add_argument("--refine-budget", type=int, default=32)
    ap.add_argument("--records", default=None,
                    help="JSONL records prefix; one file per method so "
                         "no method warm-starts from another's cache")
    from repro.compiler.executor import add_worker_args, validate_worker_args
    from repro.compiler.surrogate_store import add_surrogate_args
    add_surrogate_args(ap)   # GBT warm start for --coopt (cross-network)
    add_worker_args(ap)
    args = ap.parse_args()
    validate_worker_args(ap, args)

    n_iter = max(args.budget // 32, 2)
    cfg = TunerConfig(iteration_opt=n_iter, b_measure=32,
                      episodes_per_iter=3,
                      mappo=mappo.MappoConfig(n_steps=64, n_envs=16),
                      gbt_rounds=20)
    tasks = TuningTask.conv_tasks("resnet-18")
    print(f"ResNet-18: {sum(t.multiplicity for t in tasks)} conv layers, "
          f"{len(tasks)} unique tuning tasks\n")

    # One tracer spanning every method's session: sub-runs without their
    # own trace= inherit the ambient tracer, so the whole comparison lands
    # in a single merged timeline.
    tracer = obs.Tracer(name="tune-resnet18",
                        sample_rate=args.trace_sample_rate) \
        if args.trace else None
    scope = obs.use(tracer) if tracer else contextlib.nullcontext()
    # ... and one monitor server shared (borrowed) by every sub-run: each
    # attaches its own /status source, finalized when that run ends.
    args.monitor_server = None
    if args.monitor is not None:
        args.monitor_server = obs.MonitorServer(port=args.monitor).start()
        print(f"live monitor at {args.monitor_server.url} "
              "(/metrics /status /trace)")
    try:
        with scope:
            if args.coopt:
                coopt_comparison(args, cfg, tasks)
            else:
                if args.warm_from or args.save_surrogates:
                    raise SystemExit("--warm-from/--save-surrogates apply to "
                                     "the co-optimizer; add --coopt")
                software_only_comparison(args, cfg, tasks)
    finally:
        if tracer:
            tracer.save(args.trace)
            print(f"trace written to {args.trace}")
        if args.monitor_server is not None:
            args.monitor_server.stop()


if __name__ == "__main__":
    main()
