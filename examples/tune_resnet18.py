"""Paper end-to-end flow: tune every ResNet-18 conv task, compare ARCO vs
the software-only baselines (Table 6 / Fig. 5 protocol at reduced budget).

    PYTHONPATH=src python examples/tune_resnet18.py [--budget 256]
"""
import argparse
import time

from repro.core import mappo
from repro.core.baselines import autotvm_tune, chameleon_tune
from repro.core.task import conv_tasks, network_latency
from repro.core.tuner import TunerConfig, arco_tune


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", type=int, default=192)
    args = ap.parse_args()

    n_iter = max(args.budget // 32, 2)
    cfg = TunerConfig(iteration_opt=n_iter, b_measure=32,
                      episodes_per_iter=3,
                      mappo=mappo.MappoConfig(n_steps=64, n_envs=16),
                      gbt_rounds=20)
    tasks = conv_tasks("resnet-18")
    print(f"ResNet-18: {sum(t.multiplicity for t in tasks)} conv layers, "
          f"{len(tasks)} unique tuning tasks, "
          f"budget {args.budget} measurements/task\n")

    frameworks = {"arco": arco_tune, "autotvm": autotvm_tune,
                  "chameleon": chameleon_tune}
    totals, walls = {}, {}
    for fw, tune in frameworks.items():
        t0 = time.time()
        best = {}
        for t in tasks:
            r = tune(t.space, cfg)
            best[t.name] = r.best_latency
        totals[fw] = network_latency(tasks, best)
        walls[fw] = time.time() - t0
        print(f"{fw:10s} network conv latency "
              f"{totals[fw] * 1e6:10.1f} us   tuning wall {walls[fw]:6.1f}s")

    print(f"\nthroughput vs AutoTVM*: "
          f"ARCO {totals['autotvm'] / totals['arco']:.2f}x  "
          f"(paper Fig.5: ResNet-18 ~1.38x), "
          f"CHAMELEON {totals['autotvm'] / totals['chameleon']:.2f}x")


if __name__ == "__main__":
    main()
