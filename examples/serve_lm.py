"""Batched serving demo: continuous-batching slots, per-sequence depths.

    PYTHONPATH=src python examples/serve_lm.py --requests 8 --slots 4
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import transformer as T
from repro.train.server import Request, Server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    srv = Server(params, cfg, n_slots=args.slots, max_len=128)

    rng = np.random.default_rng(0)
    for i in range(args.requests):
        srv.submit(Request(
            uid=i, prompt=rng.integers(
                0, cfg.vocab, size=int(rng.integers(4, 20))).astype(
                np.int32),
            max_new_tokens=args.max_new))
    t0 = time.time()
    done = sorted(srv.run_until_drained(), key=lambda r: r.uid)
    dt = time.time() - t0
    toks = sum(len(r.output) for r in done)
    for r in done:
        print(f"req {r.uid}: prompt[{len(r.prompt)}] -> "
              f"{r.output[:8]}{'...' if len(r.output) > 8 else ''} "
              f"({r.latency_s:.2f}s)")
    print(f"\n{len(done)} requests, {toks} tokens, "
          f"{toks / dt:.1f} tok/s with {args.slots} slots")


if __name__ == "__main__":
    main()
