"""End-to-end tuner behaviour: ARCO + baselines on real conv tasks."""
import dataclasses

import numpy as np
import pytest

from repro.core import mappo
from repro.core.baselines import (autotvm_tune, chameleon_tune,
                                  default_hardware_config, random_tune)
from repro.core.design_space import DesignSpace
from repro.core.task import conv_tasks, network_latency, total_conv_layers
from repro.core.tuner import TunerConfig, arco_tune
from repro.models import cnn

WL = dict(b=1, h=14, w=14, ci=128, co=128, kh=3, kw=3, stride=1, pad=1)
FAST = TunerConfig.fast()


@pytest.fixture(scope="module")
def space():
    return DesignSpace.for_conv2d(WL)


def test_arco_improves_over_budget(space):
    r = arco_tune(space, FAST)
    assert r.n_measurements <= FAST.iteration_opt * FAST.b_measure
    first_best = r.history[0][1]
    assert r.best_latency <= first_best
    assert np.isfinite(r.best_latency) and r.best_latency < 1.0
    # history is monotone non-increasing
    bests = [b for _, b, _ in r.history]
    assert all(b2 <= b1 * 1.0001 for b1, b2 in zip(bests, bests[1:]))


@pytest.mark.stochastic
def test_arco_beats_hw_frozen_baselines_long_run(space):
    """The paper's headline: co-optimizing hardware knobs beats software-only
    tuning (baselines run the default accelerator geometry).

    Resolved by the ROADMAP search-quality investigation (see
    ``benchmarks/search_quality_sweep.py``): with the paper's *constant*
    CS batch the surrogate refits too rarely to exploit late-run signal
    and ARCO lost to the baselines on 3/5 seeds; a decaying batch
    schedule (``TunerConfig.b_growth=0.6`` — same 288-measurement total,
    more refits) won on 5/5 swept seeds at ~1.7x below the software-only
    optimum.  Entropy 0.003..0.1 and n_steps 128 moved medians < 15%.
    Stays quarantined only because it is a multi-minute multi-seed run;
    the seeded short-horizon test below guards the same property in
    tier-1."""
    cfg = TunerConfig(iteration_opt=6, b_measure=48, episodes_per_iter=3,
                      mappo=mappo.MappoConfig(n_steps=64, n_envs=16),
                      gbt_rounds=20, b_growth=0.6)
    for seed in (0, 1, 2):
        scfg = dataclasses.replace(cfg, seed=seed)
        r_arco = arco_tune(space, scfg)
        r_atvm = autotvm_tune(space, scfg)
        r_rand = random_tune(space, scfg)
        assert r_arco.best_latency < r_atvm.best_latency, f"seed {seed}"
        assert r_arco.best_latency < r_rand.best_latency, f"seed {seed}"


def test_arco_short_horizon_convergence_deterministic(space):
    """Seeded, deterministic replacement for the long-run assertion in
    tier-1: at a fixed seed and a 160-measurement budget with the decayed
    CS batch schedule, ARCO must land within 25% of the exhaustively
    enumerated space optimum and strictly beat both hw-frozen baselines
    at the same seed and budget.  Everything is seeded (MAPPO, CS, GBT,
    the baselines' SA/sampling), so this either always passes or always
    fails — no flake budget."""
    import jax.numpy as jnp
    grids = np.meshgrid(*[np.arange(len(c)) for c in space.choices],
                        indexing="ij")
    all_cfg = np.stack([g.reshape(-1) for g in grids], axis=1)
    optimum = float(np.min(np.asarray(
        space.measure(jnp.asarray(all_cfg, jnp.int32)))))

    cfg = TunerConfig(iteration_opt=5, b_measure=32, episodes_per_iter=3,
                      mappo=mappo.MappoConfig(n_steps=48, n_envs=16),
                      gbt_rounds=20, seed=1, b_growth=0.6)
    r = arco_tune(space, cfg, budget=160)
    assert r.n_measurements <= 160
    assert r.best_latency <= optimum * 1.25
    r_atvm = autotvm_tune(space, cfg, budget=160)
    r_rand = random_tune(space, cfg, budget=160)
    assert r.best_latency < r_atvm.best_latency
    assert r.best_latency < r_rand.best_latency


def test_baselines_respect_frozen_hardware_knobs(space):
    hw_default = default_hardware_config(space)
    for tune in (random_tune, autotvm_tune, chameleon_tune):
        r = tune(space, FAST)
        np.testing.assert_array_equal(r.best_config[:3], hw_default)


def test_task_extraction_matches_table3():
    for model in cnn.MODELS:
        assert total_conv_layers(model) == cnn.expected_task_count(model)
        tasks = conv_tasks(model)
        assert sum(t.multiplicity for t in tasks) == \
            cnn.expected_task_count(model)


def test_network_latency_sums_multiplicity():
    tasks = conv_tasks("resnet-18")
    best = {t.name: 1e-3 for t in tasks}
    assert abs(network_latency(tasks, best) - 17e-3) < 1e-9


def test_results_reproducible(space):
    r1 = arco_tune(space, FAST)
    r2 = arco_tune(space, FAST)
    assert r1.best_latency == r2.best_latency
    np.testing.assert_array_equal(r1.best_config, r2.best_config)


def test_tuned_config_deployable(space):
    """The tuned configuration actually runs through the Pallas GEMM core
    and matches the conv oracle — compiler output is usable."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops, ref

    r = arco_tune(space, FAST)
    vals = np.asarray(space.values(jnp.asarray(r.best_config)))
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 14, 14, 128),
                          jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 128, 128),
                          jnp.float32)
    out = ops.conv2d_from_knobs(
        x, w, 1, 1, tile_b=int(vals[0]), tile_h=int(vals[5]),
        tile_w=int(vals[6]), tile_ci=int(vals[1]), tile_co=int(vals[2]),
        h_threading=int(vals[3]), oc_threading=int(vals[4]))
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.conv2d_ref(x, w, 1, 1)),
                               rtol=1e-4, atol=1e-4)
