"""End-to-end tuner behaviour: ARCO + baselines on real conv tasks."""
import numpy as np
import pytest

from repro.core import mappo
from repro.core.baselines import (autotvm_tune, chameleon_tune,
                                  default_hardware_config, random_tune)
from repro.core.design_space import DesignSpace
from repro.core.task import conv_tasks, network_latency, total_conv_layers
from repro.core.tuner import TunerConfig, arco_tune
from repro.models import cnn

WL = dict(b=1, h=14, w=14, ci=128, co=128, kh=3, kw=3, stride=1, pad=1)
FAST = TunerConfig.fast()


@pytest.fixture(scope="module")
def space():
    return DesignSpace.for_conv2d(WL)


def test_arco_improves_over_budget(space):
    r = arco_tune(space, FAST)
    assert r.n_measurements <= FAST.iteration_opt * FAST.b_measure
    first_best = r.history[0][1]
    assert r.best_latency <= first_best
    assert np.isfinite(r.best_latency) and r.best_latency < 1.0
    # history is monotone non-increasing
    bests = [b for _, b, _ in r.history]
    assert all(b2 <= b1 * 1.0001 for b1, b2 in zip(bests, bests[1:]))


@pytest.mark.stochastic
def test_arco_beats_hw_frozen_baselines_long_run(space):
    """The paper's headline: co-optimizing hardware knobs beats software-only
    tuning (baselines run the default accelerator geometry).

    Quarantined (fails at seed): ARCO's long-run advantage is not reproduced
    on this conv task yet — ROADMAP keeps the search-quality investigation
    (MAPPO hyperparams / CS batch schedule) open."""
    cfg = TunerConfig(iteration_opt=6, b_measure=48, episodes_per_iter=3,
                      mappo=mappo.MappoConfig(n_steps=64, n_envs=16),
                      gbt_rounds=20)
    r_arco = arco_tune(space, cfg)
    r_atvm = autotvm_tune(space, cfg)
    r_rand = random_tune(space, cfg)
    assert r_arco.best_latency < r_atvm.best_latency
    assert r_arco.best_latency < r_rand.best_latency


def test_baselines_respect_frozen_hardware_knobs(space):
    hw_default = default_hardware_config(space)
    for tune in (random_tune, autotvm_tune, chameleon_tune):
        r = tune(space, FAST)
        np.testing.assert_array_equal(r.best_config[:3], hw_default)


def test_task_extraction_matches_table3():
    for model in cnn.MODELS:
        assert total_conv_layers(model) == cnn.expected_task_count(model)
        tasks = conv_tasks(model)
        assert sum(t.multiplicity for t in tasks) == \
            cnn.expected_task_count(model)


def test_network_latency_sums_multiplicity():
    tasks = conv_tasks("resnet-18")
    best = {t.name: 1e-3 for t in tasks}
    assert abs(network_latency(tasks, best) - 17e-3) < 1e-9


def test_results_reproducible(space):
    r1 = arco_tune(space, FAST)
    r2 = arco_tune(space, FAST)
    assert r1.best_latency == r2.best_latency
    np.testing.assert_array_equal(r1.best_config, r2.best_config)


def test_tuned_config_deployable(space):
    """The tuned configuration actually runs through the Pallas GEMM core
    and matches the conv oracle — compiler output is usable."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops, ref

    r = arco_tune(space, FAST)
    vals = np.asarray(space.values(jnp.asarray(r.best_config)))
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 14, 14, 128),
                          jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 128, 128),
                          jnp.float32)
    out = ops.conv2d_from_knobs(
        x, w, 1, 1, tile_b=int(vals[0]), tile_h=int(vals[5]),
        tile_w=int(vals[6]), tile_ci=int(vals[1]), tile_co=int(vals[2]),
        h_threading=int(vals[3]), oc_threading=int(vals[4]))
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.conv2d_ref(x, w, 1, 1)),
                               rtol=1e-4, atol=1e-4)
