"""Unit + property tests for the paper's core algorithm (ARCO)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # dependency-light env: seeded spot-checks instead
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import confidence_sampling as CS
from repro.core import mappo
from repro.core.cost_model import GBTModel
from repro.core.design_space import (AGENT_KNOBS, AGENTS, DesignSpace,
                                     N_KNOBS, reward_with_penalty)
from repro.core import agents as A
from repro.hw.analytical import conv2d_min_latency

WL = dict(b=1, h=14, w=14, ci=64, co=64, kh=3, kw=3, stride=1, pad=1)


@pytest.fixture(scope="module")
def space():
    return DesignSpace.for_conv2d(WL)


# ------------------------------------------------------------ design space

def test_agent_partition_covers_all_knobs():
    got = sorted(i for ks in AGENT_KNOBS.values() for i in ks)
    assert got == list(range(N_KNOBS))
    assert set(AGENT_KNOBS) == set(AGENTS)


def test_space_values_and_clip(space):
    rng = jax.random.PRNGKey(0)
    cfgs = space.random_configs(rng, 64)
    assert cfgs.shape == (64, N_KNOBS)
    assert bool((cfgs >= 0).all())
    assert bool((np.asarray(cfgs) < space.n_choices[None, :]).all())
    vals = space.values(cfgs)
    for i, ch in enumerate(space.choices):
        assert set(np.asarray(vals)[:, i]).issubset(set(ch))


def test_measure_positive_and_beats_roofline(space):
    cfgs = space.random_configs(jax.random.PRNGKey(1), 128)
    lat = np.asarray(space.measure(cfgs))
    assert (lat > 0).all()
    # no configuration beats the roofline lower bound
    assert lat.min() >= conv2d_min_latency(WL) * 0.999


@settings(max_examples=20, deadline=None)
@given(deltas=st.lists(st.integers(-1, 1), min_size=N_KNOBS,
                       max_size=N_KNOBS))
def test_apply_deltas_stays_in_bounds(deltas):
    space = DesignSpace.for_conv2d(WL)
    cfg = jnp.zeros((N_KNOBS,), jnp.int32)
    out = np.asarray(space.apply_deltas(cfg, jnp.asarray(deltas)))
    assert (out >= 0).all() and (out < space.n_choices).all()


def test_penalty_reduces_reward():
    lat = jnp.asarray(1e-4)
    r_ok = reward_with_penalty(lat, jnp.asarray(1e6))
    r_bad = reward_with_penalty(lat, jnp.asarray(300e6))
    assert float(r_bad) < float(r_ok)


# ------------------------------------------------------- confidence sampling

def test_cs_selects_at_most_n(space):
    rng = np.random.default_rng(0)
    configs = np.asarray(space.random_configs(jax.random.PRNGKey(2), 200))
    v = rng.normal(size=200)
    out = CS.confidence_sampling(configs, v, 32, space.n_choices)
    assert len(out) <= 32
    assert out.shape[1] == N_KNOBS
    assert (out >= 0).all() and (out < space.n_choices[None]).all()


def test_cs_prefers_high_value_configs(space):
    """Probability-guided selection: high-scored configs dominate picks."""
    configs = np.asarray(space.random_configs(jax.random.PRNGKey(3), 500))
    configs = np.unique(configs, axis=0)
    v = np.linspace(-5, 5, len(configs))  # later configs better
    out = CS.confidence_sampling(configs, v, 40, space.n_choices, seed=1)
    idx_of = {tuple(c): i for i, c in enumerate(configs)}
    ranks = [idx_of[tuple(c)] for c in out if tuple(c) in idx_of]
    assert np.mean(ranks) > len(configs) * 0.6


def test_cs_threshold_is_median():
    v = np.asarray([1.0, 2.0, 3.0, 4.0, 100.0])
    assert CS.compute_dynamic_threshold(v) == 3.0


def test_cs_synthesize_modes():
    rng = np.random.default_rng(0)
    configs = np.asarray([[0, 1, 2, 0, 0, 1, 1]] * 8 + [[3, 3, 3, 1, 1, 0, 0]])
    out = CS.synthesize(configs, np.asarray([9] * 7), rng, 1)
    np.testing.assert_array_equal(out[0], [0, 1, 2, 0, 0, 1, 1])


# ----------------------------------------------------------------- MAPPO

def test_gae_matches_naive_loop():
    T, E = 7, 3
    rng = np.random.default_rng(0)
    rewards = jnp.asarray(rng.normal(size=(T, E)), jnp.float32)
    values = jnp.asarray(rng.normal(size=(T, E)), jnp.float32)
    last = jnp.asarray(rng.normal(size=(E,)), jnp.float32)
    gamma, lam = 0.9, 0.8
    advs, rets = mappo.gae(rewards, values, last, gamma, lam)

    vals = np.concatenate([np.asarray(values), np.asarray(last)[None]], 0)
    expect = np.zeros((T, E))
    running = np.zeros(E)
    for t in reversed(range(T)):
        delta = np.asarray(rewards)[t] + gamma * vals[t + 1] - vals[t]
        running = delta + gamma * lam * running
        expect[t] = running
    np.testing.assert_allclose(np.asarray(advs), expect, rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(rets),
                               expect + np.asarray(values), rtol=1e-5,
                               atol=1e-5)


def test_action_decode_roundtrip():
    for agent in AGENTS:
        n = A.AGENT_N_ACTIONS[agent]
        deltas = A.decode_action(agent, jnp.arange(n))
        assert deltas.shape == (n, A.AGENT_N_KNOBS[agent])
        assert bool((deltas >= -1).all()) and bool((deltas <= 1).all())
        # all joint adjustments distinct
        assert len(np.unique(np.asarray(deltas), axis=0)) == n


def test_mappo_episode_improves_surrogate(space):
    """Policy should climb the (fixed) surrogate over episodes."""
    hp = mappo.MappoConfig(n_steps=24, n_envs=8, epochs=4)
    env = mappo.env_params_from_space(space)
    # surrogate: GBT trained on real oracle -> dense, informative reward
    cfgs = space.random_configs(jax.random.PRNGKey(0), 256)
    gbt = GBTModel(n_rounds=16)
    gbt.update(np.asarray(space.feature_vector(cfgs)),
               -np.log(np.asarray(space.measure(cfgs))))
    forest = gbt.to_forest()
    params, opt_state = mappo.init_state(jax.random.PRNGKey(1), hp)
    rewards = []
    rng = jax.random.PRNGKey(2)
    for ep in range(12):
        rng, r = jax.random.split(rng)
        params, opt_state, visited, stats = mappo.train_episode(
            params, opt_state, r, env, forest, hp)
        rewards.append(float(stats["mean_reward"]))
    assert np.mean(rewards[-3:]) > np.mean(rewards[:3])


# ------------------------------------------------------------- cost model

def test_gbt_learns_latency_surface(space):
    cfgs = space.random_configs(jax.random.PRNGKey(5), 512)
    X = np.asarray(space.feature_vector(cfgs))
    y = -np.log(np.asarray(space.measure(cfgs)))
    m = GBTModel(n_rounds=25)
    m.update(X[:400], y[:400])
    pred = m.predict(X[400:])
    corr = np.corrcoef(pred, y[400:])[0, 1]
    assert corr > 0.8, corr


def test_gbt_jnp_matches_numpy_predict(space):
    from repro.core import cost_model as CM
    cfgs = space.random_configs(jax.random.PRNGKey(6), 128)
    X = np.asarray(space.feature_vector(cfgs))
    y = -np.log(np.asarray(space.measure(cfgs)))
    m = GBTModel(n_rounds=10)
    m.update(X, y)
    jp = np.asarray(CM.predict(m.to_forest(), jnp.asarray(X)))
    np.testing.assert_allclose(jp, m.predict(X), rtol=1e-5, atol=1e-5)
