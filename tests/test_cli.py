"""``python -m repro.compiler.cli`` — argparse smoke + JSON round-trips.

Runs ``main(argv)`` in-process (no subprocess spawn, no jax re-init) at
2-measurement budgets: the ``tune`` subcommand, its legacy flag-only
spelling, the new ``netopt`` subcommand and its baselines, and the
``--out`` JSON documents round-tripping through the typed reports.
"""
import json

import pytest

from repro.compiler.cli import main
from repro.compiler.netopt import NetworkReport
from repro.compiler.session import SessionReport


def test_tune_smoke_and_json_roundtrip(tmp_path, capsys):
    out = tmp_path / "session.json"
    rc = main(["tune", "--matmul", "64x64x64", "--budget", "2",
               "--out", str(out)])
    assert rc == 0
    # stdout is compact JSON (measurements stripped, history truncated)
    stdout = json.loads(capsys.readouterr().out)
    assert list(stdout["reports"]) == ["matmul_64x64x64"]
    assert "measurements" not in stdout["reports"]["matmul_64x64x64"]
    # the --out document is the full report and round-trips typed
    sr = SessionReport.from_dict(json.loads(out.read_text()))
    rep = sr.single
    assert rep.n_measurements == 2
    assert rep.best_latency > 0
    assert sr.network_latency() == rep.best_latency  # multiplicity 1


def test_tune_legacy_flags_without_subcommand(capsys):
    rc = main(["--matmul", "64x64x64", "--budget", "2"])
    assert rc == 0
    assert "matmul_64x64x64" in json.loads(capsys.readouterr().out)["reports"]


def test_tune_rejects_ambiguous_task_flags(capsys):
    with pytest.raises(SystemExit):
        main(["tune", "--model", "resnet-18", "--matmul", "8x8x8"])
    capsys.readouterr()


def test_tune_timeout_without_workers_errors(capsys):
    with pytest.raises(SystemExit):
        main(["tune", "--matmul", "8x8x8", "--timeout-s", "5"])
    capsys.readouterr()


def test_tune_remote_plus_workers_errors(capsys):
    with pytest.raises(SystemExit):
        main(["tune", "--matmul", "8x8x8", "--remote", "127.0.0.1:9999",
              "--workers", "2"])
    assert "mutually exclusive" in capsys.readouterr().err


def test_netopt_smoke_and_json_roundtrip(tmp_path, capsys):
    out = tmp_path / "net.json"
    rc = main(["netopt", "--model", "resnet-18", "--max-tasks", "2",
               "--seed-candidates", "2", "--hw-rounds", "0",
               "--layer-budget", "2", "--refine-budget", "2",
               "--out", str(out)])
    assert rc == 0
    stdout = json.loads(capsys.readouterr().out)
    rep = NetworkReport.from_dict(json.loads(out.read_text()))
    assert rep.to_dict() == stdout
    assert rep.algo == "netopt"
    assert len(rep.layers) == 2
    assert rep.verify_shared_hardware()
    assert rep.network_latency == pytest.approx(sum(
        l["latency"] * l["multiplicity"] for l in rep.layers.values()))
    assert rep.trace and rep.pareto()


def test_netopt_zoo_network_and_surrogate_flags(tmp_path, capsys):
    """--network picks a zoo network; --save-surrogates then --warm-from
    on a different zoo network round-trips the transfer stats."""
    store = str(tmp_path / "surr.jsonl")
    rc = main(["netopt", "--network", "bert-gemm", "--max-tasks", "1",
               "--seed-candidates", "2", "--hw-rounds", "0",
               "--layer-budget", "2", "--refine-budget", "0",
               "--save-surrogates", store])
    assert rc == 0
    rep = NetworkReport.from_dict(json.loads(capsys.readouterr().out))
    assert rep.network == "bert-gemm"
    assert rep.surrogates["hw_rows_saved"] >= 1
    rc = main(["netopt", "--network", "resnet-18", "--max-tasks", "1",
               "--seed-candidates", "2", "--hw-rounds", "0",
               "--layer-budget", "2", "--refine-budget", "0",
               "--warm-from", store])
    assert rc == 0
    rep2 = NetworkReport.from_dict(json.loads(capsys.readouterr().out))
    assert rep2.surrogates["readonly"]
    assert rep2.surrogates["warm_sw_rows"] > 0
    with pytest.raises(SystemExit):  # --network excludes --model
        main(["netopt", "--network", "resnet-18", "--model", "resnet-18"])
    capsys.readouterr()


def test_netopt_k_chips_pipeline(tmp_path, capsys):
    out = tmp_path / "k2.json"
    rc = main(["netopt", "--model", "resnet-18", "--max-tasks", "3",
               "--k-chips", "2", "--seed-candidates", "2",
               "--hw-rounds", "0", "--layer-budget", "2",
               "--refine-budget", "0", "--out", str(out)])
    assert rc == 0
    capsys.readouterr()
    rep = NetworkReport.from_dict(json.loads(out.read_text()))
    assert rep.k_chips == 2
    assert len(rep.hw_configs) == 2
    assert rep.partition["k"] == 2 and len(rep.partition["cuts"]) == 1
    assert rep.verify_shared_hardware()
    assert "pipeline" in rep.summary()


def test_netopt_baseline_genetic(capsys):
    rc = main(["netopt", "--model", "resnet-18", "--max-tasks", "2",
               "--k-chips", "2", "--seed-candidates", "1",
               "--hw-rounds", "0", "--layer-budget", "2",
               "--refine-budget", "0", "--baseline", "genetic"])
    assert rc == 0
    rep = NetworkReport.from_dict(json.loads(capsys.readouterr().out))
    assert rep.algo == "genetic"
    assert all(r["phase"] == "genetic" for r in rep.trace)
    assert rep.verify_shared_hardware()
    # equal-budget contract: n_evals = n_candidates + 1 at split budget
    assert rep.trace[0]["layer_budget"] == max(
        ((1 + 1) * 2 + 0) // (1 + 1), 1)


def test_netopt_compact_flag(tmp_path, capsys):
    store = str(tmp_path / "surr.jsonl")
    rc = main(["netopt", "--model", "resnet-18", "--max-tasks", "1",
               "--seed-candidates", "2", "--hw-rounds", "0",
               "--layer-budget", "2", "--refine-budget", "0",
               "--save-surrogates", store, "--compact"])
    assert rc == 0
    assert "compacted" in capsys.readouterr().err
    with pytest.raises(SystemExit):  # --compact without a writable store
        main(["netopt", "--model", "resnet-18", "--compact"])
    capsys.readouterr()


def test_netopt_baseline_hw_frozen(capsys):
    rc = main(["netopt", "--model", "resnet-18", "--max-tasks", "1",
               "--seed-candidates", "1", "--hw-rounds", "0",
               "--layer-budget", "2", "--refine-budget", "0",
               "--baseline", "hw-frozen"])
    assert rc == 0
    rep = NetworkReport.from_dict(json.loads(capsys.readouterr().out))
    assert rep.algo == "hw_frozen"
    assert rep.hw_candidates == 1
    assert rep.trace[0]["phase"] == "frozen"
    # equal-budget contract: the single frozen chip gets the co-optimizer's
    # whole upper-bound budget, (n_candidates + 1) * layer_budget + refine
    assert rep.trace[0]["layer_budget"] == (1 + 1) * 2 + 0
