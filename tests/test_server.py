"""Serving-stack regression tests: latency accounting, graceful
rejection, termination modes, slot reuse, interleaved-admission parity,
and abandoned-request marking — the serving bugfixes of the online-tuning
PR, pinned down.

One reduced model + one shared jitted decode function for the whole
module (every ``Server`` re-jitting its own decode would dominate the
suite's wall clock)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.train.server import (ABANDONED, DONE, QUEUED, REJECTED, Request,
                                Server)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen2-1.5b", reduced=True).with_(
        dtype=jnp.float32, param_dtype=jnp.float32, remat=False)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    decode = jax.jit(lambda p, c, t: T.decode_step(p, c, t, cfg),
                     donate_argnums=(1,))
    return cfg, params, decode


@pytest.fixture(scope="module")
def srv(setup):
    """One shared server — every test drains it before returning."""
    cfg, params, decode = setup
    return Server(params, cfg, n_slots=2, max_len=64, decode_fn=decode)


def _prompt(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab, size=n).astype(np.int32)


def test_latency_breakdown_and_slot_reuse(setup, srv):
    cfg, _, _ = setup
    reqs = [Request(uid=i, prompt=_prompt(cfg, 5 + 2 * i, seed=i),
                    max_new_tokens=4) for i in range(5)]
    for r in reqs:
        srv.submit(r)
        assert r.status == QUEUED and r.submit_s is not None
    done = srv.run_until_drained()
    # 5 requests through 2 slots: slots were freed and reused mid-batch
    assert len(done) == 5 and not srv.abandoned
    assert sorted(srv.free) == [0, 1] and not srv.active
    for r in done:
        assert r.status == DONE and r.ok
        assert len(r.output) == r.max_new_tokens
        # end-to-end latency spans submit -> finish and decomposes into
        # the queue/prefill/decode breakdown (the pre-fix timer started
        # after prefill and missed the first two entirely)
        assert r.queue_s >= 0 and r.prefill_s > 0 and r.decode_s > 0
        assert r.latency_s == pytest.approx(
            r.queue_s + r.prefill_s + r.decode_s, rel=1e-6)
        assert r.latency_s > r.decode_s  # prefill is visible in the total
    # the 5th request waited for a slot: real queue time on record
    assert done[-1].finish_s > done[0].finish_s


def test_oversized_and_empty_prompts_rejected(setup, srv):
    cfg, _, _ = setup
    base_rejected = len(srv.rejected)
    too_long = srv.submit(Request(uid=100, prompt=_prompt(cfg, 64),
                                  max_new_tokens=4))
    empty = srv.submit(Request(
        uid=101, prompt=np.zeros(0, np.int32), max_new_tokens=4))
    for r, frag in ((too_long, "max_len"), (empty, "empty")):
        assert r.status == REJECTED and not r.ok
        assert frag in r.error
        assert r.output == [] and r.latency_s is None
    assert len(srv.rejected) == base_rejected + 2
    assert not srv.queue  # neither was admitted
    # the slot cache is uncorrupted: a valid request still serves
    ok = srv.submit(Request(uid=102, prompt=_prompt(cfg, 6),
                            max_new_tokens=3))
    assert srv.run_until_drained() == [ok] and ok.status == DONE


def test_eos_and_too_long_termination(setup, srv):
    cfg, _, _ = setup
    prompt = _prompt(cfg, 8, seed=7)
    ref = srv.submit(Request(uid=110, prompt=prompt, max_new_tokens=6))
    srv.run_until_drained()
    # greedy decode is deterministic: replaying the same prompt with
    # eos_id set to a known upcoming token must stop right there
    eos = ref.output[2]
    if eos not in ref.output[:2]:  # eos earlier would end sooner
        again = srv.submit(Request(uid=111, prompt=prompt,
                                   max_new_tokens=6, eos_id=int(eos)))
        srv.run_until_drained()
        assert again.output == ref.output[:3]
        assert again.status == DONE
    # near-full context: generation is cut off at max_len, not run over
    long = srv.submit(Request(uid=112, prompt=_prompt(cfg, 55),
                              max_new_tokens=100))
    srv.run_until_drained()
    assert long.status == DONE
    assert len(long.output) < 100
    assert 55 + len(long.output) >= srv.max_len - 2


def test_interleaved_vs_sequential_parity(setup, srv):
    cfg, _, _ = setup
    pa, pb = _prompt(cfg, 9, seed=11), _prompt(cfg, 7, seed=12)
    # sequential references, one at a time on the drained server
    ra = srv.submit(Request(uid=120, prompt=pa, max_new_tokens=10))
    srv.run_until_drained()
    rb = srv.submit(Request(uid=121, prompt=pb, max_new_tokens=6))
    srv.run_until_drained()
    # interleaved: B joins while A is mid-decode
    ia = srv.submit(Request(uid=122, prompt=pa, max_new_tokens=10))
    for _ in range(3):
        srv.step()
    ib = srv.submit(Request(uid=123, prompt=pb, max_new_tokens=6))
    srv.run_until_drained()
    assert ia.output == ra.output
    assert ib.output == rb.output


def test_abandoned_requests_marked_loudly(setup, srv):
    cfg, _, _ = setup
    base_abandoned = len(srv.abandoned)
    active = [srv.submit(Request(uid=130 + i, prompt=_prompt(cfg, 5, seed=i),
                                 max_new_tokens=500))
              for i in range(2)]
    queued = srv.submit(Request(uid=140, prompt=_prompt(cfg, 5),
                                max_new_tokens=4))
    done = srv.run_until_drained(max_steps=3)
    # nothing finished — but nothing is silent either
    assert done == []
    assert len(srv.abandoned) == base_abandoned + 3
    for r in active:
        assert r.status == ABANDONED and not r.ok
        assert r.latency_s is None and r.decode_s is None
        assert r.output  # partial generation is preserved
    assert queued.status == ABANDONED and queued.output is None
    # the server recovered its capacity: slots free, queue empty
    assert sorted(srv.free) == [0, 1] and not srv.active and not srv.queue
    ok = srv.submit(Request(uid=141, prompt=_prompt(cfg, 5),
                            max_new_tokens=3))
    assert srv.run_until_drained() == [ok] and ok.status == DONE
