"""Make ``python -m pytest`` work from the repo root with no environment
setup: puts ``src`` (the repro package) and this directory (the
``_hypothesis_compat`` shim) on ``sys.path`` before collection."""
import os
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
_SRC = os.path.abspath(os.path.join(_HERE, "..", "src"))

for _p in (_SRC, _HERE):
    if _p not in sys.path:
        sys.path.insert(0, _p)
