"""Dry-run pipeline tests (subprocess with 8 placeholder devices; the
production 512-device sweep artifacts live in artifacts/dryrun)."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def run_dryrun(args, devices=8, timeout=420):
    env = dict(os.environ, PYTHONPATH=SRC,
               REPRO_DRYRUN_DEVICES=str(devices))
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun"] + args,
        capture_output=True, text=True, timeout=timeout, env=env)
    return res


@pytest.mark.parametrize("arch,shape", [
    ("qwen2-1.5b", "train_4k"),
    ("whisper-base", "decode_32k"),
    ("xlstm-1.3b", "long_500k"),
])
def test_dryrun_cell_compiles(tmp_path, arch, shape):
    res = run_dryrun(["--arch", arch, "--shape", shape, "--mesh", "pod",
                      "--batch", "8", "--out", str(tmp_path)])
    assert res.returncode == 0, res.stdout + res.stderr
    files = [f for f in os.listdir(tmp_path) if f.endswith(".json")]
    assert len(files) == 1
    art = json.load(open(tmp_path / files[0]))
    assert art["status"] == "ok"
    assert art["weighted"]["dot_flops_per_device"] > 0
    assert art["temp_size_in_bytes"] > 0


def test_dryrun_multipod_axis_shards(tmp_path):
    res = run_dryrun(["--arch", "smollm-360m", "--shape", "train_4k",
                      "--mesh", "multipod", "--batch", "8",
                      "--out", str(tmp_path)])
    assert res.returncode == 0, res.stdout + res.stderr
    art = json.load(open(tmp_path / os.listdir(tmp_path)[0]))
    assert art["status"] == "ok"
    assert "pod=2" in art["mesh_desc"]


def test_dryrun_long_context_skip(tmp_path):
    res = run_dryrun(["--arch", "qwen2-1.5b", "--shape", "long_500k",
                      "--mesh", "pod", "--out", str(tmp_path)])
    assert res.returncode == 0
    art = json.load(open(tmp_path / os.listdir(tmp_path)[0]))
    assert art["status"] == "skipped"
    assert "full-attention" in art["reason"]


@pytest.mark.skipif(not os.path.isdir(ART),
                    reason="production sweep artifacts not generated")
def test_production_sweep_complete():
    """The committed 512-device sweep must cover all 80 cells, no errors."""
    arts = [json.load(open(os.path.join(ART, f)))
            for f in os.listdir(ART) if f.endswith(".json")]
    assert len(arts) == 80
    by_status = {}
    for a in arts:
        by_status.setdefault(a["status"], []).append(a)
    assert "error" not in by_status, [
        (a["arch"], a["shape"]) for a in by_status["error"]]
    assert len(by_status["ok"]) == 66
    assert len(by_status["skipped"]) == 14  # 7 full-attn archs x 2 meshes
    for a in by_status["ok"]:
        assert a["weighted"]["dot_flops_per_device"] > 0
