"""Workload zoo + cross-network surrogate transfer + bench artifacts.

Covers ``repro.compiler.zoo`` (registry, typed networks, the pod proxy
oracle), ``repro.compiler.surrogate_store`` (JSONL round-trip, dedup,
schema-mismatch rejection, dimension/network filtering, warm starts),
the ``surrogates=`` wiring through ``Session`` and ``netopt`` (transfer
stats, GBT-ranked warm seeding, the warm-from-self == record-replay
invariant), the new surrogate fields in the report round-trips, and the
hardened ``repro-bench/2`` artifact writer.
"""
import glob
import importlib.util
import json
import os
import sys

import numpy as np
import pytest

from repro.compiler.netopt import (NetOptConfig, NetworkCoOptimizer,
                                   NetworkReport, network_hw_frozen_tune)
from repro.compiler.session import Session, SessionReport
from repro.compiler.surrogate_store import (RecordingGBT, SCHEMA,
                                            SurrogateSchemaError,
                                            SurrogateStore)
from repro.compiler.task import TuningTask
from repro.compiler.zoo import NetworkTask, ZOO, get_network, network_names
from repro.core import mappo
from repro.core.cost_model import GBTModel
from repro.core.design_space import DesignSpace
from repro.core.tuner import TunerConfig

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

TINY = TunerConfig(iteration_opt=2, b_measure=6, episodes_per_iter=2,
                   mappo=mappo.MappoConfig(n_steps=12, n_envs=8),
                   gbt_rounds=8)
WL_A1 = dict(b=1, h=14, w=14, ci=256, co=256, kh=3, kw=3, stride=1, pad=1)
WL_A2 = dict(b=1, h=28, w=28, ci=128, co=128, kh=3, kw=3, stride=1, pad=1)
WL_B1 = dict(b=1, h=14, w=14, ci=128, co=256, kh=3, kw=3, stride=1, pad=1)
WL_B2 = dict(b=1, h=28, w=28, ci=128, co=256, kh=3, kw=3, stride=1, pad=1)


def _net(name, *wls):
    return [TuningTask.from_space(f"{name}{i}", DesignSpace.for_conv2d(wl))
            for i, wl in enumerate(wls)]


def _tiny_netcfg(**kw):
    base = dict(seed_candidates=2, hw_rounds=1, hw_per_round=1,
                layer_budget=6, refine_budget=4, tuner=TINY)
    base.update(kw)
    return NetOptConfig(**base)


def _load_benchmarks(name):
    path = os.path.join(ROOT, "benchmarks", f"{name}.py")
    if os.path.join(ROOT, "benchmarks") not in sys.path:
        sys.path.insert(0, os.path.join(ROOT, "benchmarks"))
    spec = importlib.util.spec_from_file_location(name, path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ------------------------------------------------------------------- zoo

def test_zoo_registry_covers_required_families():
    names = network_names()
    assert len(names) >= 5
    assert {"resnet-18", "vgg-11", "mobilenet-dw", "bert-gemm",
            "pod-cells"} <= set(names)
    kinds = {get_network(n).kind for n in names}
    assert {"conv", "gemm", "pod"} <= kinds
    with pytest.raises(KeyError):
        get_network("no-such-network")


@pytest.mark.parametrize("name", sorted(ZOO))
def test_zoo_networks_build_and_measure(name):
    net = get_network(name)
    assert isinstance(net, NetworkTask)
    assert net.n_tasks >= 3 and net.n_layers >= net.n_tasks
    assert name in net.summary()
    task_names = [t.name for t in net.tasks]
    assert len(set(task_names)) == len(task_names)
    for t in net.tasks[:2]:
        d = t.descriptor()
        assert d.shape == (11,) and np.isfinite(d).all()
        # one oracle measurement per network family stays cheap and finite
        oracle = t.make_oracle()
        lat, feats = oracle.measure(np.zeros((1, t.space.n_knobs), np.int64))
        assert np.isfinite(lat).all() and lat[0] > 0
        assert feats.shape == (1, 18)


def test_zoo_pod_proxy_prefers_parallelism():
    """The pod proxy must reward sharding enough that search has signal:
    TP=4 on the train cell beats TP=max on nothing else changed? No —
    just assert the proxy separates configs instead of being flat."""
    net = get_network("pod-cells")
    space = net.tasks[0].space
    cfgs = np.zeros((space.n_knobs,), np.int64)
    lats = []
    for j in range(len(space.choices[0])):
        c = cfgs.copy()
        c[0] = j
        lats.append(float(space.measure(c[None])[0]))
    assert len(set(lats)) > 1  # model-axis degree matters
    assert all(np.isfinite(lats))


# -------------------------------------------------------- surrogate store

def test_store_roundtrip_dedup_and_filters(tmp_path):
    path = str(tmp_path / "s.jsonl")
    store = SurrogateStore(path)
    assert not store.exists()
    assert store.rows("sw", 18)[0].shape == (0, 18)
    x = np.arange(18, dtype=np.float32) / 10
    assert store.add("sw", x, 1.5, network="netA")
    assert not store.add("sw", x, 1.5, network="netA")   # exact dup
    assert store.add("sw", x, 2.5, network="netB")       # new target
    assert store.add("hw", np.ones(14), 0.5, network="netA")
    # a fresh instance reloads (and re-dedups) from disk
    back = SurrogateStore(path)
    assert back.counts() == {"sw": 2, "hw": 1}
    assert back.networks() == ("netA", "netB")
    X, y = back.rows("sw", 18)
    assert X.shape == (2, 18) and set(y.tolist()) == {1.5, 2.5}
    X, y = back.rows("sw", 18, exclude_network="netA")
    assert y.tolist() == [2.5]
    assert back.rows("sw", 14)[0].shape == (0, 14)  # dim filter
    # family filter: pod rows reuse the 18-dim layout with different
    # semantics and must never reach a core GBT (and vice versa)
    assert back.add("sw", x + 1, 3.5, network="podnet", family="pod")
    assert back.rows("sw", 18)[1].tolist() == [1.5, 2.5]
    assert back.rows("sw", 18, family="pod")[1].tolist() == [3.5]
    assert not back.add("sw", x, 2.5, network="netB")  # dup across reload
    # merge is schema-checked, deduplicated, and family-preserving
    other = SurrogateStore(str(tmp_path / "t.jsonl"))
    assert other.merge_from(path) == 4
    assert other.merge_from(path) == 0
    assert other.rows("sw", 18, family="pod")[1].tolist() == [3.5]
    # readonly stores never write
    ro = SurrogateStore(path, readonly=True)
    assert not ro.add("sw", np.zeros(18), 9.0)
    assert SurrogateStore(path).counts() == {"sw": 3, "hw": 1}
    with pytest.raises(ValueError):
        store.add("bogus-kind", x, 0.0)


def test_store_rejects_schema_mismatch(tmp_path):
    path = str(tmp_path / "stale.jsonl")
    with open(path, "w") as f:
        f.write(json.dumps({"schema": "repro-surrogate/0", "kind": "sw",
                            "dim": 2, "x": [0.0, 1.0], "y": 1.0}) + "\n")
    with pytest.raises(SurrogateSchemaError):
        SurrogateStore(path).counts()
    with open(path, "w") as f:
        f.write(json.dumps({"schema": SCHEMA, "kind": "wat", "dim": 1,
                            "x": [0.0], "y": 1.0}) + "\n")
    with pytest.raises(SurrogateSchemaError):
        SurrogateStore(path).rows("sw", 18)
    # and a valid store keeps working after the check
    ok = str(tmp_path / "ok.jsonl")
    s = SurrogateStore(ok)
    s.add("sw", np.zeros(18), 1.0)
    assert SurrogateStore(ok).counts()["sw"] == 1


def test_recording_gbt_tees_updates_but_not_primes(tmp_path):
    store = SurrogateStore(str(tmp_path / "s.jsonl"))
    gbt = RecordingGBT(n_rounds=4, n_features=18, store=store,
                       network="netA")
    rng = np.random.default_rng(0)
    Xp, yp = rng.random((5, 18)), rng.random(5)
    gbt.prime(Xp, yp)                      # warm start: not recorded
    assert store.counts()["sw"] == 0
    X, y = rng.random((3, 18)), rng.random(3)
    gbt.update(X, y)                       # real training rows: recorded
    assert store.counts()["sw"] == 3
    assert gbt.n_samples == 8
    # warm_start routes through prime (no re-recording) and respects
    # the exclude-own-network rule
    g2 = GBTModel(n_rounds=4, n_features=18)
    assert store.warm_start(g2, "sw") == 3
    assert g2.n_samples == 3
    g3 = RecordingGBT(n_rounds=4, n_features=18, store=store,
                      network="netB")
    assert store.warm_start(g3, "sw", exclude_network="netA") == 0
    assert store.counts()["sw"] == 3
    # executor failure-penalty rows train the in-run GBT but are never
    # persisted (a transient worker crash must not poison every later
    # network's warm start); deterministic analytical infeasibility
    # (the 1e12 sentinel) IS transferable knowledge and passes through
    from repro.compiler.oracle import Oracle
    lats = np.asarray([Oracle.penalty_latency, 1e12, 1e-4])
    gbt.update(rng.random((3, 18)), -np.log(lats))
    assert gbt.n_samples == 11
    assert store.counts()["sw"] == 5  # penalty row dropped, other 2 kept


# --------------------------------------------------------------- session

def test_session_saves_and_warm_starts_sw_rows(tmp_path):
    path = str(tmp_path / "surr.jsonl")
    t_a = TuningTask.from_space("a", DesignSpace.for_conv2d(WL_A1))
    t_b = TuningTask.from_space("b", DesignSpace.for_conv2d(WL_B1))
    sr_a = Session(t_a, tuner=TINY, budget=6, surrogates=path).run()
    assert sr_a.surrogates["warm_sw_rows"] == 0
    n_rows = SurrogateStore(path).counts()["sw"]
    assert n_rows >= 6
    sr_b = Session(t_b, tuner=TINY, budget=6, surrogates=path).run()
    assert sr_b.surrogates["warm_sw_rows"] == n_rows
    # re-running the same task set excludes its own rows (self-transfer
    # is a no-op by design)
    sr_a2 = Session(t_a, tuner=TINY, budget=6, surrogates=path).run()
    assert sr_a2.surrogates["warm_sw_rows"] == \
        SurrogateStore(path).counts()["sw"] - n_rows
    with pytest.raises(ValueError):
        Session(t_a, tuner=TINY, budget=4, surrogates=path,
                gbt=GBTModel(n_rounds=4))
    with pytest.raises(ValueError):
        Session(t_a, tuner=TINY, budget=4, surrogates=path,
                share_cost_model=False)


# ----------------------------------------------------- netopt transfer

def test_netopt_transfer_stats_and_warm_seeding(tmp_path):
    cfg = _tiny_netcfg(seed_candidates=3)
    path = str(tmp_path / "surr.jsonl")
    net_a, net_b = _net("a", WL_A1, WL_A2), _net("b", WL_B1, WL_B2)
    ra = NetworkCoOptimizer(net_a, cfg, name="netA",
                            surrogates=path).run()
    assert ra.surrogates["warm_hw_rows"] == 0
    assert ra.surrogates["warm_sw_rows"] == 0
    assert not ra.surrogates["warm_seeded"]
    # >= : the refine pass re-evaluates the winner and appends one more
    # hw row whenever it improves the candidate's latency
    assert ra.surrogates["hw_rows_saved"] >= ra.hw_candidates
    counts = SurrogateStore(path).counts()
    assert counts["hw"] == ra.surrogates["hw_rows_saved"]
    assert counts["sw"] > 0

    rb = NetworkCoOptimizer(net_b, cfg, name="netB",
                            surrogates=path).run()
    assert rb.surrogates["warm_hw_rows"] == counts["hw"]
    assert rb.surrogates["warm_sw_rows"] == counts["sw"]
    assert rb.surrogates["warm_seeded"]
    # warm seeding keeps the two guaranteed seeds: the default chip and
    # the largest geometry (frontier probe)
    default = rb.trace[0]["hw"]
    hw = NetworkCoOptimizer(net_b, cfg, name="x").hw
    assert default == dict(zip(
        ("tile_b", "tile_ci", "tile_co"), hw.default_values(net_b)))
    assert rb.trace[1]["hw"] == dict(zip(
        ("tile_b", "tile_ci", "tile_co"),
        (c[-1] for c in hw.choices)))
    # the frozen baseline records transfer stats too (it shares the store
    # machinery), and co-opt still dominates it at equal budget
    frozen = network_hw_frozen_tune(net_b, cfg, name="netB-frozen",
                                    surrogates=path)
    assert frozen.surrogates["warm_sw_rows"] > 0
    assert rb.network_latency <= frozen.network_latency


def test_netopt_warm_from_self_still_replays_with_zero_measurements(
        tmp_path):
    """Transfer and replay must stay orthogonal: re-running a network
    against its own records AND its own store (which may also hold other
    networks' rows) replays bit-identically — own-network rows are
    excluded from the warm start, so the search trajectory is unchanged
    and every measurement hits the record cache."""
    cfg = _tiny_netcfg(seed_candidates=3)
    store = str(tmp_path / "surr.jsonl")
    records = str(tmp_path / "b.records.jsonl")
    # the store starts with a foreign network's rows (the realistic case)
    NetworkCoOptimizer(_net("a", WL_A1), cfg, name="netA",
                       surrogates=store).run()
    net_b = _net("b", WL_B1, WL_B2)
    r1 = NetworkCoOptimizer(net_b, cfg, records=records, name="netB",
                            surrogates=store).run()
    assert r1.total_measurements > 0
    r2 = NetworkCoOptimizer(net_b, cfg, records=records, name="netB",
                            surrogates=store).run()
    assert r2.total_measurements == 0
    assert r2.hw_config == r1.hw_config
    assert r2.network_latency == r1.network_latency
    assert r2.surrogates["warm_hw_rows"] == r1.surrogates["warm_hw_rows"]


# ------------------------------------------------- report round-trips

def test_network_report_roundtrips_surrogate_fields(tmp_path):
    cfg = _tiny_netcfg()
    rep = NetworkCoOptimizer(_net("a", WL_A1), cfg, name="netA",
                             surrogates=str(tmp_path / "s.jsonl")).run()
    assert rep.surrogates["hw_rows_saved"] >= 1
    back = NetworkReport.from_dict(json.loads(json.dumps(rep.to_dict())))
    assert back.surrogates == rep.surrogates
    assert back.measurements_to(rep.network_latency) == \
        rep.measurements_to(rep.network_latency)
    assert rep.measurements_to(0.0) is None
    # an infinitely lax target is hit inside the FIRST candidate's session
    # (the within-candidate trajectory resolves it at or before the
    # candidate's cumulative spend)
    hit = rep.measurements_to(float("inf"))
    assert 0 < hit <= int(rep.trace[0]["cum_measurements"])
    # old documents (no surrogates key) deserialize with the default
    d = rep.to_dict()
    d.pop("surrogates")
    assert NetworkReport.from_dict(d).surrogates == {}


def test_session_report_roundtrips_surrogate_fields(tmp_path):
    t = TuningTask.from_space("a", DesignSpace.for_conv2d(WL_A1))
    sr = Session(t, tuner=TINY, budget=6,
                 surrogates=str(tmp_path / "s.jsonl")).run()
    back = SessionReport.from_dict(json.loads(json.dumps(sr.to_dict())))
    assert back.surrogates == sr.surrogates
    assert back.single.to_dict() == sr.single.to_dict()  # TuneReport trip
    d = sr.to_dict()
    d.pop("surrogates")
    assert SessionReport.from_dict(d).surrogates == {}


# ------------------------------------------------------ bench artifacts

def test_write_bench_artifact_includes_git_rev_and_validates(tmp_path):
    tr = _load_benchmarks("tuning_runs")
    path = str(tmp_path / "BENCH_x.json")
    doc = tr.write_bench_artifact(path, "x", {"m": 1.0}, config={"n": 2})
    assert doc["schema"] == tr.BENCH_SCHEMA == "repro-bench/2"
    assert doc["git_rev"] and isinstance(doc["git_rev"], str)
    assert tr.validate_bench_doc(json.load(open(path))) == doc
    for bad in (
            {**doc, "schema": "repro-bench/0"},
            {**doc, "metrics": {}},
            {**doc, "metrics": {"m": float("nan")}},
            {**doc, "metrics": {"m": {"nested": 1.0}}},
            {**doc, "metrics": {"m": True}},
            {**doc, "git_rev": ""},
            {**doc, "config": None},
    ):
        with pytest.raises(ValueError):
            tr.validate_bench_doc(bad)
    with pytest.raises(ValueError):  # rejected before touching disk
        tr.write_bench_artifact(str(tmp_path / "BENCH_bad.json"), "x",
                                {"m": float("inf")}, config={})
    assert not os.path.exists(str(tmp_path / "BENCH_bad.json"))


def test_committed_bench_artifacts_are_valid():
    tr = _load_benchmarks("tuning_runs")
    paths = sorted(glob.glob(os.path.join(ROOT, "BENCH_*.json")))
    assert {os.path.basename(p) for p in paths} >= \
        {"BENCH_netopt.json", "BENCH_transfer.json", "BENCH_hetero.json",
         "BENCH_serve.json"}
    for p in paths:
        doc = tr.validate_bench_doc(json.load(open(p)))
        assert doc["git_rev"] != "unknown", p


def test_hetero_bench_artifact_shows_pipeline_win():
    """The committed BENCH_hetero.json must demonstrate the netopt-v2
    headline: on the mixed conv+GEMM network, K=2 pipeline co-optimization
    strictly beats BOTH the single-chip K=1 run and the DiGamma-style
    genetic baseline on end-to-end latency at equal budget."""
    with open(os.path.join(ROOT, "BENCH_hetero.json")) as f:
        doc = json.load(f)
    m = doc["metrics"]
    assert m["k2_network_latency_s"] < m["k1_network_latency_s"]
    assert m["k2_network_latency_s"] < m["genetic_network_latency_s"]
    assert m["k2_speedup_vs_k1"] > 1.0
    assert m["k2_speedup_vs_genetic"] > 1.0
    # the pipeline cut is interior (a real 2-stage partition, not a
    # degenerate everything-on-one-chip split)
    assert 0 < m["k2_cut"] < 12


def test_serve_bench_artifact_shows_online_tuning_win():
    """The committed BENCH_serve.json must demonstrate the
    tuning-as-a-service headline: on the synthetic million-request trace
    the online search converged to within 10% of the offline-tuned
    geometry, p99-SLA violations stayed under 3%, and the post-tuning
    phase beats the default-geometry baseline on both p99 latency and
    tokens/sec — with end-to-end (queue + prefill + decode) latency
    accounting."""
    with open(os.path.join(ROOT, "BENCH_serve.json")) as f:
        doc = json.load(f)
    m = doc["metrics"]
    assert m["served_requests"] >= 1_000_000
    assert m["online_offline_min_ratio"] >= 0.9
    assert m["sla_violation_pct"] < 3.0
    assert m["after_p99_latency_s"] < m["before_p99_latency_s"]
    assert m["after_tokens_per_sec"] > m["before_tokens_per_sec"]
    assert m["throughput_gain_x"] > 1.0
    # measurements ran as best-effort work: some were preempted by live
    # traffic, and the idle time they consumed is accounted
    assert m["measurements"] > 0 and m["measurements_preempted"] > 0
    assert m["measure_idle_s"] > 0
    # end-to-end accounting: queue wait is visible in the latency numbers
    # (p99 before tuning reflects burst queueing, not just decode time)
    assert m["mean_queue_s"] > 0
    assert m["before_p99_latency_s"] > 50 * m["online_decode_step_s"]


def test_transfer_bench_artifact_shows_transfer_win():
    """The committed BENCH_transfer.json must demonstrate the headline:
    on at least one zoo pair the transferred run reached the cold run's
    best latency with fewer new measurements, and the warm-from-self leg
    replayed with zero new measurements."""
    with open(os.path.join(ROOT, "BENCH_transfer.json")) as f:
        doc = json.load(f)
    m = doc["metrics"]
    pairs = {k.split("/")[0] for k in m if "/" in k}
    assert pairs
    wins = 0
    for p in pairs:
        assert m[f"{p}/warm_self_new_measurements"] == 0.0
        reached = m[f"{p}/transfer_measurements_to_cold_best"]
        if 0 <= reached < m[f"{p}/cold_measurements_to_best"]:
            wins += 1
    assert wins >= 1, f"no pair shows a transfer win: {m}"
