"""Per-architecture smoke tests (reduced configs) + decode consistency."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_NAMES, all_configs, get_config
from repro.configs.shapes import SHAPES, cell_supported, input_specs
from repro.models import transformer as T


def _batch_for(cfg, rng, b=2, s=48):
    text = s - cfg.vision_prefix if cfg.vision_prefix else s
    batch = {"tokens": jax.random.randint(rng, (b, text), 0, cfg.vocab),
             "labels": jax.random.randint(rng, (b, text), 0, cfg.vocab)}
    if cfg.vision_prefix:
        batch["patches"] = jax.random.normal(
            rng, (b, cfg.vision_prefix, cfg.d_model), cfg.dtype)
    if cfg.enc_dec:
        batch["frames"] = jax.random.normal(
            rng, (b, cfg.enc_seq, cfg.d_model), cfg.dtype)
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_reduced_train_step(arch):
    """One forward/loss on CPU: correct shapes, finite, loss ~ log V."""
    cfg = get_config(arch, reduced=True)
    rng = jax.random.PRNGKey(0)
    params = T.init_params(rng, cfg)
    batch = _batch_for(cfg, rng)
    loss, metrics = T.loss_fn(params, batch, cfg)
    assert np.isfinite(float(loss))
    assert 0.5 * np.log(cfg.vocab) < float(loss) < 2.5 * np.log(cfg.vocab)
    grads = jax.grad(lambda p: T.loss_fn(p, batch, cfg)[0])(params)
    gn = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_reduced_prefill_decode(arch):
    cfg = get_config(arch, reduced=True)
    rng = jax.random.PRNGKey(0)
    params = T.init_params(rng, cfg)
    batch = {k: v for k, v in _batch_for(cfg, rng).items() if k != "labels"}
    logits, cache = T.prefill(params, batch, cfg, max_len=64)
    assert logits.shape == (2, cfg.vocab)
    tok = jnp.argmax(logits, -1)[:, None]
    logits2, cache2 = T.decode_step(params, cache, tok, cfg)
    assert logits2.shape == (2, cfg.vocab)
    assert bool(jnp.isfinite(logits2).all())
    assert bool((cache2["pos"] == cache["pos"] + 1).all())


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "mixtral-8x22b",
                                  "xlstm-1.3b", "jamba-1.5-large-398b",
                                  "whisper-base"])
def test_decode_matches_prefill_fp32(arch):
    """Teacher-forced decode must reproduce prefill logits (fp32)."""
    cfg = get_config(arch, reduced=True).with_(
        remat=False, dtype=jnp.float32, param_dtype=jnp.float32)
    rng = jax.random.PRNGKey(0)
    params = T.init_params(rng, cfg)
    s = 13
    batch = _batch_for(cfg, rng, b=1, s=s)
    batch.pop("labels")
    full_tokens = batch["tokens"]
    pre = dict(batch, tokens=full_tokens[:, :s - 1 - (cfg.vision_prefix and 0)])
    pre["tokens"] = full_tokens[:, :-1]
    _, cache = T.prefill(params, pre, cfg, max_len=32)
    ld, _ = T.decode_step(params, cache, full_tokens[:, -1:], cfg)
    lfull, _ = T.prefill(params, batch, cfg, max_len=32)
    np.testing.assert_allclose(np.asarray(ld), np.asarray(lfull),
                               rtol=1e-3, atol=1e-4)


def test_swa_ring_cache_long_decode():
    """Mixtral ring cache: decoding past the window stays finite and
    matches a non-ring cache within the window."""
    cfg = get_config("mixtral-8x22b", reduced=True).with_(
        remat=False, dtype=jnp.float32, param_dtype=jnp.float32)
    assert cfg.swa_window == 16
    rng = jax.random.PRNGKey(0)
    params = T.init_params(rng, cfg)
    toks = jax.random.randint(rng, (1, 40), 0, cfg.vocab)
    # ring cache: max_len == window -> ring buffer
    _, ring_cache = T.prefill(params, {"tokens": toks[:, :8]}, cfg,
                              max_len=cfg.swa_window)
    # big cache: no ring
    _, big_cache = T.prefill(params, {"tokens": toks[:, :8]}, cfg,
                             max_len=64)
    for i in range(8, 30):
        lr, ring_cache = T.decode_step(params, ring_cache, toks[:, i:i + 1],
                                       cfg)
        lb, big_cache = T.decode_step(params, big_cache, toks[:, i:i + 1],
                                      cfg)
        np.testing.assert_allclose(np.asarray(lr), np.asarray(lb),
                                   rtol=2e-3, atol=1e-3)


def test_param_counts_full_configs():
    """Full (non-reduced) configs hit their published scale (abstract)."""
    expected = {  # total params, tolerance band
        "qwen2-1.5b": (1.2e9, 2.2e9),
        "smollm-360m": (0.3e9, 0.5e9),
        "qwen1.5-4b": (3e9, 5e9),
        "minitron-4b": (3.4e9, 5.8e9),
        "mixtral-8x22b": (1.2e11, 1.6e11),
        # the assigned 48L x 64e x d_ff=1408 config totals ~28B with ~4B
        # active (a3b-class active size; see DESIGN.md)
        "moonshot-v1-16b-a3b": (2.4e10, 3.2e10),
        "jamba-1.5-large-398b": (3.2e11, 4.6e11),
        "xlstm-1.3b": (0.9e9, 1.8e9),
        "internvl2-26b": (1.5e10, 2.6e10),  # backbone only (no ViT)
        "whisper-base": (0.5e8, 1.2e8),
    }
    for arch, (lo, hi) in expected.items():
        cfg = get_config(arch)
        ab = T.abstract_params(jax.random.PRNGKey(0), cfg)
        n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(ab))
        assert lo <= n <= hi, f"{arch}: {n:.3e} not in [{lo:.1e},{hi:.1e}]"


def test_long_context_rule():
    sub_q = {a for a in ARCH_NAMES
             if cell_supported(get_config(a), SHAPES["long_500k"])[0]}
    assert sub_q == {"mixtral-8x22b", "xlstm-1.3b", "jamba-1.5-large-398b"}


@pytest.mark.parametrize("arch", ARCH_NAMES)
@pytest.mark.parametrize("shape", list(SHAPES))
def test_input_specs_no_allocation(arch, shape):
    cfg = get_config(arch)
    cell = SHAPES[shape]
    ok, _ = cell_supported(cfg, cell)
    if not ok:
        pytest.skip("cell skipped by long-context rule")
    spec = input_specs(cfg, cell)
    for leaf in jax.tree.leaves(spec):
        assert isinstance(leaf, jax.ShapeDtypeStruct)
    if cell.kind == "train":
        assert spec["tokens"].shape[0] == cell.global_batch
    if cell.kind == "decode":
        assert spec["tokens"].shape == (cell.global_batch, 1)


def test_moe_dense_vs_dropping_close():
    """With generous capacity, dropping == dense routing math."""
    from repro.models import moe as MOE
    rng = jax.random.PRNGKey(0)
    p = MOE.init_moe(rng, 32, 64, 4, jnp.float32)
    x = jax.random.normal(rng, (2, 16, 32), jnp.float32)
    yd, _ = MOE.moe_dense(x, p, 2)
    yc, _ = MOE.moe_dropping(x, p, 2, capacity_factor=4.0, group_size=32)
    np.testing.assert_allclose(np.asarray(yd), np.asarray(yc), rtol=2e-3,
                               atol=2e-3)
