"""Unit tests for ``repro.dist.sharding`` on a single-device CPU mesh.

Multi-device placement behaviour is covered by ``tests/test_distributed.py``
(subprocess with 8 placeholder devices); here we pin down the rule *logic*:
the recommended-rules policy across all 10 archs, spec construction and
divisibility fallbacks, and a smoke train step built through the sharded
builders.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_NAMES, get_config
from repro.dist import sharding as SH
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.train import steps as ST

# §Perf policy: SP on for pure-attention stacks, off for MoE / recurrent.
SP_ON = {"qwen2-1.5b", "minitron-4b", "smollm-360m", "qwen1.5-4b",
         "internvl2-26b", "whisper-base"}
SP_OFF = {"mixtral-8x22b", "moonshot-v1-16b-a3b", "xlstm-1.3b",
          "jamba-1.5-large-398b"}


@pytest.fixture(autouse=True)
def _reset_batch_axes():
    yield
    T.set_batch_axes(None)  # builders mutate module state; keep tests isolated


def test_recommended_rules_all_archs():
    assert SP_ON | SP_OFF == set(ARCH_NAMES)
    for name in ARCH_NAMES:
        rules = SH.ShardingRules.recommended(get_config(name))
        assert rules.sequence_parallel == (name in SP_ON), name
        assert rules.tp_axis == "model"


def test_fit_axes_and_axis_size_single_device():
    mesh = make_host_mesh(1, 1)
    # everything divides a size-1 axis
    assert SH.fit_axes(15, "model", mesh) == "model"
    assert SH.fit_axes(7, ("pod", "data"), mesh) == ("data",)
    # absent axes never appear
    assert SH.fit_axes(8, "pod", mesh) is None
    assert SH.fit_axes(8, None, mesh) is None
    assert SH.axis_size(mesh, "model") == 1
    assert SH.axis_size(mesh, None) == 1
    assert SH.axis_size(mesh, ("data", "model")) == 1
    assert SH.data_axes(mesh) == ("data",)


@pytest.mark.parametrize("arch", ["smollm-360m", "mixtral-8x22b",
                                  "jamba-1.5-large-398b", "whisper-base"])
def test_param_shardings_valid_namedshardings(arch):
    mesh = make_host_mesh(1, 1)
    cfg = get_config(arch, reduced=True)
    ab = T.abstract_params(jax.random.PRNGKey(0), cfg)
    sh = SH.param_shardings(ab, mesh, cfg)
    flat_ab = jax.tree.leaves(ab)
    flat_sh = jax.tree.leaves(
        sh, is_leaf=lambda x: isinstance(x, NamedSharding))
    assert len(flat_ab) == len(flat_sh) and flat_sh
    for s in flat_sh:
        assert isinstance(s, NamedSharding)
    SH.validate_shardings(ab, sh)  # every spec'd dim divides its axes


def test_param_shardings_layout_rules():
    """Spec shapes on a 1-device mesh (axes of size 1 always fit)."""
    mesh = make_host_mesh(1, 1)
    cfg = get_config("smollm-360m", reduced=True)
    ab = T.abstract_params(jax.random.PRNGKey(0), cfg)
    sh = SH.param_shardings(ab, mesh, cfg)
    assert sh["embed"].spec[0] == "model"             # vocab rows
    assert sh["lm_head"].spec[1] == "model"           # vocab cols
    mix = sh["layers"][0]["mix"]
    assert mix["wq"].spec[-1] == "model"              # column parallel
    assert mix["wo"].spec[1] == "model"               # row parallel (stacked)
    assert mix["wo"].spec[0] is None                  # stack dim never shards
    ffn = sh["layers"][0]["ffn"]
    assert ffn["w_gate"].spec[-1] == "model"
    assert ffn["w_down"].spec[1] == "model"
    assert all(a is None for a in sh["final_ln"].spec)  # norms replicated


def test_moe_expert_parallel_dim():
    mesh = make_host_mesh(1, 1)
    cfg = get_config("mixtral-8x22b", reduced=True)
    ab = T.abstract_params(jax.random.PRNGKey(0), cfg)
    sh = SH.param_shardings(ab, mesh, cfg)
    ffn = sh["layers"][0]["ffn"]
    # stacked MoE weights are (repeats, experts, ...) -> expert dim shards
    assert ffn["w_gate"].spec[1] == "model"
    assert ffn["w_down"].spec[1] == "model"
    assert all(a is None for a in ffn["router"].spec)


def test_fsdp_rules_shard_remaining_dim():
    mesh = make_host_mesh(1, 1)
    cfg = get_config("smollm-360m")  # full size so leaves clear fsdp_min_size
    ab = T.abstract_params(jax.random.PRNGKey(0), cfg)
    sh = SH.param_shardings(ab, mesh, cfg,
                            SH.ShardingRules(fsdp_weights=True))
    wq = sh["layers"][0]["mix"]["wq"].spec
    assert wq[-1] == "model" and wq[1] == ("data",)   # TP + ZeRO-3
    SH.validate_shardings(ab, sh)


def test_batch_specs_and_batch_sharding():
    mesh = make_host_mesh(1, 1)
    batch = {"tokens": jax.ShapeDtypeStruct((4, 16), jnp.int32),
             "labels": jax.ShapeDtypeStruct((4, 16), jnp.int32),
             "patches": jax.ShapeDtypeStruct((4, 8, 32), jnp.bfloat16)}
    sh = SH.batch_specs(batch, mesh)
    for k, s in sh.items():
        assert isinstance(s, NamedSharding), k
        assert s.spec[0] == ("data",), k
        assert all(a is None for a in s.spec[1:]), k
    tok = SH.batch_sharding(mesh, 4, 1)
    assert tok.spec == P(("data",))


def test_cache_shardings_batch_and_kv_dims():
    mesh = make_host_mesh(1, 1)
    cfg = get_config("qwen2-1.5b", reduced=True)
    cache = jax.eval_shape(lambda: T.init_cache(cfg, 2, 32))
    sh = SH.cache_shardings(cache, mesh, cfg)
    assert sh["pos"].spec[0] == ("data",)
    entry = sh["layers"][0]
    assert entry["k"].spec[1] == ("data",)    # batch dim
    assert entry["k"].spec[3] == "model"      # kv-head dim
    assert entry["k"].spec[2] is None         # cache seq never sharded
    SH.validate_shardings(cache, sh)


def test_param_bytes_per_device_counts_shards():
    mesh = make_host_mesh(1, 1)
    cfg = get_config("qwen2-1.5b", reduced=True)
    ab = T.abstract_params(jax.random.PRNGKey(0), cfg)
    sh = SH.param_shardings(ab, mesh, cfg)
    total = sum(int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize
                for l in jax.tree.leaves(ab))
    # 1-device mesh: every "shard" is the whole array
    assert SH.param_bytes_per_device(ab, sh) == total


def test_build_sharded_train_step_smoke():
    """One real optimization step through the sharded builders on CPU."""
    mesh = make_host_mesh(1, 1)
    cfg = get_config("smollm-360m", reduced=True)
    tc = ST.TrainConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    jitted, sh = ST.build_sharded_train_step(cfg, tc, mesh)
    opt = ST.make_optimizer(tc)
    with mesh:
        params = jax.jit(lambda r: T.init_params(r, cfg),
                         out_shardings=sh["params"])(jax.random.PRNGKey(0))
        opt_state = jax.jit(opt.init, out_shardings=sh["opt"])(params)
        batch = {"tokens": jnp.zeros((2, 32), jnp.int32),
                 "labels": jnp.ones((2, 32), jnp.int32)}
        # snapshot before the call: the jit donates the params buffers
        before = [np.asarray(l, np.float32) for l in jax.tree.leaves(params)]
        fn = jitted(jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch))
        p2, o2, metrics = fn(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0.0
    # params actually moved
    deltas = [float(np.max(np.abs(a - np.asarray(b, np.float32))))
              for a, b in zip(before, jax.tree.leaves(p2))]
    assert max(deltas) > 0.0


def test_sequence_parallel_rules_smoke():
    """SP rules lower and run on a 1-device mesh (seq divisor 1)."""
    mesh = make_host_mesh(1, 1)
    cfg = get_config("qwen2-1.5b", reduced=True)
    rules = SH.ShardingRules(sequence_parallel=True)
    tc = ST.TrainConfig(lr=1e-3)
    jitted, sh = ST.build_sharded_train_step(cfg, tc, mesh, rules=rules)
    opt = ST.make_optimizer(tc)
    with mesh:
        params = jax.jit(lambda r: T.init_params(r, cfg),
                         out_shardings=sh["params"])(jax.random.PRNGKey(1))
        opt_state = jax.jit(opt.init, out_shardings=sh["opt"])(params)
        batch = {"tokens": jnp.zeros((2, 16), jnp.int32),
                 "labels": jnp.ones((2, 16), jnp.int32)}
        fn = jitted(jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch))
        _, _, metrics = fn(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
