"""Distributed-runtime tests: sharding rules, checkpoint, data pipeline,
fault-tolerant trainer, serving, gradient compression.

Multi-device behaviour is exercised in a subprocess with 8 placeholder host
devices (the parent process must keep its single-device view for the other
tests — jax locks device count at first init)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM
from repro.train import checkpoint as CKPT

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_subprocess(code: str, devices: int = 8, timeout: int = 420):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=SRC)
    res = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    return res.stdout


# ------------------------------------------------------------- data pipeline

def test_data_deterministic_and_resumable():
    cfg = DataConfig(vocab=64, seq_len=16, global_batch=4, seed=3)
    ds = SyntheticLM(cfg)
    b1 = ds.batch_at(7)
    b2 = ds.batch_at(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 16)
    assert (b1["labels"][:, -1] == -1).all()
    # host sharding partitions the global batch
    parts = []
    for h in range(2):
        dsh = SyntheticLM(DataConfig(vocab=64, seq_len=16, global_batch=4,
                                     seed=3, n_hosts=2, host_id=h))
        parts.append(dsh.batch_at(7)["tokens"])
    np.testing.assert_array_equal(np.concatenate(parts), b1["tokens"])


def test_prefetcher_matches_direct():
    cfg = DataConfig(vocab=64, seq_len=8, global_batch=2, seed=0)
    ds = SyntheticLM(cfg)
    pf = Prefetcher(ds, start_step=5)
    try:
        for step in (5, 6, 7):
            np.testing.assert_array_equal(pf.next()["tokens"],
                                          ds.batch_at(step)["tokens"])
        assert pf.state()["step"] == 8
    finally:
        pf.close()


# --------------------------------------------------------------- checkpoint

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.asarray([1, 2, 3], jnp.int32)}}
    CKPT.save(str(tmp_path), 5, tree, {"note": "x"})
    target = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                          tree)
    step, out, meta = CKPT.restore(str(tmp_path), target=target)
    assert step == 5 and meta["note"] == "x"
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(out["b"]["c"]),
                                  np.asarray(tree["b"]["c"]))


def test_checkpoint_corruption_detected(tmp_path):
    tree = {"a": jnp.ones((4,), jnp.float32)}
    d = CKPT.save(str(tmp_path), 1, tree)
    # flip bytes in the data file
    f = os.path.join(d, "data.msgpack.zst")
    blob = bytearray(open(f, "rb").read())
    blob[len(blob) // 2] ^= 0xFF
    open(f, "wb").write(bytes(blob))
    with pytest.raises(Exception):
        CKPT.restore(str(tmp_path),
                     target=jax.tree.map(
                         lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                         tree))


def test_checkpoint_manager_keep_k(tmp_path):
    mgr = CKPT.CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save_sync(s, {"x": jnp.asarray([s])})
    assert CKPT.available_steps(str(tmp_path)) == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_async(tmp_path):
    mgr = CKPT.CheckpointManager(str(tmp_path), keep=3)
    mgr.save_async(7, {"x": jnp.ones((128, 128))})
    mgr.wait()
    assert mgr.latest_step() == 7


# ---------------------------------------------------- trainer fault-tolerance

def test_trainer_loss_decreases_and_survives_faults(tmp_path):
    from repro.configs import get_config
    from repro.launch.mesh import make_host_mesh
    from repro.train.steps import TrainConfig
    from repro.train.trainer import FailureInjector, Trainer, TrainerConfig

    cfg = get_config("smollm-360m", reduced=True)
    mesh = make_host_mesh(1, 1)
    tc = TrainConfig(lr=1e-3, warmup_steps=5, total_steps=60)
    trc = TrainerConfig(steps=40, ckpt_dir=str(tmp_path), ckpt_every=10,
                        log_every=5)
    injector = FailureInjector(crash_at=17, nan_at=26)
    from repro.data.pipeline import DataConfig as DC
    tr = Trainer(cfg, tc, trc, mesh,
                 data_cfg=DC(vocab=cfg.vocab, seq_len=64, global_batch=4,
                             structure=16),
                 injector=injector)
    log = tr.run()
    assert tr.step == 40
    assert len(injector.fired) == 2          # both faults triggered
    rollbacks = [e for e in log if "event" in e]
    assert len(rollbacks) == 2               # both recovered
    losses = [(e["step"], e["loss"]) for e in log if "loss" in e]
    first = np.mean([l for s, l in losses[:2]])
    last = np.mean([l for s, l in losses[-2:]])
    assert last < first, (first, last)       # still learning after recovery


def test_trainer_restart_resumes_from_checkpoint(tmp_path):
    from repro.configs import get_config
    from repro.launch.mesh import make_host_mesh
    from repro.train.steps import TrainConfig
    from repro.train.trainer import Trainer, TrainerConfig
    from repro.data.pipeline import DataConfig as DC

    cfg = get_config("qwen2-1.5b", reduced=True)
    mesh = make_host_mesh(1, 1)
    tc = TrainConfig(lr=5e-4, warmup_steps=2, total_steps=30)
    dc = DC(vocab=cfg.vocab, seq_len=32, global_batch=2, structure=8)
    trc = TrainerConfig(steps=10, ckpt_dir=str(tmp_path), ckpt_every=5)
    Trainer(cfg, tc, trc, mesh, data_cfg=dc).run()
    # process "restarts": a new Trainer picks up from the final checkpoint
    trc2 = TrainerConfig(steps=16, ckpt_dir=str(tmp_path), ckpt_every=5)
    tr2 = Trainer(cfg, tc, trc2, mesh, data_cfg=dc)
    assert tr2.step == 10                    # resumed, not reinitialized
    tr2.run()
    assert tr2.step == 16


# ------------------------------------------------------------- sharding rules

def test_sharding_rules_multidevice():
    run_subprocess("""
        import jax, numpy as np
        import jax.numpy as jnp
        from repro.configs import get_config
        from repro.launch.mesh import make_host_mesh
        from repro.dist import sharding as SH
        from repro.models import transformer as T
        from jax.sharding import PartitionSpec as P

        mesh = make_host_mesh(2, 4)
        # smollm: 15 heads % 4 != 0 -> wq TP falls back; d_ff shards
        cfg = get_config('smollm-360m')
        ab = T.abstract_params(jax.random.PRNGKey(0), cfg)
        sh = SH.param_shardings(ab, mesh, cfg)
        wq = sh['layers'][0]['mix']['wq'].spec
        wg = sh['layers'][0]['ffn']['w_gate'].spec
        assert wq[-1] == 'model', wq       # 15 heads * 64 = 960 % 4 == 0
        assert wg[-1] == 'model', wg       # d_ff=2560 % 4 == 0
        emb = sh['embed'].spec
        assert emb[0] == 'model', emb      # vocab shards

        # divisibility fallback: 15 heads on model axis -> check fit_axes
        assert SH.fit_axes(15, 'model', mesh) is None
        assert SH.fit_axes(16, 'model', mesh) == 'model'
        assert SH.fit_axes(8, ('pod','data'), mesh) == ('data',) or \\
               SH.fit_axes(8, ('pod','data'), mesh) == 'data'

        # moe EP vs TP fallback
        cfg2 = get_config('mixtral-8x22b')
        ab2 = T.abstract_params(jax.random.PRNGKey(0), cfg2)
        sh2 = SH.param_shardings(ab2, mesh, cfg2)
        spec = sh2['layers'][0]['ffn']['w_gate'].spec
        assert spec[1] == 'model', spec    # 8 experts % 4 == 0 -> EP
        print('sharding rules OK')
        """)


def test_train_step_runs_sharded_multidevice():
    run_subprocess("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.configs import get_config
        from repro.launch.mesh import make_host_mesh
        from repro.train import steps as ST
        from repro.models import transformer as T

        mesh = make_host_mesh(2, 4)
        cfg = get_config('qwen2-1.5b', reduced=True)
        tc = ST.TrainConfig(lr=1e-3)
        jitted, sh = ST.build_sharded_train_step(cfg, tc, mesh)
        opt = ST.make_optimizer(tc)
        with mesh:
            params = jax.jit(lambda r: T.init_params(r, cfg),
                             out_shardings=sh['params'])(jax.random.PRNGKey(0))
            opt_state = jax.jit(opt.init, out_shardings=sh['opt'])(params)
            batch = {'tokens': jnp.zeros((4, 32), jnp.int32),
                     'labels': jnp.ones((4, 32), jnp.int32)}
            fn = jitted(jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch))
            p2, o2, m = fn(params, opt_state, batch)
            assert np.isfinite(float(m['loss']))
        print('sharded train step OK', float(m['loss']))
        """)


def test_compressed_allreduce_multidevice():
    run_subprocess("""
        import jax, numpy as np, jax.numpy as jnp, functools
        from repro.launch.mesh import make_host_mesh
        from repro.optim import compression as C
        from repro.optim.adam import Adam

        mesh = make_host_mesh(4, 1)
        # toy quadratic: params converge under compressed DP gradients
        def loss_fn(params, batch):
            pred = batch['x'] @ params['w']
            return jnp.mean((pred - batch['y'])**2), {}

        rng = np.random.default_rng(0)
        w_true = rng.normal(size=(8, 1)).astype(np.float32)
        params = {'w': jnp.zeros((8, 1), jnp.float32)}
        opt = Adam(lr=3e-2)
        opt_state = opt.init(params)
        err = C.init_error_state(params)
        step = C.make_ddp_compressed_step(loss_fn, opt, mesh)
        losses = []
        with mesh:
            for i in range(150):
                x = rng.normal(size=(16, 8)).astype(np.float32)
                y = x @ w_true
                params, opt_state, err, loss = step(
                    params, opt_state, err, {'x': jnp.asarray(x),
                                             'y': jnp.asarray(y)})
                losses.append(float(loss))
        assert losses[-1] < 1e-2 * losses[0], (losses[0], losses[-1])
        print('compressed DP OK', losses[0], '->', losses[-1])
        """)


def test_elastic_restore_across_meshes(tmp_path):
    """Checkpoint written on a 1-device mesh restores onto a 2x4 mesh with
    different shardings (elastic re-scaling)."""
    run_subprocess(f"""
        import jax, numpy as np, jax.numpy as jnp
        from repro.configs import get_config
        from repro.launch.mesh import make_host_mesh
        from repro.dist import sharding as SH
        from repro.models import transformer as T
        from repro.train import checkpoint as CKPT

        cfg = get_config('qwen2-1.5b', reduced=True)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        CKPT.save({str(tmp_path)!r}, 3, params)

        mesh = make_host_mesh(2, 4)
        ab = T.abstract_params(jax.random.PRNGKey(0), cfg)
        sh = SH.param_shardings(ab, mesh, cfg)
        step, restored, _ = CKPT.restore({str(tmp_path)!r}, target=ab,
                                         shardings=sh)
        assert step == 3
        # values identical, now sharded on the new mesh
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        n_shards = {{len(l.sharding.device_set)
                    for l in jax.tree.leaves(restored)}}
        assert max(n_shards) > 1   # actually distributed
        print('elastic restore OK')
        """)


# ------------------------------------------------------------------ serving

def test_server_continuous_batching():
    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.train.server import Request, Server

    cfg = get_config("qwen2-1.5b", reduced=True).with_(
        dtype=jnp.float32, param_dtype=jnp.float32, remat=False)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    srv = Server(params, cfg, n_slots=2, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab, size=5 + 3 * i)
                    .astype(np.int32),
                    max_new_tokens=4 + i) for i in range(5)]
    for r in reqs:
        srv.submit(r)
    done = srv.run_until_drained()
    assert len(done) == 5
    for r in done:
        assert len(r.output) == r.max_new_tokens
    # batched output == standalone decode for one request
    solo = Server(params, cfg, n_slots=1, max_len=64)
    solo.submit(Request(uid=99, prompt=reqs[0].prompt,
                        max_new_tokens=reqs[0].max_new_tokens))
    ref = solo.run_until_drained()[0]
    batched = [r for r in done if r.uid == 0][0]
    assert ref.output == batched.output


def test_recommended_rules_policy():
    """SP policy learned in §Perf: on for pure-attention stacks, off for
    MoE / recurrent mixers."""
    from repro.configs import get_config
    from repro.dist.sharding import ShardingRules
    on = ("qwen2-1.5b", "minitron-4b", "smollm-360m", "qwen1.5-4b",
          "internvl2-26b", "whisper-base")
    off = ("mixtral-8x22b", "moonshot-v1-16b-a3b", "xlstm-1.3b",
           "jamba-1.5-large-398b")
    for a in on:
        assert ShardingRules.recommended(get_config(a)).sequence_parallel, a
    for a in off:
        assert not ShardingRules.recommended(
            get_config(a)).sequence_parallel, a
