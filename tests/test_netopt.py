"""``repro.compiler.netopt`` — network-scope HW/SW co-optimization.

Covers the pinning primitive (``DesignSpace.pin`` + the pinned MAPPO
action heads), the hardware candidate space, the co-optimization loop
(shared chip invariant, multiplicity-weighted latency, equal-budget win
over the network hw-frozen baseline, per-(hw, layer) warm resume), the
network baselines, and the ``SessionReport.network_latency`` satellite.
"""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.compiler.netopt import (HW_KNOBS, HwCandidateSpace, hw_tag,
                                   NetOptConfig, NetworkCoOptimizer,
                                   NetworkReport, network_hw_frozen_tune,
                                   network_random_hw_tune)
from repro.compiler.session import Session, SessionReport
from repro.compiler.task import TuningTask
from repro.core import agents as A
from repro.core import mappo
from repro.core.design_space import DesignSpace
from repro.core.tuner import ArcoLoop, TunerConfig

WL_BIG = dict(b=1, h=14, w=14, ci=256, co=256, kh=3, kw=3, stride=1, pad=1)
WL_MID = dict(b=1, h=28, w=28, ci=128, co=128, kh=3, kw=3, stride=1, pad=1)
TINY = TunerConfig(iteration_opt=3, b_measure=8, episodes_per_iter=2,
                   mappo=mappo.MappoConfig(n_steps=16, n_envs=8),
                   gbt_rounds=10)


@pytest.fixture(scope="module")
def tasks():
    return [TuningTask.from_space("c1", DesignSpace.for_conv2d(WL_BIG),
                                  multiplicity=2),
            TuningTask.from_space("c2", DesignSpace.for_conv2d(WL_MID),
                                  multiplicity=1)]


def _tiny_netcfg(**kw):
    base = dict(seed_candidates=2, hw_rounds=1, hw_per_round=1,
                layer_budget=8, refine_budget=8, tuner=TINY)
    base.update(kw)
    return NetOptConfig(**base)


# ------------------------------------------------------------------ pin()

def test_pin_shrinks_space_and_clamps():
    space = DesignSpace.for_conv2d(WL_BIG)
    p = space.pin(HW_KNOBS, (1, 64, 128))
    assert p.size * np.prod([len(space.choices[k]) for k in HW_KNOBS]) \
        == space.size
    assert p.choices[1] == (64,) and p.choices[2] == (128,)
    assert p.pinned == (True, True, True, False, False, False, False)
    # a value beyond the layer's table clamps to the nearest choice (the
    # layer underutilizes the shared dimension)
    assert space.pin((1,), (4096,)).choices[1] == (256,)
    # pinning composes and survives dataclass identity checks
    pp = p.pin((5,), (space.choices[5][0],))
    assert pp.pinned[5] and pp.pinned[0]
    # values/measure still work on the pinned space
    lat = p.measure(jnp.zeros((1, p.n_knobs), jnp.int32))
    assert np.isfinite(float(lat[0]))


def test_pin_measures_identically_to_full_space():
    """A pinned config and the corresponding full-space config decode to
    the same knob values, hence the same oracle latency."""
    space = DesignSpace.for_conv2d(WL_BIG)
    values = (1, 64, 128)
    p = space.pin(HW_KNOBS, values)
    full_idx = np.zeros(space.n_knobs, np.int64)
    for k, v in zip(HW_KNOBS, values):
        full_idx[k] = space.choices[k].index(v)
    pin_idx = np.zeros(space.n_knobs, np.int64)  # pinned knobs: index 0
    lat_full = float(space.measure(jnp.asarray([full_idx], jnp.int32))[0])
    lat_pin = float(p.measure(jnp.asarray([pin_idx], jnp.int32))[0])
    assert lat_full == lat_pin


def test_pinned_action_heads_masked():
    space = DesignSpace.for_conv2d(WL_BIG).pin(HW_KNOBS, (1, 64, 128))
    env = mappo.env_params_from_space(space)
    hw_mask = np.asarray(A.action_mask("hardware", env.pinned))
    assert hw_mask.sum() == 1          # all-pinned agent keeps the no-op
    assert hw_mask[13]                 # deltas (0,0,0) for the 3-knob head
    assert np.asarray(A.action_mask("mapping", env.pinned)).all()
    # unpinned spaces mask nothing (mask is all-True => logits unchanged)
    env0 = mappo.env_params_from_space(DesignSpace.for_conv2d(WL_BIG))
    for agent in ("hardware", "scheduling", "mapping"):
        assert np.asarray(A.action_mask(agent, env0.pinned)).all()


def test_arco_on_pinned_space_never_moves_pinned_knobs():
    space = DesignSpace.for_conv2d(WL_BIG).pin(HW_KNOBS, (1, 64, 128))
    loop = ArcoLoop(space, TINY, task="pinned")
    loop.seed(budget=8)
    loop.step(budget=16)
    seen = np.asarray([list(c) for c in loop.track.seen])
    assert (seen[:, list(HW_KNOBS)] == 0).all()


# ------------------------------------------------------ hw candidate space

def test_hw_candidate_space_from_tasks(tasks):
    hw = HwCandidateSpace.from_tasks(tasks)
    assert hw.n_knobs == 3
    # unions cover both layers' tables
    assert max(hw.choices[1]) == 256 and max(hw.choices[2]) == 256
    assert hw.size == np.prod([len(c) for c in hw.choices])
    # values <-> index round-trip and feature shape
    v = hw.values(hw.index_config((1, 64, 128)))
    assert v == (1, 64, 128)
    assert hw.features(v).shape == (14,)
    assert len(hw.all_index_configs()) == hw.size
    # default chip is in the global lists; seeds start with it
    default = hw.default_values(tasks)
    seeds = hw.seed_values(3, tasks, np.random.default_rng(0))
    assert seeds[0] == default
    assert len(seeds) == len(set(seeds)) == 3
    assert hw_tag(v) == "hw[b1,ci64,co128]"


# --------------------------------------------------------------- the loop

def test_coopt_shared_chip_and_equal_budget_win(tasks, tmp_path):
    cfg = _tiny_netcfg()
    rep = NetworkCoOptimizer(tasks, cfg,
                             records=str(tmp_path / "coopt.jsonl"),
                             name="toy").run()
    frozen = network_hw_frozen_tune(tasks, cfg,
                                    records=str(tmp_path / "frozen.jsonl"),
                                    name="toy")
    # ONE shared hardware config, identical across all layer mappings
    assert rep.verify_shared_hardware()
    for layer in rep.layers.values():
        assert layer["hardware"] == rep.hw_config
        assert set(layer["mapping"]).isdisjoint(rep.hw_config)
        # small layers underutilize the shared dimension, never exceed it
        assert all(layer["hw_utilized"][k] <= rep.hw_config[k]
                   for k in layer["hw_utilized"])
    # multiplicity-weighted end-to-end latency
    assert rep.network_latency == pytest.approx(sum(
        l["latency"] * l["multiplicity"] for l in rep.layers.values()))
    assert rep.n_layers == 3
    # the headline: co-optimized <= network hw-frozen at equal budget;
    # the baseline gets coopt's upper bound, so the comparison is
    # conservative — coopt's real spend must come in at or under it
    assert frozen.trace[0]["layer_budget"] == cfg.total_layer_budget()
    assert rep.total_measurements <= cfg.total_layer_budget() * len(tasks)
    assert rep.network_latency <= frozen.network_latency
    # trace/progress bookkeeping
    assert rep.hw_candidates >= cfg.seed_candidates
    assert [r["phase"] for r in rep.trace][0] == "seed"
    assert rep.trace[-1]["phase"] == "refine"
    assert rep.progress()[-1][1] == rep.network_latency
    assert rep.total_measurements == rep.trace[-1]["cum_measurements"]
    # multi-objective pareto: latency-sorted, area strictly descending
    front = rep.pareto()
    assert front and front[0][0] == rep.network_latency
    assert all(a[0] < b[0] and a[1] > b[1]
               for a, b in zip(front, front[1:]))
    # JSON round-trip
    back = NetworkReport.from_dict(json.loads(json.dumps(rep.to_dict())))
    assert back.network_latency == rep.network_latency
    assert back.hw_config == rep.hw_config
    assert back.progress() == rep.progress()
    assert back.pareto() == rep.pareto()


def test_coopt_warm_resume_replays_from_records(tasks, tmp_path):
    cfg = _tiny_netcfg()
    path = str(tmp_path / "resume.jsonl")
    r1 = NetworkCoOptimizer(tasks, cfg, records=path, name="toy").run()
    assert r1.total_measurements > 0
    r2 = NetworkCoOptimizer(tasks, cfg, records=path, name="toy").run()
    assert r2.total_measurements == 0  # every (hw, layer) row replayed
    assert r2.hw_config == r1.hw_config
    assert r2.network_latency == r1.network_latency


def test_network_random_hw_baseline(tasks):
    cfg = _tiny_netcfg(refine_budget=0)
    rep = network_random_hw_tune(tasks, cfg, n_candidates=2, name="toy")
    assert rep.algo == "random_hw"
    assert rep.hw_candidates == 2
    assert all(r["phase"] == "random" for r in rep.trace)
    assert rep.verify_shared_hardware()
    # equal total budget split across candidates
    assert rep.trace[0]["layer_budget"] == cfg.total_layer_budget() // 2


# ----------------------------------------- SessionReport.network_latency

def test_session_network_latency_weights_multiplicity(tasks):
    sr = Session(tasks, tuner=TINY, budget=8).run()
    assert sr["c1"].multiplicity == 2 and sr["c2"].multiplicity == 1
    expect = 2 * sr["c1"].best_latency + sr["c2"].best_latency
    assert sr.network_latency() == pytest.approx(expect)
    # multiplicity survives the JSON round-trip
    back = SessionReport.from_dict(json.loads(json.dumps(sr.to_dict())))
    assert back.network_latency() == pytest.approx(expect)
    # old dicts without the field default to 1 (backward compat)
    d = sr.to_dict()
    for rep in d["reports"].values():
        rep.pop("multiplicity")
    legacy = SessionReport.from_dict(d)
    assert legacy.network_latency() == pytest.approx(
        sr["c1"].best_latency + sr["c2"].best_latency)
