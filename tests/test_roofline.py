"""Roofline model tests: analytic FLOPs/traffic formulas + cell analysis."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.hw import roofline as RL
from repro.hw.tpu_spec import DEFAULT


def test_param_counts_active_vs_total_moe():
    cfg = get_config("mixtral-8x22b")
    c = RL._param_counts(cfg)
    # 8 experts top-2: active ~ total * ~(2/8) on the expert share
    assert c["active"] < 0.45 * c["total"]
    dense = get_config("qwen2-1.5b")
    cd = RL._param_counts(dense)
    assert cd["active"] == cd["total"]


def test_model_flops_train_matches_6nd():
    cfg = get_config("qwen2-1.5b")
    c = RL._param_counts(cfg)
    seq, batch = 4096, 256
    mf = RL.model_flops(cfg, "train", seq, batch, c)
    base = 6.0 * c["total"] * seq * batch
    assert base <= mf <= 1.5 * base  # attention adds a bounded extra


def test_decode_flops_linear_in_batch():
    cfg = get_config("minitron-4b")
    f1 = RL.model_flops(cfg, "decode", 32768, 1)
    f128 = RL.model_flops(cfg, "decode", 32768, 128)
    assert abs(f128 / f1 - 128) < 1


def test_swa_caps_attention_flops():
    cfg = get_config("mixtral-8x22b")
    c = RL._param_counts(cfg)
    f_32k = RL.model_flops(cfg, "prefill", 32768, 1, c)
    # without SWA the quadratic term would dominate; with window 4096 the
    # attention share stays < the projection share
    proj = 2.0 * c["active"] * 32768
    assert f_32k < 2.2 * proj


def test_kv_cache_bytes_swa_ring():
    cfg = get_config("mixtral-8x22b")
    full = RL.kv_cache_bytes(cfg.with_(swa_window=None), 524288, 1)
    ring = RL.kv_cache_bytes(cfg, 524288, 1)
    assert ring < full / 100  # window 4096 vs 524288


def test_memory_traffic_decode_dominated_by_weights_or_cache():
    cfg = get_config("moonshot-v1-16b-a3b")
    mesh = {"data": 16, "model": 16}
    m = RL.memory_traffic(cfg, "decode", 32768, 128, mesh)
    assert m > 0
    # must be at least the TP-sharded weight stream
    c = RL._param_counts(cfg)
    assert m >= c["total"] * 2.0 / 16


def test_analyze_cell_and_fraction():
    cfg = get_config("qwen2-1.5b")
    art = {"weighted": {"dot_flops_per_device": 1e14,
                        "wire_bytes_per_device": 1e10,
                        "collective_bytes_by_op": {}}}
    r = RL.analyze_cell(cfg, "train", 4096, 256,
                        {"data": 16, "model": 16}, art)
    assert r.dominant in ("compute", "memory", "collective")
    assert r.step_s == max(r.compute_s, r.memory_s, r.collective_s)
    frac = RL.roofline_fraction(r, n_dev=256)
    assert 0 < frac <= 1.5
