"""Tier-1 integrity guards over the test suite itself.

``pytest.ini`` excludes ``-m stochastic`` from tier-1, which makes the
marker a quiet escape hatch: any test wearing it silently leaves CI.
This guard pins the quarantine to an explicit allowlist — growing it is
a reviewed decision (edit this file and justify it), never a side
effect.
"""
import os
import re

TESTS_DIR = os.path.dirname(os.path.abspath(__file__))

# The full stochastic quarantine.  Adding an entry means permanently
# removing a test from tier-1 — do it in the same change that documents
# why (see ROADMAP), not by decoration alone.
ALLOWED_STOCHASTIC = {
    ("test_tuner.py", "test_arco_beats_hw_frozen_baselines_long_run"),
}

_MARK = re.compile(r"^\s*@pytest\.mark\.stochastic\b")
_DEF = re.compile(r"^\s*(?:def|class)\s+(\w+)")
# module-level `pytestmark = ...stochastic...` quarantines a whole file
_MODMARK = re.compile(r"^\s*pytestmark\s*=.*stochastic")


def _stochastic_tests():
    found = set()
    for fname in sorted(os.listdir(TESTS_DIR)):
        if not fname.endswith(".py") or fname == os.path.basename(__file__):
            continue
        with open(os.path.join(TESTS_DIR, fname)) as f:
            lines = f.readlines()
        for i, line in enumerate(lines):
            if _MODMARK.match(line):
                found.add((fname, "<module pytestmark>"))
                continue
            if not _MARK.match(line):
                continue
            for after in lines[i + 1:]:
                m = _DEF.match(after)
                if m:  # a decorated class quarantines every test in it
                    found.add((fname, m.group(1)))
                    break
    return found


def test_stochastic_marker_set_has_not_grown():
    found = _stochastic_tests()
    new = found - ALLOWED_STOCHASTIC
    assert not new, (
        f"tests quarantined from tier-1 without review: {sorted(new)} — "
        "either keep them in tier-1 or extend ALLOWED_STOCHASTIC with a "
        "ROADMAP justification")
    gone = ALLOWED_STOCHASTIC - found
    assert not gone, (f"allowlisted stochastic tests vanished: "
                      f"{sorted(gone)} — update ALLOWED_STOCHASTIC")


def test_pytest_ini_still_excludes_stochastic():
    with open(os.path.join(os.path.dirname(TESTS_DIR), "pytest.ini")) as f:
        ini = f.read()
    assert 'not stochastic' in ini
    assert "stochastic:" in ini  # marker stays registered
