"""``repro.obs.serve`` — the live monitoring service — and its riders.

Covers the :class:`MonitorServer` endpoints and lifecycle (ephemeral
ports, source attach/finalize/freeze, broken-callback isolation, the
``active_servers()`` registry), the Prometheus text exposition, the
span-sampling bookkeeping (dropped measure/dispatch seconds folded back
exactly — never estimated — through both export forms), the
``trace_diff`` and ``bench_compare`` regression gates, the metrics
edge cases (bucket quantiles, all three executor ``stats()`` shapes,
concurrent counter increments), and the acceptance bar: a live netopt
run over a loopback worker daemon whose final ``/metrics`` scrape
matches the :class:`NetworkReport` exactly — with the report itself
byte-identical monitor-on vs monitor-off.
"""
import glob
import importlib.util
import json
import math
import os
import sys
import threading
import time
import urllib.error
import urllib.request

import pytest

from repro import obs
from repro.compiler.cli import main as cli_main
from repro.compiler.executor import (RemoteExecutor, SerialExecutor,
                                     WorkerDaemon, WorkerSpec)
from repro.compiler.executor.stub import make_stub
from repro.compiler.netopt import NetOptConfig, NetworkCoOptimizer
from repro.compiler.oracle import SettingsOracle
from repro.compiler.session import Session
from repro.compiler.task import TuningTask
from repro.core import mappo
from repro.core.design_space import DesignSpace
from repro.core.tuner import TunerConfig
from repro.obs.metrics import Counter, Histogram, Metrics
from repro.obs.serve import MonitorServer, coerce_monitor, prometheus_text

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
STUB = "repro.compiler.executor.stub:make_stub"
STUB_SPEC = WorkerSpec(factory=STUB)
WL_BIG = dict(b=1, h=14, w=14, ci=256, co=256, kh=3, kw=3, stride=1, pad=1)
WL_MID = dict(b=1, h=28, w=28, ci=128, co=128, kh=3, kw=3, stride=1, pad=1)
TINY = TunerConfig(iteration_opt=3, b_measure=8, episodes_per_iter=2,
                   mappo=mappo.MappoConfig(n_steps=16, n_envs=8),
                   gbt_rounds=10)


def _load_tool(name):
    path = os.path.join(ROOT, "tools", f"{name}.py")
    spec = importlib.util.spec_from_file_location(name, path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _load_benchmarks(name):
    path = os.path.join(ROOT, "benchmarks", f"{name}.py")
    if os.path.join(ROOT, "benchmarks") not in sys.path:
        sys.path.insert(0, os.path.join(ROOT, "benchmarks"))
    spec = importlib.util.spec_from_file_location(name, path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _get(url, timeout=10.0):
    with urllib.request.urlopen(url, timeout=timeout) as r:
        return r.status, r.read().decode()


def _get_json(url):
    status, body = _get(url)
    assert status == 200
    return json.loads(body)


def _metric_value(text, name):
    """The sample value for ``name`` in a Prometheus exposition body."""
    for line in text.splitlines():
        if line.startswith(name + " "):
            return float(line.split()[-1])
    raise KeyError(f"{name} not in:\n{text}")


# ------------------------------------------------------- server lifecycle

def test_monitor_server_endpoints_and_lifecycle():
    srv = MonitorServer(port=0).start()
    try:
        assert srv.port > 0 and srv.running
        assert srv in obs.active_servers()
        srv.metrics.gauge("demo.g").set(3.5)
        srv.attach("demo", lambda: {"kind": "demo", "n": 7})
        status, body = _get(srv.url + "/")
        assert status == 200
        assert set(json.loads(body)["endpoints"]) == {"/metrics", "/status",
                                                      "/trace"}
        st = _get_json(srv.url + "/status")
        assert st["sources"]["demo"] == {"kind": "demo", "n": 7}
        assert st["uptime_s"] >= 0.0
        status, text = _get(srv.url + "/metrics")
        assert status == 200
        assert _metric_value(text, "repro_demo_g") == 3.5
        assert _get_json(srv.url + "/trace") == {"spans": []}  # no tracer
        with pytest.raises(urllib.error.HTTPError) as ei:
            _get(srv.url + "/nope")
        assert ei.value.code == 404
    finally:
        srv.stop()
    assert not srv.running and srv not in obs.active_servers()
    with pytest.raises(urllib.error.URLError):
        _get(srv.url + "/status", timeout=2.0)


def test_monitor_start_stop_idempotent_and_context_manager():
    with MonitorServer(port=0) as srv:
        assert srv.start() is srv  # second start is a no-op
        port = srv.port
        assert _get_json(f"http://127.0.0.1:{port}/status")["sources"] == {}
    assert not srv.running
    srv.stop()  # second stop is a no-op


def test_attach_collision_suffix_and_finalize_freezes():
    state = {"n": 1}
    collected = []
    srv = MonitorServer(port=0).start()
    try:
        a = srv.attach("run", lambda: dict(state),
                       collector=lambda m: collected.append(1))
        b = srv.attach("run", lambda: {"other": True})
        assert (a, b) == ("run", "run#2")  # borrowed server, two runs
        state["n"] = 5
        assert srv.status_snapshot()["sources"]["run"] == {"n": 5}
        srv.metrics_text()
        n_live = len(collected)
        assert n_live >= 1  # collectors run at scrape time
        srv.finalize("run")
        state["n"] = 99  # too late: the snapshot was frozen at finalize
        srv.finalize("run")  # idempotent: collector must not run again
        assert len(collected) == n_live + 1
        st = srv.status_snapshot()["sources"]
        assert st["run"] == {"n": 5, "final": True}
        assert st["run#2"] == {"other": True}  # still live
        srv.metrics_text()
        assert len(collected) == n_live + 1  # dropped from live collectors
    finally:
        srv.stop()


def test_broken_callbacks_never_kill_scrapes():
    def boom():
        raise RuntimeError("kaput")

    srv = MonitorServer(port=0).start()
    try:
        srv.attach("bad", boom, collector=lambda m: boom())
        srv.attach("good", lambda: {"ok": True})
        st = _get_json(srv.url + "/status")
        assert "RuntimeError" in st["sources"]["bad"]["error"]
        assert st["sources"]["good"] == {"ok": True}
        status, _text = _get(srv.url + "/metrics")  # collector failure
        assert status == 200                        # -> logged, not fatal
    finally:
        srv.stop()


def test_coerce_monitor_owned_vs_borrowed():
    assert coerce_monitor(None) == (None, False)
    srv, owned = coerce_monitor(0)
    assert isinstance(srv, MonitorServer) and owned and not srv.running
    srv2, owned2 = coerce_monitor(srv)
    assert srv2 is srv and not owned2


# -------------------------------------------------- prometheus exposition

def test_prometheus_text_rendering():
    m = Metrics()
    m.counter("executor.remote.jobs").inc(60)
    m.gauge("netopt.best_network_latency_s").set(0.0001665)
    for v in (1.0, 3.0, 2.0):
        m.histogram("lat.s").observe(v)
    text = prometheus_text(m.snapshot())
    assert "# TYPE repro_executor_remote_jobs counter" in text
    assert _metric_value(text, "repro_executor_remote_jobs") == 60
    assert "# TYPE repro_netopt_best_network_latency_s gauge" in text
    # exact round-trip: repr() for non-integral floats
    assert _metric_value(text, "repro_netopt_best_network_latency_s") \
        == 0.0001665
    assert "# TYPE repro_lat_s summary" in text
    assert 'repro_lat_s{quantile="0.5"} 2' in text
    assert 'repro_lat_s{quantile="0.99"} 3' in text
    assert _metric_value(text, "repro_lat_s_count") == 3
    assert _metric_value(text, "repro_lat_s_sum") == 6.0
    assert prometheus_text({}) == ""
    assert prometheus_text(Metrics().snapshot()) == ""


# ------------------------------------------------------ metrics edge cases

def test_histogram_quantiles_and_edge_cases():
    h = Histogram()
    assert h.snapshot() == {"count": 0, "sum": 0.0}
    assert math.isnan(h.quantile(0.5))
    h.observe(5.0)  # single value: every quantile clamps to it
    assert h.quantile(0.0) == h.quantile(0.5) == h.quantile(1.0) == 5.0
    h2 = Histogram()
    for v in (1.0, 3.0, 2.0):
        h2.observe(v)
    assert (h2.quantile(0.5), h2.quantile(0.9), h2.quantile(0.99)) \
        == (2.0, 3.0, 3.0)
    h3 = Histogram()  # non-positive values share one underflow bucket
    for v in (-5.0, 0.0, 4.0):
        h3.observe(v)
    assert h3.quantile(0.01) == 0.0  # the underflow bucket's upper bound
    assert h3.quantile(1.0) == 4.0
    assert h3.snapshot()["min"] == -5.0 and h3.snapshot()["max"] == 4.0
    h4 = Histogram()  # all-negative stream: bound clamps down to max
    h4.observe(-5.0)
    assert h4.quantile(0.5) == -5.0


def test_record_executor_stats_all_three_shapes():
    m = Metrics()
    serial = SerialExecutor().stats()
    assert serial["kind"] == "serial"
    m.record_executor_stats(serial)
    # the other two pools answer the same eight keys (remote adds the
    # per-endpoint block, which maps no instrument); shapes mirror
    # SubprocessExecutor.stats() / RemoteExecutor.stats()
    m.record_executor_stats({"kind": "subprocess", "workers_alive": 2,
                             "respawns": 1, "queued": 3, "running": 2,
                             "max_inflight": 4, "jobs": 10, "failures": 2})
    m.record_executor_stats({"kind": "remote", "workers_alive": 1,
                             "respawns": 0, "queued": 0, "running": 1,
                             "max_inflight": 8, "jobs": 60, "failures": 0,
                             "endpoints": {"h:1": {"jobs": 60}}})
    snap = m.snapshot()
    for kind in ("serial", "subprocess", "remote"):
        assert f"executor.{kind}.jobs" in snap["counters"]
        assert f"executor.{kind}.workers_alive" in snap["gauges"]
    assert snap["counters"]["executor.subprocess.jobs"] == 10.0
    assert snap["counters"]["executor.remote.jobs"] == 60.0
    assert snap["gauges"]["executor.remote.max_inflight"] == 8.0
    # re-recording overwrites (source is a running total), never adds
    m.record_executor_stats({"kind": "remote", "jobs": 61})
    assert m.snapshot()["counters"]["executor.remote.jobs"] == 61.0


def test_counter_concurrent_increments_exact():
    c = Counter()
    n_threads, n_incs = 8, 5_000

    def work():
        for _ in range(n_incs):
            c.inc()

    threads = [threading.Thread(target=work) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == float(n_threads * n_incs)


# ----------------------------------------------------------- span sampling

def _sampled_tracer(n=400, rate=0.25):
    tr = obs.Tracer(name="s", sample_rate=rate, sample_seed=1)
    with tr.span("phase:seed", cat="phase"):
        for i in range(n):
            tr.add_span_mono("measure", cat="measure",
                             start_mono_s=float(i), dur_s=1.0)
    return tr


def test_span_sampling_exact_bookkeeping():
    with pytest.raises(ValueError):
        obs.Tracer(name="bad", sample_rate=1.5)
    tr = _sampled_tracer()
    spans = tr.spans()
    # phase spans are NEVER sampled; measure spans are
    assert [s for s in spans if s["cat"] == "phase"]
    kept = [s for s in spans if s["cat"] == "measure"]
    st = tr.sampling_stats()
    assert st["sample_rate"] == 0.25
    ms = st["cats"]["measure"]
    assert ms["kept"] == len(kept)
    assert ms["kept"] + ms["dropped"] == 400
    assert 0 < ms["kept"] < 400  # it actually sampled
    # the dropped seconds are EXACT (each span was 1.0s), not estimated
    assert ms["dropped_dur_s"] == float(ms["dropped"])
    # full-rate tracer reports no sampling at all
    assert obs.Tracer(name="full").sampling_stats() == {}
    assert obs.NOOP.sampling_stats() == {}


def test_sampling_honest_totals_through_both_exports(tmp_path):
    ts = _load_tool("trace_summary")
    tr = _sampled_tracer()
    for suffix in ("run.json", "run.jsonl"):
        path = str(tmp_path / suffix)
        tr.save(path)
        events = ts.load_events(path)
        sampling = ts.sampling_info(events)
        assert sampling["sample_rate"] == 0.25
        # category totals fold the dropped seconds back in: exactly the
        # 400 x 1.0s that were recorded, regardless of what was kept
        cats = ts.category_totals(events, sampling)
        assert cats["measure"] == pytest.approx(400.0, abs=1e-9)
        assert "sampled trace" in ts.summarize(path)
    # unsampled traces keep byte-for-byte identical summaries: no
    # sampling row, no correction
    full = obs.Tracer(name="f")
    full.add_span_mono("measure", cat="measure", start_mono_s=0.0, dur_s=2.0)
    p = str(tmp_path / "full.jsonl")
    full.save(p)
    ev = ts.load_events(p)
    assert ts.sampling_info(ev) == {}
    assert ts.category_totals(ev)["measure"] == pytest.approx(2.0)


def test_recent_spans_tail_is_wall_anchored_and_bounded():
    tr = obs.Tracer(name="tail")
    for _ in range(50):
        with tr.span("measure", cat="measure"):
            pass
    tail = tr.recent_spans(limit=8)
    assert len(tail) == 8
    now = time.time()
    for s in tail:
        assert s["name"] == "measure" and s["cat"] == "measure"
        assert s["dur_s"] >= 0.0
        assert abs(s["wall_s"] - now) < 60.0  # anchored to the wall clock
    assert obs.NOOP.recent_spans() == []


# --------------------------------------------------------------- trace_diff

def _write_trace(tmp_path, name, phase_s, measure_s):
    tr = obs.Tracer(name="d")
    tr.add_span_mono("phase:seed", cat="phase", start_mono_s=0.0,
                     dur_s=phase_s)
    tr.add_span_mono("measure", cat="measure", start_mono_s=0.0,
                     dur_s=measure_s)
    path = str(tmp_path / name)
    tr.save(path)
    return path


def test_trace_diff_same_trace_passes_gate(tmp_path, capsys):
    td = _load_tool("trace_diff")
    old = _write_trace(tmp_path, "a.json", 1.0, 0.5)
    new = _write_trace(tmp_path, "b.json", 1.0, 0.5)
    assert td.main([old, new, "--fail-on-regression", "10"]) == 0
    out = capsys.readouterr().out
    assert "phase:seed" in out and "+0.0%" in out


def test_trace_diff_flags_injected_slowdown(tmp_path, capsys):
    td = _load_tool("trace_diff")
    old = _write_trace(tmp_path, "a.json", 1.0, 0.5)
    slow = _write_trace(tmp_path, "c.json", 1.6, 0.5)  # +60% in the phase
    assert td.main([old, slow]) == 0  # report-only without the gate
    capsys.readouterr()
    assert td.main([old, slow, "--fail-on-regression", "25"]) == 1
    out = capsys.readouterr().out
    assert "REGRESSION" in out and "phase:seed" in out
    rows = td.diff_rows({"p": 1.0}, {"p": 1.6, "q": 2.0})
    assert rows == [("p", 1.0, 1.6, pytest.approx(60.0)),
                    ("q", 0.0, 2.0, float("inf"))]
    # brand-new rows (no old baseline) never fail the gate
    assert td.regressions(rows, 25.0, 0.05) == [("p", 1.0, 1.6,
                                                 pytest.approx(60.0))]


def test_trace_diff_noise_floor_protects_tiny_rows(tmp_path):
    td = _load_tool("trace_diff")
    old = _write_trace(tmp_path, "a.json", 0.01, 0.002)
    new = _write_trace(tmp_path, "b.json", 0.04, 0.004)  # +300%, all tiny
    assert td.main([old, new, "--fail-on-regression", "25"]) == 0
    assert td.main([old, new, "--fail-on-regression", "25",
                    "--min-s", "0.001"]) == 1


# ------------------------------------------------------------ bench_compare

def _bench_doc(tmp_path, name, schema="repro-bench/2", **metrics):
    doc = {"schema": schema, "bench": "b", "created_unix": 1.0,
           "git_rev": "abc", "config": {}, "metrics": metrics}
    path = str(tmp_path / name)
    with open(path, "w") as f:
        json.dump(doc, f)
    return path


def test_bench_compare_deltas_direction_and_gate(tmp_path, capsys):
    bc = _load_tool("bench_compare")
    old = _bench_doc(tmp_path, "old.json", coopt_network_latency_s=1.0,
                     coopt_speedup_vs_frozen=2.0, coopt_measurements=100.0,
                     phase_times={"phase:seed": 1.0})
    new = _bench_doc(tmp_path, "new.json", coopt_network_latency_s=1.5,
                     coopt_speedup_vs_frozen=1.0, coopt_measurements=200.0,
                     phase_times={"phase:seed": 1.1})
    rows = bc.compare(bc.load(old), bc.load(new))
    byname = {r[0]: r for r in rows}
    assert byname["phase_times.phase:seed"][3] == pytest.approx(10.0)
    assert byname["coopt_network_latency_s"][4] == -1   # lower is better
    assert byname["coopt_speedup_vs_frozen"][4] == +1   # higher is better
    assert byname["coopt_measurements"][4] is None      # count: ungated
    assert bc.main([old, new]) == 0  # report-only
    capsys.readouterr()
    assert bc.main([old, new, "--fail-on-regression", "20"]) == 1
    out = capsys.readouterr().out
    # latency +50% and speedup -50% both fail; phase +10% and the
    # direction-less measurement count never can
    assert "REGRESSION: 2 metric(s)" in out
    assert bc.main([old, new, "--fail-on-regression", "60"]) == 0
    capsys.readouterr()
    assert bc.main([old, new, "--keys", "phase_times.phase:seed",
                    "--fail-on-regression", "20"]) == 0
    capsys.readouterr()
    with pytest.raises(KeyError):
        bc.compare(bc.load(old), bc.load(new), keys=["nope"])


def test_bench_compare_rejects_malformed_docs(tmp_path):
    bc = _load_tool("bench_compare")
    with pytest.raises(ValueError, match="finite"):
        bc.load(_bench_doc(tmp_path, "nan.json", lat_s=float("nan")))
    with pytest.raises(ValueError, match="schema"):
        bc.load(_bench_doc(tmp_path, "v3.json", schema="repro-bench/3",
                           lat_s=1.0))
    with pytest.raises(ValueError):  # unsanctioned nesting
        bc.validate({"schema": "repro-bench/2", "bench": "b",
                     "created_unix": 1.0, "git_rev": "a", "config": {},
                     "metrics": {"other": {"x": 1.0}}})
    with pytest.raises(ValueError):  # /1 never allowed phase_times
        bc.load(_bench_doc(tmp_path, "v1.json", schema="repro-bench/1",
                           lat_s=1.0, phase_times={"p": 1.0}))
    with pytest.raises(ValueError, match="metrics"):
        bc.validate({"schema": "repro-bench/2", "bench": "b",
                     "created_unix": 1.0, "git_rev": "a", "config": {},
                     "metrics": {}})


def test_committed_bench_artifacts_validate():
    """Every BENCH_*.json in the repo passes both the canonical
    validator and bench_compare's standalone mirror — the regression
    gate can always consume what the benchmarks commit."""
    tr = _load_benchmarks("tuning_runs")
    bc = _load_tool("bench_compare")
    paths = sorted(glob.glob(os.path.join(ROOT, "BENCH_*.json")))
    assert paths, "no committed bench artifacts found"
    for path in paths:
        doc = json.loads(open(path).read())
        assert tr.validate_bench_doc(doc) is doc, path
        assert bc.validate(doc) is doc, path


# ----------------------------------------------- session + monitor wiring

def test_session_final_scrape_matches_report_borrowed_server():
    srv = MonitorServer(port=0).start()
    try:
        task = TuningTask.from_space("c", DesignSpace.for_conv2d(WL_MID),
                                     multiplicity=3)
        rep = Session(task, tuner=TINY, budget=8, seed=3,
                      monitor=srv).run()
        assert srv.running  # borrowed: the session must NOT stop it
        st = _get_json(srv.url + "/status")["sources"]["session"]
        assert st["final"] is True and st["kind"] == "session"
        assert st["tasks"]["c"]["best_latency"] == rep.single.best_latency
        assert st["measurements"] == rep.single.n_measurements
        assert st["best_network_latency"] == pytest.approx(
            rep.single.best_latency * 3)
        assert st["oracle"]["hits"] + st["oracle"]["misses"] > 0
        _status, text = _get(srv.url + "/metrics")
        assert _metric_value(text, "repro_session_measurements") \
            == rep.single.n_measurements
        # the frozen gauge equals the report exactly — not approximately
        assert _metric_value(text, "repro_session_network_latency") \
            == rep.single.best_latency * 3
    finally:
        srv.stop()


def test_session_owned_monitor_stops_with_run():
    before = set(obs.active_servers())
    task = TuningTask.from_space("c", DesignSpace.for_conv2d(WL_MID))
    Session(task, tuner=TINY, budget=8, monitor=0).run()
    assert set(obs.active_servers()) == before  # owned server torn down


def test_session_reports_byte_identical_with_monitor_on_off():
    docs = {}
    for label, monitor in (("off", None), ("on", 0)):
        task = TuningTask.from_space("c", DesignSpace.for_conv2d(WL_MID))
        doc = Session(task, tuner=TINY, budget=8, seed=5,
                      monitor=monitor).run().to_dict()
        doc["wall_time_s"] = 0.0
        doc["executor_stats"] = {}
        for rep in doc["reports"].values():
            rep["wall_time_s"] = 0.0
            rep["history"] = [[n, lat, 0.0] for n, lat, _ in rep["history"]]
        docs[label] = json.dumps(doc, sort_keys=True)
    assert docs["on"] == docs["off"]


# -------------------------------------------- netopt acceptance, live run

def _stub_conv_tasks():
    def factory(task, records, workers=0, timeout_s=None, executor=None):
        if executor is not None:
            return SettingsOracle(task.space, fn=None, executor=executor,
                                  task=task.name, records=records,
                                  worker_spec=STUB_SPEC)
        return SettingsOracle(task.space, fn=make_stub(), task=task.name,
                              records=records)
    return [TuningTask(name="c1", space=DesignSpace.for_conv2d(WL_BIG),
                       oracle_factory=factory, multiplicity=2),
            TuningTask(name="c2", space=DesignSpace.for_conv2d(WL_MID),
                       oracle_factory=factory, multiplicity=1)]


def test_netopt_live_monitor_final_scrape_matches_report():
    """The acceptance bar: a netopt run over a loopback remote daemon,
    scraped WHILE running, whose final ``/metrics`` values equal the
    ``NetworkReport`` exactly and whose ``/status`` carries fleet
    health down to the daemon's heartbeat load."""
    cfg = NetOptConfig(seed_candidates=2, hw_rounds=1, hw_per_round=1,
                       layer_budget=4, refine_budget=4, tuner=TINY)
    srv = MonitorServer(port=0).start()
    daemon = WorkerDaemon(slots=2, heartbeat_s=0.2).start()
    live, stop_polling = [], threading.Event()

    def poll():
        while not stop_polling.is_set():
            try:
                live.append(_get_json(srv.url + "/status"))
            except Exception:
                pass
            time.sleep(0.05)

    poller = threading.Thread(target=poll, daemon=True)
    poller.start()
    try:
        ex = RemoteExecutor(daemon.endpoint, heartbeat_s=0.1,
                            heartbeat_timeout_s=5.0)
        try:
            rep = NetworkCoOptimizer(_stub_conv_tasks(), cfg, remote=ex,
                                     name="obs-net", monitor=srv).run()
        finally:
            ex.close()
    finally:
        stop_polling.set()
        poller.join(timeout=5.0)
        daemon.stop()
    try:
        mid_run = [s["sources"]["netopt:obs-net"] for s in live
                   if "netopt:obs-net" in s.get("sources", {})
                   and not s["sources"]["netopt:obs-net"].get("final")]
        assert mid_run, "no successful /status scrape while running"
        assert all(s["kind"] == "netopt" for s in mid_run)
        # the final scrape equals the report EXACTLY
        _status, text = _get(srv.url + "/metrics")
        assert _metric_value(text, "repro_netopt_best_network_latency_s") \
            == rep.network_latency
        assert _metric_value(text, "repro_netopt_measurements") \
            == rep.total_measurements
        assert _metric_value(text, "repro_executor_remote_jobs") > 0
        st = _get_json(srv.url + "/status")["sources"]["netopt:obs-net"]
        assert st["final"] is True and st["phase"] == "refine"
        assert st["best_network_latency"] == rep.network_latency
        # fleet health: per-endpoint detail incl. daemon heartbeat load
        ep = st["executor"]["endpoints"][daemon.endpoint]
        assert ep["jobs"] > 0 and ep["daemon"]["busy"] == 0
    finally:
        srv.stop()


def test_worker_daemon_self_serves_status_and_metrics():
    daemon = WorkerDaemon(slots=2, heartbeat_s=0.2, status_port=0).start()
    try:
        deadline = time.monotonic() + 10.0
        while not daemon.monitor.running and time.monotonic() < deadline:
            time.sleep(0.02)
        assert daemon.monitor.running
        st = _get_json(daemon.monitor.url + "/status")["sources"]["worker"]
        assert st["kind"] == "worker" and st["endpoint"] == daemon.endpoint
        assert st["slots"] == 2 and st["load"]["jobs_done"] == 0
        ex = RemoteExecutor(daemon.endpoint, heartbeat_s=0.1,
                            heartbeat_timeout_s=5.0)
        try:
            handles = [ex.submit("t", {"model_axis": 1 << i},
                                 spec=STUB_SPEC) for i in range(3)]
            ex.drain(handles)
            assert all(h.result().ok for h in handles)
        finally:
            ex.close()
        _status, text = _get(daemon.monitor.url + "/metrics")
        assert _metric_value(text, "repro_worker_jobs_done") == 3
        assert _metric_value(text, "repro_worker_busy") == 0
        monitor = daemon.monitor
    finally:
        daemon.stop()
    assert not monitor.running  # stopped with the daemon


# --------------------------------------------------------- CLI smoke test

def test_cli_tune_monitor_smoke(capsys):
    """``--monitor 0`` on the CLI: the ephemeral server is discoverable
    via ``active_servers()``, serves a ``/status`` poll mid-run, and is
    gone after a clean exit."""
    before = set(obs.active_servers())
    rc = {}

    def run():
        rc["v"] = cli_main(["tune", "--matmul", "64x64x64", "--budget", "4",
                            "--monitor", "0"])

    th = threading.Thread(target=run)
    th.start()
    srv = None
    try:
        deadline = time.monotonic() + 60.0
        while srv is None and time.monotonic() < deadline:
            fresh = [s for s in obs.active_servers() if s not in before]
            if fresh:
                srv = fresh[0]
            elif not th.is_alive():
                break
            else:
                time.sleep(0.01)
        assert srv is not None, "--monitor 0 never started a server"
        st = _get_json(srv.url + "/status")
        assert st["sources"]["session"]["kind"] == "session"
        _status, text = _get(srv.url + "/metrics")
        assert "repro_session_measurements" in text
    finally:
        th.join(timeout=300.0)
    capsys.readouterr()
    assert rc.get("v") == 0 and not th.is_alive()
    assert set(obs.active_servers()) == before  # shut down cleanly
