"""Per-kernel allclose vs the pure-jnp oracles (interpret mode on CPU),
with shape/dtype sweeps and hypothesis property tests."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # dependency-light env: seeded spot-checks instead
    from _hypothesis_compat import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.gemm import GemmConfig, gemm_config_from_knobs

jax.config.update("jax_enable_x64", False)


# ----------------------------------------------------------------- gemm

GEMM_SHAPES = [(8, 8, 8), (100, 70, 90), (128, 128, 128), (1, 256, 33),
               (257, 129, 65)]
GEMM_CONFIGS = [GemmConfig(32, 32, 32), GemmConfig(128, 128, 128),
                GemmConfig(16, 64, 128, parallel_m=False),
                GemmConfig(8, 128, 256, parallel_n=False)]


@pytest.mark.parametrize("m,k,n", GEMM_SHAPES)
@pytest.mark.parametrize("cfg", GEMM_CONFIGS[:2])
def test_gemm_shapes(m, k, n, cfg):
    a = jax.random.normal(jax.random.PRNGKey(0), (m, k), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32)
    out = ops.matmul(a, b, cfg)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.matmul_ref(a, b)),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("cfg", GEMM_CONFIGS)
def test_gemm_configs(cfg):
    a = jax.random.normal(jax.random.PRNGKey(2), (96, 80), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(3), (80, 112), jnp.float32)
    out = ops.matmul(a, b, cfg)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.matmul_ref(a, b)),
                               rtol=1e-5, atol=1e-5)


def test_gemm_bf16():
    a = jax.random.normal(jax.random.PRNGKey(4), (64, 64), jnp.bfloat16)
    b = jax.random.normal(jax.random.PRNGKey(5), (64, 64), jnp.bfloat16)
    out = ops.matmul(a, b, GemmConfig(32, 32, 32))
    expect = ref.matmul_ref(a, b)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=3e-2, atol=3e-2)


@settings(max_examples=15, deadline=None)
@given(m=st.integers(1, 80), k=st.integers(1, 80), n=st.integers(1, 80),
       bm=st.sampled_from([8, 16, 32]), bn=st.sampled_from([16, 32, 64]),
       bk=st.sampled_from([16, 32, 64]))
def test_gemm_property(m, k, n, bm, bn, bk):
    """Any tile geometry yields the same product (padding correctness)."""
    a = jax.random.normal(jax.random.PRNGKey(m * 83 + k), (m, k),
                          jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(n), (k, n), jnp.float32)
    out = ops.matmul(a, b, GemmConfig(bm, bn, bk))
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.matmul_ref(a, b)),
                               rtol=2e-5, atol=2e-5)


def test_gemm_knob_mapping():
    cfg = gemm_config_from_knobs(tile_m=7, tile_n=100, tile_k=60,
                                 h_threading=2, oc_threading=1)
    assert cfg.block_m % 8 == 0 and cfg.block_n % 128 == 0
    assert cfg.block_k % 128 == 0
    assert cfg.parallel_m and not cfg.parallel_n


# ----------------------------------------------------------------- conv2d

@pytest.mark.parametrize("stride,pad", [(1, 1), (2, 0), (2, 1), (1, 0)])
@pytest.mark.parametrize("kh", [1, 3])
def test_conv2d(stride, pad, kh):
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 13, 13, 5), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(7), (kh, kh, 5, 7), jnp.float32)
    out = ops.conv2d(x, w, stride, pad, GemmConfig(32, 32, 64))
    expect = ref.conv2d_ref(x, w, stride, pad)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-4)


def test_conv2d_from_knobs():
    x = jax.random.normal(jax.random.PRNGKey(8), (1, 14, 14, 16), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(9), (3, 3, 16, 32), jnp.float32)
    out = ops.conv2d_from_knobs(x, w, 1, 1, tile_b=1, tile_h=4, tile_w=4,
                                tile_ci=16, tile_co=32, h_threading=2,
                                oc_threading=2)
    expect = ref.conv2d_ref(x, w, 1, 1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-4)


# ------------------------------------------------------- flash attention

@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 32)])
@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (6, 1)])
def test_flash_attention(causal, window, hq, hkv):
    q = jax.random.normal(jax.random.PRNGKey(10), (2, 100, hq, 16),
                          jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(11), (2, 100, hkv, 16),
                          jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(12), (2, 100, hkv, 16),
                          jnp.float32)
    out = ops.attention(q, k, v, causal=causal, window=window,
                        block_q=32, block_k=32)
    expect = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-3, atol=2e-3)


@settings(max_examples=10, deadline=None)
@given(s=st.integers(3, 70), bq=st.sampled_from([16, 32]),
       bk=st.sampled_from([16, 64]), causal=st.booleans())
def test_flash_attention_property(s, bq, bk, causal):
    """Block sizes never change the result (online-softmax correctness)."""
    q = jax.random.normal(jax.random.PRNGKey(s), (1, s, 2, 8), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(s + 1), (1, s, 2, 8),
                          jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(s + 2), (1, s, 2, 8),
                          jnp.float32)
    out = ops.attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    expect = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-3, atol=2e-3)


def test_chunked_attention_matches_ref():
    """The differentiable training-path attention == oracle."""
    from repro.models.layers import chunked_attention
    q = jax.random.normal(jax.random.PRNGKey(20), (2, 50, 4, 16),
                          jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(21), (2, 50, 2, 16),
                          jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(22), (2, 50, 2, 16),
                          jnp.float32)
    for chunk in (7, 16, 50, 128):
        out = chunked_attention(q, k, v, causal=True, chunk=chunk)
        expect = ref.attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=2e-3, atol=2e-3)


def test_chunked_attention_grads_finite():
    from repro.models.layers import chunked_attention

    def f(q, k, v):
        return chunked_attention(q, k, v, chunk=16).sum()

    q = jax.random.normal(jax.random.PRNGKey(23), (1, 33, 2, 8), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(24), (1, 33, 2, 8), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(25), (1, 33, 2, 8), jnp.float32)
    grads = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    for g in grads:
        assert bool(jnp.isfinite(g).all())


# ----------------------------------------------------------------- rmsnorm

@pytest.mark.parametrize("shape", [(4, 64), (2, 100, 96), (1, 7, 33),
                                   (129, 256)])
@pytest.mark.parametrize("block_rows", [8, 32, 128])
def test_rmsnorm_kernel(shape, block_rows):
    from repro.kernels.rmsnorm import rmsnorm
    from repro.models.layers import rmsnorm as ref_rmsnorm
    x = jax.random.normal(jax.random.PRNGKey(0), shape, jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), shape[-1:], jnp.float32)
    out = rmsnorm(x, w, block_rows=block_rows)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref_rmsnorm(x, w)),
                               rtol=1e-5, atol=1e-5)
