"""The unified tuning-session API: oracles, records, sessions, transfer."""
import json

import jax
import numpy as np
import pytest

from repro.compiler.oracle import (AnalyticalOracle, SettingsOracle,
                                   decode_config)
from repro.compiler.records import RecordLog

from repro.compiler.session import Session, SessionReport
from repro.compiler.task import TuningTask
from repro.core import mappo
from repro.core.design_space import DesignSpace, N_KNOBS
from repro.core.shard_space import ShardSpace
from repro.core.tuner import ArcoLoop, TunerConfig

WL = dict(b=1, h=14, w=14, ci=64, co=64, kh=3, kw=3, stride=1, pad=1)
FAST = TunerConfig.fast()


@pytest.fixture(scope="module")
def space():
    return DesignSpace.for_conv2d(WL)


def _tiny_cfg(**kw):
    base = dict(iteration_opt=3, b_measure=8, episodes_per_iter=2,
                mappo=mappo.MappoConfig(n_steps=16, n_envs=8), gbt_rounds=10)
    base.update(kw)
    return TunerConfig(**base)


# ------------------------------------------------------------------ oracle

def test_oracle_memoization_hit_miss(space):
    oracle = AnalyticalOracle(space, task="memo")
    cfgs = np.asarray(space.random_configs(jax.random.PRNGKey(0), 6))
    cfgs = np.unique(cfgs, axis=0)
    n = len(cfgs)
    lat1, feats1 = oracle.measure(cfgs)
    assert oracle.misses == n and oracle.hits == 0
    assert feats1.shape == (n, 18)
    lat2, feats2 = oracle.measure(cfgs)  # all cached
    assert oracle.misses == n and oracle.hits == n
    np.testing.assert_array_equal(lat1, lat2)
    np.testing.assert_array_equal(feats1, feats2)
    # half-overlapping batch: only the new half is measured
    fresh = np.asarray(space.random_configs(jax.random.PRNGKey(1), 20))
    fresh = np.asarray([c for c in np.unique(fresh, axis=0)
                        if tuple(int(x) for x in c) not in oracle.seen])[:n]
    mixed = np.concatenate([cfgs[: n // 2], fresh])
    oracle.measure(mixed)
    assert oracle.misses == n + len(fresh)
    assert oracle.hits == n + n // 2
    assert oracle.stats()["cached"] == n + len(fresh)


def test_oracle_batch_duplicates_measured_once(space):
    # an in-batch duplicate on a cold cache is a *dedup*, not a cache hit
    oracle = AnalyticalOracle(space, task="dup")
    cfg = np.asarray(space.random_configs(jax.random.PRNGKey(2), 1))
    batch = np.concatenate([cfg, cfg])
    lat, _ = oracle.measure(batch)
    assert oracle.misses == 1 and oracle.hits == 0
    assert oracle.stats()["dedup"] == 1
    assert lat[0] == lat[1]
    # re-measuring the same batch: one real hit, the duplicate still dedups
    oracle.measure(batch)
    assert oracle.misses == 1 and oracle.hits == 2
    assert oracle.stats()["dedup"] == 1


def _flaky_cell(fail_when_sp):
    def fn(settings):
        if settings["sequence_parallel"] == fail_when_sp:
            raise RuntimeError("compile blew up")
        return 1.0 / settings["model_axis"]
    return ShardSpace.for_cell("qwen2-1.5b", "train_4k", None,
                               n_devices=256), fn


def test_failed_measurement_penalty_recorded(tmp_path):
    space, fn = _flaky_cell(fail_when_sp=True)
    log = RecordLog(str(tmp_path / "rec.jsonl"))
    oracle = SettingsOracle(space, fn, task="flaky", records=log)
    # one config with SP on (fails), one with SP off (ok)
    bad = np.zeros(N_KNOBS, np.int64)
    bad[6] = 1   # tile_w slot -> sequence_parallel on
    good = np.zeros(N_KNOBS, np.int64)
    lat, _ = oracle.measure(np.stack([bad, good]))
    assert lat[0] == oracle.penalty_latency
    assert lat[1] == pytest.approx(1.0 / space.choices[0][0])
    assert oracle.failures == 1
    rows = log.load(task="flaky")
    assert len(rows) == 2
    errs = [r for r in rows if "error" in r]
    assert len(errs) == 1 and "compile blew up" in errs[0]["error"]
    assert errs[0]["latency"] == oracle.penalty_latency
    assert errs[0]["settings"]["sequence_parallel"] is True


def test_decode_config_both_space_kinds(space):
    named = decode_config(space, np.zeros(N_KNOBS, np.int64))
    assert set(named) == set(space.knob_names)
    shard, _ = _flaky_cell(True)
    s = decode_config(shard, np.zeros(N_KNOBS, np.int64))
    assert s["model_axis"] == shard.choices[0][0]
    assert s["sequence_parallel"] is False


# ---------------------------------------------------------- seed budget fix

def test_seed_batch_consumes_full_budget():
    # tiny space (144 configs) -> 64 random draws certainly collide;
    # np.unique dedup used to shrink iteration 0, leaking seed budget —
    # the top-up must restore the full batch of *distinct* configs
    space = DesignSpace.for_matmul(2, 2, 2)
    assert space.size < 200
    cfg = _tiny_cfg(b_measure=64)
    loop = ArcoLoop(space, cfg, task="seed")
    loop.seed(budget=64)
    assert loop.track.count == 64
    assert len(loop.track.seen) == 64


# ------------------------------------------------------- records + resume

def test_session_resume_from_records(tmp_path, space):
    path = str(tmp_path / "session.jsonl")
    task = TuningTask.from_space("conv64", space)
    cfg = _tiny_cfg()

    r1 = Session(task, tuner=cfg, budget=24, records=path).run().single
    assert r1.oracle_stats["misses"] > 0

    # same session again: replays warm from the records, same best config,
    # zero new oracle measurements
    r2 = Session(task, tuner=cfg, budget=24, records=path).run().single
    assert r2.oracle_stats["misses"] == 0
    assert r2.oracle_stats["hits"] == r2.n_measurements
    assert r2.best_latency == r1.best_latency
    assert r2.best_config == r1.best_config

    # a larger budget continues the search instead of restarting it
    r3 = Session(task, tuner=cfg, budget=40, records=path).run().single
    assert r3.n_measurements == 40
    assert r3.oracle_stats["misses"] <= 40 - 24 + cfg.b_measure
    assert r3.best_latency <= r1.best_latency


# ------------------------------------------------------------- session API

def test_multi_task_session_shared_gbt(tmp_path):
    tasks = [TuningTask.matmul(256, 512, 512), TuningTask.matmul(512, 512, 512)]
    path = str(tmp_path / "cells.jsonl")
    sr = Session(tasks, tuner=_tiny_cfg(), budget=24, records=path).run()
    assert set(sr.reports) == {t.name for t in tasks}
    for rep in sr:
        assert rep.n_measurements == 24
        assert np.isfinite(rep.best_latency)
    rows = RecordLog(path).load()
    assert {r["task"] for r in rows} == {t.name for t in tasks}
    # every row carries the full GBT feature vector for warm refits
    assert all(len(r["features"]) == 18 for r in rows)


def test_session_report_json_roundtrip(space):
    task = TuningTask.from_space("conv64", space)
    sr = Session(task, tuner=_tiny_cfg(), budget=16).run()
    d = json.loads(json.dumps(sr.to_dict()))
    back = SessionReport.from_dict(d)
    rep = back.single
    assert rep.best_latency == sr.single.best_latency
    assert rep.best_config == sr.single.best_config
    assert rep.history == sr.single.history
    assert rep.best_settings == sr.single.best_settings


def test_report_best_settings_and_gflops(space):
    rep = Session(TuningTask.from_space("conv64", space),
                  tuner=_tiny_cfg(), budget=16).run().single
    assert set(rep.best_settings) == set(space.knob_names)
    assert rep.best_gflops(space) > 0


def test_baseline_algos_through_session(space):
    task = TuningTask.from_space("conv64", space)
    for algo in ("random", "autotvm", "chameleon"):
        rep = Session(task, tuner=_tiny_cfg(), algo=algo,
                      budget=16).run().single
        assert rep.n_measurements <= 16
        assert np.isfinite(rep.best_latency)
        assert rep.oracle_stats["misses"] == rep.n_measurements


# --------------------------------------------------- cross-task transfer

def _transfer_surfaces():
    """Two toy (arch x shape)-style cells sharing one latency surface but
    carrying different cell descriptors — the transfer-friendly regime."""
    def make(arch):
        space = ShardSpace.for_cell(arch, "train_4k", None, n_devices=256)

        def fn(settings):
            step = 1.0 + abs(np.log2(settings["model_axis"] / 16))
            step *= 0.2 if settings["sequence_parallel"] else 1.0
            step *= 0.8 if settings["remat"] else 1.0
            step *= {1: 1.2, 2: 1.0, 4: 1.1, 8: 1.3}[settings["grad_accum"]]
            return step

        def factory(task, records):
            return SettingsOracle(space, fn, task=task.name, records=records)

        return TuningTask(name=arch, space=space, oracle_factory=factory)

    return [make("qwen2-1.5b"), make("qwen1.5-4b")]


def _mean_measured(sr):
    """Search efficiency: mean latency over everything the run measured."""
    return float(np.mean([l for rep in sr for _, l in rep.measurements]))


def test_shared_gbt_beats_independent_arco():
    tasks = _transfer_surfaces()
    # distinct cell descriptors are what let one GBT serve both cells
    assert not np.allclose(tasks[0].descriptor(), tasks[1].descriptor())
    cfg = TunerConfig(iteration_opt=5, b_measure=8, episodes_per_iter=2,
                      mappo=mappo.MappoConfig(n_steps=16, n_envs=8),
                      gbt_rounds=10)
    shared = Session(tasks, tuner=cfg, budget=40,
                     share_cost_model=True).run()
    indep = Session(tasks, tuner=cfg, budget=40,
                    share_cost_model=False).run()
    s_total = shared.total_best_latency()
    i_total = indep.total_best_latency()
    assert s_total < i_total, (s_total, i_total)
    assert _mean_measured(shared) < _mean_measured(indep)


def test_shared_gbt_beats_independent_autotvm():
    """The surrogate-driven baseline benefits from transfer on every seed:
    its SA proposals follow the GBT surface directly, so the cell-descriptor
    features let cell B's search start from cell A's surface."""
    tasks = _transfer_surfaces()
    cfg = _tiny_cfg(b_measure=8)
    shared = Session(tasks, tuner=cfg, algo="autotvm", budget=32,
                     share_cost_model=True).run()
    indep = Session(tasks, tuner=cfg, algo="autotvm", budget=32,
                    share_cost_model=False).run()
    assert shared.total_best_latency() <= indep.total_best_latency()
    assert _mean_measured(shared) < _mean_measured(indep)
