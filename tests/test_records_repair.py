"""Property-style tests for ``RecordLog`` torn-tail repair.

A run killed mid-append can leave any byte-prefix of its final line on
disk.  The durability contract: the *next* run (a fresh ``RecordLog`` on
the same path) must always warm-resume — every intact row survives, the
torn fragment disappears, and a new append never merges into it.  These
tests enumerate every possible kill point byte-for-byte instead of
sampling a few.
"""
import json
import os

import pytest

from repro.compiler.records import RecordLog


def _write_rows(path, rows):
    log = RecordLog(path)
    for row in rows:
        log.append(row)
    return open(path, "rb").read()


def _rows(n):
    return [{"task": f"t{i % 2}", "config": [i, i + 1],
             "latency": 1e-4 * (i + 1), "features": [0.5 * i, 1.0]}
            for i in range(n)]


def test_truncation_at_every_byte_of_final_line(tmp_path):
    """Cut a healthy log at every byte offset inside its final line; warm
    resume must always succeed, keep exactly the intact prefix rows, and
    a post-kill append must never merge with the fragment."""
    rows = _rows(4)
    ref = _write_rows(str(tmp_path / "ref.jsonl"), rows)
    lines = ref.splitlines(keepends=True)
    last_start = len(ref) - len(lines[-1])
    new_row = {"task": "resume", "config": [9, 9], "latency": 5e-4,
               "features": [9.0]}

    for cut in range(last_start, len(ref) + 1):
        path = str(tmp_path / f"cut{cut}.jsonl")
        with open(path, "wb") as f:
            f.write(ref[:cut])
        resumed = RecordLog(path)
        # load() before any append tolerates the torn tail and always
        # yields an intact prefix of the original rows (only the row
        # being written when the kill hit may be missing)
        before = resumed.load()
        assert before == rows[:len(before)], f"cut at byte {cut}"
        assert len(before) >= len(rows) - 1, f"cut at byte {cut}"
        resumed.append(new_row)
        # the appended row lands whole behind an intact prefix — never
        # merged into the fragment.  (A cut that removed only the final
        # newline leaves a parseable row that load() keeps but the
        # append-time repair drops: the write was never acknowledged.)
        after = resumed.load()
        assert after[-1] == new_row, f"cut at byte {cut}"
        assert after[:-1] == rows[:len(after) - 1], f"cut at byte {cut}"
        assert len(after) - 1 >= len(rows) - 1, f"cut at byte {cut}"
        # every line on disk parses on its own
        with open(path) as f:
            for ln in f.read().splitlines():
                json.loads(ln)


def test_truncation_of_a_single_row_file(tmp_path):
    """Degenerate log: one row, killed mid-first-append.  Every prefix
    must resume to an empty-then-appended log."""
    rows = _rows(1)
    ref = _write_rows(str(tmp_path / "ref.jsonl"), rows)
    new_row = {"task": "t0", "config": [1], "latency": 1.0, "features": []}
    for cut in range(0, len(ref) + 1):
        path = str(tmp_path / f"cut{cut}.jsonl")
        with open(path, "wb") as f:
            f.write(ref[:cut])
        resumed = RecordLog(path)
        before = resumed.load(task="t0")
        assert before in ([], rows)
        resumed.append(new_row)
        after = resumed.load(task="t0")
        assert after[-1] == new_row
        assert after[:-1] in ([], rows)


def test_midfile_corruption_still_raises(tmp_path):
    """Only the *trailing* line is recoverable; corruption anywhere else
    is a real error and must not be silently dropped."""
    path = str(tmp_path / "log.jsonl")
    ref = _write_rows(path, _rows(3))
    lines = ref.splitlines(keepends=True)
    broken = lines[0] + lines[1][: len(lines[1]) // 2] + b"\n" + lines[2]
    with open(path, "wb") as f:
        f.write(broken)
    with pytest.raises(ValueError, match="mid-file"):
        RecordLog(path).load()


def test_torn_tail_repair_truncates_once_before_append(tmp_path):
    """The repair physically removes the fragment (so the file itself is
    healthy for any other reader), and a healthy file is left untouched."""
    path = str(tmp_path / "log.jsonl")
    ref = _write_rows(path, _rows(2))
    healthy_size = os.path.getsize(path)
    with open(path, "ab") as f:
        f.write(b'{"task": "t0", "conf')   # torn tail
    log = RecordLog(path)
    log.append({"task": "t0", "config": [5], "latency": 1.0,
                "features": []})
    data = open(path, "rb").read()
    assert b'"conf' not in data.replace(b'"config"', b"")
    assert data[:healthy_size] == ref
    # second instance on the now-healthy file: no-op repair
    size = os.path.getsize(path)
    RecordLog(path).append({"task": "t0", "config": [6], "latency": 1.0,
                            "features": []})
    assert os.path.getsize(path) > size
