"""``repro.obs`` — tracing + metrics layer and its stack integration.

Covers the tracer core (span nesting, thread safety, the disabled-path
overhead guard), both export formats (Chrome-trace JSON validity, raw
JSONL), the metrics registry's uniform executor-stats mapping, the
remote fabric round-trip (daemon-shipped measure spans merged into the
local timeline, heartbeat load telemetry in ``RemoteExecutor.stats()``),
the no-observable-effect guarantee (byte-identical session reports with
tracing on vs off at a fixed seed), the netopt ``--trace`` acceptance
bar (named phase spans covering >= 95% of the run's wall clock, remote
spans included), and the ``repro-bench/2`` artifact schema
(``phase_times`` nesting sanctioned, everything else still flat/finite).
"""
import importlib.util
import json
import math
import os
import sys
import threading
import time

import pytest

from repro import obs
from repro.compiler.executor import (RemoteExecutor, WorkerDaemon,
                                     WorkerSpec)
from repro.compiler.executor.stub import make_stub, stub_latency
from repro.compiler.netopt import NetOptConfig, NetworkCoOptimizer
from repro.compiler.oracle import SettingsOracle
from repro.compiler.session import Session
from repro.compiler.task import TuningTask
from repro.core import mappo
from repro.core.design_space import DesignSpace
from repro.core.tuner import TunerConfig
from repro.obs.export import chrome_trace

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
STUB = "repro.compiler.executor.stub:make_stub"
STUB_SPEC = WorkerSpec(factory=STUB)
WL_BIG = dict(b=1, h=14, w=14, ci=256, co=256, kh=3, kw=3, stride=1, pad=1)
WL_MID = dict(b=1, h=28, w=28, ci=128, co=128, kh=3, kw=3, stride=1, pad=1)
TINY = TunerConfig(iteration_opt=3, b_measure=8, episodes_per_iter=2,
                   mappo=mappo.MappoConfig(n_steps=16, n_envs=8),
                   gbt_rounds=10)


def _load_tool(name):
    path = os.path.join(ROOT, "tools", f"{name}.py")
    spec = importlib.util.spec_from_file_location(name, path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _load_benchmarks(name):
    path = os.path.join(ROOT, "benchmarks", f"{name}.py")
    if os.path.join(ROOT, "benchmarks") not in sys.path:
        sys.path.insert(0, os.path.join(ROOT, "benchmarks"))
    spec = importlib.util.spec_from_file_location(name, path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ------------------------------------------------------------- tracer core

def test_span_nesting_records_depth_and_order():
    tr = obs.Tracer(name="t")
    with tr.span("outer", cat="phase"):
        with tr.span("inner", cat="measure", n=3):
            pass
        tr.event("tick", cat="mark")
    spans = {s["name"]: s for s in tr.spans()}
    assert spans["inner"]["depth"] == 1 and spans["outer"]["depth"] == 0
    assert spans["inner"]["args"] == {"n": 3}
    # inner closed first, and sits inside outer's interval
    assert spans["outer"]["t"] <= spans["inner"]["t"]
    assert (spans["inner"]["t"] + spans["inner"]["dur"]
            <= spans["outer"]["t"] + spans["outer"]["dur"] + 1e-6)
    events = [e for e in tr.events() if e["ph"] == "i"]
    assert len(events) == 1 and events[0]["name"] == "tick"


def test_tracer_thread_safety():
    tr = obs.Tracer(name="mt")
    n_threads, n_spans = 8, 200

    def work(i):
        for j in range(n_spans):
            with tr.span(f"w{i}", cat="measure"):
                pass

    threads = [threading.Thread(target=work, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    spans = tr.spans()
    assert len(spans) == n_threads * n_spans
    # per-thread depth stacks never interleave: everything is top-level
    assert all(s["depth"] == 0 for s in spans)


def test_ambient_default_is_noop_and_use_restores():
    assert obs.current() is obs.NOOP
    tr = obs.Tracer(name="scoped")
    with obs.use(tr):
        assert obs.current() is tr
        with obs.use(None):  # re-entrant; None -> NOOP
            assert obs.current() is obs.NOOP
        assert obs.current() is tr
    assert obs.current() is obs.NOOP


def test_disabled_tracer_overhead_guard():
    """The no-op path must stay nearly free: 50k span sites through the
    NOOP singleton in well under the time 50k stub measurements take
    (the <=1%-throughput-regression acceptance bar, expressed as an
    in-test guard with generous headroom for CI jitter)."""
    n = 50_000
    t0 = time.perf_counter()
    for _ in range(n):
        with obs.current().span("x", cat="measure"):
            pass
    noop_s = time.perf_counter() - t0
    settings = {"tile_b": 1, "tile_ci": 64}
    t0 = time.perf_counter()
    for _ in range(2_000):
        stub_latency(settings)
    stub_per_call = (time.perf_counter() - t0) / 2_000
    # 1% of the equivalent stub-measure time, with 10x slack
    assert noop_s < max(0.01 * stub_per_call * n * 10, 0.5), (
        f"noop span overhead {noop_s:.3f}s over {n} sites")


def test_noop_tracer_full_api_is_inert(tmp_path):
    noop = obs.NOOP
    noop.event("e")
    noop.add_span("s", wall_start_s=0.0, dur_s=1.0)
    noop.add_span_mono("s", start_mono_s=0.0, dur_s=1.0)
    noop.metrics.counter("c").inc()
    noop.metrics.record_executor_stats({"kind": "serial", "jobs": 3})
    assert noop.phase_times() == {}
    assert noop.metrics.snapshot() == {}
    noop.save(str(tmp_path / "never.json"))
    assert not (tmp_path / "never.json").exists()


# ------------------------------------------------------ metrics registry

def test_metrics_registry_and_executor_stats_mapping():
    m = obs.Metrics()
    m.counter("jobs").inc()
    m.counter("jobs").inc(2)
    m.gauge("depth").set(7)
    h = m.histogram("lat")
    for v in (1.0, 3.0, 2.0):
        h.observe(v)
    m.record_executor_stats({"kind": "remote", "jobs": 10, "failures": 1,
                             "workers_alive": 2, "queued": 0,
                             "running": 1, "max_inflight": 4})
    m.record_executor_stats({"kind": "remote", "jobs": 12, "failures": 1,
                             "workers_alive": 2})  # overwrite, not add
    snap = m.snapshot()
    assert snap["counters"]["jobs"] == 3.0
    assert snap["counters"]["executor.remote.jobs"] == 12.0
    assert snap["gauges"]["executor.remote.workers_alive"] == 2.0
    assert snap["histograms"]["lat"] == {"count": 3, "sum": 6.0, "min": 1.0,
                                         "max": 3.0, "mean": 2.0,
                                         "p50": 2.0, "p90": 3.0, "p99": 3.0}


# ------------------------------------------------------------ export forms

def _tiny_trace():
    tr = obs.Tracer(name="exp")
    with tr.span("phase:seed", cat="phase"):
        with tr.span("measure", cat="measure"):
            pass
    tr.event("mark", cat="note")
    tr.add_span("measure", cat="measure", wall_start_s=time.time() - 1.0,
                dur_s=0.5, tid="host:123")
    return tr


def test_chrome_trace_export_is_valid(tmp_path):
    tr = _tiny_trace()
    path = tmp_path / "run.json"
    tr.save(str(path))
    doc = json.loads(path.read_text())
    assert isinstance(doc["traceEvents"], list) and doc["traceEvents"]
    assert doc["displayTimeUnit"] == "ms"
    assert doc["otherData"]["tracer"] == "exp"
    for ev in doc["traceEvents"]:
        assert {"name", "cat", "ph", "ts", "pid", "tid"} <= set(ev)
        assert isinstance(ev["ts"], float) and math.isfinite(ev["ts"])
        if ev["ph"] == "X":
            assert ev["dur"] >= 0.0
        if ev["ph"] == "i":
            assert ev["s"] == "t"
    # the remote span landed on its endpoint lane at an earlier wall time
    remote = [e for e in doc["traceEvents"] if e["tid"] == "host:123"]
    assert len(remote) == 1 and remote[0]["ph"] == "X"
    local = [e for e in doc["traceEvents"] if e["name"] == "phase:seed"]
    assert remote[0]["ts"] < local[0]["ts"]


def test_jsonl_export_and_summary_tools(tmp_path):
    ts = _load_tool("trace_summary")
    tr = _tiny_trace()
    for suffix in ("run.jsonl", "run.json"):
        path = tmp_path / suffix
        tr.save(str(path))
        events = ts.load_events(str(path))
        spans = [e for e in events if e["ph"] == "X" and e["dur_s"] > 0]
        assert len(spans) == 3
        assert ts.phase_totals(events).keys() == {"phase:seed"}
        assert ts.tid_totals(events)["host:123"] == pytest.approx(0.5,
                                                                  rel=1e-6)
        assert "phase union coverage" in ts.summarize(str(path))
    # jsonl rows carry absolute wall_s, one JSON object per line
    lines = [json.loads(l) for l in
             (tmp_path / "run.jsonl").read_text().splitlines()]
    assert all("wall_s" in r and "t" not in r for r in lines)


def test_union_seconds_merges_overlaps():
    ts = _load_tool("trace_summary")
    mk = lambda a, d: {"start_s": a, "dur_s": d}
    assert ts.union_seconds([mk(0, 2), mk(1, 2), mk(5, 1)]) == \
        pytest.approx(4.0)
    assert ts.union_seconds([]) == 0.0


# --------------------------------------------------- remote span round-trip

def test_remote_spans_and_heartbeat_load_roundtrip():
    """A real loopback daemon ships its own measure-fn timing inside the
    result frame and load telemetry inside heartbeats: the executor-side
    tracer shows per-endpoint measure spans, ``stats()`` the daemon
    load."""
    daemon = WorkerDaemon(heartbeat_s=0.2).start()
    tr = obs.Tracer(name="remote")
    try:
        with obs.use(tr):
            ex = RemoteExecutor(daemon.endpoint, heartbeat_s=0.1,
                                heartbeat_timeout_s=2.0)
            settings = [{"model_axis": 1 << i} for i in range(4)]
            handles = [ex.submit("t", s, spec=STUB_SPEC) for s in settings]
            ex.drain(handles)
            assert all(h.result().ok for h in handles)
            deadline = time.monotonic() + 5.0
            load = {}
            while time.monotonic() < deadline:  # next daemon heartbeat
                ex.poll()  # the executor is cooperative: pump the selector
                load = ex.stats()["endpoints"][daemon.endpoint]["daemon"]
                if load.get("jobs_done", 0) >= 4:
                    break
                time.sleep(0.05)
            ex.close()
        spans = [s for s in tr.spans() if s["tid"] == daemon.endpoint]
        assert len(spans) == 4
        assert all(s["cat"] == "measure" and s["dur"] >= 0.0 for s in spans)
        # re-anchored onto the local timeline: within the run's extent
        local_now = time.monotonic()
        assert all(-60.0 < s["t"] <= local_now for s in spans)
        assert load["jobs_done"] >= 4 and load["busy"] == 0
        assert load["mean_measure_s"] is None or load["mean_measure_s"] >= 0
    finally:
        daemon.stop()


# --------------------------------------- tracing changes nothing measured

def test_session_reports_byte_identical_with_tracing_on_off(tmp_path):
    space = DesignSpace.for_conv2d(WL_MID)
    docs = {}
    for label, trace in (("off", None), ("on", str(tmp_path / "t.json"))):
        task = TuningTask.from_space("c", space)
        doc = Session(task, tuner=TINY, budget=8, seed=5,
                      trace=trace).run().to_dict()
        doc["wall_time_s"] = 0.0
        doc["executor_stats"] = {}
        for rep in doc["reports"].values():
            rep["wall_time_s"] = 0.0
            rep["history"] = [[n, lat, 0.0] for n, lat, _ in rep["history"]]
        docs[label] = json.dumps(doc, sort_keys=True)
    assert docs["on"] == docs["off"]
    assert (tmp_path / "t.json").exists()  # and the trace was still written


# ----------------------------------------------- netopt --trace acceptance

def _stub_conv_tasks():
    """Conv-space tasks measured by the stub fn so a remote executor
    (rather than the analytical in-process path) does the measuring."""
    def factory(task, records, workers=0, timeout_s=None, executor=None):
        if executor is not None:
            return SettingsOracle(task.space, fn=None, executor=executor,
                                  task=task.name, records=records,
                                  worker_spec=STUB_SPEC)
        return SettingsOracle(task.space, fn=make_stub(), task=task.name,
                              records=records)
    return [TuningTask(name="c1", space=DesignSpace.for_conv2d(WL_BIG),
                       oracle_factory=factory, multiplicity=2),
            TuningTask(name="c2", space=DesignSpace.for_conv2d(WL_MID),
                       oracle_factory=factory, multiplicity=1)]


def test_netopt_trace_phase_coverage_with_remote_daemon(tmp_path):
    """The acceptance bar: a traced netopt run over a loopback daemon
    produces a Perfetto-loadable Chrome trace whose named phase spans
    cover >= 95% of the reported wall time, including spans the daemon
    timed itself."""
    ts = _load_tool("trace_summary")
    path = tmp_path / "netopt.trace.json"
    cfg = NetOptConfig(seed_candidates=2, hw_rounds=1, hw_per_round=1,
                       layer_budget=4, refine_budget=4, tuner=TINY)
    daemon = WorkerDaemon(slots=2, heartbeat_s=0.2).start()
    try:
        ex = RemoteExecutor(daemon.endpoint, heartbeat_s=0.1,
                            heartbeat_timeout_s=5.0)
        try:
            rep = NetworkCoOptimizer(_stub_conv_tasks(), cfg, remote=ex,
                                     name="obs-net",
                                     trace=str(path)).run()
        finally:
            ex.close()
    finally:
        daemon.stop()
    assert rep.wall_time_s > 0
    doc = json.loads(path.read_text())  # valid Chrome-trace JSON
    assert doc["traceEvents"] and doc["displayTimeUnit"] == "ms"
    events = ts.load_events(str(path))
    phase_spans = [e for e in events
                   if e["ph"] == "X" and e["cat"] == "phase"]
    assert {"phase:seed", "phase:refine"} <= {e["name"]
                                              for e in phase_spans}
    covered = ts.union_seconds(phase_spans)
    assert covered >= 0.95 * rep.wall_time_s, (
        f"phase spans cover {covered:.3f}s of {rep.wall_time_s:.3f}s "
        f"({100 * covered / rep.wall_time_s:.1f}% < 95%)")
    # daemon-side spans made it across the wire onto the endpoint lane
    remote_spans = ts.tid_totals(events, "measure")
    assert daemon.endpoint in remote_spans
    # terminal executor stats rode along in the metrics snapshot
    counters = doc["otherData"]["metrics"]["counters"]
    assert counters.get("executor.remote.jobs", 0) > 0


# ------------------------------------------------------ bench schema v2

def _bench_doc(schema="repro-bench/2", **metrics):
    base = {"coopt_network_latency_s": 1.5, "wall_time_s": 2.0}
    base.update(metrics)
    return {"schema": schema, "bench": "b", "created_unix": 1.0,
            "git_rev": "abc", "config": {}, "metrics": base}


def test_bench_schema_v2_accepts_phase_times_rejects_other_nesting():
    tr = _load_benchmarks("tuning_runs")
    assert tr.BENCH_SCHEMA == "repro-bench/2"
    ok = _bench_doc(phase_times={"phase:seed": 1.0, "phase:cs": 0.5})
    assert tr.validate_bench_doc(ok) is ok
    # /1 (strictly flat) still validates
    assert tr.validate_bench_doc(_bench_doc(schema="repro-bench/1"))
    with pytest.raises(ValueError, match="phase_times"):
        tr.validate_bench_doc(_bench_doc(phase_times={"p": float("nan")}))
    with pytest.raises(ValueError, match="metric"):  # unsanctioned nesting
        tr.validate_bench_doc(_bench_doc(other={"nested": 1.0}))
    with pytest.raises(ValueError):  # /1 never allowed nesting; still true
        tr.validate_bench_doc(_bench_doc(schema="repro-bench/1",
                                         phase_times={"p": 1.0}))
    with pytest.raises(ValueError, match="schema"):
        tr.validate_bench_doc(_bench_doc(schema="repro-bench/3"))
    with pytest.raises(ValueError, match="finite"):
        tr.validate_bench_doc(_bench_doc(bad=float("inf")))


def test_write_bench_artifact_roundtrips_phase_times(tmp_path):
    tr = _load_benchmarks("tuning_runs")
    path = str(tmp_path / "BENCH_x.json")
    doc = tr.write_bench_artifact(
        path, "x", {"lat_s": 0.25, "phase_times": {"phase:seed": 1.25}},
        config={"budget": 4})
    reread = json.loads(open(path).read())
    assert reread["schema"] == "repro-bench/2"
    assert reread["metrics"]["phase_times"] == {"phase:seed": 1.25}
    assert tr.validate_bench_doc(reread)
    assert doc["metrics"]["lat_s"] == 0.25


def test_tracer_phase_times_sums_by_name():
    tr = obs.Tracer(name="pt")
    tr.add_span_mono("phase:seed", cat="phase", start_mono_s=0.0, dur_s=1.0)
    tr.add_span_mono("phase:seed", cat="phase", start_mono_s=2.0, dur_s=0.5)
    tr.add_span_mono("measure", cat="measure", start_mono_s=0.0, dur_s=9.0)
    assert tr.phase_times() == {"phase:seed": 1.5}
