"""Online tuning-as-a-service (:mod:`repro.compiler.serve_tune`): the
idle-slot executor's control inversion, the admission-aware preemption
contract, SLA-violation reward penalties, online-vs-offline convergence,
warm resume through the stock records machinery, and the monitor's
``serve`` /status source.

Everything except the live-server test runs on the virtual-time sim host
— deterministic and sub-second."""
import json

import numpy as np
import pytest

from repro.compiler.serve_tune import (IdleSlotExecutor, LiveServeHost,
                                       ServeModel, ServeReport, ServeSLA,
                                       SimServeHost, TraceConfig,
                                       synthetic_trace, tune_while_serving)
from repro.core import mappo
from repro.core.tuner import TunerConfig

TINY = TunerConfig(iteration_opt=2, b_measure=4, episodes_per_iter=1,
                   mappo=mappo.MappoConfig(n_steps=8, n_envs=4),
                   gbt_rounds=5)


@pytest.fixture(scope="module")
def model():
    return ServeModel()


# ---------------------------------------------------------------- trace

def test_synthetic_trace_deterministic_and_plausible():
    cfg = TraceConfig(n_requests=5000, rate_per_s=50.0, seed=9)
    a = list(synthetic_trace(cfg))
    assert a == list(synthetic_trace(cfg))  # same seed -> same trace
    assert len(a) == 5000
    times = [t for t, _, _ in a]
    assert times == sorted(times) and times[0] > 0
    for _, plen, mnew in a:
        assert cfg.prompt_len[0] <= plen <= cfg.prompt_len[1]
        assert cfg.max_new[0] <= mnew <= cfg.max_new[1]
    # bursts only ever speed arrivals up: duration is bounded by the
    # base-rate expectation and below by the all-burst expectation
    assert (5000 / (cfg.rate_per_s * cfg.burst_factor)
            < times[-1] < 2.0 * 5000 / cfg.rate_per_s)
    assert list(synthetic_trace(
        TraceConfig(n_requests=100, seed=1))) != list(synthetic_trace(
            TraceConfig(n_requests=100, seed=2)))


# ------------------------------------------------- preemption + penalty

def test_sla_violations_penalize_inflight_measurement(model):
    """Requests that violate the SLA while a candidate measurement is in
    flight are folded into its measured value as a hard penalty."""
    sla = ServeSLA(target_s=0.0, measure_penalty_s=10.0)  # all violate
    host = SimServeHost(model, [(0.5, 8, 4), (0.6, 8, 4)], sla=sla,
                        measure_cost_s=5.0)
    ex = IdleSlotExecutor(host)
    fn = model.measure_fn("decode")
    host.register_task("t", "decode", fn)
    settings = model.default_settings["decode"]
    handle = ex.submit("t", settings)
    assert not handle.done()  # only queued: no idle time has passed yet
    ex.drain([handle])
    res = handle.result()
    assert res.ok
    raw = model.cost_s("decode", settings)
    # both requests finished mid-measurement and violated: 2 hard hits
    assert res.value == pytest.approx(raw + 2 * sla.measure_penalty_s)
    assert host.served == 2 and host.violations == 2
    # the stats surface speaks the uniform executor schema
    st = ex.stats()
    assert {"kind", "workers_alive", "respawns", "queued", "running",
            "max_inflight", "jobs", "failures"} <= set(st)
    assert st["kind"] == "idle-slot" and st["jobs"] == 1


def test_measurements_only_progress_in_idle_windows(model):
    """With traffic saturating every slot from t=0, a queued measurement
    makes no progress until the backlog clears."""
    # 4 slots, 8 concurrent long requests -> no idle capacity for a while
    trace = [(0.0, 8, 200)] * 8
    host = SimServeHost(model, trace, sla=ServeSLA(target_s=1e9),
                        n_slots=4, measure_cost_s=0.01)
    ex = IdleSlotExecutor(host)
    host.register_task("t", "decode", model.measure_fn("decode"))
    handle = ex.submit("t", model.default_settings["decode"])
    job = host.jobs[0]
    while host.served < 8:
        assert host.pump()
        if host.served < 4:  # both waves still occupy every slot
            assert job.progress_s == 0.0
    ex.drain([handle])
    assert handle.result().ok


# ------------------------------------------------------ end-to-end (sim)

def test_online_converges_to_offline_within_10pct(model):
    host = SimServeHost(model,
                        TraceConfig(n_requests=3000, rate_per_s=100.0,
                                    seed=1),
                        sla=ServeSLA(target_s=0.5),
                        measure_cost_s=0.05, tune_after_s=5.0)
    rep = tune_while_serving(host, tuner=TINY, budget=8, seed=0)
    s = rep.serve
    assert s["served"] == 3000
    # the headline: online search within 10% of offline, SLA held
    assert min(rep.convergence.values()) >= 0.9
    assert s["violation_pct"] < 3.0
    # both phases populated; tuning visibly helped
    assert s["before"]["n_requests"] > 0 and s["after"]["n_requests"] > 0
    assert s["after"]["p99_latency_s"] < s["before"]["p99_latency_s"]
    assert s["switches"] and s["tuned_from_s"] >= 5.0
    # measurement accounting: jobs ran on idle slots only, preemption
    # does not lose accrued progress
    assert 0 < s["measurements"] <= 16
    assert s["measure_idle_s"] == pytest.approx(0.05 * s["measurements"])
    assert s["preempted"] >= 0 and s["measure_failures"] == 0
    # report round-trips through JSON
    rt = ServeReport.from_dict(json.loads(json.dumps(rep.to_dict())))
    assert rt.serve["served"] == 3000
    assert rt.convergence == rep.convergence
    assert rt.session.reports.keys() == rep.session.reports.keys()


def test_warm_resume_replays_without_new_measurements(model, tmp_path):
    """records= warm resume works unchanged through the idle-slot path:
    the rerun replays every measurement from the JSONL and still ends up
    serving under the tuned geometry (applied from the session winner,
    not from job completions)."""
    records = str(tmp_path / "serve_records.jsonl")
    trace = TraceConfig(n_requests=600, rate_per_s=200.0, seed=4)
    host1 = SimServeHost(model, trace, measure_cost_s=0.02)
    rep1 = tune_while_serving(host1, tuner=TINY, budget=8, seed=0,
                              records=records, offline_compare=False)
    assert rep1.serve["measurements"] > 0
    host2 = SimServeHost(model, trace, measure_cost_s=0.02)
    rep2 = tune_while_serving(host2, tuner=TINY, budget=8, seed=0,
                              records=records, offline_compare=False)
    assert rep2.serve["measurements"] == 0  # pure replay
    assert rep2.online == rep1.online
    for name, r1 in rep1.session.reports.items():
        assert rep2.session.reports[name].best_latency == r1.best_latency
    # the tuned geometry landed anyway and the tail was served under it
    assert rep2.serve["geometry"]["decode"] == \
        rep1.online["decode"]["settings"]
    assert rep2.serve["after"]["n_requests"] > 0


def test_monitor_gains_serve_source(model):
    import urllib.request

    from repro.obs.serve import MonitorServer
    mon = MonitorServer(port=0).start()
    try:
        host = SimServeHost(model,
                            TraceConfig(n_requests=400, rate_per_s=200.0,
                                        seed=3),
                            measure_cost_s=0.02)
        rep = tune_while_serving(host, tuner=TINY, budget=8, monitor=mon,
                                 offline_compare=False)
        assert mon.running  # borrowed: never stopped by the run
        with urllib.request.urlopen(mon.url + "/status") as r:
            sources = json.loads(r.read())["sources"]
        # the run attached BOTH a serve source and the session's own
        assert "serve" in sources and "session" in sources
        serve = sources["serve"]
        assert serve["final"] is True
        assert serve["served"] == rep.serve["served"]
        assert serve["measurements"]["done"] == rep.serve["measurements"]
        assert serve["queued"] == 0 and serve["active"] == 0
    finally:
        mon.stop()


# ------------------------------------------------------------- live host

def test_live_host_tunes_on_a_real_server():
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.train.server import Server

    cfg = get_config("smollm-360m", reduced=True).with_(
        dtype=jnp.float32, param_dtype=jnp.float32, remat=False)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    srv = Server(params, cfg, n_slots=2, max_len=32)
    host = LiveServeHost(
        srv,
        TraceConfig(n_requests=8, rate_per_s=100.0, prompt_len=(4, 8),
                    max_new=(2, 4), seed=2),
        sla=ServeSLA(target_s=60.0), vocab=cfg.vocab, seed=0)
    rep = tune_while_serving(host, tuner=TINY, budget=4,
                             offline_compare=False)
    assert rep.serve["served"] == 8
    assert rep.serve["measurements"] > 0  # ran through best_effort ticks
    assert not srv.abandoned and not srv.rejected
    for r in host.done:
        assert r.ok and r.latency_s == pytest.approx(
            r.queue_s + r.prefill_s + r.decode_s, rel=1e-6)
    assert set(rep.online) == {"decode", "prefill"}
