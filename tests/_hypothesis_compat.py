"""Deterministic fallback for ``hypothesis`` in dependency-light envs.

When the real package is absent, property tests degrade to seeded
spot-checks: ``@given`` runs the test body over a fixed number of draws
from a PRNG seeded by the test name, so failures reproduce exactly and the
suite needs nothing beyond the standard library.

Usage (at the top of a test module):

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_compat import given, settings, strategies as st

Only the strategy surface the repo's tests use is implemented: integers,
booleans, sampled_from, lists.  ``REPRO_COMPAT_MAX_EXAMPLES`` caps draws
per test (default 8) to keep the fallback cheap.
"""
from __future__ import annotations

import functools
import inspect
import os
import random
import zlib
from typing import Any, Callable, Dict

_DEFAULT_MAX_EXAMPLES = int(os.environ.get("REPRO_COMPAT_MAX_EXAMPLES", "8"))


class Strategy:
    """A draw rule: ``example(rng)`` produces one value."""

    def __init__(self, draw: Callable[[random.Random], Any], label: str):
        self._draw = draw
        self.label = label

    def example(self, rng: random.Random) -> Any:
        return self._draw(rng)

    def __repr__(self) -> str:
        return f"Strategy({self.label})"


class strategies:
    """Namespace mirroring ``hypothesis.strategies`` (the used subset)."""

    @staticmethod
    def integers(min_value: int, max_value: int) -> Strategy:
        return Strategy(lambda rng: rng.randint(min_value, max_value),
                        f"integers({min_value}, {max_value})")

    @staticmethod
    def booleans() -> Strategy:
        return Strategy(lambda rng: bool(rng.getrandbits(1)), "booleans()")

    @staticmethod
    def sampled_from(values) -> Strategy:
        values = list(values)
        return Strategy(lambda rng: values[rng.randrange(len(values))],
                        f"sampled_from({values!r})")

    @staticmethod
    def lists(elements: Strategy, min_size: int = 0,
              max_size: int = 10) -> Strategy:
        def draw(rng: random.Random):
            n = rng.randint(min_size, max_size)
            return [elements.example(rng) for _ in range(n)]
        return Strategy(draw, f"lists({elements.label})")


st = strategies


def settings(**kw):
    """Records hypothesis settings; only ``max_examples`` is honored."""

    def deco(fn):
        setattr(fn, "_compat_settings", dict(kw))
        return fn

    return deco


def given(**strats: Strategy):
    """Run the wrapped test over deterministic seeded draws.

    The PRNG seed mixes the test name and the draw index, so every run (and
    every machine) exercises the identical example set.
    """

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            conf = getattr(wrapper, "_compat_settings",
                           getattr(fn, "_compat_settings", {}))
            n = min(int(conf.get("max_examples", _DEFAULT_MAX_EXAMPLES)),
                    _DEFAULT_MAX_EXAMPLES)
            base = zlib.crc32(fn.__qualname__.encode())
            for i in range(max(n, 1)):
                rng = random.Random(base ^ (0x9E3779B9 * (i + 1)))
                drawn = {k: s.example(rng) for k, s in strats.items()}
                try:
                    fn(*args, **drawn, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example (draw {i}): {drawn!r}") from e

        # hide the strategy params from pytest's fixture resolution
        wrapper.__signature__ = inspect.Signature(
            [p for name, p in
             inspect.signature(fn).parameters.items() if name not in strats])
        wrapper.hypothesis_compat = True
        return wrapper

    return deco
