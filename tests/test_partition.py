"""``repro.compiler.netopt`` v2 — heterogeneous K-chip partitioning.

Covers the partition primitives (``HwPartition`` / ``PartitionSpace``:
contiguity, canonicalization, encode/decode, features, pipeline latency,
silicon area), the K=1 regression anchor (byte-identical ``to_dict()``
against the pre-refactor golden file, modulo the new fields), K>=2
co-optimization (pipeline win, warm resume at zero measurements), the
DiGamma-style genetic baseline, the stable-ranking early stop, the
within-candidate ``measurements_to`` resolution, and surrogate-store
compaction.
"""
import json
import os

import numpy as np
import pytest

from repro.compiler.netopt import (HwPartition, NetOptConfig,
                                   NetworkCoOptimizer, NetworkReport,
                                   PartitionSpace, network_genetic_hw_tune)
from repro.compiler.netopt.genetic import crossover, mutate
from repro.compiler.surrogate_store import SurrogateStore
from repro.compiler.task import TuningTask
from repro.core import mappo
from repro.core.design_space import DesignSpace
from repro.core.tuner import TunerConfig
from repro.hw.analytical import chip_area_mm2, interchip_transfer_s

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
GOLDEN = os.path.join(ROOT, "tests", "golden", "netopt_k1_golden.json")

# EXACTLY the fixtures the golden file was captured with (pre-refactor);
# any drift here invalidates the anchor comparison, not the code under test
WL_BIG = dict(b=1, h=14, w=14, ci=256, co=256, kh=3, kw=3, stride=1, pad=1)
WL_MID = dict(b=1, h=28, w=28, ci=128, co=128, kh=3, kw=3, stride=1, pad=1)
TINY = TunerConfig(iteration_opt=3, b_measure=8, episodes_per_iter=2,
                   mappo=mappo.MappoConfig(n_steps=16, n_envs=8),
                   gbt_rounds=10)


@pytest.fixture(scope="module")
def tasks():
    return [TuningTask.from_space("c1", DesignSpace.for_conv2d(WL_BIG),
                                  multiplicity=2),
            TuningTask.from_space("c2", DesignSpace.for_conv2d(WL_MID),
                                  multiplicity=1)]


def _tiny_netcfg(**kw):
    base = dict(seed_candidates=2, hw_rounds=1, hw_per_round=1,
                layer_budget=8, refine_budget=8, tuner=TINY)
    base.update(kw)
    return NetOptConfig(**base)


# ------------------------------------------------------- partition space

def test_partition_space_geometry(tasks):
    ps = PartitionSpace(tasks, k_chips=2)
    assert ps.k == 2
    assert ps.n_features == 2 * (14 + 1)   # per-segment block + weight
    # k clamps to the task count and MAX_K
    assert PartitionSpace(tasks, k_chips=5).k == 2
    assert PartitionSpace(tasks[:1], k_chips=2).k == 1
    # contiguity: every enumerated cut vector is strictly increasing and
    # interior
    p = ps.default_partition()
    assert p.k == 2 and p.cuts == (1,)
    assert p.segments(len(tasks)) == [(0, 1), (1, 2)]
    with pytest.raises(ValueError):
        HwPartition((1,), ((1, 64, 64),))   # k mismatch
    # encode/decode round-trips through clamping canonicalization
    vec = ps.encode(p)
    assert ps.decode(vec) == p
    wild = [999] * len(vec)
    q = ps.decode(wild)
    assert q.k == 2 and all(len(v) == 3 for v in q.hw_values)
    # features dispatch on the PARTITION's k: a coerced single-chip value
    # keeps the v1 14-dim layout even inside a K=2 space
    f2 = ps.features(p)
    assert f2.shape == (30,) and np.isfinite(f2).all()
    k1 = PartitionSpace(tasks, k_chips=1)
    f1 = k1.features(k1.default_partition())
    assert f1.shape == (14,)
    # tags: K=1 keeps the bare v1 tag (record-key compatibility), K>=2
    # suffixes the stage
    assert "#seg" not in k1.default_partition().tags()[0]
    assert [t.endswith(f"#seg{j}") for j, t in enumerate(p.tags())] \
        == [True, True]


def test_partition_seeds_pool_and_balanced_cuts(tasks):
    ps = PartitionSpace(tasks, k_chips=2)
    rng = np.random.default_rng(0)
    seeds = ps.seed_partitions(4, rng)
    assert seeds[0] == ps.default_partition()
    assert len(seeds) == len(set(seeds))
    assert all(s.k == 2 for s in seeds)
    assert ps.balanced_cuts() == (1,)
    pool = ps.candidate_pool(seed=0, limit=16)
    assert 0 < len(pool) <= 16
    assert len(pool) == len(set(pool))
    # deterministic: same seed, same pool
    assert pool == ps.candidate_pool(seed=0, limit=16)


def test_pipeline_latency_and_area(tasks):
    ps = PartitionSpace(tasks, k_chips=2)
    p = ps.default_partition()
    lat = {"c1": 3e-5, "c2": 1e-5}   # per-instance; c1 has multiplicity 2
    pipe = ps.pipeline_latency(p, lat)
    xfer = interchip_transfer_s(ps.boundary_bytes(p)[0])
    assert pipe == pytest.approx(max(2 * 3e-5, 1e-5) + xfer)
    assert pipe < 2 * 3e-5 + 1e-5    # the pipelining win at equal chips
    # K=1 degenerates to the plain weighted sum (no transfer term)
    k1 = PartitionSpace(tasks, k_chips=1)
    assert k1.pipeline_latency(k1.default_partition(), lat) \
        == pytest.approx(7e-5)
    # area grows with chip count and with geometry
    assert ps.area_mm2(p) > k1.area_mm2(k1.default_partition()) > 0
    assert chip_area_mm2(1, 256, 256) > chip_area_mm2(1, 64, 64) > 0
    assert ps.boundary_bytes(p)[0] > 0


# -------------------------------------------------- K=1 regression anchor

def _subset(golden, new, path=""):
    """Every golden key/element must appear bit-identically in ``new``;
    new keys are the (allowed) v2 additions."""
    if isinstance(golden, dict):
        assert isinstance(new, dict), path
        for k, v in golden.items():
            assert k in new, f"{path}.{k} missing"
            _subset(v, new[k], f"{path}.{k}")
    elif isinstance(golden, list):
        assert isinstance(new, list) and len(new) == len(golden), path
        for i, (g, n) in enumerate(zip(golden, new)):
            _subset(g, n, f"{path}[{i}]")
    else:
        assert golden == new, f"{path}: {golden!r} != {new!r}"


def test_k1_partition_reproduces_pre_refactor_golden(tasks):
    """The tentpole's regression anchor: a K=1 run of the refactored
    partition code must produce a ``to_dict()`` that contains the
    pre-refactor report byte-for-byte (same RNG draws, same tags, same
    trace) — the new partition fields only ADD keys."""
    cfg = _tiny_netcfg()
    rep = NetworkCoOptimizer(tasks, cfg, name="toy").run().to_dict()
    rep.pop("wall_time_s")
    with open(GOLDEN) as f:
        golden = json.load(f)
    _subset(golden, rep)
    added = set(rep) - set(golden)
    assert added == {"early_stop", "executor_stats", "hw_configs",
                     "k_chips", "partition"}


def test_k1_warm_resume_records_are_tag_compatible(tasks, tmp_path):
    """K=1 record tags carry NO segment suffix, so pre-refactor record
    files warm-resume unchanged."""
    cfg = _tiny_netcfg()
    path = str(tmp_path / "r.jsonl")
    r1 = NetworkCoOptimizer(tasks, cfg, records=path, name="toy").run()
    assert r1.total_measurements > 0
    with open(path) as f:
        assert all("#seg" not in json.loads(ln)["task"]
                   for ln in f if ln.strip())
    r2 = NetworkCoOptimizer(tasks, cfg, records=path, name="toy").run()
    assert r2.total_measurements == 0


# ------------------------------------------------------------ K>=2 co-opt

def test_k2_coopt_pipeline_beats_k1_and_resumes(tasks, tmp_path):
    cfg1, cfg2 = _tiny_netcfg(), _tiny_netcfg(k_chips=2)
    r1 = NetworkCoOptimizer(tasks, cfg1, name="toy").run()
    path = str(tmp_path / "k2.jsonl")
    r2 = NetworkCoOptimizer(tasks, cfg2, records=path, name="toy").run()
    assert r2.k_chips == 2 and len(r2.hw_configs) == 2
    assert r2.partition["k"] == 2 and r2.partition["cuts"] == [1]
    assert r2.verify_shared_hardware()
    assert set(r2.partition["assignment"].values()) == {0, 1}
    # max-over-stages <= sum: the pipeline reward makes K=2 dominate K=1
    # on this 2-task toy (same candidate budget)
    assert r2.network_latency <= r1.network_latency
    # K>=2 trace rows carry the partition shape
    assert all(isinstance(row["hw"], list) and row["cuts"] == [1]
               for row in r2.trace)
    # single-chip accessor refuses multi-chip reports
    with pytest.raises(ValueError):
        _ = r2.hw_config
    # warm resume replays every (stage-tagged hw, layer) session from the
    # record file
    r3 = NetworkCoOptimizer(tasks, cfg2, records=path, name="toy").run()
    assert r3.total_measurements == 0
    assert r3.network_latency == r2.network_latency
    assert r3.hw_configs == r2.hw_configs
    # JSON round-trip keeps the partition fields
    back = NetworkReport.from_dict(json.loads(json.dumps(r2.to_dict())))
    assert back.partition == r2.partition
    assert back.hw_configs == r2.hw_configs
    assert back.pareto() == r2.pareto()


def test_k2_surrogate_rows_keyed_by_segment_variant(tasks, tmp_path):
    """K>=2 hw rows are a different feature dimension AND carry the segs
    descriptor, so K=1 and K=2 runs never cross-contaminate warm starts."""
    store = str(tmp_path / "s.jsonl")
    NetworkCoOptimizer(tasks, _tiny_netcfg(), name="netA",
                       surrogates=store).run()
    NetworkCoOptimizer(tasks, _tiny_netcfg(k_chips=2), name="netA",
                       surrogates=store).run()
    rows = [json.loads(ln) for ln in open(store) if ln.strip()]
    hw = [r for r in rows if r["kind"] == "hw"]
    assert {r["dim"] for r in hw} == {14, 30}
    assert all(r["segs"] == (1 if r["dim"] == 14 else 2) for r in hw)
    s = SurrogateStore(store)
    assert s.rows("hw", 14)[0].shape[1] == 14
    assert s.rows("hw", 30)[0].shape[1] == 30


# ------------------------------------------------------- genetic baseline

def test_genetic_operators_preserve_validity(tasks):
    ps = PartitionSpace(tasks, k_chips=2)
    rng = np.random.default_rng(3)
    a, b = ps.seed_partitions(2, rng)
    for _ in range(32):
        child = mutate(ps, crossover(ps, a, b, rng), rng)
        assert child.k == 2
        assert list(child.cuts) == sorted(set(child.cuts))
        assert all(0 < c < len(tasks) for c in child.cuts)
        # values stay inside each segment's table (canonicalized)
        assert ps.canonical(child.cuts, child.hw_values) == child


def test_genetic_baseline_equal_budget(tasks):
    cfg = _tiny_netcfg(k_chips=2)
    rep = network_genetic_hw_tune(tasks, cfg, name="toy")
    assert rep.algo == "genetic"
    assert rep.k_chips == 2
    assert all(r["phase"] == "genetic" for r in rep.trace)
    assert rep.verify_shared_hardware()
    n_evals = cfg.n_candidates + 1
    per_layer = max(cfg.total_layer_budget() // n_evals, 1)
    assert rep.trace[0]["layer_budget"] == per_layer
    assert rep.hw_candidates <= n_evals
    # the GA never outspends the co-optimizer's upper bound
    assert rep.total_measurements \
        <= cfg.total_layer_budget() * len(tasks)
    # k_chips override spelling used by repro.core.baselines
    rep1 = network_genetic_hw_tune(tasks, _tiny_netcfg(), k_chips=2,
                                   name="toy")
    assert rep1.k_chips == 2


# ------------------------------------------------------------- early stop

def test_stop_on_stable_ranking_saves_measurements(tasks):
    cfg = _tiny_netcfg(hw_rounds=3, stop_on_stable_ranking=1)
    rep = NetworkCoOptimizer(tasks, cfg, name="toy").run()
    es = rep.early_stop
    assert es, "the toy landscape must trigger the stable-ranking stop"
    assert es["stable_refits"] == 1
    assert es["skipped_candidates"] == 2
    assert es["measurements_saved"] \
        == es["skipped_candidates"] * cfg.layer_budget * len(tasks)
    # the marker row sits in the trace but never pollutes the curves
    markers = [r for r in rep.trace if r.get("phase") == "early_stop"]
    assert len(markers) == 1
    assert markers[0]["measurements_saved"] == es["measurements_saved"]
    assert rep.trace[-1]["phase"] == "refine"
    assert all("network_latency" in r or r["phase"] == "early_stop"
               for r in rep.trace)
    assert rep.progress() and rep.pareto()
    # fewer candidates than the no-stop budget allows
    assert rep.hw_candidates < cfg.n_candidates
    # off by default: no marker, full candidate count
    rep0 = NetworkCoOptimizer(tasks, _tiny_netcfg(hw_rounds=3),
                              name="toy").run()
    assert not rep0.early_stop
    assert rep0.hw_candidates == _tiny_netcfg(hw_rounds=3).n_candidates


# ------------------------------------------- measurements_to trajectories

def _synthetic_report(with_trajectory=True):
    rows = [
        {"hw": {}, "network_latency": 3.0, "layer_budget": 8,
         "new_measurements": 16, "cum_measurements": 16,
         "best_so_far": 3.0, "phase": "seed",
         "trajectory": [[4, 5.0], [10, 3.0]]},
        {"hw": {}, "network_latency": 2.0, "layer_budget": 8,
         "new_measurements": 16, "cum_measurements": 32,
         "best_so_far": 2.0, "phase": "cs",
         "trajectory": [[6, 2.5], [12, 2.0]]},
        {"phase": "early_stop", "cum_measurements": 32,
         "measurements_saved": 16},
        {"hw": {}, "network_latency": 1.0, "layer_budget": 16,
         "new_measurements": 16, "cum_measurements": 48,
         "best_so_far": 1.0, "phase": "refine",
         "trajectory": [[16, 1.0]]},
    ]
    if not with_trajectory:
        rows = [{k: v for k, v in r.items() if k != "trajectory"}
                for r in rows]
    return NetworkReport(network="x", algo="netopt",
                         hw_configs=[{"tile_b": 1}], layers={},
                         network_latency=1.0, n_layers=1, hw_candidates=3,
                         total_measurements=48, wall_time_s=0.0,
                         trace=rows)


def test_measurements_to_resolves_inside_candidates():
    rep = _synthetic_report()
    # the fix: spend to first hit counts the FULL session spend up to the
    # within-candidate improvement, not the end-of-candidate total
    assert rep.measurements_to(5.0) == 4
    assert rep.measurements_to(3.0) == 10      # not 16 (candidate end)
    assert rep.measurements_to(2.2) == 16 + 12  # resolved in candidate 2
    assert rep.measurements_to(1.0) == 32 + 16
    assert rep.measurements_to(0.5) is None
    # old documents (no trajectory) fall back to candidate granularity
    old = _synthetic_report(with_trajectory=False)
    assert old.measurements_to(3.0) == 16
    assert old.measurements_to(2.2) == 32
    assert old.measurements_to(1.0) == 48
    # progress() skips the marker row
    assert old.progress() == [(16, 3.0), (32, 2.0), (48, 1.0)]


def test_real_runs_emit_monotone_trajectories(tasks):
    rep = NetworkCoOptimizer(tasks, _tiny_netcfg(), name="toy").run()
    assert any(row.get("trajectory") for row in rep.trace)
    for row in rep.trace:
        traj = row.get("trajectory", [])
        lats = [lat for _, lat in traj]
        assert lats == sorted(lats, reverse=True)   # improvements only
        if traj:
            assert traj[-1][0] <= row["new_measurements"]
            assert traj[-1][1] == row["network_latency"]


# ------------------------------------------------------ store compaction

def test_store_compact_bounds_size_and_keeps_frontier(tmp_path):
    path = str(tmp_path / "s.jsonl")
    store = SurrogateStore(path)
    rng = np.random.default_rng(0)
    ys = rng.permutation(200).astype(float)
    for i, y in enumerate(ys):
        store.add("sw", rng.random(18), float(y), network="netA")
    size_before = os.path.getsize(path)
    stats = store.compact(keep_best=32)
    assert stats["kept"] + stats["dropped"] == 200
    assert os.path.getsize(path) < size_before
    back = SurrogateStore(path)
    n = back.counts()["sw"]
    assert n == stats["kept"]
    # the improvement frontier survives: running best-so-far y values
    frontier = []
    best = -np.inf
    for y in ys:
        if y > best:
            best = y
            frontier.append(float(y))
    _, kept_y = back.rows("sw", 18)
    assert set(frontier) <= set(kept_y.tolist())
    # ... as do the top-32 targets
    assert set(np.sort(ys)[-32:].tolist()) <= set(kept_y.tolist())
    # compacting an already-compact store rewrites nothing
    assert store.compact(keep_best=32)["dropped"] == 0
    # readonly stores refuse
    with pytest.raises(ValueError):
        SurrogateStore(path, readonly=True).compact()
