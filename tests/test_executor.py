"""``repro.compiler.executor`` — parallel, crash-isolated measurement.

Covers the executor protocol itself (serial + subprocess pools), every
failure path the issue names (worker raise, worker crash, per-measurement
timeout — each must record the failure-penalty row, keep the session
running, and leave ``stats()['failures']`` correct), records durability
under kills, and serial-vs-subprocess determinism at a fixed seed.
"""
import json
import os

import numpy as np
import pytest

from repro.compiler.executor import (MeasureResult, SerialExecutor,
                                     SubprocessExecutor, WorkerSpec)
from repro.compiler.executor.stub import make_stub, stub_latency
from repro.compiler.oracle import SettingsOracle, decode_config
from repro.compiler.records import RecordLog
from repro.compiler.session import Session
from repro.compiler.task import TuningTask
from repro.core import mappo
from repro.core.design_space import N_KNOBS
from repro.core.shard_space import ShardSpace
from repro.core.tuner import TunerConfig

STUB = "repro.compiler.executor.stub:make_stub"


@pytest.fixture(scope="module")
def space():
    return ShardSpace.for_cell("qwen2-1.5b", "train_4k", None, n_devices=256)


def _cfg(knob: int = -1, idx: int = 1) -> np.ndarray:
    """All-defaults config, optionally with one knob bumped to ``idx``."""
    c = np.zeros(N_KNOBS, np.int64)
    if knob >= 0:
        c[knob] = idx
    return c


# Settings triggered by single knob bumps (see shard_space knob order):
FAIL_COND = {"fsdp": True}               # knob 2 -> fsdp on
HANG_COND = {"sequence_parallel": True}  # knob 6 -> SP on
EXIT_COND = {"remat": True}              # knob 4 -> remat on
FAIL_CFG, HANG_CFG, EXIT_CFG = _cfg(2), _cfg(6), _cfg(4)


# ----------------------------------------------------------------- executors

def test_serial_executor_runs_and_reports_errors():
    ex = SerialExecutor(fn=make_stub(fail_when=FAIL_COND))
    ok = ex.submit("t", {"model_axis": 4})
    assert ok.done() and ok.result().ok
    assert ok.result().value == stub_latency({"model_axis": 4})
    bad = ex.submit("t", {"model_axis": 4, "fsdp": True})
    res = bad.result()
    assert not res.ok and "RuntimeError: stub measurement failed" in res.error


def test_subprocess_pool_matches_serial_values(space):
    spec = WorkerSpec(factory=STUB, kwargs={"delay_s": 0.05})
    settings = [decode_config(space, _cfg(0, i)) for i in range(4)]
    with SubprocessExecutor(spec, workers=2) as pool:
        handles = [pool.submit("t", s) for s in settings]
        pool.drain(handles)
        for s, h in zip(settings, handles):
            assert h.result().ok
            assert h.result().value == stub_latency(s)
    assert pool.stats()["workers_alive"] == 0  # context exit tore it down


def test_subprocess_bad_factory_fails_jobs_not_pool():
    spec = WorkerSpec(factory="repro.compiler.executor.stub:nope")
    with SubprocessExecutor(spec, workers=1) as pool:
        h = pool.submit("t", {"x": 1})
        res = h.result()
        assert not res.ok and "WorkerInitError" in res.error
        # the worker survives a bad factory (no respawn churn)
        assert pool.stats()["respawns"] == 0


# ------------------------------------------------- adaptive in-flight depth

def test_adaptive_inflight_policy():
    """The pure policy: classic 2x with no observations, 2x floor for long
    measurements (compiles), deepens toward the 8x cap as measurements get
    short relative to the service lead."""
    from repro.compiler.executor.pool import adaptive_inflight
    assert adaptive_inflight(2, None) == 4          # no data: 2 * workers
    assert adaptive_inflight(2, 60.0) == 4          # long compiles: floor
    assert adaptive_inflight(2, 0.001) == 16        # fast stubs: 8x cap
    assert adaptive_inflight(3, 0.2) == 9           # 1 + ceil(.25/.2) = 3x
    assert adaptive_inflight(1, 0.05) == 6          # 1 + ceil(.25/.05) = 6x


def test_pool_adapts_inflight_from_observed_durations(space):
    """With ``max_inflight=None`` the pool starts at the classic 2x bound
    and deepens once observed measurement durations show the jobs are
    cheap; an explicit ``max_inflight`` stays pinned."""
    spec = WorkerSpec(factory=STUB, kwargs={"delay_s": 0.01})
    with SubprocessExecutor(spec, workers=2) as pool:
        assert pool.stats()["max_inflight"] == 4  # nothing observed yet
        handles = [pool.submit("t", decode_config(space, _cfg(0, i % 5)))
                   for i in range(8)]
        pool.drain(handles)
        assert all(h.result().ok for h in handles)
        assert pool.stats()["max_inflight"] > 4   # grew for fast jobs
    with SubprocessExecutor(spec, workers=2, max_inflight=3) as pool:
        handles = [pool.submit("t", decode_config(space, _cfg(0, i % 5)))
                   for i in range(6)]
        pool.drain(handles)
        assert pool.stats()["max_inflight"] == 3  # pinned bound never moves


# -------------------------------------------------- oracle failure paths

def _oracle(space, pool, records=None, **kw):
    return SettingsOracle(space, fn=None, executor=pool, own_executor=True,
                          task="exec", records=records, **kw)


def test_worker_raise_records_penalty_row(space, tmp_path):
    log = RecordLog(str(tmp_path / "raise.jsonl"))
    spec = WorkerSpec(factory=STUB, kwargs={"fail_when": FAIL_COND})
    oracle = _oracle(space, SubprocessExecutor(spec, workers=2), records=log)
    batch = np.stack([FAIL_CFG, _cfg(), _cfg(0, 1)])
    lat, feats = oracle.measure(batch)
    oracle.close()
    assert lat[0] == oracle.penalty_latency
    assert lat[1] == stub_latency(decode_config(space, _cfg()))
    assert oracle.stats()["failures"] == 1
    assert feats.shape[0] == 3
    rows = log.load(task="exec")
    errs = [r for r in rows if "error" in r]
    assert len(rows) == 3 and len(errs) == 1
    assert "stub measurement failed" in errs[0]["error"]
    assert errs[0]["latency"] == oracle.penalty_latency
    assert errs[0]["settings"]["fsdp"] is True


def test_worker_timeout_kills_respawns_and_continues(space, tmp_path):
    log = RecordLog(str(tmp_path / "hang.jsonl"))
    spec = WorkerSpec(factory=STUB, kwargs={"hang_when": HANG_COND})
    # worker start-up (spawn + import) is not billed to the measurement:
    # the deadline restarts when the worker acks that the measure fn is
    # running, so a short timeout is safe even on a loaded CI box
    pool = SubprocessExecutor(spec, workers=2, timeout_s=1.0)
    oracle = _oracle(space, pool, records=log)
    batch = np.stack([HANG_CFG, _cfg(), _cfg(0, 2)])
    lat, _ = oracle.measure(batch)
    assert lat[0] == oracle.penalty_latency
    assert oracle.stats()["failures"] == 1
    assert pool.respawns == 1  # the hung worker was killed
    rows = log.load(task="exec")
    assert any("TimeoutError" in r.get("error", "") for r in rows)
    # the pool keeps serving measurements after the kill
    lat2, _ = oracle.measure(np.stack([_cfg(0, 3), _cfg(0, 4)]))
    assert oracle.stats()["failures"] == 1  # no new failures
    assert np.all(lat2 < 1.0)
    oracle.close()


def test_worker_crash_is_isolated(space, tmp_path):
    log = RecordLog(str(tmp_path / "crash.jsonl"))
    spec = WorkerSpec(factory=STUB, kwargs={"exit_when": EXIT_COND})
    pool = SubprocessExecutor(spec, workers=2)
    oracle = _oracle(space, pool, records=log)
    lat, _ = oracle.measure(np.stack([EXIT_CFG, _cfg(), _cfg(0, 1)]))
    assert lat[0] == oracle.penalty_latency
    assert lat[1] < 1.0 and lat[2] < 1.0
    assert oracle.stats()["failures"] == 1
    assert pool.respawns == 1
    assert any("WorkerCrash" in r.get("error", "")
               for r in log.load(task="exec"))
    # warm resume across the failure: a fresh oracle replays from records
    resumed = SettingsOracle(space, fn=make_stub(), task="exec", records=log)
    lat3, _ = resumed.measure(np.stack([EXIT_CFG, _cfg()]))
    assert resumed.stats()["misses"] == 0
    assert lat3[0] == oracle.penalty_latency
    oracle.close()


def test_measure_async_overlaps_with_parent_work(space):
    spec = WorkerSpec(factory=STUB, kwargs={"delay_s": 0.2})
    oracle = _oracle(space, SubprocessExecutor(spec, workers=2))
    batch = oracle.measure_async(np.stack([_cfg(), _cfg(0, 1)]))
    overlapped = 0
    while not batch.ready():  # parent stays free while workers measure
        overlapped += 1
    lat, _ = batch.get()
    assert overlapped > 0
    assert list(lat) == [stub_latency(decode_config(space, _cfg())),
                         stub_latency(decode_config(space, _cfg(0, 1)))]
    assert oracle.stats() == {"hits": 0, "misses": 2, "dedup": 0,
                              "failures": 0, "cached": 2}
    oracle.close()


# ----------------------------------------------------------- determinism

def _stub_task(space, name, subprocess_workers=0):
    def factory(task, records, workers=0, timeout_s=None):
        if subprocess_workers:
            pool = SubprocessExecutor(
                WorkerSpec(factory=STUB), workers=subprocess_workers,
                timeout_s=timeout_s)
            return SettingsOracle(space, fn=None, executor=pool,
                                  own_executor=True, task=task.name,
                                  records=records)
        return SettingsOracle(space, fn=make_stub(), task=task.name,
                              records=records)
    return TuningTask(name=name, space=space, oracle_factory=factory)


def test_serial_and_subprocess_reports_identical(space):
    cfg = TunerConfig(iteration_opt=2, b_measure=6, episodes_per_iter=2,
                      mappo=mappo.MappoConfig(n_steps=16, n_envs=8),
                      gbt_rounds=8, seed=3)
    runs = {}
    for label, w in (("serial", 0), ("subprocess", 1)):
        rep = Session(_stub_task(space, "det", subprocess_workers=w),
                      tuner=cfg, budget=12).run().single
        runs[label] = rep
    a, b = runs["serial"], runs["subprocess"]
    assert a.best_config == b.best_config
    assert a.best_latency == b.best_latency
    assert a.measurements == b.measurements
    assert [(n, lat) for n, lat, _ in a.history] == \
           [(n, lat) for n, lat, _ in b.history]
    assert a.oracle_stats["failures"] == b.oracle_stats["failures"] == 0


def test_session_survives_failures_and_resumes(space, tmp_path):
    """A session whose oracle raises on part of the space keeps running,
    records penalty rows, and warm-resumes from the same records file."""
    path = str(tmp_path / "flaky.jsonl")

    def factory(task, records, workers=0, timeout_s=None):
        pool = SubprocessExecutor(
            WorkerSpec(factory=STUB, kwargs={"fail_when": FAIL_COND}),
            workers=2)
        return SettingsOracle(space, fn=None, executor=pool,
                              own_executor=True, task=task.name,
                              records=records)

    task = TuningTask(name="flaky", space=space, oracle_factory=factory)
    cfg = TunerConfig(iteration_opt=2, b_measure=6, episodes_per_iter=2,
                      mappo=mappo.MappoConfig(n_steps=16, n_envs=8),
                      gbt_rounds=8, seed=0)
    r1 = Session(task, tuner=cfg, budget=12, records=path).run().single
    assert r1.oracle_stats["misses"] > 0
    assert np.isfinite(r1.best_latency)
    # penalty rows never win the search
    assert r1.best_latency < SettingsOracle.penalty_latency
    r2 = Session(task, tuner=cfg, budget=12, records=path).run().single
    assert r2.oracle_stats["misses"] == 0  # fully warm, incl. failure rows
    assert r2.best_latency == r1.best_latency


def test_env_conflict_between_specs_fails_loudly():
    """A spec whose env pin contradicts what a worker already applied
    (e.g. a different device count after jax initialized) must fail its
    jobs instead of silently measuring on the wrong topology."""
    a = WorkerSpec(factory=STUB, env={"REPRO_TEST_PIN": "1"})
    b = WorkerSpec(factory=STUB, env={"REPRO_TEST_PIN": "2"})
    with SubprocessExecutor(workers=1) as pool:
        assert pool.submit("t", {"x": 1}, spec=a).result().ok
        res = pool.submit("t", {"x": 2}, spec=b).result()
        assert not res.ok and "WorkerEnvConflict" in res.error
        # the worker itself survives; compatible jobs still run
        assert pool.submit("t", {"x": 3}, spec=a).result().ok
        assert pool.stats()["respawns"] == 0


def test_malformed_result_records_penalty_not_crash(space):
    """A measure fn returning a dict without step_penalized_s (or a
    non-numeric value) is a failure row, not a session crash."""
    oracle = SettingsOracle(space, fn=lambda s: {"step_s": 1.0}, task="bad")
    lat, _ = oracle.measure(np.stack([_cfg()]))
    assert lat[0] == oracle.penalty_latency
    assert oracle.stats()["failures"] == 1
    oracle2 = SettingsOracle(space, fn=lambda s: None, task="bad2")
    lat2, _ = oracle2.measure(np.stack([_cfg()]))
    assert lat2[0] == oracle2.penalty_latency
    assert oracle2.stats()["failures"] == 1


def test_session_shares_one_pool_across_tasks(space):
    """Session(workers=N) hands every task the same executor — N worker
    processes total, not N per task — and tears it down afterwards."""
    seen = []

    def make_task(name):
        def factory(task, records, workers=0, timeout_s=None, executor=None):
            seen.append(executor)
            return SettingsOracle(space, fn=None, executor=executor,
                                  own_executor=False, task=task.name,
                                  worker_spec=WorkerSpec(factory=STUB))
        return TuningTask(name=name, space=space, oracle_factory=factory)

    cfg = TunerConfig(iteration_opt=2, b_measure=4, episodes_per_iter=2,
                      mappo=mappo.MappoConfig(n_steps=16, n_envs=8),
                      gbt_rounds=8, seed=1)
    sr = Session([make_task("cellA"), make_task("cellB")], tuner=cfg,
                 budget=8, workers=2).run()
    assert len(seen) == 2
    assert seen[0] is seen[1] and seen[0] is not None
    assert seen[0].n_workers == 2
    for rep in sr:
        assert rep.n_measurements == 8
        assert np.isfinite(rep.best_latency)
        assert rep.oracle_stats["failures"] == 0
    assert seen[0].stats()["workers_alive"] == 0  # closed with the session


# ----------------------------------------------------------------- records

def test_recordlog_drops_corrupt_trailing_line(tmp_path):
    log = RecordLog(str(tmp_path / "rec.jsonl"))
    log.append({"task": "t", "config": [0], "latency": 1.0, "features": []})
    log.append({"task": "t", "config": [1], "latency": 2.0, "features": []})
    with open(log.path, "a") as f:
        f.write('{"task": "t", "config": [2], "lat')  # killed mid-append
    rows = log.load()
    assert [r["latency"] for r in rows] == [1.0, 2.0]
    # a resumed run (fresh RecordLog on the same path) truncates the torn
    # tail before its first append, so the new row lands on its own line
    # instead of merging into the fragment (which would turn trailing
    # corruption into an unrecoverable mid-file error)
    resumed = RecordLog(log.path)
    resumed.append({"task": "t", "config": [3], "latency": 3.0,
                    "features": []})
    assert [r["latency"] for r in resumed.load()] == [1.0, 2.0, 3.0]


def test_recordlog_raises_on_midfile_corruption(tmp_path):
    log = RecordLog(str(tmp_path / "rec.jsonl"))
    with open(log.path, "w") as f:
        f.write('not json at all\n')
        f.write(json.dumps({"task": "t", "latency": 1.0}) + "\n")
    with pytest.raises(ValueError, match="mid-file"):
        log.load()


def test_recordlog_append_is_single_complete_line(tmp_path):
    log = RecordLog(str(tmp_path / "rec.jsonl"))
    row = {"task": "t", "config": [1, 2], "latency": 0.5, "features": [0.1]}
    log.append(row)
    with open(log.path, "rb") as f:
        data = f.read()
    assert data.endswith(b"\n") and data.count(b"\n") == 1
    assert json.loads(data.decode()) == row
    assert os.path.getsize(log.path) == len(data)
