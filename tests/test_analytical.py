"""Property tests for the TPU analytical latency oracle (the measurement
simulator) and the HLO analysis machinery."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # dependency-light env: seeded spot-checks instead
    from _hypothesis_compat import given, settings, strategies as st

from repro.hw import analytical as AN
from repro.hw.tpu_spec import DEFAULT, mxu_efficiency
from repro.hw import hlo_analysis as HA

WL = dict(b=1, h=28, w=28, ci=96, co=128, kh=3, kw=3, stride=1, pad=1)


def test_min_latency_is_lower_bound():
    lo = AN.conv2d_min_latency(WL)
    rng = np.random.default_rng(0)
    for _ in range(50):
        lat, _ = AN.conv2d_latency(
            WL,
            tile_b=1, tile_h=2 ** rng.integers(0, 5),
            tile_w=2 ** rng.integers(0, 5),
            tile_ci=2 ** rng.integers(0, 7), tile_co=2 ** rng.integers(0, 8),
            h_threading=2 ** rng.integers(0, 3),
            oc_threading=2 ** rng.integers(0, 3))
        assert float(lat) >= lo * 0.999


def test_threading_overlaps_compute_and_memory():
    """Threaded config (VTA virtual-thread analog) is never slower."""
    kw = dict(tile_b=1, tile_h=8, tile_w=8, tile_ci=32, tile_co=64)
    lat1, _ = AN.conv2d_latency(WL, h_threading=1, oc_threading=1, **kw)
    lat2, _ = AN.conv2d_latency(WL, h_threading=2, oc_threading=2, **kw)
    assert float(lat2) < float(lat1)


def test_vmem_overflow_is_infeasible():
    lat, vmem = AN.gemm_latency(4096, 4096, 4096, 4096, 4096, 4096, 4, 4)
    assert float(vmem) > DEFAULT.vmem_bytes
    assert float(lat) >= 1e11  # failure sentinel


def test_mxu_alignment_efficiency():
    assert mxu_efficiency(128) == 1.0
    assert mxu_efficiency(64) == 0.5
    assert abs(mxu_efficiency(129) - 129 / 256) < 1e-9


@settings(max_examples=20, deadline=None)
@given(m=st.integers(32, 2048), n=st.integers(32, 2048),
       k=st.integers(32, 2048))
def test_gemm_latency_monotone_in_problem_size(m, n, k):
    """2x the work in any dim never makes the (fixed-tile) GEMM faster."""
    kw = dict(tile_m=128, tile_n=128, tile_k=128, threads_m=2, threads_n=2)
    l1, _ = AN.gemm_latency(m, n, k, **kw)
    l2, _ = AN.gemm_latency(2 * m, n, k, **kw)
    l3, _ = AN.gemm_latency(m, 2 * n, k, **kw)
    assert float(l2) >= float(l1) * 0.999
    assert float(l3) >= float(l1) * 0.999


def test_latency_vectorizes_under_vmap():
    f = lambda t: AN.gemm_latency(512, 512, 512, t, 128, 128, 2, 2)[0]
    tiles = jnp.asarray([8.0, 32.0, 128.0, 512.0])
    out = jax.vmap(f)(tiles)
    assert out.shape == (4,)
    assert bool(jnp.isfinite(out).all())


# ------------------------------------------------------------ HLO analysis

_FAKE_HLO = """\
HloModule test, entry_computation_layout={()->f32[4]{0}}

%body.1 (p: (s32[], f32[4])) -> (s32[], f32[4]) {
  %p = (s32[], f32[4]) parameter(0)
  %lhs = f32[8,16]{1,0} constant(0)
  %rhs = f32[16,4]{1,0} constant(0)
  %d = f32[8,4]{1,0} dot(%lhs, %rhs), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[4]{0} all-reduce(%gte), replica_groups={}, to_apply=%sum.2
  ROOT %t = (s32[], f32[4]) tuple(%c, %gte)
}

%sum.2 (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

%cond.3 (p: (s32[], f32[4])) -> pred[] {
  %p = (s32[], f32[4]) parameter(0)
  ROOT %lt = pred[] constant(true)
}

ENTRY %main.4 (x: f32[4]) -> f32[4] {
  %x = f32[4]{0} parameter(0)
  %init = (s32[], f32[4]) tuple(%c0, %x)
  %w = (s32[], f32[4]) while(%init), condition=%cond.3, body=%body.1, backend_config={"known_trip_count":{"n":"10"}}
  %d2 = f32[8,4]{1,0} dot(%lhs2, %rhs2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %out = f32[4]{0} get-tuple-element(%w), index=1
}
"""


def test_hlo_trip_count_weighting():
    # the body dot needs operand shape knowledge; provide via same comp
    hlo = _FAKE_HLO.replace("%d2 = f32[8,4]{1,0} dot(%lhs2, %rhs2)",
                            "%lhs2 = f32[8,16]{1,0} constant(0)\n"
                            "  %rhs2 = f32[16,4]{1,0} constant(0)\n"
                            "  %d2 = f32[8,4]{1,0} dot(%lhs2, %rhs2)")
    r = HA.analyze(hlo)
    # body dot: 2*8*4*16 = 1024 flops x trip 10; entry dot: 1024 x 1
    assert r["weighted_dot_flops"] == 1024 * 10 + 1024
    # all-reduce: 16 bytes x 10 trips, wire mult 2
    assert r["collective_bytes_by_op"]["all-reduce"] == 16 * 10
    assert r["wire_bytes_per_device"] == 2 * 16 * 10
