"""Remote measurement fabric — wire protocol, worker daemons, and the
``RemoteExecutor`` driving them.

Covers the frame protocol units, daemon round-trips, loopback parity with
``SubprocessExecutor(workers=1)`` at a fixed seed, heterogeneous
capability routing, and the fault semantics the subsystem promises: a
killed daemon mid-batch yields penalty rows while the session completes
and warm-resumes; a restarted daemon rejoins via bounded
reconnect-with-backoff; a hung measurement times out from its
started-ack.  In-process daemons (``WorkerDaemon.start()``) keep most of
this fast; one test goes through the real ``python -m`` CLI via
``spawn_daemon``.
"""
import dataclasses
import json
import threading
import time

import pytest

from repro.compiler.executor import (RemoteExecutor, SerialExecutor,
                                     SubprocessExecutor, WorkerDaemon,
                                     WorkerSpec, parse_endpoints,
                                     spawn_daemon)
from repro.compiler.executor.stub import make_stub, stub_latency
from repro.compiler.executor.wire import (PROTOCOL_VERSION, FrameBuffer,
                                          ProtocolError, WorkerCapabilities,
                                          device_count_pin, encode_frame,
                                          spec_compatible)
from repro.compiler.oracle import Oracle, SettingsOracle
from repro.compiler.session import Session, SessionReport
from repro.compiler.task import TuningTask
from repro.core import mappo
from repro.core.design_space import DesignSpace
from repro.core.shard_space import ShardSpace
from repro.core.tuner import TunerConfig

STUB = "repro.compiler.executor.stub:make_stub"
STUB_SPEC = WorkerSpec(factory=STUB)
HANG_COND = {"sequence_parallel": True}  # knob 6 -> SP on


@pytest.fixture(scope="module")
def space():
    return ShardSpace.for_cell("qwen2-1.5b", "train_4k", None, n_devices=256)


def _fast_executor(endpoints, **kw):
    """RemoteExecutor with test-speed fault knobs."""
    kw.setdefault("heartbeat_s", 0.1)
    kw.setdefault("heartbeat_timeout_s", 1.0)
    kw.setdefault("reconnect_backoff_s", 0.05)
    kw.setdefault("max_backoff_s", 0.2)
    kw.setdefault("startup_grace_s", 5.0)
    return RemoteExecutor(endpoints, **kw)


# ------------------------------------------------------------ wire protocol

def test_frame_roundtrip_survives_arbitrary_chunking():
    msgs = [{"type": "job", "job_id": 7, "settings": {"a": 1}},
            {"type": "heartbeat"},
            {"type": "result", "job_id": 7, "ok": True, "value": 0.25}]
    blob = b"".join(encode_frame(m) for m in msgs)
    for chunk in (1, 2, 3, len(blob)):  # byte-dribble through re-framing
        buf = FrameBuffer()
        out = []
        for i in range(0, len(blob), chunk):
            out.extend(buf.feed(blob[i:i + chunk]))
        assert out == msgs


def test_frame_buffer_rejects_garbage():
    buf = FrameBuffer()
    with pytest.raises(ProtocolError):  # announced length beyond the cap
        buf.feed(b"\xff\xff\xff\xff")
    bad = encode_frame({"type": "x"})[:4] + b'{"type": brok'
    with pytest.raises(ProtocolError):
        FrameBuffer().feed(bad[:4] + b"x" * (len(bad) - 4))


def test_parse_endpoints_forms():
    assert parse_endpoints("h1:10,h2:11") == [("h1", 10), ("h2", 11)]
    assert parse_endpoints(["a:1", "b:2"]) == [("a", 1), ("b", 2)]
    assert parse_endpoints(":5000") == [("127.0.0.1", 5000)]
    assert parse_endpoints("[::1]:9") == [("::1", 9)]
    with pytest.raises(ValueError):
        parse_endpoints("nocolon")
    with pytest.raises(ValueError):
        parse_endpoints("")


def test_capabilities_version_mismatch_is_loud():
    caps = WorkerCapabilities(slots=2, backend="cpu", device_count=4)
    wire = caps.to_wire()
    assert WorkerCapabilities.from_wire(wire).device_count == 4
    wire["version"] = PROTOCOL_VERSION + 1
    with pytest.raises(ProtocolError, match="version"):
        WorkerCapabilities.from_wire(wire)


def test_spec_compatibility_routes_on_device_pin():
    pin4 = WorkerSpec(factory=STUB, env={
        "XLA_FLAGS": "--xla_force_host_platform_device_count=4"})
    assert device_count_pin(pin4.env) == 4
    assert spec_compatible(pin4, WorkerCapabilities(device_count=4))
    assert not spec_compatible(pin4, WorkerCapabilities(device_count=2))
    # a wildcard daemon applies the pin itself at factory resolution
    assert spec_compatible(pin4, WorkerCapabilities(device_count=None))
    # spec without a pin runs anywhere
    assert spec_compatible(STUB_SPEC, WorkerCapabilities(device_count=8))
    assert spec_compatible(None, WorkerCapabilities(device_count=8))


# ----------------------------------------------------- daemon round-trips

def test_remote_executor_round_trip_and_stats():
    daemon = WorkerDaemon(slots=2).start()
    try:
        ex = RemoteExecutor(daemon.endpoint)
        settings = [{"model_axis": 1 << i} for i in range(6)]
        handles = [ex.submit("t", s, spec=STUB_SPEC) for s in settings]
        ex.drain(handles)
        for s, h in zip(settings, handles):
            assert h.result().ok
            assert h.result().value == stub_latency(s)
        st = ex.stats()
        assert st["kind"] == "remote" and st["jobs"] == 6
        assert st["failures"] == 0 and st["workers_alive"] == 2
        (ep_stats,) = st["endpoints"].values()
        assert ep_stats["jobs"] == 6 and ep_stats["reconnects"] == 0
        assert ep_stats["mean_ack_to_result_s"] >= 0.0
        ex.close()
    finally:
        daemon.stop()


def test_measure_fn_exception_is_failure_not_crash():
    daemon = WorkerDaemon().start()
    try:
        ex = _fast_executor(daemon.endpoint)
        bad = ex.submit("t", {"fsdp": True},
                        spec=WorkerSpec(factory=STUB,
                                        kwargs={"fail_when": {"fsdp": True}}))
        good = ex.submit("t", {"model_axis": 2}, spec=STUB_SPEC)
        ex.drain([bad, good])
        assert not bad.result().ok
        assert "stub measurement failed" in bad.result().error
        assert good.result().ok  # the daemon survived the raise
        assert ex.stats()["reconnects"] == 0
        ex.close()
    finally:
        daemon.stop()


def test_spec_without_factory_fails_fast():
    daemon = WorkerDaemon().start()
    try:
        ex = RemoteExecutor(daemon.endpoint)
        h = ex.submit("t", {"x": 1})  # no spec: nothing to rebuild remotely
        assert not h.result().ok and "NoWorkerSpec" in h.result().error
        ex.close()
    finally:
        daemon.stop()


def test_unreachable_fleet_raises_at_construction():
    with pytest.raises(ConnectionError, match="no worker daemon reachable"):
        RemoteExecutor("127.0.0.1:1", connect_timeout_s=0.5)
    with pytest.raises(ValueError, match="duplicate"):
        RemoteExecutor("h:1,h:1")


# ------------------------------------------------- heterogeneous routing

def test_heterogeneous_routing_by_device_count():
    d2 = WorkerDaemon(slots=1, device_count=2).start()
    d4 = WorkerDaemon(slots=1, device_count=4).start()
    try:
        ex = RemoteExecutor([d2.endpoint, d4.endpoint])
        pin = lambda n: WorkerSpec(factory=STUB, env={
            "XLA_FLAGS": f"--xla_force_host_platform_device_count={n}"})
        h2 = [ex.submit("t", {"i": i, "model_axis": 2}, spec=pin(2))
              for i in range(3)]
        h4 = [ex.submit("t", {"i": i, "model_axis": 4}, spec=pin(4))
              for i in range(3)]
        ex.drain(h2 + h4)
        assert all(h.result().ok for h in h2 + h4)
        st = ex.stats()["endpoints"]
        assert st[d2.endpoint]["jobs"] == 3  # pinned jobs never cross over
        assert st[d4.endpoint]["jobs"] == 3
        # a pin no daemon serves fails fast instead of wedging the queue
        h8 = ex.submit("t", {"model_axis": 8}, spec=pin(8))
        assert not h8.result().ok
        assert "NoCompatibleWorker" in h8.result().error
        ex.close()
    finally:
        d2.stop()
        d4.stop()


# -------------------------------------------------------- loopback parity

def _remote_task(space, name, endpoint=None, subprocess_workers=0):
    """Stub-oracle task backed by a remote daemon, a subprocess pool, or
    the in-process serial path — same measurements everywhere."""
    def factory(task, records, workers=0, timeout_s=None):
        if endpoint is not None:
            ex = RemoteExecutor(endpoint)
        elif subprocess_workers:
            ex = SubprocessExecutor(WorkerSpec(factory=STUB),
                                    workers=subprocess_workers)
        else:
            return SettingsOracle(space, fn=make_stub(), task=task.name,
                                  records=records)
        return SettingsOracle(space, fn=None, executor=ex,
                              own_executor=True, task=task.name,
                              records=records, worker_spec=STUB_SPEC)
    return TuningTask(name=name, space=space, oracle_factory=factory)


def test_loopback_parity_with_subprocess_pool(space):
    """The acceptance bar: one loopback daemon at a fixed seed produces a
    session report identical to ``SubprocessExecutor(workers=1)`` —
    same configs, same measurements, same history, byte-identical
    serialized reports once wall-time and transport stats (which cannot
    match by construction) are masked."""
    cfg = TunerConfig(iteration_opt=2, b_measure=6, episodes_per_iter=2,
                      mappo=mappo.MappoConfig(n_steps=16, n_envs=8),
                      gbt_rounds=8, seed=3)
    daemon = WorkerDaemon().start()
    try:
        docs = {}
        for label, task in (
                ("remote", _remote_task(space, "det",
                                        endpoint=daemon.endpoint)),
                ("subprocess", _remote_task(space, "det",
                                            subprocess_workers=1))):
            doc = Session(task, tuner=cfg, budget=12).run().to_dict()
            doc["wall_time_s"] = 0.0
            doc["executor_stats"] = {}
            for rep in doc["reports"].values():
                rep["wall_time_s"] = 0.0
                rep["history"] = [[n, lat, 0.0]
                                  for n, lat, _ in rep["history"]]
            docs[label] = json.dumps(doc, sort_keys=True)
        assert docs["remote"] == docs["subprocess"]
    finally:
        daemon.stop()


def test_session_remote_kwarg_runs_and_records_stats(space):
    """`Session(remote=...)` builds the fleet executor itself and lands
    the final stats() snapshot in the report (round-trips via JSON)."""
    daemon = WorkerDaemon(slots=2).start()
    try:
        cfg = TunerConfig(iteration_opt=2, b_measure=4, episodes_per_iter=2,
                          mappo=mappo.MappoConfig(n_steps=16, n_envs=8),
                          gbt_rounds=8, seed=0)

        def factory(task, records, workers=0, timeout_s=None, executor=None):
            return SettingsOracle(space, fn=None, executor=executor,
                                  task=task.name, records=records,
                                  worker_spec=STUB_SPEC)

        task = TuningTask(name="rk", space=space, oracle_factory=factory)
        sr = Session(task, tuner=cfg, budget=8,
                     remote=daemon.endpoint).run()
        assert sr.executor_stats["kind"] == "remote"
        assert sr.executor_stats["jobs"] >= 8
        assert daemon.endpoint in sr.executor_stats["endpoints"]
        rt = SessionReport.from_dict(json.loads(json.dumps(sr.to_dict())))
        assert rt.executor_stats["jobs"] == sr.executor_stats["jobs"]
    finally:
        daemon.stop()


def test_session_rejects_remote_plus_workers(space):
    with pytest.raises(ValueError, match="mutually exclusive"):
        Session(_remote_task(space, "x"), remote="h:1", workers=2)


# --------------------------------------------------------- fault semantics

def test_daemon_killed_mid_batch_fails_inflight_then_fleet_down():
    daemon = WorkerDaemon(slots=2).start()
    ex = _fast_executor(daemon.endpoint, max_reconnects=2)
    slow = WorkerSpec(factory=STUB, kwargs={"delay_s": 30.0})
    handles = [ex.submit("t", {"i": i}, spec=slow) for i in range(2)]
    time.sleep(0.3)  # let both jobs start on the daemon
    daemon.stop()  # connection dies mid-measurement
    extra = ex.submit("t", {"i": 9}, spec=slow)  # queued, never served
    ex.drain(handles + [extra])
    for h in handles:
        assert not h.result().ok and "WorkerCrash" in h.result().error
    assert not extra.result().ok
    assert "FleetDown" in extra.result().error
    assert ex.stats()["failures"] >= 2
    ex.close()


def test_restarted_daemon_rejoins_and_jobs_flow():
    daemon = WorkerDaemon().start()
    port = daemon.address[1]
    ex = _fast_executor(daemon.endpoint, max_reconnects=50)
    ok = ex.submit("t", {"model_axis": 2}, spec=STUB_SPEC)
    assert ok.result().ok
    daemon.stop()
    deadline = time.monotonic() + 10.0  # wait for the EOF to be noticed —
    while ex.stats()["endpoints"][ex._eps[0].label]["connected"]:
        assert time.monotonic() < deadline  # else a fresh job could be
        ex.poll()                           # dispatched onto the corpse
        time.sleep(0.01)
    daemon2 = WorkerDaemon(port=port).start()  # same endpoint, new pid
    try:
        again = ex.submit("t", {"model_axis": 4}, spec=STUB_SPEC)
        assert again.result().ok  # served by the restarted daemon
        st = ex.stats()
        assert st["reconnects"] >= 1
        assert st["endpoints"][ex._eps[0].label]["reconnects"] >= 1
        ex.close()
    finally:
        daemon2.stop()


def test_timeout_counted_from_started_ack_drops_connection():
    daemon = WorkerDaemon().start()
    try:
        ex = _fast_executor(daemon.endpoint, timeout_s=0.4,
                            startup_grace_s=5.0, max_reconnects=50)
        hang = WorkerSpec(factory=STUB, kwargs={"hang_when": HANG_COND})
        h = ex.submit("t", {"sequence_parallel": True}, spec=hang)
        t0 = time.monotonic()
        res = h.result()
        assert not res.ok and "TimeoutError" in res.error
        assert time.monotonic() - t0 < 10.0
        # the dropped connection re-dials; fresh jobs flow again
        ok = ex.submit("t", {"model_axis": 2}, spec=hang)
        assert ok.result().ok
        assert ex.stats()["reconnects"] >= 1
        ex.close()
    finally:
        daemon.stop()


def test_session_records_penalties_and_warm_resumes_after_crash(
        space, tmp_path):
    """Kill the fleet's only daemon mid-session: failed measurements land
    as penalty rows, the session still completes, and a re-run against a
    healthy daemon replays every recorded row before paying for new
    ones."""
    path = str(tmp_path / "crash.jsonl")
    cfg = TunerConfig(iteration_opt=2, b_measure=4, episodes_per_iter=2,
                      mappo=mappo.MappoConfig(n_steps=16, n_envs=8),
                      gbt_rounds=8, seed=1)
    daemon = WorkerDaemon(slots=2).start()
    killer = threading.Timer(0.5, daemon.stop)

    def factory(task, records, workers=0, timeout_s=None):
        ex = _fast_executor(daemon.endpoint, max_reconnects=2)
        return SettingsOracle(space, fn=None, executor=ex,
                              own_executor=True, task=task.name,
                              records=records,
                              worker_spec=WorkerSpec(
                                  factory=STUB,
                                  kwargs={"delay_s": 0.2}))

    task = TuningTask(name="crashy", space=space, oracle_factory=factory)
    killer.start()
    try:
        rep = Session(task, tuner=cfg, budget=12, records=path).run().single
    finally:
        killer.cancel()
        daemon.stop()
    assert rep.n_measurements == 12  # completed despite the dead fleet
    assert rep.oracle_stats["failures"] >= 1  # crash -> penalty rows
    assert any(lat == Oracle.penalty_latency
               for _, lat in rep.measurements)
    # warm resume: healthy daemon, same records — replays, no re-payment
    daemon2 = WorkerDaemon(slots=2).start()

    def factory2(task, records, workers=0, timeout_s=None):
        ex = _fast_executor(daemon2.endpoint)
        return SettingsOracle(space, fn=None, executor=ex,
                              own_executor=True, task=task.name,
                              records=records, worker_spec=STUB_SPEC)

    try:
        rep2 = Session(dataclasses.replace(task, oracle_factory=factory2),
                       tuner=cfg, budget=12, records=path).run().single
    finally:
        daemon2.stop()
    assert rep2.oracle_stats["misses"] == 0  # fully warm, incl. penalties
    assert rep2.n_measurements == rep.n_measurements


# -------------------------------------------- netopt over a daemon fleet

def test_netopt_over_two_daemons_survives_crash_and_restart():
    """The issue's netopt acceptance bar: a co-optimization over two
    daemons rides out one daemon dying mid-run (penalty rows recorded,
    reconnect counted once it returns) and still emits a valid
    JSON-round-trippable NetworkReport."""
    from repro.compiler.netopt import NetOptConfig, NetworkCoOptimizer
    from repro.compiler.netopt.report import NetworkReport

    wl_a = dict(b=1, h=14, w=14, ci=256, co=256, kh=3, kw=3, stride=1, pad=1)
    wl_b = dict(b=1, h=28, w=28, ci=128, co=128, kh=3, kw=3, stride=1, pad=1)
    tiny = TunerConfig(iteration_opt=3, b_measure=8, episodes_per_iter=2,
                       mappo=mappo.MappoConfig(n_steps=16, n_envs=8),
                       gbt_rounds=10)
    slow_spec = WorkerSpec(factory=STUB, kwargs={"delay_s": 0.05})

    def factory(task, records, workers=0, timeout_s=None, executor=None):
        return SettingsOracle(task.space, fn=None, executor=executor,
                              task=task.name, records=records,
                              worker_spec=slow_spec)

    tasks = [TuningTask(name=n, space=DesignSpace.for_conv2d(wl),
                        oracle_factory=factory, multiplicity=m)
             for n, wl, m in (("c1", wl_a, 2), ("c2", wl_b, 1))]
    d1, d2 = WorkerDaemon(slots=1).start(), WorkerDaemon(slots=1).start()
    port2 = d2.address[1]
    ex = _fast_executor([d1.endpoint, d2.endpoint], max_reconnects=200)
    stopper = {}

    def chaos():  # kill d2 once it holds work, restart it shortly after
        label = d2.endpoint
        deadline = time.monotonic() + 30.0
        while time.monotonic() < deadline:
            st = ex.stats()["endpoints"].get(label)
            if st and st["in_flight"] > 0:
                d2.stop()
                time.sleep(0.3)
                stopper["d2b"] = WorkerDaemon(port=port2).start()
                return
            time.sleep(0.01)

    th = threading.Thread(target=chaos, daemon=True)
    th.start()
    cfg = NetOptConfig(seed_candidates=2, hw_rounds=1, hw_per_round=1,
                       layer_budget=6, refine_budget=6, tuner=tiny)
    try:
        rep = NetworkCoOptimizer(tasks, cfg, remote=ex,
                                 name="remote-net").run()
    finally:
        th.join(timeout=30)
        ex.close()
        d1.stop()
        d2.stop()
        if "d2b" in stopper:
            stopper["d2b"].stop()
    es = rep.executor_stats
    assert es["kind"] == "remote" and es["jobs"] > 0
    assert es["failures"] >= 1          # the crash cost in-flight jobs...
    assert es["reconnects"] >= 1        # ...and the restart rejoined
    assert rep.network_latency > 0 and rep.verify_shared_hardware()
    doc = json.loads(json.dumps(rep.to_dict()))
    rt = NetworkReport.from_dict(doc)
    assert rt.network_latency == rep.network_latency
    assert rt.executor_stats["reconnects"] == es["reconnects"]


# ----------------------------------------------------- protocol-wide stats

def test_stats_is_uniform_across_executors(space):
    serial = SerialExecutor(fn=make_stub())
    keys = {"kind", "workers_alive", "respawns", "queued", "running",
            "max_inflight", "jobs", "failures"}
    assert keys <= set(serial.stats())
    assert serial.stats()["kind"] == "serial"
    assert all(v == 0 for k, v in serial.stats().items() if k != "kind")
    with SubprocessExecutor(WorkerSpec(factory=STUB), workers=1) as pool:
        h = pool.submit("t", {"model_axis": 2})
        assert h.result().ok
        st = pool.stats()
        assert keys <= set(st)
        assert st["kind"] == "subprocess" and st["jobs"] == 1
    daemon = WorkerDaemon().start()
    try:
        ex = RemoteExecutor(daemon.endpoint)
        assert keys <= set(ex.stats())
        ex.close()
    finally:
        daemon.stop()


# --------------------------------------------------------------- CLI path

def test_spawned_daemon_cli_serves_jobs():
    """End-to-end through the real entry point: ``python -m
    repro.compiler.executor.worker`` (via spawn_daemon's --port-file
    discovery), one job round-trip, clean termination."""
    proc, endpoint = spawn_daemon(slots=1)
    try:
        ex = RemoteExecutor(endpoint)
        h = ex.submit("t", {"model_axis": 4}, spec=STUB_SPEC)
        assert h.result().ok
        assert h.result().value == stub_latency({"model_axis": 4})
        ex.close()
    finally:
        proc.terminate()
        proc.wait(timeout=10)
