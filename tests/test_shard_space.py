"""Pod-level ARCO (beyond-paper) — tested against a mock compile oracle so
no multi-device lowering is needed; the real oracle is exercised by
repro.launch.autotune (artifacts/autotune)."""
import numpy as np
import pytest

from repro.core import mappo
from repro.core.shard_space import (ShardSpace, knob_values_to_settings,
                                    MODEL_AXIS)
from repro.core.tuner import TunerConfig, arco_tune


def mock_oracle(settings):
    """Synthetic pod cost surface with a known optimum:
    TP=16, SP on, remat on, grad_accum 2."""
    tp = settings["model_axis"]
    step = 1.0
    step *= (1.0 + abs(np.log2(tp / 16)))          # TP sweet spot at 16
    step *= 0.2 if settings["sequence_parallel"] else 1.0
    step *= 0.8 if settings["remat"] else 1.0
    step *= {1: 1.2, 2: 1.0, 4: 1.1, 8: 1.3}.get(
        settings.get("grad_accum", 1), 1.0)
    return step


@pytest.fixture(scope="module")
def space():
    return ShardSpace.for_cell("qwen2-1.5b", "train_4k", mock_oracle,
                               n_devices=256)


def test_space_structure(space):
    assert space.n_knobs == 7
    assert space.choices[0] == tuple(m for m in MODEL_AXIS if m <= 256)
    # decode cells pin grad_accum to 1
    dspace = ShardSpace.for_cell("qwen2-1.5b", "decode_32k", mock_oracle)
    assert dspace.choices[3] == (1,)


def test_settings_decode():
    vals = np.asarray([16, 2, 2, 4, 2, 1024, 2], np.float64)
    s = knob_values_to_settings(vals)
    assert s == {"model_axis": 16, "moment_dtype": "float32", "fsdp": True,
                 "grad_accum": 4, "remat": True, "attn_chunk": 1024,
                 "sequence_parallel": True}


def test_measure_matches_oracle(space):
    import jax.numpy as jnp
    cfgs = space.random_configs(__import__("jax").random.PRNGKey(0), 8)
    lats = space.measure(np.asarray(cfgs))
    for c, l in zip(np.asarray(cfgs), lats):
        vals = np.asarray([space.choices[k][c[k]]
                           for k in range(7)], np.float64)
        assert abs(l - mock_oracle(knob_values_to_settings(vals))) < 1e-9


def test_arco_finds_mock_optimum(space):
    cfg = TunerConfig(iteration_opt=6, b_measure=16, episodes_per_iter=3,
                      mappo=mappo.MappoConfig(n_steps=32, n_envs=8),
                      gbt_rounds=12)
    r = arco_tune(space, cfg)
    best = knob_values_to_settings(np.asarray(
        [space.choices[k][r.best_config[k]] for k in range(7)]))
    # optimum: tp 16, sp on, remat on, ga 2 -> 0.2*0.8 = 0.16; within the
    # 96-measurement budget ARCO must land in its basin (<= 0.25)
    assert r.best_latency <= 0.25, (r.best_latency, best)
    assert best["sequence_parallel"] is True
    assert best["model_axis"] in (8, 16, 32)


def test_feature_vector_shape(space):
    import jax
    cfgs = space.random_configs(jax.random.PRNGKey(1), 4)
    fv = space.feature_vector(cfgs)
    assert fv.shape == (4, 18)  # 7 knobs + 11 cell descriptors
