"""Shared tuning sweep: every unique conv task of the paper's 7 networks
tuned by ARCO / AutoTVM-analog / CHAMELEON-analog at an equal measurement
budget (the paper's equal-compilation-duration protocol).

Results are cached as JSON under artifacts/tuning/ so table6 / fig5 / fig6 /
fig7 all read one sweep.  REPRO_PAPER=1 switches to the full Table-4 budget
(1024 measurements/task); the default budget (256) preserves every paper
trend at ~6x less wall time.

``--json-out BENCH_netopt.json`` instead runs the network-scope
co-optimization benchmark (ResNet-18 coopt vs hw-frozen vs per-layer
fantasy at equal budget) and writes the standardized bench-artifact
document (:func:`write_bench_artifact`) — the ``BENCH_*.json`` convention
perf-trajectory tooling diffs across commits.  ``--bench hetero`` swaps
in the heterogeneous-partitioning benchmark instead: K=2 pipeline netopt
vs the single-chip K=1 netopt vs the DiGamma-style genetic baseline on
the mixed conv-front + GEMM-tail ``resnet-bert`` zoo network, all at
equal measurement budget.
"""
from __future__ import annotations

import argparse
import json
import math
import numbers
import os
import subprocess
import time
from typing import Dict, Optional

from repro import obs
from repro.compiler import Session, TuningTask
from repro.core import mappo
from repro.core.task import Task, conv_tasks
from repro.core.tuner import TunerConfig
from repro.models import cnn

BENCH_SCHEMA = "repro-bench/2"
# /2 additionally allows ONE nested block — metrics["phase_times"], a
# name -> finite-seconds dict from the run's tracer (repro.obs); /1 docs
# (strictly flat) are still accepted by validate_bench_doc.
BENCH_SCHEMAS = ("repro-bench/1", BENCH_SCHEMA)
ART = os.environ.get("REPRO_ART", "artifacts/tuning")
PAPER = os.environ.get("REPRO_PAPER", "0") == "1"
# bump when the per-run row schema changes (2: TuneReport.to_dict rows,
# wall_time_s instead of wall_s) — stale caches are re-tuned, not crashed on
SWEEP_SCHEMA = 2

NETWORKS = list(cnn.MODELS)
FRAMEWORKS = ("autotvm", "chameleon", "arco")


def tuner_config() -> TunerConfig:
    if PAPER:  # Table 4: 16 x 64 ~ 1000 measurements
        return TunerConfig(iteration_opt=16, b_measure=64,
                           episodes_per_iter=8,
                           mappo=mappo.MappoConfig(n_steps=250, n_envs=16),
                           gbt_rounds=40)
    return TunerConfig(iteration_opt=8, b_measure=32, episodes_per_iter=3,
                       mappo=mappo.MappoConfig(n_steps=64, n_envs=16),
                       gbt_rounds=24)


def unique_tasks() -> Dict[str, Task]:
    """Global dedupe across networks (identical conv workloads share one
    tuning run, as TVM task extraction does)."""
    seen: Dict[str, Task] = {}
    for net in NETWORKS:
        for t in conv_tasks(net):
            key = json.dumps(sorted(t.space.workload.items()))
            if key not in seen:
                seen[key] = t
    return seen


def _tune(framework: str, space, cfg: TunerConfig, workers: int = 0,
          timeout_s: Optional[float] = None, remote=None):
    """One framework on one task via the session API; the typed report is
    JSON-serializable end-to-end (no hand re-packing)."""
    task = TuningTask.from_space("bench", space)
    report = Session(task, tuner=cfg, algo=framework, workers=workers,
                     timeout_s=timeout_s, remote=remote).run().single
    return report.to_dict()


def run_sweep(force: bool = False, workers: int = 0,
              timeout_s: Optional[float] = None, remote=None) -> Dict:
    os.makedirs(ART, exist_ok=True)
    path = os.path.join(ART, f"sweep_{'paper' if PAPER else 'default'}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            sweep = json.load(f)
        if sweep.get("config", {}).get("schema") == SWEEP_SCHEMA:
            return sweep
        print(f"sweep cache {path} has an old schema; re-tuning", flush=True)
    cfg = tuner_config()
    tasks = unique_tasks()
    out: Dict[str, Dict] = {"tasks": {}, "config": {
        "budget": cfg.iteration_opt * cfg.b_measure, "paper": PAPER,
        "schema": SWEEP_SCHEMA}}
    for i, (key, task) in enumerate(tasks.items()):
        wl = task.space.workload
        entry = {"workload": wl}
        for fw in FRAMEWORKS:
            entry[fw] = _tune(fw, task.space, cfg, workers=workers,
                              timeout_s=timeout_s, remote=remote)
        out["tasks"][key] = entry
        print(f"[{i + 1}/{len(tasks)}] {wl['h']}x{wl['w']}x{wl['ci']}->"
              f"{wl['co']} k{wl['kh']}s{wl['stride']}: " +
              " ".join(f"{fw}={entry[fw]['best_latency']:.2e}"
                       for fw in FRAMEWORKS), flush=True)
        with open(path, "w") as f:   # checkpoint the sweep as it goes
            json.dump(out, f)
    return out


def network_results(sweep: Dict) -> Dict[str, Dict[str, float]]:
    """Per-network mean inference time (conv-dominated) per framework."""
    out: Dict[str, Dict[str, float]] = {}
    for net in NETWORKS:
        res = {fw: 0.0 for fw in FRAMEWORKS}
        wall = {fw: 0.0 for fw in FRAMEWORKS}
        for t in conv_tasks(net):
            key = json.dumps(sorted(t.space.workload.items()))
            entry = sweep["tasks"][key]
            for fw in FRAMEWORKS:
                res[fw] += entry[fw]["best_latency"] * t.multiplicity
        # tuning wall time: each network pays for its unique tasks
        seen = set()
        for t in conv_tasks(net):
            key = json.dumps(sorted(t.space.workload.items()))
            if key in seen:
                continue
            seen.add(key)
            for fw in FRAMEWORKS:
                wall[fw] += sweep["tasks"][key][fw]["wall_time_s"]
        out[net] = {"latency": res, "tuning_wall_s": wall}
    return out


def git_revision() -> str:
    """Short git revision of the working tree (``-dirty`` suffixed when
    uncommitted changes exist); ``"unknown"`` outside a repo."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    try:
        rev = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             cwd=root, capture_output=True, text=True,
                             timeout=10)
        if rev.returncode != 0:
            return "unknown"
        dirty = subprocess.run(["git", "status", "--porcelain"], cwd=root,
                               capture_output=True, text=True, timeout=10)
        suffix = "-dirty" if dirty.stdout.strip() else ""
        return rev.stdout.strip() + suffix
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def _check_metric(k, v, where: str) -> None:
    if not isinstance(k, str):
        raise ValueError(f"{where} name {k!r} is not a str")
    if isinstance(v, bool) or not isinstance(v, numbers.Real) \
            or not math.isfinite(float(v)):
        raise ValueError(f"{where} {k!r} must be a finite float, "
                         f"got {v!r}")


def validate_bench_doc(doc: Dict) -> Dict:
    """Assert ``doc`` is a well-formed ``repro-bench/1`` or ``/2``
    artifact; returns it.  The contract trajectory tooling diffs across
    commits: flat finite-float metrics (structure goes in metric
    *names*), a JSON-object config, a git revision, a creation
    timestamp.  ``/2`` additionally permits exactly one nested block —
    ``metrics["phase_times"]``, itself a flat name -> finite-seconds
    dict (the run's span-level time attribution)."""
    if not isinstance(doc, dict):
        raise ValueError(f"bench doc must be a dict, got {type(doc)}")
    if doc.get("schema") not in BENCH_SCHEMAS:
        raise ValueError(f"bench schema {doc.get('schema')!r} not in "
                         f"{BENCH_SCHEMAS!r}")
    if not doc.get("bench") or not isinstance(doc["bench"], str):
        raise ValueError("bench doc needs a nonempty str 'bench' name")
    if not isinstance(doc.get("created_unix"), numbers.Real):
        raise ValueError("bench doc needs a numeric 'created_unix'")
    if not doc.get("git_rev") or not isinstance(doc["git_rev"], str):
        raise ValueError("bench doc needs a nonempty str 'git_rev'")
    if not isinstance(doc.get("config"), dict):
        raise ValueError("bench doc needs a dict 'config'")
    metrics = doc.get("metrics")
    if not isinstance(metrics, dict) or not metrics:
        raise ValueError("bench doc needs a nonempty 'metrics' dict")
    for k, v in metrics.items():
        if (k == "phase_times" and doc["schema"] == BENCH_SCHEMA
                and isinstance(v, dict)):
            for pk, pv in v.items():
                _check_metric(pk, pv, "phase_times entry")
            continue
        _check_metric(k, v, "metric")
    return doc


def write_bench_artifact(path: str, bench: str, metrics: Dict[str, float],
                         config: Dict) -> Dict:
    """The standardized ``BENCH_*.json`` artifact: one flat document of

        {"schema": "repro-bench/2", "bench": <name>, "created_unix": <ts>,
         "git_rev": <short rev[-dirty]>, "config": {...what was run...},
         "metrics": {name: float, ..., "phase_times": {name: secs, ...}}}

    ``metrics`` is a flat name->float dict so trajectory tooling can diff
    runs across commits without schema knowledge; put structure in names
    (``coopt_network_latency_s``), not nesting.  The ONE sanctioned
    nested block is ``phase_times`` — span-level wall-clock attribution
    from the run's tracer (:mod:`repro.obs`), itself flat name->seconds.
    The document is validated (:func:`validate_bench_doc`) before
    anything touches disk — a NaN metric or unsanctioned nesting fails
    the run, not the downstream diff."""
    doc = {"schema": BENCH_SCHEMA, "bench": bench,
           "created_unix": time.time(), "git_rev": git_revision(),
           "config": config,
           "metrics": {k: ({pk: float(pv) for pk, pv in v.items()}
                           if k == "phase_times" and isinstance(v, dict)
                           else float(v))
                       for k, v in metrics.items()}}
    validate_bench_doc(doc)
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"wrote {path}: " + " ".join(f"{k}={v:.3e}"
                                       for k, v in doc["metrics"].items()
                                       if not isinstance(v, dict)),
          flush=True)
    return doc


def netopt_bench(workers: int = 0, timeout_s: Optional[float] = None,
                 layer_budget: int = 8, refine_budget: int = 8,
                 remote=None) -> Dict:
    """ResNet-18 network co-optimization vs its equal-budget comparison
    points; returns the flat metrics dict for the bench artifact."""
    from repro.compiler.netopt import (NetOptConfig, NetworkCoOptimizer,
                                       network_hw_frozen_tune)
    ncfg = NetOptConfig(seed_candidates=2, hw_rounds=1, hw_per_round=1,
                        layer_budget=layer_budget,
                        refine_budget=refine_budget, tuner=tuner_config())
    tasks = TuningTask.conv_tasks("resnet-18")
    t0 = time.perf_counter()
    tracer = obs.Tracer(name="netopt_bench")
    with obs.use(tracer):  # every arm's spans land in one phase_times
        coopt = NetworkCoOptimizer(tasks, ncfg, workers=workers,
                                   timeout_s=timeout_s, remote=remote,
                                   name="resnet-18").run()
        frozen = network_hw_frozen_tune(tasks, ncfg, workers=workers,
                                        timeout_s=timeout_s, remote=remote,
                                        name="resnet-18")
        fantasy = Session(tasks, tuner=ncfg.tuner,
                          budget=ncfg.total_layer_budget(), workers=workers,
                          timeout_s=timeout_s, remote=remote).run()
    return {
        "phase_times": tracer.phase_times(),
        "coopt_network_latency_s": coopt.network_latency,
        "hw_frozen_network_latency_s": frozen.network_latency,
        "fantasy_network_latency_s": fantasy.network_latency(),
        "coopt_speedup_vs_frozen": (frozen.network_latency
                                    / coopt.network_latency),
        "coopt_hw_candidates": coopt.hw_candidates,
        "coopt_measurements": coopt.total_measurements,
        "budget_per_layer": ncfg.total_layer_budget(),
        "wall_time_s": time.perf_counter() - t0,
    }


def hetero_tuner_config() -> TunerConfig:
    """Small deterministic per-layer tuner for the hetero bench: the
    comparison is between *outer* search strategies (K=1 netopt vs K=2
    netopt vs genetic), so the inner software tuner just needs to be
    identical and cheap across all three arms."""
    return TunerConfig(iteration_opt=8, b_measure=8, episodes_per_iter=2,
                       mappo=mappo.MappoConfig(n_steps=16, n_envs=8),
                       gbt_rounds=10)


def hetero_bench(workers: int = 0, timeout_s: Optional[float] = None,
                 layer_budget: int = 16, refine_budget: int = 48,
                 remote=None) -> Dict:
    """Heterogeneous partitioning on the mixed ``resnet-bert`` network
    (ResNet-18 conv front, BERT GEMM tail): K=2 pipeline co-optimization
    vs single-chip K=1 co-optimization vs the DiGamma-style genetic
    baseline over the same joint (partition, hw) space, every arm at the
    same total measurement budget; returns the flat metrics dict."""
    from repro.compiler.netopt import (NetOptConfig, NetworkCoOptimizer,
                                       network_genetic_hw_tune)
    from repro.compiler.zoo import get_network
    tasks = list(get_network("resnet-bert").tasks)
    base = dict(seed_candidates=2, hw_rounds=1, hw_per_round=1,
                layer_budget=layer_budget, refine_budget=refine_budget,
                tuner=hetero_tuner_config())
    t0 = time.perf_counter()
    tracer = obs.Tracer(name="hetero_bench")
    with obs.use(tracer):
        k1 = NetworkCoOptimizer(tasks, NetOptConfig(**base), workers=workers,
                                timeout_s=timeout_s, remote=remote,
                                name="resnet-bert").run()
        k2 = NetworkCoOptimizer(tasks, NetOptConfig(k_chips=2, **base),
                                workers=workers, timeout_s=timeout_s,
                                remote=remote, name="resnet-bert").run()
        ga = network_genetic_hw_tune(tasks, NetOptConfig(k_chips=2, **base),
                                     workers=workers, timeout_s=timeout_s,
                                     remote=remote, name="resnet-bert")
    return {
        "phase_times": tracer.phase_times(),
        "k1_network_latency_s": k1.network_latency,
        "k2_network_latency_s": k2.network_latency,
        "genetic_network_latency_s": ga.network_latency,
        "k2_speedup_vs_k1": k1.network_latency / k2.network_latency,
        "k2_speedup_vs_genetic": ga.network_latency / k2.network_latency,
        "k2_cut": float(k2.partition["cuts"][0]),
        "k1_measurements": k1.total_measurements,
        "k2_measurements": k2.total_measurements,
        "genetic_measurements": ga.total_measurements,
        "budget_per_layer": NetOptConfig(**base).total_layer_budget(),
        "wall_time_s": time.perf_counter() - t0,
    }


if __name__ == "__main__":
    from repro.compiler.executor import add_worker_args, validate_worker_args
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--force", action="store_true",
                    help="re-tune even if a cached sweep exists "
                         "(REPRO_FORCE=1 also works)")
    ap.add_argument("--json-out", default=None, metavar="BENCH_netopt.json",
                    help="run the selected benchmark and write the "
                         "standardized bench artifact here (skips the sweep)")
    ap.add_argument("--bench", choices=("netopt", "hetero"),
                    default="netopt",
                    help="which --json-out benchmark to run: netopt = "
                         "ResNet-18 shared-chip coopt; hetero = K=2 "
                         "pipeline vs K=1 vs genetic on resnet-bert")
    add_worker_args(ap)
    args = ap.parse_args()
    validate_worker_args(ap, args)
    if args.json_out and args.bench == "hetero":
        metrics = hetero_bench(workers=args.workers,
                               timeout_s=args.timeout_s,
                               remote=args.remote)
        write_bench_artifact(
            args.json_out, "hetero_resnet_bert", metrics,
            config={"paper": PAPER, "networks": ["resnet-bert"],
                    "k_chips": [1, 2], "baseline": "genetic",
                    "budget_per_layer": metrics.pop("budget_per_layer")})
    elif args.json_out:
        metrics = netopt_bench(workers=args.workers,
                               timeout_s=args.timeout_s,
                               remote=args.remote)
        write_bench_artifact(
            args.json_out, "netopt_resnet18", metrics,
            config={"paper": PAPER, "networks": ["resnet-18"],
                    "budget_per_layer": metrics.pop("budget_per_layer")})
    else:
        run_sweep(force=args.force
                  or os.environ.get("REPRO_FORCE", "0") == "1",
                  workers=args.workers, timeout_s=args.timeout_s,
                  remote=args.remote)
