"""Search-quality sweep for the quarantined long-run assertion.

``tests/test_tuner.py::test_arco_beats_hw_frozen_baselines_long_run``
(stochastic marker) asks ARCO to beat the hw-frozen AutoTVM/random
baselines on one conv task at a 288-measurement budget and has failed
since seed.  This sweep runs the ROADMAP's open investigation: MAPPO
entropy coefficient x Confidence-Sampling batch schedule
(``TunerConfig.b_growth``) on that exact task, several seeds each,
against the baselines at the same budget.

    PYTHONPATH=src python benchmarks/search_quality_sweep.py \
        [--seeds 5] [--out artifacts/sweep_quality.json]

Findings go to ROADMAP; the deterministic short-horizon convergence test
in tier-1 pins the chosen configuration at a fixed seed.
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import numpy as np

from repro.core import mappo
from repro.core.baselines import autotvm_tune, random_tune
from repro.core.design_space import DesignSpace
from repro.core.tuner import TunerConfig, arco_tune

# the stochastic test's task and budget, verbatim
WL = dict(b=1, h=14, w=14, ci=128, co=128, kh=3, kw=3, stride=1, pad=1)


def long_run_cfg(seed: int = 0, ent_coef: float = 0.01,
                 b_growth: float = 1.0,
                 n_steps: int = 64) -> TunerConfig:
    return TunerConfig(
        iteration_opt=6, b_measure=48, episodes_per_iter=3,
        mappo=mappo.MappoConfig(n_steps=n_steps, n_envs=16,
                                ent_coef=ent_coef),
        gbt_rounds=20, seed=seed, b_growth=b_growth)


VARIANTS = {
    "base": {},
    "ent0.003": {"ent_coef": 0.003},
    "ent0.03": {"ent_coef": 0.03},
    "ent0.1": {"ent_coef": 0.1},
    "growth0.6": {"b_growth": 0.6},
    "growth1.5": {"b_growth": 1.5},
    "ent0.03+growth0.6": {"ent_coef": 0.03, "b_growth": 0.6},
    "steps128": {"n_steps": 128},
}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seeds", type=int, default=5)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    space = DesignSpace.for_conv2d(WL)
    budget = 6 * 48

    results = {}
    base = {"autotvm": [], "random": []}
    for seed in range(args.seeds):
        cfg = long_run_cfg(seed=seed)
        base["autotvm"].append(autotvm_tune(space, cfg).best_latency)
        base["random"].append(random_tune(space, cfg).best_latency)
    for fw, lats in base.items():
        print(f"{fw:20s} " + " ".join(f"{1e6 * x:8.2f}" for x in lats)
              + f"   med {1e6 * float(np.median(lats)):8.2f} us", flush=True)
    results["baselines"] = base

    for name, kw in VARIANTS.items():
        lats, wins = [], 0
        for seed in range(args.seeds):
            r = arco_tune(space, long_run_cfg(seed=seed, **kw))
            assert r.n_measurements <= budget
            lats.append(r.best_latency)
            wins += (r.best_latency < base["autotvm"][seed]
                     and r.best_latency < base["random"][seed])
        print(f"arco/{name:15s} " + " ".join(f"{1e6 * x:8.2f}" for x in lats)
              + f"   med {1e6 * float(np.median(lats)):8.2f} us  "
              f"beats-both {wins}/{args.seeds}", flush=True)
        results[name] = {"latencies": lats, "wins": wins,
                         "cfg": {k: v for k, v in kw.items()}}

    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
