"""Online tuning-as-a-service benchmark: ``serve --autotune`` under a
synthetic million-request trace.

Plays a Poisson + bursty arrival trace through the virtual-time serving
host (:class:`repro.compiler.serve_tune.SimServeHost`) while a stock
tuning session measures candidate decode/prefill geometries on idle
decode slots, then compares the online winners against an unconstrained
offline session over the identical spaces at the same budget and seed.

    PYTHONPATH=src python benchmarks/serve_runs.py --json-out BENCH_serve.json

Headline claims the committed ``BENCH_serve.json`` must demonstrate (both
asserted here before anything is written, and regression-tested from the
committed artifact by ``tests/test_zoo_transfer.py``):

* the online search converges to within 10% of the offline-tuned
  geometry's step time (``online_offline_min_ratio >= 0.9``);
* p99-SLA violations stay under 3% overall while it does so;
* the post-tuning phase beats the pre-tuning baseline on both p99
  latency and tokens/sec.
"""
from __future__ import annotations

import argparse
import os
import sys
import time
from typing import Dict, Optional

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from repro import obs  # noqa: E402
from repro.compiler.session import Session  # noqa: E402
from repro.compiler.serve_tune import (  # noqa: E402
    ServeModel, ServeSLA, SimServeHost, TraceConfig, serve_tasks,
    serve_tuner_config, tune_while_serving)


def serve_bench(n_requests: int = 1_000_000, rate_per_s: float = 100.0,
                budget: int = 48, sla_target_s: float = 0.5,
                n_slots: int = 8, measure_cost_s: float = 0.25,
                tune_after_s: float = 120.0, seed: int = 0,
                records: Optional[str] = None) -> Dict:
    """Run the online-vs-offline serving comparison; returns the flat
    metrics dict for the bench artifact."""
    model = ServeModel()
    sla = ServeSLA(target_s=sla_target_s)
    trace = TraceConfig(n_requests=n_requests, rate_per_s=rate_per_s,
                        seed=seed)
    host = SimServeHost(model, trace, sla=sla, n_slots=n_slots,
                        measure_cost_s=measure_cost_s,
                        tune_after_s=tune_after_s)
    t0 = time.perf_counter()
    tracer = obs.Tracer(name="serve_bench")
    with obs.use(tracer):
        with obs.current().span("online_serve", cat="phase"):
            rep = tune_while_serving(host, budget=budget, seed=seed,
                                     records=records,
                                     offline_compare=False)
        with obs.current().span("offline_compare", cat="phase"):
            off = Session(serve_tasks(model), tuner=serve_tuner_config(),
                          budget=budget, seed=seed).run()
    s = rep.serve
    metrics: Dict[str, object] = {
        "phase_times": tracer.phase_times(),
        "served_requests": float(s["served"]),
        "sim_time_s": s["sim_time_s"],
        "sla_violation_pct": s["violation_pct"],
        "p50_latency_s": s["p50_latency_s"],
        "p99_latency_s": s["p99_latency_s"],
        "tokens_per_sec": s["tokens_per_sec"],
        "mean_queue_s": s["mean_queue_s"],
        "mean_prefill_s": s["mean_prefill_s"],
        "tuned_from_s": s["tuned_from_s"],
        "geometry_switches": float(len(s["switches"])),
        "measurements": float(s["measurements"]),
        "measurements_preempted": float(s["preempted"]),
        "measure_idle_s": s["measure_idle_s"],
        "wall_time_s": time.perf_counter() - t0,
    }
    for ph in ("before", "after"):
        for k in ("p50_latency_s", "p99_latency_s", "tokens_per_sec",
                  "violation_pct"):
            name = f"{ph}_sla_{k}" if k == "violation_pct" else f"{ph}_{k}"
            metrics[name] = s[ph][k]
    ratios = []
    for kind in ("decode", "prefill"):
        online_step = rep.online[kind]["step_s"]
        r = off.reports[f"serve:{model.arch}/{kind}"]
        offline_step = model.cost_s(kind, model.settings_of(
            kind, r.best_config))
        ratio = offline_step / max(online_step, 1e-12)
        ratios.append(ratio)
        metrics[f"online_{kind}_step_s"] = online_step
        metrics[f"offline_{kind}_step_s"] = offline_step
        metrics[f"online_offline_{kind}_ratio"] = ratio
    metrics["online_offline_min_ratio"] = min(ratios)
    metrics["throughput_gain_x"] = (
        s["after"]["tokens_per_sec"] / s["before"]["tokens_per_sec"])

    # the headline claims, enforced before the artifact exists
    assert metrics["online_offline_min_ratio"] >= 0.9, \
        f"online search missed offline by >10%: {metrics}"
    assert metrics["sla_violation_pct"] < 3.0, \
        f"SLA violations above 3%: {metrics['sla_violation_pct']}"
    assert metrics["after_p99_latency_s"] < metrics["before_p99_latency_s"]
    assert metrics["after_tokens_per_sec"] > metrics["before_tokens_per_sec"]
    return metrics


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--requests", type=int, default=1_000_000)
    ap.add_argument("--rate", type=float, default=100.0)
    ap.add_argument("--budget", type=int, default=48)
    ap.add_argument("--sla-s", type=float, default=0.5)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--measure-cost-s", type=float, default=0.25)
    ap.add_argument("--tune-after-s", type=float, default=120.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--records", default=None, metavar="PATH",
                    help="JSONL measurement records (warm resume)")
    ap.add_argument("--json-out", default=None, metavar="BENCH_serve.json",
                    help="write the standardized bench artifact here")
    args = ap.parse_args(argv)

    metrics = serve_bench(n_requests=args.requests, rate_per_s=args.rate,
                          budget=args.budget, sla_target_s=args.sla_s,
                          n_slots=args.slots,
                          measure_cost_s=args.measure_cost_s,
                          tune_after_s=args.tune_after_s, seed=args.seed,
                          records=args.records)
    for k, v in metrics.items():
        if not isinstance(v, dict):
            print(f"  {k:36s} {v:.6g}")
    if args.json_out:
        from tuning_runs import write_bench_artifact
        write_bench_artifact(
            args.json_out, "serve_autotune", metrics,
            config={"arch": "qwen2-1.5b", "n_devices": 256,
                    "n_requests": args.requests, "rate_per_s": args.rate,
                    "burst_factor": TraceConfig().burst_factor,
                    "budget": args.budget, "sla_target_s": args.sla_s,
                    "n_slots": args.slots,
                    "measure_cost_s": args.measure_cost_s,
                    "tune_after_s": args.tune_after_s,
                    "seed": args.seed})
    return 0


if __name__ == "__main__":
    sys.exit(main())
